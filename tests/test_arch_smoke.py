"""Per-architecture smoke tests: reduced config, one forward + one grad
step + one decode step on CPU. Asserts shapes and finiteness (no NaNs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.shapes import make_batch
from repro.models import LM
from repro.models.lm import ModelFamily

BATCH, SEQ = 2, 32


@pytest.fixture(scope="module")
def nprng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad_step(arch, nprng):
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, batch=BATCH, seq=SEQ, rng=nprng)

    logits = jax.jit(model.forward)(params, batch["tokens"],
                                    patch_embeds=batch.get("patch_embeds"))
    if cfg.n_codebooks > 1:
        assert logits.shape == (BATCH, SEQ, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    def loss_fn(p):
        loss, metrics = model.loss(p, batch)
        return loss, metrics

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True)
    )(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert loss.shape == ()
    # a sensible CE for random tokens: close to log(vocab)
    assert float(metrics["ce"]) < np.log(cfg.vocab) + 2.0
    gnorms = [
        float(jnp.abs(g).max())
        for g in jax.tree_util.tree_leaves(grads)
    ]
    assert all(np.isfinite(g) for g in gnorms), f"{arch}: non-finite grads"
    assert any(g > 0 for g in gnorms), f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch, nprng):
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = model.init_decode_state(BATCH, max_len=cfg.max_decode_len)
    if cfg.n_codebooks > 1:
        tok = jnp.zeros((BATCH, 1, cfg.n_codebooks), jnp.int32)
    else:
        tok = jnp.zeros((BATCH, 1), jnp.int32)
    lengths = jnp.zeros((BATCH,), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, state = step(params, state, tok, lengths)
    if cfg.n_codebooks > 1:
        assert logits.shape == (BATCH, 1, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (BATCH, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # second step with incremented lengths must also be finite
    logits2, _ = step(params, state, tok, lengths + 1)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())


@pytest.mark.parametrize(
    "arch,tol",
    [
        ("yi_6b", 2e-2),
        ("h2o_danube_3_4b", 2e-2),
        ("xlstm_350m", 2e-2),
        # associative-scan (train) vs sequential (decode) RG-LRU orderings
        # differ by a few bf16 ulps per layer — not a semantic divergence
        ("recurrentgemma_9b", 1e-1),
    ],
)
def test_decode_matches_forward(arch, tol, nprng):
    """Greedy decode logits == forward logits at the same positions."""
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    seq = 8
    tokens = jnp.asarray(
        nprng.integers(0, cfg.vocab, (1, seq)).astype(np.int32)
    )
    full = model.forward(params, tokens)  # (1, S, V)
    state = model.init_decode_state(1, max_len=32)
    step = jax.jit(model.decode_step)
    for t in range(seq):
        logits, state = step(
            params, state, tokens[:, t : t + 1], jnp.array([t], jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(logits[0, 0], np.float32),
            np.asarray(full[0, t], np.float32),
            rtol=tol, atol=tol,
        )


def test_all_archs_have_configs():
    from repro.configs import all_configs

    cfgs = all_configs()
    assert len(cfgs) == 10
    # exact spec rows from the assignment
    spec = {
        "deepseek_v3_671b": (61, 7168, 128, 128, 129280),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 151936),
        "h2o_danube_3_4b": (24, 3840, 32, 8, 32000),
        "granite_34b": (88, 6144, 48, 1, 49152),
        "yi_6b": (32, 4096, 32, 4, 64000),
        "qwen3_32b": (64, 5120, 64, 8, 151936),
        "internvl2_2b": (24, 2048, 16, 8, 92553),
        "xlstm_350m": (24, 1024, 4, 4, 50304),
        "musicgen_medium": (48, 1536, 24, 24, 2048),
        "recurrentgemma_9b": (38, 4096, 16, 1, 256000),
    }
    for arch, (layers, d, h, kv, vocab) in spec.items():
        c = cfgs[arch]
        assert c.n_layers == layers, arch
        assert c.d_model == d, arch
        assert c.n_heads == h, arch
        assert c.n_kv_heads == kv, arch
        assert c.vocab == vocab, arch
