"""SQL v2: joins, richer grammar, kernel routing, zero registration.

Four contracts under test:

* the grammar parses JOIN/OR/IN/BETWEEN with *positioned* SqlErrors for
  everything it rejects (trailing garbage, reserved-word aliases,
  composite ON conditions, multiple statements);
* join execution matches a numpy oracle — first-match gather semantics,
  inner drop / left zero-fill for misses;
* the kernel route is byte-identical to the jnp reference wherever
  ``engine="auto"`` takes it (and the router refuses everything it
  cannot prove exact), across dtypes, group cardinalities, empty-after-
  filter, and parallelism levels — engine choice never touches
  artifacts or fingerprints;
* ``client.query`` resolves every table name against the catalog with
  zero registration, scans through the pooled chunked feed, and reports
  its engine path + phase breakdown on ``QueryExecuted``.
"""
import numpy as np
import pytest

from repro.api import Client
from repro.core import Pipeline
from repro.core.physical import PlannerConfig
from repro.engine import Columnar, compile_query, execute_query, parse_sql
from repro.engine.route import (
    RouteDecision,
    RouteError,
    plan_route,
)
from repro.engine.sql import SqlError
from repro.runtime import ExecutorConfig

N_TRIPS = 3_000
N_ZONES = 16


def _trips(rng, n=N_TRIPS, fare_dtype=np.int32):
    return {
        "zone": rng.integers(0, N_ZONES, n).astype(np.int32),
        "fare": rng.integers(1, 50, n).astype(fare_dtype),
        "dist": rng.integers(0, 30, n).astype(np.int32),
    }


def _zones(n=N_ZONES):
    return {
        "zone_id": np.arange(n, dtype=np.int32),
        "borough": (np.arange(n, dtype=np.int32) % 4) + 100,
    }


JOIN_SQL = """
SELECT z.borough, COUNT(*) AS count, SUM(t.fare) AS total
FROM trips AS t JOIN zones AS z ON t.zone = z.zone_id
WHERE t.dist > 5 GROUP BY z.borough ORDER BY z.borough
"""


# --------------------------------------------------------------- grammar
def test_parse_join_clause():
    q = parse_sql(JOIN_SQL)
    assert q.source == "trips" and q.source_alias == "t"
    (j,) = q.joins
    assert (j.table, j.alias, j.how) == ("zones", "z", "inner")
    assert (j.left_on, j.right_on) == ("t.zone", "z.zone_id")
    assert q.source_tables() == ["trips", "zones"]


def test_parse_join_orientation_flipped():
    q = parse_sql(
        "SELECT * FROM trips AS t JOIN zones AS z ON z.zone_id = t.zone"
    )
    (j,) = q.joins
    assert (j.left_on, j.right_on) == ("t.zone", "z.zone_id")


def test_parse_left_join():
    for kw in ("LEFT JOIN", "LEFT OUTER JOIN"):
        q = parse_sql(
            f"SELECT * FROM trips AS t {kw} zones AS z ON t.zone = z.zone_id"
        )
        assert q.joins[0].how == "left"


def test_composite_on_condition_rejected():
    with pytest.raises(SqlError, match="composite join conditions"):
        parse_sql(
            "SELECT * FROM a JOIN b ON a.x = b.x AND a.y = b.y"
        )


@pytest.mark.parametrize(
    "sql, match",
    [
        ("SELECT fare FROM trips ORDER BY fare ASC 42", "trailing"),
        ("SELECT fare FROM trips; SELECT 1", "multiple SQL statements"),
        ("SELECT fare AS select FROM trips", "reserved"),
        ("SELECT fare FROM trips AS group", "reserved"),
        ("SELECT fare FROM trips LIMIT 5x", "LIMIT"),
    ],
)
def test_positioned_syntax_errors(sql, match):
    with pytest.raises(SqlError, match=match) as exc:
        parse_sql(sql)
    e = exc.value
    assert 0 <= e.pos <= len(sql)
    assert e.fragment  # carries the offending region


def test_trailing_semicolon_ok():
    q = parse_sql("SELECT fare FROM trips;")
    assert q.source == "trips"


def test_agg_alias_count_stays_legal():
    # the paper's Appendix SQL aliases to reserved agg names
    q = parse_sql("SELECT passenger_count AS count FROM taxi_table")
    assert q.projections[0][0] == "count"


def test_or_in_between_vs_numpy(rng):
    rel = Columnar.from_numpy(_trips(rng))
    zone = np.asarray(rel.columns["zone"])
    fare = np.asarray(rel.columns["fare"])
    dist = np.asarray(rel.columns["dist"])
    cases = {
        "SELECT fare FROM t WHERE zone = 3 OR fare > 40":
            (zone == 3) | (fare > 40),
        "SELECT fare FROM t WHERE zone IN (1, 4, 9)":
            np.isin(zone, [1, 4, 9]),
        "SELECT fare FROM t WHERE zone NOT IN (1, 4, 9)":
            ~np.isin(zone, [1, 4, 9]),
        "SELECT fare FROM t WHERE dist BETWEEN 10 AND 20":
            (dist >= 10) & (dist <= 20),
        "SELECT fare FROM t WHERE dist NOT BETWEEN 10 AND 20":
            ~((dist >= 10) & (dist <= 20)),
        "SELECT fare FROM t WHERE (zone = 1 OR zone = 2) AND fare < 10":
            ((zone == 1) | (zone == 2)) & (fare < 10),
    }
    for sql, mask in cases.items():
        out = execute_query(parse_sql(sql), rel).to_numpy()
        np.testing.assert_array_equal(out["fare"], fare[mask], err_msg=sql)


# --------------------------------------------------- join exec vs oracle
def _join_oracle(trips, zones, how):
    """First-match gather oracle in plain numpy."""
    lookup = {}
    for i, k in enumerate(zones["zone_id"]):
        lookup.setdefault(int(k), i)  # first match wins
    rows = []
    for i, k in enumerate(trips["zone"]):
        j = lookup.get(int(k))
        if j is None and how == "inner":
            continue
        rows.append((i, j))
    out = {c: trips[c][[i for i, _ in rows]] for c in trips}
    for c in zones:
        vals = np.array(
            [zones[c][j] if j is not None else 0 for _, j in rows],
            dtype=zones[c].dtype,
        )
        out[c] = vals
    return out


@pytest.mark.parametrize("how", ["inner", "left"])
def test_join_matches_oracle(rng, how):
    trips = _trips(rng, n=400)
    zones = _zones()
    # duplicate right keys (first match must win) + missing left keys
    zones["zone_id"] = np.concatenate(
        [zones["zone_id"][: N_ZONES - 4], zones["zone_id"][:4]]
    )
    trips["zone"][:25] = 99  # no match in zones
    kw = "JOIN" if how == "inner" else "LEFT JOIN"
    sql = (
        "SELECT t.zone, t.fare, z.borough FROM trips AS t "
        f"{kw} zones AS z ON t.zone = z.zone_id"
    )
    out = compile_query(parse_sql(sql))(
        Columnar.from_numpy(trips), {"zones": Columnar.from_numpy(zones)}
    ).to_numpy()
    want = _join_oracle(trips, zones, how)
    np.testing.assert_array_equal(out["zone"], want["zone"])
    np.testing.assert_array_equal(out["fare"], want["fare"])
    np.testing.assert_array_equal(out["borough"], want["borough"])


def test_join_key_dtype_checked(rng):
    trips = {"zone": (rng.random(16)).astype(np.float32)}
    zones = _zones()
    sql = "SELECT * FROM trips AS t JOIN zones AS z ON t.zone = z.zone_id"
    with pytest.raises(TypeError, match="join"):
        execute_query(
            parse_sql(sql),
            Columnar.from_numpy(trips),
            joined={"zones": Columnar.from_numpy(zones)},
        )


# --------------------------------------------------------------- routing
def _stats(**kv):
    return dict(kv)


def test_route_auto_takes_kernel_when_exact():
    q = parse_sql("SELECT zone, SUM(fare) AS s FROM t GROUP BY zone")
    r = plan_route(
        q, stats=_stats(zone=(0, 15), fare=(1, 50)), total_rows=10_000
    )
    assert r.engine_path == "kernel"
    assert r.num_groups >= 16


def test_route_auto_refuses_floats():
    q = parse_sql("SELECT zone, SUM(fare) AS s FROM t GROUP BY zone")
    # fare absent from stats = not a kernel-safe dtype (float column)
    r = plan_route(q, stats=_stats(zone=(0, 15)), total_rows=10_000)
    assert r.engine_path == "jnp"


def test_route_auto_refuses_wide_key_range():
    q = parse_sql("SELECT zone, COUNT(*) AS n FROM t GROUP BY zone")
    r = plan_route(q, stats=_stats(zone=(0, 10**6)), total_rows=1_000)
    assert r.engine_path == "jnp"


def test_route_auto_refuses_overflow_risk():
    q = parse_sql("SELECT zone, SUM(fare) AS s FROM t GROUP BY zone")
    r = plan_route(
        q, stats=_stats(zone=(0, 15), fare=(0, 2**20)), total_rows=2**20
    )
    assert r.engine_path == "jnp"


def test_route_jnp_pins_reference_path():
    q = parse_sql("SELECT zone, SUM(fare) AS s FROM t GROUP BY zone")
    r = plan_route(
        q, engine="jnp", stats=_stats(zone=(0, 15), fare=(1, 50)),
        total_rows=100,
    )
    assert r.engine_path == "jnp"


def test_route_forced_kernel_raises_on_structural_miss():
    q = parse_sql("SELECT zone, dist, COUNT(*) AS n FROM t GROUP BY zone, dist")
    with pytest.raises(RouteError):
        plan_route(q, engine="kernel", stats=_stats(zone=(0, 3), dist=(0, 3)))


# ------------------------------------------- kernel/jnp parity (matrix)
def _parity_case(rng, *, n, groups, key_dtype, sql):
    rel = Columnar.from_numpy({
        "zone": rng.integers(0, groups, n).astype(key_dtype),
        "fare": rng.integers(1, 50, n).astype(np.int32),
        "dist": rng.integers(0, 30, n).astype(np.int32),
    })
    q = parse_sql(sql)
    kmax = groups - 1
    route = plan_route(
        q, engine="kernel",
        stats=_stats(zone=(0, kmax), fare=(1, 50), dist=(0, 30)),
        total_rows=n,
    )
    got = execute_query(q, rel, route=route).to_numpy()
    want = execute_query(q, rel).to_numpy()
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)
        assert got[k].dtype == want[k].dtype, k


PARITY_SQL = (
    "SELECT zone, COUNT(*) AS n, SUM(fare) AS s, AVG(fare) AS m "
    "FROM t WHERE dist > 5 GROUP BY zone"
)


@pytest.mark.parametrize("key_dtype", [np.int32, np.int8, np.bool_])
def test_kernel_parity_key_dtypes(rng, key_dtype):
    groups = 2 if key_dtype is np.bool_ else 13
    _parity_case(
        rng, n=700, groups=groups, key_dtype=key_dtype, sql=PARITY_SQL
    )


@pytest.mark.parametrize("groups", [1, 7, 128, 1000])
def test_kernel_parity_group_cardinalities(rng, groups):
    _parity_case(
        rng, n=2_000, groups=groups, key_dtype=np.int32, sql=PARITY_SQL
    )


def test_kernel_parity_empty_after_filter(rng):
    _parity_case(
        rng, n=300, groups=8, key_dtype=np.int32,
        sql="SELECT zone, COUNT(*) AS n, SUM(fare) AS s FROM t "
            "WHERE dist > 1000 GROUP BY zone",
    )


def test_kernel_parity_unfiltered_and_count_only(rng):
    for sql in (
        "SELECT zone, SUM(fare) AS s FROM t GROUP BY zone",
        "SELECT zone, COUNT(*) AS n FROM t GROUP BY zone",
    ):
        _parity_case(rng, n=900, groups=11, key_dtype=np.int32, sql=sql)


def test_auto_falls_back_at_exactness_boundary(rng):
    """Right at the f32-exactness boundary auto must choose jnp; the
    forced kernel on safe data stays byte-identical (fallback boundary)."""
    q = parse_sql("SELECT zone, SUM(fare) AS s FROM t GROUP BY zone")
    n = 4_096
    safe = plan_route(
        q, stats=_stats(zone=(0, 3), fare=(0, (2**24 // n) - 1)), total_rows=n
    )
    unsafe = plan_route(
        q, stats=_stats(zone=(0, 3), fare=(0, 2**24 // n + 1)), total_rows=n
    )
    assert safe.engine_path == "kernel"
    assert unsafe.engine_path == "jnp"


# --------------------------------------------- zero-registration client
@pytest.fixture
def lake(tmp_path, rng):
    with Client(tmp_path / "lake") as client:
        client.write_table("trips", _trips(rng))
        client.write_table("zones", _zones())
        yield client


def test_client_join_query_zero_registration(lake):
    out = lake.query(JOIN_SQL)
    # regenerate the fixture's data with the same seed (the lake fixture
    # consumed the shared rng's first draws)
    trips, zones = _trips(np.random.default_rng(0)), _zones()
    borough = zones["borough"][trips["zone"]]
    mask = trips["dist"] > 5
    for i, b in enumerate(out["borough"]):
        sel = mask & (borough == b)
        assert out["count"][i] == sel.sum()
        assert out["total"][i] == trips["fare"][sel].sum()


def test_client_engine_parity_and_telemetry(lake):
    results = {e: lake.query(JOIN_SQL, engine=e) for e in ("auto", "kernel", "jnp")}
    for k in results["jnp"]:
        for e in ("auto", "kernel"):
            np.testing.assert_array_equal(results[e][k], results["jnp"][k])
            assert results[e][k].dtype == results["jnp"][k].dtype
    evs = [e for e in lake.events() if type(e).__name__ == "QueryExecuted"]
    assert [e.engine_path for e in evs[-3:]] == ["kernel", "kernel", "jnp"]
    last = evs[-1]
    assert last.parse_s > 0 and last.plan_s > 0
    assert last.scan_s > 0 and last.exec_s > 0
    assert last.parse_s + last.plan_s + last.scan_s + last.exec_s <= last.wall_s


def test_client_unknown_names_are_sql_errors(lake):
    with pytest.raises(SqlError, match="unknown table 'nope'"):
        lake.query("SELECT x FROM nope")
    with pytest.raises(SqlError, match="unknown column 'missing'"):
        lake.query("SELECT missing FROM trips")
    with pytest.raises(SqlError, match="no column 'missing'"):
        lake.query(
            "SELECT z.missing FROM trips AS t JOIN zones AS z "
            "ON t.zone = z.zone_id"
        )
    with pytest.raises(SqlError, match="unknown table qualifier"):
        lake.query("SELECT q.fare FROM trips AS t")


def test_client_select_star_over_join(lake):
    out = lake.query(
        "SELECT * FROM trips AS t JOIN zones AS z ON t.zone = z.zone_id "
        "LIMIT 5"
    )
    # plain names where unique; both tables' columns present
    assert set(out) == {"zone", "fare", "dist", "zone_id", "borough"}
    assert all(len(v) == 5 for v in out.values())


# ---------------------------- pipeline parity: parallelism x engine
def _run_join_pipeline(parallelism, sql_engine, rng):
    p = Pipeline("sql_v2_parity")
    p.sql("by_borough", JOIN_SQL, materialize=True)
    with Client.ephemeral(
        shard_rows=512,
        executor_config=ExecutorConfig(
            max_workers=8, max_concurrent_stages=parallelism
        ),
    ) as client:
        client.write_table("trips", _trips(rng))
        client.write_table("zones", _zones())
        handle = client.run(
            p,
            parallelism=parallelism,
            planner_config=PlannerConfig(sql_engine=sql_engine),
            cache=False,
        ).raise_for_state()
        out = client.query("SELECT * FROM by_borough", engine="jnp")
        return dict(handle.artifacts), out


def test_pipeline_parity_parallelism_x_engine(rng):
    base_art, base_out = _run_join_pipeline(1, "jnp", np.random.default_rng(5))
    for parallelism in (1, 2, 8):
        for engine in ("auto", "kernel", "jnp"):
            art, out = _run_join_pipeline(
                parallelism, engine, np.random.default_rng(5)
            )
            assert art == base_art, (parallelism, engine)
            for k in base_out:
                np.testing.assert_array_equal(
                    out[k], base_out[k], err_msg=f"{parallelism}/{engine}/{k}"
                )


def test_engine_switch_keeps_cache_warm(rng):
    """Routing is not fingerprinted: a warm cache built under one engine
    must fully satisfy a re-run under the other."""
    p = Pipeline("sql_v2_cache")
    p.sql("by_borough", JOIN_SQL, materialize=True)
    with Client.ephemeral(shard_rows=512) as client:
        client.write_table("trips", _trips(rng))
        client.write_table("zones", _zones())
        cold = client.run(
            p, planner_config=PlannerConfig(sql_engine="kernel")
        ).raise_for_state()
        assert cold.stats["cache"]["nodes_executed"] >= 1
        warm = client.run(
            p, planner_config=PlannerConfig(sql_engine="jnp")
        ).raise_for_state()
        assert warm.stats["cache"]["nodes_executed"] == 0
        assert warm.stats["cache"]["hits"] >= 1


def test_single_table_fingerprints_unchanged():
    """v2 must not perturb the single-table query population's JSON form
    (node fingerprints hash it — the differential cache stays warm)."""
    q = parse_sql("SELECT fare FROM trips WHERE dist > 5")
    d = q.to_json_dict()
    assert "joins" not in d and "source_alias" not in d
    d2 = parse_sql(JOIN_SQL).to_json_dict()
    assert "joins" in d2 and d2["source_alias"] == "t"


# ------------------------------------------------------ lineage goldens
def test_lineage_join_golden_report():
    from repro.analysis.lint import lint_pipeline
    from repro.table.schema import Schema

    ext = {
        "trips": Schema.of(zone="int32", fare="int32", dist="int32"),
        "zones": Schema.of(zone_id="int32", borough="int32"),
    }
    p = Pipeline("lineage_joins")
    p.sql("ok", JOIN_SQL)
    p.sql(
        "bad_col",
        "SELECT z.missing FROM trips AS t JOIN zones AS z "
        "ON t.zone = z.zone_id",
    )
    p.sql(
        "bad_order",
        "SELECT t.fare FROM trips AS t JOIN zones AS z "
        "ON t.zone = z.zone_id ORDER BY z.borough",
    )
    rep = lint_pipeline(p, external_schemas=ext)
    got = sorted((f.rule, f.node) for f in rep.findings)
    assert got == [("L001", "bad_col"), ("L003", "bad_order")]
    (l001,) = [f for f in rep.findings if f.rule == "L001"]
    assert "'zones'" in l001.message  # attributed to the owning table


def test_lineage_propagates_join_schemas():
    from repro.analysis.lineage import propagate_schema
    from repro.table.schema import Schema

    ext = {
        "trips": Schema.of(zone="int32", fare="int32", dist="int32"),
        "zones": Schema.of(zone_id="int32", borough="int32"),
    }
    p = Pipeline("lineage_schemas")
    agg = p.sql("agg", JOIN_SQL)
    star = p.sql(
        "star",
        "SELECT * FROM trips AS t JOIN zones AS z ON t.zone = z.zone_id",
    )
    out = propagate_schema(agg, ext)
    assert [(c.name, c.dtype) for c in out.columns] == [
        ("borough", "int32"), ("count", "int32"), ("total", "int32")
    ]
    out_star = propagate_schema(star, ext)
    assert out_star.names == ["zone", "fare", "dist", "zone_id", "borough"]


def test_lineage_l004_covers_join_tables():
    from repro.analysis.lint import lint_pipeline

    p = Pipeline("lineage_l004")
    p.sql(
        "j",
        "SELECT * FROM trips AS t JOIN nowhere AS n ON t.zone = n.zone_id",
    )
    rep = lint_pipeline(p, external_schemas={})
    assert {f.rule for f in rep.findings} >= {"L004"}
    assert any("nowhere" in f.message for f in rep.findings)


# --------------------------------------------------- telemetry/back-compat
def test_query_executed_event_roundtrip_and_backcompat():
    from repro.telemetry.events import QueryExecuted, event_from_json_dict

    ev = QueryExecuted(
        table="trips", rows_out=4, shards_read=2, wall_s=0.5,
        engine_path="kernel", parse_s=0.01, plan_s=0.02, scan_s=0.3,
        exec_s=0.1,
    )
    back = event_from_json_dict(ev.to_json_dict())
    assert back == ev
    # a pre-v2 run log (no engine_path/phase fields) still loads
    old = {"kind": "QueryExecuted", "table": "t", "rows_out": 1,
           "shards_read": 1, "wall_s": 0.1}
    legacy = event_from_json_dict(old)
    assert legacy.engine_path == "jnp" and legacy.exec_s == 0.0


# --------------------------------------------------------- chunked scans
def test_execute_scan_chunk_rows_preserves_bytes(fmt, rng):
    from concurrent.futures import ThreadPoolExecutor

    from repro.table import execute_scan, plan_scan
    from repro.table.schema import Schema

    data = _trips(rng, n=5_000)
    snap = fmt.write(
        "trips",
        Schema.of(**{c: str(a.dtype) for c, a in data.items()}),
        data,
    )
    plan = plan_scan(snap)
    serial = execute_scan(fmt, plan)
    with ThreadPoolExecutor(max_workers=4) as pool:
        for chunk_rows in (1, 128, 8192, 10**9):
            chunked = execute_scan(fmt, plan, pool=pool, chunk_rows=chunk_rows)
            for c in serial:
                np.testing.assert_array_equal(serial[c], chunked[c])
