"""Scheduler v2 unit + integration tests: cost model, critical-path
ordering, memory-capped admission, forecast persistence.

The byte-identity contract across ordering/streaming/parallelism lives in
test_parallel_runner.py; this file covers the scheduler's own arithmetic
(longest-path weights on hand-built DAGs, cold-vs-seeded cost estimates
on a directly-constructed Stage) and its runtime behavior (admission
under a tiny memory budget, predicted-vs-actual forecasts landing in the
``latencyhist`` namespace, `repro trace` agreeing with the dispatch
order's implementation).
"""
import numpy as np
import pytest

from repro.api import Client
from repro.core import Pipeline
from repro.core.physical import (
    Stage,
    critical_path_ids,
    estimate_stage_costs,
    longest_path_weights,
    stage_function_spec,
)
from repro.examples_data import TAXI_SCHEMA, make_taxi_data
from repro.runtime import ExecutorConfig
from repro.runtime.resources import ResourceRequest
from repro.telemetry.events import StageScheduled


# ------------------------------------------------------- longest path math
def test_longest_path_weights_linear_chain():
    # 0 -> 1 -> 2: every stage carries itself plus everything downstream
    costs = {0: 1.0, 1: 2.0, 2: 4.0}
    parents = {0: (), 1: (0,), 2: (1,)}
    assert longest_path_weights(costs, parents) == {0: 7.0, 1: 6.0, 2: 4.0}


def test_longest_path_weights_diamond_takes_heavier_arm():
    #     0
    #    / \
    #   1   2      (1 is cheap, 2 is expensive)
    #    \ /
    #     3
    costs = {0: 1.0, 1: 0.5, 2: 10.0, 3: 1.0}
    parents = {0: (), 1: (0,), 2: (0,), 3: (1, 2)}
    w = longest_path_weights(costs, parents)
    assert w[3] == 1.0
    assert w[2] == 11.0  # 2 + sink
    assert w[1] == 1.5
    assert w[0] == 12.0  # through the heavy arm
    assert critical_path_ids(costs, parents) == [0, 2, 3]


def test_longest_path_weights_independent_roots():
    # two disjoint chains: 0->2 (total 3) and 1 (total 5)
    costs = {0: 1.0, 1: 5.0, 2: 2.0}
    parents = {0: (), 1: (), 2: (0,)}
    w = longest_path_weights(costs, parents)
    assert w == {0: 3.0, 1: 5.0, 2: 2.0}
    assert critical_path_ids(costs, parents) == [1]


def test_critical_path_tie_breaks_toward_lowest_stage_id():
    costs = {0: 1.0, 1: 1.0}
    parents = {0: (), 1: ()}
    assert critical_path_ids(costs, parents) == [0]


# --------------------------------------------------------- cost estimation
def _mk_stage(sid: int, fn, *, parents=(), mem_gb: int = 1) -> Stage:
    return Stage(
        stage_id=sid,
        node_names=(f"n{sid}",),
        scans={},
        internal_inputs=(),
        outputs=(f"n{sid}",),
        checks=(),
        fn=fn,
        resources=ResourceRequest(memory_gb=mem_gb),
        fingerprint=f"fp{sid}",
        parent_stages=tuple(parents),
    )


def _fn(ctx):
    return {}


def test_estimate_stage_costs_cold_falls_back_to_bytes():
    """No latency history -> the bytes heuristic; a zero-scan stage still
    carries the fixed overhead so it is never weightless."""
    stages = [_mk_stage(0, _fn), _mk_stage(1, _fn, parents=(0,))]
    costs = estimate_stage_costs(stages, "p", {})
    assert costs[0].source == "bytes"
    assert costs[0].est_s > 0.0
    # chain: upstream inherits downstream weight
    assert costs[0].cp_weight_s == pytest.approx(
        costs[0].est_s + costs[1].est_s
    )
    assert costs[0].cp_rank == 0 and costs[1].cp_rank == 1


def test_estimate_stage_costs_seeded_uses_latency_median():
    """A seeded history for the stage's function fingerprint (the SAME
    fingerprint stage_function_spec derives — the executor's history key)
    overrides the bytes heuristic with the median."""
    stage = _mk_stage(0, _fn)
    fp = stage_function_spec("p", stage).fingerprint
    costs = estimate_stage_costs([stage], "p", {fp: 2.5})
    assert costs[0].source == "latency"
    assert costs[0].est_s == 2.5
    # a different pipeline name is a different fingerprint -> cold again
    assert estimate_stage_costs([stage], "other", {fp: 2.5})[0].source == "bytes"


def test_stage_spec_fingerprint_matches_executor_history_key():
    """The one-construction-site guarantee: latency medians recorded by
    the executor under a dispatched spec's fingerprint are found by the
    cost model's lookup for the same stage."""
    from repro.runtime.executor import ServerlessExecutor

    stage = _mk_stage(0, lambda x: x)
    spec = stage_function_spec("pipe", stage)
    ex = ServerlessExecutor(ExecutorConfig(max_workers=2))
    try:
        ex.seed_latency_history({spec.fingerprint: [1.0, 3.0, 2.0]})
        medians = ex.latency_medians()
        costs = estimate_stage_costs([stage], "pipe", medians)
        assert costs[0].source == "latency"
        assert costs[0].est_s == 2.0  # median of [1, 2, 3]
    finally:
        ex.shutdown()


# ----------------------------------------------------- runtime integration
N_ROWS = 2_000


def _fanout_pipeline(width: int = 4) -> Pipeline:
    p = Pipeline("sched_v2")
    p.sql("trips", "SELECT passenger_count as count FROM taxi_table")
    for i in range(width):

        def make(i):
            def fn(ctx, trips):
                import jax.numpy as jnp

                return {"stat": trips.column("count").astype(jnp.float32) + i}

            fn.__name__ = f"w{i}"
            return fn

        p.python(make(i))
    return p


def _write_fixture(client):
    rng = np.random.default_rng(11)
    client.write_table(
        "taxi_table", make_taxi_data(N_ROWS, rng), schema=TAXI_SCHEMA
    )


def test_memory_budget_serializes_admission():
    """A 1 GB budget with 1 GB-tier stages admits one stage at a time:
    exec spans never overlap, and the later stages report admission
    waits — while the run itself still succeeds with full results."""
    with Client.ephemeral(
        shard_rows=512,
        executor_config=ExecutorConfig(
            max_workers=8, max_concurrent_stages=8, memory_budget_gb=1.0
        ),
    ) as client:
        _write_fixture(client)
        handle = client.run(
            _fanout_pipeline(), fusion=False, pushdown=False
        ).raise_for_state()
        sched = handle.stats["scheduler"]
        assert sched["schedule"] == "critical_path"
        assert sched["memory_budget_gb"] == 1.0
        assert sched["admission_waits"] >= 1
        # from the run's own trace: no two exec spans overlap
        trace = client.trace(handle.run_id)
        spans = sorted(
            (s["exec"].start, s["exec"].end)
            for s in trace.stage_spans.values()
            if "exec" in s
        )
        assert len(spans) >= 3
        for (_, prev_end), (next_start, _) in zip(spans, spans[1:]):
            assert next_start >= prev_end - 1e-6
        waited = [
            e for e in trace.stage_scheduled.values() if e.admission == "waited"
        ]
        assert len(waited) == sched["admission_waits"]


def test_no_budget_allows_concurrent_admission():
    """memory_budget_gb=None disables the gate: the same fan-out admits
    every ready stage up to the parallelism cap."""
    with Client.ephemeral(
        shard_rows=512,
        executor_config=ExecutorConfig(
            max_workers=8, max_concurrent_stages=8, memory_budget_gb=None
        ),
    ) as client:
        _write_fixture(client)
        handle = client.run(
            _fanout_pipeline(), fusion=False, pushdown=False
        ).raise_for_state()
        sched = handle.stats["scheduler"]
        assert sched["memory_budget_gb"] is None
        assert sched["admission_waits"] == 0


def test_stage_scheduled_events_and_trace_agree_with_run_stats():
    """StageScheduled telemetry carries the same estimates the run stats
    report, and `repro trace`'s critical path uses the shared physical
    implementation (a valid root-to-sink chain of traced stages)."""
    with Client.ephemeral(
        shard_rows=512,
        executor_config=ExecutorConfig(max_workers=8, max_concurrent_stages=4),
    ) as client:
        _write_fixture(client)
        handle = client.run(
            _fanout_pipeline(), fusion=False, pushdown=False
        ).raise_for_state()
        sched = handle.stats["scheduler"]
        events = [
            e for e in client.runlog.get(handle.run_id)
            if isinstance(e, StageScheduled)
        ]
        assert {e.stage_id for e in events} == {
            int(s) for s in sched["stages"]
        }
        for e in events:
            st = sched["stages"][str(e.stage_id)]
            assert e.est_cost_s == st["est_s"]
            assert e.cp_rank == st["cp_rank"]
            assert e.cost_source == st["source"]
        # model-predicted critical path: a real chain, root at a source
        pred = sched["critical_path"]
        assert pred, "predicted critical path must be non-empty"
        trace = client.trace(handle.run_id)
        observed = trace.critical_path()
        assert observed, "observed critical path must be non-empty"
        # both paths walk dependency edges of the same DAG
        by_id = {s: set(ps) for s, ps in trace.stage_parents.items()}
        for a, b in zip(observed, observed[1:]):
            assert a in by_id.get(b, set())
        assert "scheduler:" in trace.describe()


def test_forecast_persists_to_latencyhist_refs():
    """After a run, every executed stage's latencyhist ref carries the
    scheduler's predicted-vs-actual forecast — riding the same ref the
    lakekeeper's latency_ttl_s sweep ages out."""
    with Client.ephemeral(
        shard_rows=512,
        executor_config=ExecutorConfig(max_workers=8, max_concurrent_stages=4),
    ) as client:
        _write_fixture(client)
        client.run(
            _fanout_pipeline(), fusion=False, pushdown=False
        ).raise_for_state()
        refs = client.store.list_refs("latencyhist")
        assert refs, "latency histories must persist"
        with_forecast = {
            fp: raw for fp, raw in refs.items() if "forecast" in raw
        }
        assert with_forecast, "forecasts must ride the latencyhist refs"
        for raw in with_forecast.values():
            assert raw["forecast"]["predicted_s"] > 0.0
            assert raw["forecast"]["actual_s"] > 0.0
            assert raw["updated_at"] > 0.0  # the TTL sweep's age field


def test_second_run_upgrades_cost_source_to_latency():
    """Run twice in one client: the second run's estimates come from the
    first run's recorded latency medians (self-correcting cost model)."""
    with Client.ephemeral(
        shard_rows=512,
        executor_config=ExecutorConfig(max_workers=8, max_concurrent_stages=4),
    ) as client:
        _write_fixture(client)
        first = client.run(
            _fanout_pipeline(), fusion=False, pushdown=False, cache=False
        ).raise_for_state()
        sources_first = {
            s["source"] for s in first.stats["scheduler"]["stages"].values()
        }
        assert sources_first == {"bytes"}  # cold: nothing seeded
        second = client.run(
            _fanout_pipeline(), fusion=False, pushdown=False, cache=False
        ).raise_for_state()
        sources_second = {
            s["source"] for s in second.stats["scheduler"]["stages"].values()
        }
        assert sources_second == {"latency"}  # every stage now has history


def test_invalid_schedule_rejected():
    with Client.ephemeral(shard_rows=512) as client:
        _write_fixture(client)
        with pytest.raises(ValueError, match="schedule"):
            client.run(_fanout_pipeline(), schedule="sjf")
