"""Catalog: branches, commits, merges, time travel, conflicts (paper 4.3)."""
import numpy as np
import pytest

from repro.catalog import Catalog, CatalogError, MergeConflict
from repro.table import Schema


def test_init_creates_main(catalog):
    assert catalog.branches() == ["main"]
    assert catalog.head("main").tables == {}


def test_commit_and_read(catalog):
    catalog.commit("main", {"taxi_table": "key1"}, message="add taxi")
    assert catalog.table_key("taxi_table") == "key1"
    catalog.commit("main", {"taxi_table": "key2"})
    assert catalog.table_key("taxi_table") == "key2"


def test_branch_isolation(catalog):
    catalog.commit("main", {"t": "k0"})
    catalog.create_branch("feat_1")
    catalog.commit("feat_1", {"t": "k1", "new": "k2"})
    # production untouched (the paper's sandbox guarantee)
    assert catalog.table_key("t", branch="main") == "k0"
    with pytest.raises(CatalogError):
        catalog.table_key("new", branch="main")
    assert catalog.table_key("t", branch="feat_1") == "k1"


def test_time_travel_by_commit(catalog):
    c1 = catalog.commit("main", {"t": "v1"})
    c2 = catalog.commit("main", {"t": "v2"})
    assert catalog.table_key("t", commit_id=c1.commit_id) == "v1"
    assert catalog.table_key("t", commit_id=c2.commit_id) == "v2"


def test_merge_fast_forward_like(catalog):
    catalog.commit("main", {"t": "base"})
    catalog.create_branch("feat_1")
    catalog.commit("feat_1", {"t": "feat", "extra": "e1"})
    catalog.merge("feat_1", "main", delete_source=True)
    assert catalog.table_key("t") == "feat"
    assert catalog.table_key("extra") == "e1"
    assert "feat_1" not in catalog.branches()


def test_merge_conflict_detected(catalog):
    catalog.commit("main", {"t": "base"})
    catalog.create_branch("feat_1")
    catalog.commit("feat_1", {"t": "from_feat"})
    catalog.commit("main", {"t": "from_main"})
    with pytest.raises(MergeConflict):
        catalog.merge("feat_1", "main")


def test_merge_disjoint_tables_no_conflict(catalog):
    catalog.commit("main", {"a": "base_a"})
    catalog.create_branch("feat_1")
    catalog.commit("feat_1", {"b": "feat_b"})
    catalog.commit("main", {"a": "new_a"})
    catalog.merge("feat_1", "main")
    assert catalog.table_key("a") == "new_a"
    assert catalog.table_key("b") == "feat_b"


def test_delete_table_via_none(catalog):
    catalog.commit("main", {"t": "k"})
    catalog.commit("main", {"t": None})
    with pytest.raises(CatalogError):
        catalog.table_key("t")


def test_log_lineage(catalog):
    catalog.commit("main", {"t": "1"}, message="one")
    catalog.commit("main", {"t": "2"}, message="two")
    log = catalog.log("main")
    assert [c.message for c in log] == ["two", "one", "init"]


def test_tags(catalog):
    c = catalog.commit("main", {"t": "v"})
    catalog.tag("release-1", c.commit_id)
    assert catalog.resolve_tag("release-1") == c.commit_id


def test_cannot_delete_default_branch(catalog):
    with pytest.raises(CatalogError):
        catalog.delete_branch("main")


def test_ephemeral_run_branch_pattern(catalog, fmt, rng):
    """End-to-end of Fig. 4: fork, write, merge-on-success, delete."""
    schema = Schema.of(x="float32")
    base = fmt.write("t", schema, {"x": np.ones(10, np.float32)})
    catalog.commit("main", {"t": fmt.manifest_key(base)})
    catalog.create_branch("feat_1")
    catalog.create_branch("run_12", from_branch="feat_1")
    new = fmt.write("pickups", schema, {"x": np.zeros(5, np.float32)})
    catalog.commit("run_12", {"pickups": fmt.manifest_key(new)})
    # audit passes -> merge; production visibility is atomic
    catalog.merge("run_12", "feat_1", delete_source=True)
    assert "run_12" not in catalog.branches()
    assert "pickups" in catalog.tables(branch="feat_1")
    assert "pickups" not in catalog.tables(branch="main")
