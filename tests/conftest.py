import os

# Smoke tests and benches must see ONE device. Only launch/dryrun.py sets
# xla_force_host_platform_device_count (and only in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

from repro.catalog import Catalog
from repro.io import ObjectStore
from repro.table import TableFormat


@pytest.fixture
def store(tmp_path):
    return ObjectStore(tmp_path / "lake")


@pytest.fixture
def fmt(store):
    return TableFormat(store, shard_rows=128)


@pytest.fixture
def catalog(store):
    return Catalog(store)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    # registered in pyproject.toml too; duplicated here so the marker
    # exists even when pytest runs without that config file (e.g. pytest
    # invoked on a single test file from another rootdir)
    config.addinivalue_line(
        "markers", "slow: slow property-based tests (deselect with -m 'not slow')"
    )


def pytest_collection_modifyitems(config, items):
    """Auto-mark property-based tests as slow so `-m 'not slow'` gives a
    quick signal pass.  Real hypothesis sets ``fn.hypothesis``; the offline
    fallback (tests/_hypothesis_compat.py) sets ``fn._property_test``."""
    for item in items:
        fn = getattr(item, "function", None)
        if hasattr(fn, "hypothesis") or getattr(fn, "_property_test", False):
            item.add_marker(pytest.mark.slow)
