import os

# Smoke tests and benches must see ONE device. Only launch/dryrun.py sets
# xla_force_host_platform_device_count (and only in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

from repro.catalog import Catalog
from repro.io import ObjectStore
from repro.table import TableFormat


@pytest.fixture
def store(tmp_path):
    return ObjectStore(tmp_path / "lake")


@pytest.fixture
def fmt(store):
    return TableFormat(store, shard_rows=128)


@pytest.fixture
def catalog(store):
    return Catalog(store)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
