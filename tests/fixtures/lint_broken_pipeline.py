"""A deliberately broken pipeline for lint tests and the CI smoke job.

Seeded bugs (each one a rule the linter must catch):

* ``trips`` selects ``total_fare``, which does not exist on
  ``taxi_table`` — L001 (error);
* ``jittered`` draws from an unseeded ``np.random.default_rng()`` —
  D102 (warning, cache poison).
"""
import numpy as np

import repro

broken = repro.project("lint_broken_demo")

broken.sql(
    "trips",
    "SELECT pickup_at, total_fare FROM taxi_table WHERE passenger_count > 1",
)


@broken.model()
def jittered(ctx, trips):
    rng = np.random.default_rng()
    noise = rng.normal(0.0, 1.0, trips.capacity).astype(np.float32)
    return {"pickup_at": trips["pickup_at"], "noise": noise}
