"""The explain plane — route traces, typed checks, concurrency rules.

The contract under test is *agreement*: the static verdict
(``client.explain`` / ``repro explain``) must equal what the runtime
does — same engine_path, same RouteError byte-for-byte, same routes the
physical planner stamps onto its stages — while executing nothing and
writing nothing.  Plus golden reports for every new rule family
(T401-T404, C501-C503), noqa suppression, and the generated README
catalog.
"""
import json
from pathlib import Path

import numpy as np
import pytest

import repro
from repro.analysis import (
    CONCURRENCY_RULES,
    FUNCTION_RULES,
    LintReport,
    Severity,
    TYPE_RULES,
    lint_pipeline,
    query_type_findings,
    rule_catalog_markdown,
    run_concurrency_rules,
)
from repro.analysis.catalog import CATALOG_BEGIN, CATALOG_END
from repro.api.project import Project
from repro.cli import main
from repro.core import Pipeline
from repro.core.logical import build_logical_plan
from repro.core.physical import build_physical_plan
from repro.core.runner import RunContext
from repro.engine.route import (
    EXACT_BOUND,
    ROUTE_CHECKS,
    RouteDecision,
    RouteError,
    plan_route,
)
from repro.engine.sql import SqlError, parse_sql
from repro.table.schema import Schema
from tests.helpers_taxi import TAXI_SCHEMA, make_taxi_data

TAXI = {
    "taxi_table": Schema.of(
        pickup_at="int32",
        pickup_location_id="int32",
        passenger_count="int32",
        dropoff_location_id="int32",
    )
}

JOINED = {
    "trips": Schema.of(
        zone="int32", zone_i8="int8", score="float32", fare="int32"
    ),
    "zones": Schema.of(zone_id="int32", borough="int32", weight="int32"),
}

#: module-level shared state the C-rule tests deliberately traffic in
SHARED_LOG: list = []
TOTALS: dict = {}


def lint(pipeline, schemas=TAXI) -> LintReport:
    return lint_pipeline(pipeline, external_schemas=schemas)


def rules(report: LintReport):
    return {f.rule for f in report.findings}


# =========================================================== route traces
def test_route_trace_kernel_records_every_check():
    q = parse_sql("SELECT zone, SUM(fare) AS s FROM t GROUP BY zone")
    r = plan_route(q, stats={"zone": (0, 15), "fare": (1, 50)}, total_rows=10_000)
    assert r.engine_path == "kernel"
    assert r.trace is not None and r.trace.failed is None
    ids = [c.check for c in r.trace.checks]
    assert {"R201", "R202", "R203", "R204", "R205", "R206", "R207", "R208",
            "R209"} <= set(ids)
    assert all(c.passed for c in r.trace.checks)
    # the ids always resolve in the registry repro explain documents
    assert all(c.check in ROUTE_CHECKS for c in r.trace.checks)


def test_route_trace_bails_at_first_failed_check():
    q = parse_sql("SELECT fare FROM t WHERE zone > 3")
    r = plan_route(q)
    assert r.engine_path == "jnp"
    assert r.reason == "not an aggregation"
    last = r.trace.checks[-1]
    assert last.check == "R201" and not last.passed
    assert r.trace.failed is last
    assert last.hint  # a failed check always carries a fix


def test_route_trace_engine_jnp_is_pinned():
    q = parse_sql("SELECT zone, COUNT(*) AS n FROM t GROUP BY zone")
    r = plan_route(q, engine="jnp")
    assert r.engine_path == "jnp"
    assert [c.check for c in r.trace.checks] == ["R200"]
    assert r.trace.checks[0].passed


def test_route_forced_kernel_skips_exactness_checks():
    # float aggregate column (no stats), unknown row count: auto would
    # bail at R207/R208, a forced kernel legitimately runs anyway
    q = parse_sql("SELECT zone, SUM(fare) AS s FROM t GROUP BY zone")
    r = plan_route(q, engine="kernel", stats={"zone": (0, 15)}, total_rows=None)
    assert r.engine_path == "kernel"
    ids = {c.check for c in r.trace.checks}
    assert "R207" not in ids and "R208" not in ids


def test_route_decision_equality_and_hash_ignore_trace():
    q = parse_sql("SELECT zone, SUM(fare) AS s FROM t GROUP BY zone")
    r = plan_route(q, stats={"zone": (0, 15), "fare": (1, 50)}, total_rows=10_000)
    bare = RouteDecision(
        engine_path=r.engine_path,
        reason=r.reason,
        num_groups=r.num_groups,
        key_offset=r.key_offset,
        native_filter=r.native_filter,
        interpret=r.interpret,
    )
    assert r.trace is not None and bare.trace is None
    assert r == bare
    assert hash(r) == hash(bare)


def test_route_error_positioned_like_sql_error():
    sql = "SELECT zone, fare, COUNT(*) AS n FROM t GROUP BY zone, fare"
    with pytest.raises(RouteError) as ei:
        plan_route(parse_sql(sql), engine="kernel", stats={"zone": (0, 9)})
    e = ei.value
    assert isinstance(e.pos, int) and e.pos > 0
    assert e.fragment and "fare" in e.fragment
    assert "position" in str(e)
    assert e.hint and "fix:" in str(e)
    assert e.trace is not None and e.trace.failed.check == "R202"


def test_route_error_min_aggregate_names_the_fix():
    sql = "SELECT zone, MIN(fare) AS m FROM t GROUP BY zone"
    with pytest.raises(RouteError) as ei:
        plan_route(parse_sql(sql), engine="kernel", stats={"zone": (0, 9)})
    e = ei.value
    assert e.trace.failed.check == "R203"
    assert "jnp" in e.hint


# ============================================================== T-rules
def test_t401_float_join_key_is_an_error():
    p = Pipeline("t401")
    p.sql(
        "bad",
        "SELECT t.fare FROM trips AS t JOIN zones AS z "
        "ON t.score = z.zone_id",
    )
    report = lint(p, JOINED)
    (f,) = report.by_rule("T401")
    assert f.severity is Severity.ERROR
    assert "t.score" in f.message and "float32" in f.message
    assert f.hint and "int32" in f.hint
    assert "t.score" in (f.snippet or "")
    assert f.file and f.file.endswith("test_explain.py") and f.line


def test_t402_join_key_widening_is_info():
    p = Pipeline("t402")
    p.sql(
        "j",
        "SELECT t.fare FROM trips AS t JOIN zones AS z "
        "ON t.zone_i8 = z.zone_id",
    )
    report = lint(p, JOINED)
    (f,) = report.by_rule("T402")
    assert f.severity is Severity.INFO
    assert "int8" in f.message and "int32" in f.message
    assert report.by_rule("T401") == []


def test_t403_row_count_crosses_exactness_boundary():
    q = parse_sql("SELECT zone, COUNT(*) AS n FROM t GROUP BY zone")
    schemas = {"t": Schema.of(zone="int32", fare="int32")}
    findings, _ = query_type_findings(
        q, schemas, stats={"zone": (0, 15)}, total_rows=EXACT_BOUND
    )
    (f,) = [x for x in findings if x.rule == "T403"]
    assert f.severity is Severity.WARNING
    assert "2^24" in f.message
    # one row under the bound: provably exact, no finding
    findings, _ = query_type_findings(
        q, schemas, stats={"zone": (0, 15)}, total_rows=EXACT_BOUND - 1
    )
    assert [x for x in findings if x.rule == "T403"] == []


def test_t403_sum_bound_from_shard_stats():
    q = parse_sql("SELECT zone, SUM(fare) AS s FROM t GROUP BY zone")
    schemas = {"t": Schema.of(zone="int32", fare="int32")}
    findings, _ = query_type_findings(
        q, schemas, stats={"zone": (0, 15), "fare": (0, 100_000)},
        total_rows=1_000,
    )
    (f,) = [x for x in findings if x.rule == "T403"]
    assert "fare" in f.message and "sql line 1" in f.message
    assert f.hint
    # without stats the pass under-reports rather than guesses
    findings, _ = query_type_findings(q, schemas)
    assert findings == []


def test_t404_left_join_zero_fill_fires_for_key_and_aggregate():
    p = Pipeline("t404")
    p.sql(
        "agg",
        "SELECT z.borough, SUM(z.weight) AS w FROM trips AS t "
        "LEFT JOIN zones AS z ON t.zone = z.zone_id GROUP BY z.borough",
    )
    report = lint(p, JOINED)
    found = report.by_rule("T404")
    assert len(found) == 2
    assert all(f.severity is Severity.WARNING for f in found)
    assert "zero-fill" in found[0].message
    assert "zero-filled" in found[1].message
    assert all(f.hint for f in found)


def test_t404_inner_join_is_clean():
    p = Pipeline("t404_inner")
    p.sql(
        "agg",
        "SELECT z.borough, SUM(z.weight) AS w FROM trips AS t "
        "JOIN zones AS z ON t.zone = z.zone_id GROUP BY z.borough",
    )
    assert lint(p, JOINED).by_rule("T404") == []


def test_t404_unqualified_column_attributed_to_unique_owner():
    p = Pipeline("t404_plain")
    p.sql(
        "agg",
        "SELECT borough, COUNT(*) AS n FROM trips AS t "
        "LEFT JOIN zones AS z ON t.zone = z.zone_id GROUP BY borough",
    )
    (f,) = lint(p, JOINED).by_rule("T404")
    assert "'borough'" in f.message


# ------------------------------------------------- noqa on the node line
def test_noqa_rule_scoped_suppresses_t401():
    p = Pipeline("t401_noqa")
    p.sql("bad", "SELECT t.fare FROM trips AS t JOIN zones AS z ON t.score = z.zone_id")  # repro: noqa[T401]
    report = lint(p, JOINED)
    assert report.by_rule("T401") == []
    assert report.suppressed == 1


def test_noqa_bare_suppresses_t_rules():
    p = Pipeline("t401_noqa_bare")
    p.sql("bad", "SELECT t.fare FROM trips AS t JOIN zones AS z ON t.score = z.zone_id")  # repro: noqa
    report = lint(p, JOINED)
    assert report.by_rule("T401") == []
    assert report.suppressed == 1


def test_noqa_wrong_rule_does_not_suppress_t401():
    p = Pipeline("t401_noqa_wrong")
    p.sql("bad", "SELECT t.fare FROM trips AS t JOIN zones AS z ON t.score = z.zone_id")  # repro: noqa[T402]
    report = lint(p, JOINED)
    assert len(report.by_rule("T401")) == 1
    assert report.suppressed == 0


# ============================================================== C-rules
def test_c501_artifact_shadowing_a_lake_table():
    p = Pipeline("shadow")
    p.sql("orders", "SELECT pickup_at FROM taxi_table")
    findings, suppressed = run_concurrency_rules(p, catalog_tables={"orders"})
    (f,) = findings
    assert f.rule == "C501" and f.severity is Severity.WARNING
    assert "orders" in f.message and "shadows" in f.message
    assert f.hint and "rename" in f.hint
    assert suppressed == 0
    # no catalog context -> the rule cannot fire
    assert run_concurrency_rules(p)[0] == []


def test_noqa_c501_on_registration_line():
    p = Pipeline("shadow_noqa")
    p.sql("orders", "SELECT pickup_at FROM taxi_table")  # repro: noqa[C501]
    findings, suppressed = run_concurrency_rules(p, catalog_tables={"orders"})
    assert findings == [] and suppressed == 1


def test_c502_co_schedulable_writers_to_one_global():
    proj = Project("c502_pair")

    @proj.model()
    def first_writer(ctx, taxi_table):
        SHARED_LOG.append("first")
        return {"x": np.zeros(1, dtype=np.int32)}

    @proj.model()
    def second_writer(ctx, taxi_table):
        SHARED_LOG.append("second")
        return {"x": np.zeros(1, dtype=np.int32)}

    report = lint(proj.pipeline())
    (f,) = report.by_rule("C502")
    assert f.severity is Severity.WARNING
    assert "SHARED_LOG" in f.message
    assert "first_writer" in f.message and "second_writer" in f.message
    assert f.file and f.file.endswith("test_explain.py") and f.line
    assert "SHARED_LOG" in (f.snippet or "")
    assert f.hint and "artifact" in f.hint


def test_c502_dependency_path_orders_the_writes():
    proj = Project("c502_dep")

    @proj.model()
    def base_writer(ctx, taxi_table):
        SHARED_LOG.append("base")
        return {"x": np.zeros(1, dtype=np.int32)}

    @proj.model()
    def downstream_writer(ctx, base_writer):
        SHARED_LOG.append("down")
        return {"x": np.zeros(1, dtype=np.int32)}

    report = lint(proj.pipeline())
    assert report.by_rule("C502") == []
    assert report.by_rule("C503") == []


def test_c503_co_schedulable_writer_and_reader():
    proj = Project("c503")

    @proj.model()
    def totals_writer(ctx, taxi_table):
        TOTALS["rows"] = 1
        return {"x": np.zeros(1, dtype=np.int32)}

    @proj.model()
    def totals_reader(ctx, taxi_table):
        n = TOTALS.get("rows", 0)
        return {"x": np.full(1, n, dtype=np.int32)}

    report = lint(proj.pipeline())
    (f,) = report.by_rule("C503")
    assert "TOTALS" in f.message
    assert "totals_reader" in f.message and "totals_writer" in f.message
    assert report.by_rule("C502") == []  # only one side mutates


def test_noqa_suppresses_c502_at_the_write_site():
    proj = Project("c502_noqa")

    @proj.model()
    def muted_one(ctx, taxi_table):
        SHARED_LOG.append("a")  # repro: noqa[C502]
        return {"x": np.zeros(1, dtype=np.int32)}

    @proj.model()
    def muted_two(ctx, taxi_table):
        SHARED_LOG.append("b")  # repro: noqa[C502]
        return {"x": np.zeros(1, dtype=np.int32)}

    report = lint(proj.pipeline())
    assert report.by_rule("C502") == []
    assert report.suppressed >= 1


# ====================================================== client surface
@pytest.fixture
def client(tmp_path, rng):
    with repro.Client(tmp_path / "lake") as c:
        c.write_table("taxi_table", make_taxi_data(500, rng), schema=TAXI_SCHEMA)
        c.write_table(
            "orders",
            {
                "user_id": rng.integers(0, 50, 2000).astype(np.int32),
                "amount": rng.integers(0, 100, 2000).astype(np.int32),
                "famount": (rng.random(2000) * 100).astype(np.float32),
                "country": rng.integers(0, 20, 2000).astype(np.int32),
                "wid": rng.integers(0, 100_000, 2000).astype(np.int32),
            },
        )
        c.write_table(
            "big_orders_src",
            {
                "k": rng.integers(0, 10, 2000).astype(np.int32),
                "v": rng.integers(0, 2 ** 15, 2000).astype(np.int32),
            },
        )
        yield c


def test_explain_sql_kernel_verdict_with_plan(client):
    ex = client.explain(
        "SELECT country, SUM(amount) AS rev FROM orders "
        "WHERE amount > 10 GROUP BY country"
    )
    assert ex.engine_path == "kernel"
    assert ex.error is None
    assert ex.trace is not None and ex.trace.failed is None
    assert ex.pushdown and "amount" in ex.pushdown[0]
    assert ex.scans["orders"]["rows"] == 2000
    assert [n for n, _ in ex.output_schema] == ["country", "rev"]
    text = ex.describe()
    assert "route trace" in text and "execute   kernel" in text
    data = ex.to_json_dict()
    assert data["engine_path"] == "kernel" and data["trace"]["checks"]


def test_explain_sql_exactness_bail_carries_t403(client):
    ex = client.explain("SELECT k, SUM(v) AS s FROM big_orders_src GROUP BY k")
    assert ex.engine_path == "jnp"
    assert ex.trace.failed.check == "R208"
    assert any(f.rule == "T403" for f in ex.findings)


def test_client_lint_reaches_stats_grounded_t403(client):
    p = Pipeline("t403_lake")
    p.sql("sums", "SELECT k, SUM(v) AS s FROM big_orders_src GROUP BY k")
    assert "T403" in rules(client.lint(p))


def test_client_lint_c501_against_branch_head(client):
    p = Pipeline("shadow_lake")
    p.sql("orders", "SELECT pickup_at FROM taxi_table")
    (f,) = client.lint(p).by_rule("C501")
    assert "orders" in f.message


def test_explain_sql_predicted_route_error_matches_runtime(client):
    sql = "SELECT country, MIN(amount) AS m FROM orders GROUP BY country"
    ex = client.explain(sql, engine="kernel")
    assert ex.engine_path is None and ex.route is None
    assert ex.error is not None and "R" not in ex.error[:1]  # a message, not an id
    assert ex.trace is not None and ex.trace.failed.check == "R203"
    with pytest.raises(RouteError) as ei:
        client.query(sql, engine="kernel")
    assert str(ei.value) == ex.error  # byte-for-byte


AGREE_QUERIES = [
    # kernel-eligible: int agg, provable exactness, native filter
    "SELECT country, SUM(amount) AS rev FROM orders "
    "WHERE amount > 10 GROUP BY country",
    # plain scan — nothing to fuse
    "SELECT user_id, amount FROM orders WHERE amount > 80",
    # float aggregate: auto refuses, forced kernel runs (last-ulp drift)
    "SELECT country, SUM(famount) AS s FROM orders GROUP BY country",
    # two group keys — structurally ineligible
    "SELECT country, user_id, COUNT(*) AS n FROM orders "
    "GROUP BY country, user_id",
    # wide key range — exceeds the dense group axis
    "SELECT wid, COUNT(*) AS n FROM orders GROUP BY wid",
    # MIN — not kernel-fusable
    "SELECT country, MIN(amount) AS m FROM orders GROUP BY country",
]


@pytest.mark.parametrize("engine", ["auto", "jnp", "kernel"])
def test_explain_agrees_with_runtime_matrix(client, engine):
    for sql in AGREE_QUERIES:
        ex = client.explain(sql, engine=engine)
        if ex.error is not None:
            with pytest.raises(RouteError) as ei:
                client.query(sql, engine=engine)
            assert str(ei.value) == ex.error, sql
        else:
            client.query(sql, engine=engine)
            ran = [
                e for e in client.events()
                if type(e).__name__ == "QueryExecuted"
            ][-1].engine_path
            assert ex.engine_path == ran, (sql, engine)


def test_explain_unknown_table_positioned_sql_error(client):
    with pytest.raises(SqlError) as ei:
        client.explain("SELECT x FROM phantom")
    assert ei.value.pos == len("SELECT x FROM ")
    assert "phantom" in str(ei.value)


def _route_pipeline() -> Pipeline:
    p = Pipeline("routes")
    p.sql(
        "pickup_counts",
        "SELECT pickup_location_id, COUNT(*) AS n FROM taxi_table "
        "GROUP BY pickup_location_id",
    )
    p.sql("narrow", "SELECT pickup_at FROM taxi_table WHERE passenger_count > 2")
    p.sql("top", "SELECT n FROM pickup_counts")
    return p


def test_explain_pipeline_routes_equal_planner_stage_routes(client):
    p = _route_pipeline()
    pe = client.explain(p)
    snap = client.fmt.load_snapshot(client.catalog.table_key("taxi_table"))
    logical = build_logical_plan(p, external_schemas={"taxi_table": snap.schema})
    plan = build_physical_plan(
        logical, {"taxi_table": snap}, ctx=RunContext("main", 1, {})
    )
    planned = {}
    for stage in plan.stages:
        planned.update(stage.sql_routes)
    assert set(pe.routes) == {"pickup_counts", "narrow", "top"}
    assert pe.routes == planned  # RouteDecision equality, trace excluded


def test_explain_pipeline_node_details(client):
    pe = client.explain(_route_pipeline())
    assert pe.report.ok()
    by_name = {n.name: n for n in pe.nodes}
    counts = by_name["pickup_counts"]
    assert counts.route is not None and counts.trace.checks
    assert counts.output_schema is not None
    assert dict(counts.output_schema)["n"] == "int32"
    # node-sourced input: no shard stats, auto falls back to jnp at R205
    top = by_name["top"]
    assert top.route is None or top.route.engine_path == "jnp"
    text = pe.describe()
    assert "explain pipeline" in text and "route:" in text
    data = pe.to_json_dict()
    assert {n["name"] for n in data["nodes"]} == set(by_name)
    assert data["lint"]["errors"] == 0


def test_explain_pipeline_forced_kernel_surfaces_predicted_error(client):
    p = Pipeline("forced")
    p.sql("narrow", "SELECT pickup_at FROM taxi_table WHERE passenger_count > 2")
    pe = client.explain(p, engine="kernel")
    (node,) = [n for n in pe.nodes if n.name == "narrow"]
    assert node.route is None and node.error is not None
    assert "engine='kernel' forced" in node.error
    assert pe.routes == {}


def test_explain_pipeline_embeds_full_lint(client):
    p = Pipeline("broken")
    p.sql("trips", "SELECT total_fare FROM taxi_table")
    pe = client.explain(p)
    assert not pe.report.ok()
    assert pe.report.by_rule("L001")
    assert len(pe.nodes) == 1  # still explained as far as possible


def test_client_explain_zero_store_writes(client):
    puts_before = client.store.stats.puts
    ex = client.explain(
        "SELECT country, SUM(amount) AS rev FROM orders GROUP BY country"
    )
    assert ex.engine_path in ("kernel", "jnp")
    pe = client.explain(_route_pipeline())
    assert pe.nodes
    assert client.store.stats.puts == puts_before  # read-only plane
    assert client._executor is None  # no fleet was ever constructed


# -------------------------- LEFT JOIN zero-fill: inference vs execution
@pytest.mark.parametrize("kind", ["int32", "int8", "bool"])
def test_left_join_zero_fill_schema_matches_exec(tmp_path, rng, kind):
    n = 64
    if kind == "bool":
        left_keys = (np.arange(n) % 2).astype(bool)
        right_keys = np.array([True])
    else:
        left_keys = (np.arange(n) % 10).astype(kind)
        right_keys = np.arange(5).astype(kind)  # keys 5..9 unmatched
    with repro.Client(tmp_path / "lake") as c:
        c.write_table(
            "users",
            {"uid": left_keys, "score": np.arange(n, dtype=np.int32)},
        )
        c.write_table(
            "bonus",
            {
                "uid": right_keys,
                "extra": (np.arange(len(right_keys)) + 7).astype(np.int8),
            },
        )
        sql = (
            "SELECT u.score, b.extra FROM users AS u "
            "LEFT JOIN bonus AS b ON u.uid = b.uid"
        )
        ex = c.explain(sql)
        out = c.query(sql)
        # the statically-inferred schema IS the executed schema
        assert ex.output_schema is not None
        assert dict(ex.output_schema) == {
            name: str(arr.dtype) for name, arr in out.items()
        }
        # and unmatched left rows really are zero-filled, dtype preserved
        matched = np.isin(left_keys, right_keys)
        assert not matched.all()
        assert (out["extra"][~matched] == 0).all()


# ================================================== README rule catalog
def test_readme_rule_catalog_matches_generator():
    readme = (Path(__file__).resolve().parents[1] / "README.md").read_text()
    start = readme.index(CATALOG_BEGIN) + len(CATALOG_BEGIN)
    end = readme.index(CATALOG_END)
    assert readme[start:end].strip("\n") == rule_catalog_markdown()


def test_rule_catalog_covers_every_registry():
    text = rule_catalog_markdown()
    ids = [r.id for r in FUNCTION_RULES + TYPE_RULES + CONCURRENCY_RULES]
    ids += list(ROUTE_CHECKS)
    for rid in ids:
        assert f"`{rid}`" in text, rid


# ================================================================= CLI
PIPE_SRC = """
import repro

proj = repro.project("cli_explain_clean")
proj.sql("trips", "SELECT pickup_at FROM taxi_table WHERE passenger_count > 1")
"""


@pytest.fixture
def lake(tmp_path, rng):
    with repro.Client(tmp_path / "lake") as c:
        c.write_table("taxi_table", make_taxi_data(200, rng), schema=TAXI_SCHEMA)
    return tmp_path / "lake"


def test_cli_explain_sql(lake, capsys):
    main([
        "--lake", str(lake), "explain", "-q",
        "SELECT pickup_location_id, COUNT(*) AS n FROM taxi_table "
        "GROUP BY pickup_location_id",
    ])
    out = capsys.readouterr().out
    assert "route trace" in out and "execute" in out


def test_cli_explain_predicted_error_still_exits_zero(lake, capsys):
    # the predicted refusal IS the product — explain must not fail
    main([
        "--lake", str(lake), "explain", "--engine", "kernel", "-q",
        "SELECT pickup_location_id, MIN(passenger_count) AS m "
        "FROM taxi_table GROUP BY pickup_location_id",
    ])
    out = capsys.readouterr().out
    assert "REFUSED" in out and "fix:" in out


def test_cli_explain_pipeline(lake, tmp_path, capsys):
    f = tmp_path / "clean_pipe.py"
    f.write_text(PIPE_SRC)
    main(["--lake", str(lake), "explain", str(f)])
    out = capsys.readouterr().out
    assert "explain pipeline" in out and "trips" in out


def test_cli_explain_broken_pipeline_exits_nonzero(lake, capsys):
    with pytest.raises(SystemExit) as ei:
        main([
            "--lake", str(lake), "explain",
            "tests/fixtures/lint_broken_pipeline.py",
        ])
    assert ei.value.code == 1


def test_cli_explain_requires_exactly_one_target(lake, tmp_path):
    with pytest.raises(SystemExit) as ei:
        main(["--lake", str(lake), "explain"])
    assert "exactly one target" in str(ei.value.code)
    f = tmp_path / "clean_pipe.py"
    f.write_text(PIPE_SRC)
    with pytest.raises(SystemExit):
        main(["--lake", str(lake), "explain", str(f), "-q", "SELECT 1"])


def test_cli_explain_json_reports(lake, tmp_path, capsys):
    sql_json = tmp_path / "sql.json"
    main([
        "--lake", str(lake), "explain", "-q",
        "SELECT pickup_location_id, COUNT(*) AS n FROM taxi_table "
        "GROUP BY pickup_location_id",
        "--json", str(sql_json),
    ])
    data = json.loads(sql_json.read_text())
    assert data["engine_path"] in ("kernel", "jnp")
    assert data["trace"]["checks"]

    pipe_json = tmp_path / "pipe.json"
    f = tmp_path / "clean_pipe.py"
    f.write_text(PIPE_SRC)
    main(["--lake", str(lake), "explain", str(f), "--json", str(pipe_json)])
    data = json.loads(pipe_json.read_text())
    assert {n["name"] for n in data["nodes"]} == {"trips"}
    assert data["lint"]["errors"] == 0
