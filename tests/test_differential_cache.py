"""Cross-run differential artifact cache (FaaS & Furious, arXiv 2411.08203).

The reproducibility contract — same code on the same data produces
identical results (paper 4.4.1) — turned into a performance win: stages
whose transitive fingerprint (node code + upstream fingerprints + input
snapshot ids + params) matches a previously audited run are skipped and
their outputs restored from the object store.
"""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import ExpectationFailed, PlannerConfig, Runner, build_logical_plan
from repro.core.physical import build_physical_plan
from repro.core.runner import RunContext
from repro.core.snapshot import StageCacheEntry, StageCacheRegistry
from repro.runtime import ExecutorConfig, ServerlessExecutor
from tests.helpers_taxi import TAXI_SCHEMA, build_taxi_pipeline, make_taxi_data

REPO_ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture
def runner(catalog, fmt):
    with ServerlessExecutor(ExecutorConfig(max_workers=2)) as ex:
        yield Runner(catalog, fmt, ex)


@pytest.fixture
def seeded(catalog, fmt, rng):
    data = make_taxi_data(2000, rng)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, data)
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)}, message="seed")
    return data


def _run(runner, pipeline, branch, **kw):
    kw.setdefault("fusion", False)
    kw.setdefault("pushdown", False)
    kw.setdefault("cache", True)
    return runner.run(pipeline, branch=branch, **kw)


# ------------------------------------------------------------------ hits
def test_warm_rerun_executes_zero_stages(runner, catalog, fmt, seeded):
    cold = _run(runner, build_taxi_pipeline(), "b1")
    assert cold.stats["cache"] == {
        "enabled": True, "hits": 0, "stages_executed": 3, "bytes_saved": 0,
    }
    warm = _run(runner, build_taxi_pipeline(), "b2")
    assert warm.stats["cache"]["hits"] == 3
    assert warm.stats["cache"]["stages_executed"] == 0
    assert warm.stats["cache"]["bytes_saved"] > 0
    # restored artifacts are the SAME content-addressed snapshots
    assert warm.artifacts == cold.artifacts
    # expectations downstream of only-cached inputs are skipped but
    # reported with their audited verdict
    assert warm.checks == {"trips_expectation": True}
    # restored artifacts are queryable on the target branch
    out = fmt.read(fmt.load_snapshot(warm.artifacts["pickups"]))
    assert len(out["counts"]) > 0


def test_warm_rerun_same_branch_hits(runner, catalog, fmt, seeded):
    # re-running on the SAME branch still hits: the key is snapshot ids of
    # the scanned tables, not the branch head commit
    cold = _run(runner, build_taxi_pipeline(), "main")
    warm = _run(runner, build_taxi_pipeline(), "main")
    assert warm.stats["cache"]["stages_executed"] == 0
    assert warm.artifacts == cold.artifacts


def test_fused_plan_caches_as_one_unit(runner, catalog, fmt, seeded):
    cold = runner.run(build_taxi_pipeline(), branch="f1", cache=True)
    assert len(cold.plan.stages) == 1
    warm = runner.run(build_taxi_pipeline(), branch="f2", cache=True)
    assert warm.stats["cache"]["hits"] == 1
    assert warm.stats["cache"]["stages_executed"] == 0
    assert warm.artifacts == cold.artifacts


# -------------------------------------------------------------- dirty cone
def test_edited_node_recomputes_only_dirty_cone(runner, catalog, fmt, seeded):
    _run(runner, build_taxi_pipeline(), "b1")
    # edit ONE node (the expectation threshold is captured in its closure,
    # hence in its fingerprint): upstream trips and downstream-independent
    # pickups stay cached, only the expectation stage re-executes
    edited = build_taxi_pipeline(threshold=5.0)
    res = _run(runner, edited, "b2")
    assert res.stats["cache"]["hits"] == 2
    assert res.stats["cache"]["stages_executed"] == 1
    assert res.checks == {"trips_expectation": True}


def test_input_snapshot_change_invalidates_everything(runner, catalog, fmt, rng):
    snap = fmt.write("taxi_table", TAXI_SCHEMA, make_taxi_data(2000, rng))
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})
    _run(runner, build_taxi_pipeline(), "b1")
    # new data version: every stage's transitive fingerprint changes
    snap2 = fmt.write("taxi_table", TAXI_SCHEMA, make_taxi_data(2500, rng))
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap2)})
    res = _run(runner, build_taxi_pipeline(), "b2")
    assert res.stats["cache"]["hits"] == 0
    assert res.stats["cache"]["stages_executed"] == 3


def test_param_change_invalidates(runner, catalog, fmt, seeded):
    _run(runner, build_taxi_pipeline(), "b1", params={"x": 1})
    hit = _run(runner, build_taxi_pipeline(), "b2", params={"x": 1})
    assert hit.stats["cache"]["stages_executed"] == 0
    miss = _run(runner, build_taxi_pipeline(), "b3", params={"x": 2})
    assert miss.stats["cache"]["stages_executed"] == 3


# ------------------------------------------------------------------ bypass
def test_no_cache_bypasses_in_both_directions(runner, catalog, fmt, seeded):
    _run(runner, build_taxi_pipeline(), "b1", cache=False)
    # nothing was persisted by the cache-off run
    assert StageCacheRegistry(catalog.store).entries() == {}
    _run(runner, build_taxi_pipeline(), "b2", cache=True)
    # --no-cache forces a full recompute even with a populated cache
    res = _run(runner, build_taxi_pipeline(), "b3", cache=False)
    assert res.stats["cache"] == {
        "enabled": False, "hits": 0, "stages_executed": 3, "bytes_saved": 0,
    }


def test_replay_never_uses_the_cache(runner, catalog, fmt, seeded):
    pipeline = build_taxi_pipeline()
    first = runner.run(pipeline, branch="r1", cache=True)
    runner.run(pipeline, branch="r2", cache=True)  # cache is now warm
    again = runner.replay(pipeline, first.run_id)
    # bit-identical via genuine re-execution, not cache restore
    assert again.artifacts == first.artifacts


# ---------------------------------------------------------------- rollback
def test_failed_audit_rolls_back_cache_entries(runner, catalog, fmt, rng):
    # mean passenger_count ~2 < threshold 10 -> audit fails
    data = make_taxi_data(500, rng, mean_count=2.0)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, data)
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})
    with pytest.raises(ExpectationFailed):
        _run(runner, build_taxi_pipeline(), "main")
    # the trips stage itself succeeded, but NO entry may survive a failed
    # audit — otherwise a later run could restore unaudited artifacts
    assert StageCacheRegistry(catalog.store).entries() == {}
    rec = runner.registry.get(1)
    assert rec.stage_cache == {}
    # a subsequent run starts cold
    data_ok = make_taxi_data(2000, rng)
    snap_ok = fmt.write("taxi_table", TAXI_SCHEMA, data_ok)
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap_ok)})
    res = _run(runner, build_taxi_pipeline(), "main")
    assert res.stats["cache"]["hits"] == 0


# ------------------------------------------------------------ fingerprints
def _stage_fingerprints(catalog, fmt, params=None):
    pipeline = build_taxi_pipeline()
    key = catalog.table_key("taxi_table")
    snap = fmt.load_snapshot(key)
    logical = build_logical_plan(
        pipeline, external_schemas={"taxi_table": snap.schema}
    )
    ctx = RunContext("main", 1, dict(params or {}))
    plan = build_physical_plan(
        logical, {"taxi_table": snap},
        config=PlannerConfig(fusion=False, pushdown=False), ctx=ctx,
    )
    return [s.transitive_fingerprint for s in plan.stages]


def test_fingerprints_ignore_run_identity(catalog, fmt, seeded):
    pipeline = build_taxi_pipeline()
    key = catalog.table_key("taxi_table")
    snap = fmt.load_snapshot(key)
    logical = build_logical_plan(
        pipeline, external_schemas={"taxi_table": snap.schema}
    )
    plans = [
        build_physical_plan(
            logical, {"taxi_table": snap},
            config=PlannerConfig(fusion=False, pushdown=False),
            ctx=RunContext(branch, run_id, {}),
        )
        for branch, run_id in [("main", 1), ("feat", 99)]
    ]
    a = [s.transitive_fingerprint for s in plans[0].stages]
    b = [s.transitive_fingerprint for s in plans[1].stages]
    assert a == b  # branch/run_id must not bust the cache
    assert len(set(a)) == len(a)  # distinct stages, distinct identities


def test_fingerprint_stable_across_processes(catalog, fmt, seeded, tmp_path):
    """The cache key must be identity-free: a fresh interpreter building
    the same pipeline over the same lake derives the same fingerprints."""
    local = _stage_fingerprints(catalog, fmt)
    lake_root = catalog.store.root
    script = f"""
import sys
sys.path.insert(0, {str(REPO_ROOT / 'src')!r})
sys.path.insert(0, {str(REPO_ROOT)!r})
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
from repro.catalog import Catalog
from repro.io import ObjectStore
from repro.table import TableFormat
from tests.test_differential_cache import _stage_fingerprints
store = ObjectStore({str(lake_root)!r})
print("\\n".join(_stage_fingerprints(Catalog(store), TableFormat(store))))
"""
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=str(REPO_ROOT), timeout=240,
    )
    assert proc.returncode == 0, proc.stderr
    remote = proc.stdout.strip().splitlines()
    assert remote == local


# -------------------------------------------------------------- registry
def test_registry_roundtrip_and_invalidate(store):
    reg = StageCacheRegistry(store)
    entry = StageCacheEntry(
        fingerprint="abc123", outputs={"t": "key1"}, checks={"c": True},
        output_bytes=42, run_id=7, created_at=0.0,
    )
    reg.put(entry)
    assert reg.get("abc123") == entry
    assert reg.entries() == {"abc123": entry}
    reg.invalidate("abc123")
    assert reg.get("abc123") is None
    assert reg.get("missing") is None
