"""Cross-run differential artifact cache (FaaS & Furious, arXiv 2411.08203).

The reproducibility contract — same code on the same data produces
identical results (paper 4.4.1) — turned into a performance win: logical
nodes whose transitive fingerprint (node code + upstream node
fingerprints + input content hashes + params) matches a previously
audited run are planned around — restored from the object store or
elided — and only the dirty remainder executes.  Keying at node (not
fused-stage) granularity makes the cache survive planner-config changes:
the fusion-flip tests below are the acceptance criteria for that.
"""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import ExpectationFailed, PlannerConfig, Runner, build_logical_plan
from repro.core.physical import build_physical_plan, compute_node_fingerprints
from repro.core.runner import RunContext
from repro.core.snapshot import NodeCacheEntry, NodeCacheRegistry, StageCacheEntry, StageCacheRegistry
from repro.maintenance import compact_table
from repro.runtime import ExecutorConfig, ServerlessExecutor
from tests.helpers_taxi import TAXI_SCHEMA, build_taxi_pipeline, make_taxi_data

REPO_ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture
def runner(catalog, fmt):
    with ServerlessExecutor(ExecutorConfig(max_workers=2)) as ex:
        yield Runner(catalog, fmt, ex)


@pytest.fixture
def seeded(catalog, fmt, rng):
    data = make_taxi_data(2000, rng)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, data)
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)}, message="seed")
    return data


def _run(runner, pipeline, branch, **kw):
    kw.setdefault("fusion", False)
    kw.setdefault("pushdown", False)
    kw.setdefault("cache", True)
    return runner.run(pipeline, branch=branch, **kw)


# ------------------------------------------------------------------ hits
def test_warm_rerun_executes_zero_stages(runner, catalog, fmt, seeded):
    cold = _run(runner, build_taxi_pipeline(), "b1")
    assert cold.stats["cache"] == {
        "enabled": True, "hits": 0, "nodes_executed": 3,
        "stages_executed": 3, "rehydrated": 0, "elided": 0, "bytes_saved": 0,
    }
    warm = _run(runner, build_taxi_pipeline(), "b2")
    assert warm.stats["cache"]["hits"] == 3
    assert warm.stats["cache"]["nodes_executed"] == 0
    assert warm.stats["cache"]["stages_executed"] == 0
    assert warm.stats["cache"]["bytes_saved"] > 0
    # restored artifacts are the SAME content-addressed snapshots
    assert warm.artifacts == cold.artifacts
    # expectations downstream of only-cached inputs are skipped but
    # reported with their audited verdict
    assert warm.checks == {"trips_expectation": True}
    # restored artifacts are queryable on the target branch
    out = fmt.read(fmt.load_snapshot(warm.artifacts["pickups"]))
    assert len(out["counts"]) > 0


def test_warm_rerun_same_branch_hits(runner, catalog, fmt, seeded):
    # re-running on the SAME branch still hits: the key is content hashes
    # of the scanned tables, not the branch head commit
    cold = _run(runner, build_taxi_pipeline(), "main")
    warm = _run(runner, build_taxi_pipeline(), "main")
    assert warm.stats["cache"]["nodes_executed"] == 0
    assert warm.artifacts == cold.artifacts


def test_fused_plan_publishes_node_entries(runner, catalog, fmt, seeded):
    # a fused cold run materializes only the terminal artifact, so it
    # publishes entries for pickups + the expectation verdict; the interior
    # trips node (never materialized) is elided on the warm re-run
    cold = runner.run(build_taxi_pipeline(), branch="f1", cache=True)
    assert len(cold.plan.stages) == 1
    warm = runner.run(build_taxi_pipeline(), branch="f2", cache=True)
    assert warm.stats["cache"]["hits"] == 2
    assert warm.stats["cache"]["nodes_executed"] == 0
    assert warm.stats["cache"]["elided"] == 1  # trips: no consumer needs it
    assert warm.artifacts == cold.artifacts


# -------------------------------------------------------------- dirty cone
def test_edited_node_recomputes_only_dirty_cone(runner, catalog, fmt, seeded):
    _run(runner, build_taxi_pipeline(), "b1")
    # edit ONE node (the expectation threshold is captured in its closure,
    # hence in its fingerprint): upstream trips and downstream-independent
    # pickups stay cached, only the expectation re-executes
    edited = build_taxi_pipeline(threshold=5.0)
    res = _run(runner, edited, "b2")
    assert res.stats["cache"]["hits"] == 2
    assert res.stats["cache"]["nodes_executed"] == 1
    assert res.checks == {"trips_expectation": True}


def test_input_snapshot_change_invalidates_everything(runner, catalog, fmt, rng):
    snap = fmt.write("taxi_table", TAXI_SCHEMA, make_taxi_data(2000, rng))
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})
    _run(runner, build_taxi_pipeline(), "b1")
    # new data version: every node's transitive fingerprint changes
    snap2 = fmt.write("taxi_table", TAXI_SCHEMA, make_taxi_data(2500, rng))
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap2)})
    res = _run(runner, build_taxi_pipeline(), "b2")
    assert res.stats["cache"]["hits"] == 0
    assert res.stats["cache"]["nodes_executed"] == 3


def test_param_change_invalidates(runner, catalog, fmt, seeded):
    _run(runner, build_taxi_pipeline(), "b1", params={"x": 1})
    hit = _run(runner, build_taxi_pipeline(), "b2", params={"x": 1})
    assert hit.stats["cache"]["nodes_executed"] == 0
    miss = _run(runner, build_taxi_pipeline(), "b3", params={"x": 2})
    assert miss.stats["cache"]["nodes_executed"] == 3


# --------------------------------------- fusion-flip (acceptance criteria)
def test_fusion_flip_warm_run_executes_zero_nodes(runner, catalog, fmt, seeded):
    """The tentpole claim: node-keyed fingerprints make planner-config
    changes a warm run, not a cold start."""
    cold = runner.run(build_taxi_pipeline(), branch="c", fusion=True)
    # flip fusion off: previously a guaranteed full recompute (stage
    # grouping changed -> every stage fingerprint changed)
    flip = runner.run(
        build_taxi_pipeline(), branch="w1", fusion=False, pushdown=False
    )
    assert flip.stats["cache"]["nodes_executed"] == 0
    assert flip.artifacts["pickups"] == cold.artifacts["pickups"]
    # change max_stage_nodes (different fusion grouping): still warm
    logical_cfg = runner.run(
        build_taxi_pipeline(), branch="w2",
        planner_config=PlannerConfig(fusion=True, max_stage_nodes=1),
    )
    assert logical_cfg.stats["cache"]["nodes_executed"] == 0


def test_unfused_to_fused_flip_is_warm(runner, catalog, fmt, seeded):
    _run(runner, build_taxi_pipeline(), "c")  # isomorphic cold run
    warm = runner.run(build_taxi_pipeline(), branch="w", fusion=True)
    assert warm.stats["cache"]["nodes_executed"] == 0
    assert warm.stats["cache"]["hits"] == 3


def test_fused_chain_cut_at_cache_boundary(runner, catalog, fmt, seeded):
    """A fused chain whose prefix is cached becomes a rehydrate + a
    shorter stage over only the uncached suffix."""
    _run(runner, build_taxi_pipeline(), "c")  # caches trips/te/pickups
    edited = build_taxi_pipeline(threshold=5.0)  # dirty expectation only
    res = runner.run(edited, branch="w", fusion=True)
    assert res.stats["cache"]["nodes_executed"] == 1
    (stage,) = res.plan.stages
    assert stage.node_names == ("trips_expectation",)
    assert "trips" in stage.internal_inputs  # fed by rehydration
    assert "trips" in res.plan.rehydrate


# ------------------------------------------------------------------ bypass
def test_no_cache_bypasses_in_both_directions(runner, catalog, fmt, seeded):
    _run(runner, build_taxi_pipeline(), "b1", cache=False)
    # nothing was persisted by the cache-off run
    assert NodeCacheRegistry(catalog.store).entries() == {}
    _run(runner, build_taxi_pipeline(), "b2", cache=True)
    # --no-cache forces a full recompute even with a populated cache
    res = _run(runner, build_taxi_pipeline(), "b3", cache=False)
    assert res.stats["cache"] == {
        "enabled": False, "hits": 0, "nodes_executed": 3,
        "stages_executed": 3, "rehydrated": 0, "elided": 0, "bytes_saved": 0,
    }


def test_replay_never_uses_the_cache(runner, catalog, fmt, seeded):
    pipeline = build_taxi_pipeline()
    first = runner.run(pipeline, branch="r1", cache=True)
    runner.run(pipeline, branch="r2", cache=True)  # cache is now warm
    again = runner.replay(pipeline, first.run_id)
    # bit-identical via genuine re-execution, not cache restore
    assert again.artifacts == first.artifacts


# ---------------------------------------------------------------- rollback
def test_failed_audit_rolls_back_cache_entries(runner, catalog, fmt, rng):
    # mean passenger_count ~2 < threshold 10 -> audit fails
    data = make_taxi_data(500, rng, mean_count=2.0)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, data)
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})
    with pytest.raises(ExpectationFailed):
        _run(runner, build_taxi_pipeline(), "main")
    # the trips stage itself succeeded, but NO entry may survive a failed
    # audit — otherwise a later run could restore unaudited artifacts
    assert StageCacheRegistry(catalog.store).entries() == {}
    rec = runner.registry.get(1)
    assert rec.stage_cache == {}
    # a subsequent run starts cold
    data_ok = make_taxi_data(2000, rng)
    snap_ok = fmt.write("taxi_table", TAXI_SCHEMA, data_ok)
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap_ok)})
    res = _run(runner, build_taxi_pipeline(), "main")
    assert res.stats["cache"]["hits"] == 0


# ----------------------------------------------- compaction (content hash)
def test_compaction_rewrite_keeps_cache_warm(runner, catalog, fmt, seeded):
    """Compacting a table rewrites shards in a new commit (new snapshot
    id, bit-identical data) — input identity keys on the table content
    hash, so the warm re-run still executes 0 nodes."""
    cold = _run(runner, build_taxi_pipeline(), "main")
    before = fmt.load_snapshot(catalog.table_key("taxi_table"))
    report = compact_table(catalog, fmt, "taxi_table", target_rows=1000)
    assert report.shards_merged > 0
    after = fmt.load_snapshot(catalog.table_key("taxi_table"))
    assert after.snapshot_id != before.snapshot_id
    assert fmt.content_fingerprint(after) == fmt.content_fingerprint(before)
    warm = _run(runner, build_taxi_pipeline(), "main")
    assert warm.stats["cache"]["nodes_executed"] == 0
    assert warm.artifacts == cold.artifacts


# --------------------------------------------------- legacy stage entries
def test_legacy_stage_entries_upgrade_one_way(runner, catalog, fmt, seeded):
    """A lake whose cache was written by the stage-keyed scheme (PR 1)
    must warm up, not cold-start: matched legacy entries are adopted into
    node-keyed entries and the stage-keyed originals retired."""
    import time as _time

    pipeline = build_taxi_pipeline()
    cold = _run(runner, pipeline, "b1", cache=False)  # nothing cached
    reg = NodeCacheRegistry(catalog.store)
    assert reg.entries() == {}

    # simulate the PR 1 on-disk state: stage-keyed entries in `stagecache`
    snap = fmt.load_snapshot(catalog.table_key("taxi_table"))
    logical = build_logical_plan(
        pipeline, external_schemas={"taxi_table": snap.schema}
    )
    plan = build_physical_plan(
        logical, {"taxi_table": snap},
        config=PlannerConfig(fusion=False, pushdown=False),
        ctx=RunContext("main", 1, {}),
    )
    for stage in plan.stages:
        reg.put_legacy(NodeCacheEntry(
            fingerprint=stage.transitive_fingerprint,
            outputs={n: cold.artifacts[n] for n in stage.outputs},
            checks={c: True for c in stage.checks},
            output_bytes=128,
            run_id=cold.run_id,
            created_at=_time.time(),
        ))
    assert catalog.store.list_refs("stagecache")

    warm = _run(runner, pipeline, "b2")  # same config as the legacy writer
    assert warm.stats["cache"]["nodes_executed"] == 0
    assert warm.artifacts == cold.artifacts
    # one-way upgrade: stage namespace drained, node entries in its place
    assert catalog.store.list_refs("stagecache") == {}
    assert {e.node for e in reg.entries().values()} == {
        "trips", "trips_expectation", "pickups",
    }
    # the adopted entries are fusion-config-proof from now on
    fused = runner.run(pipeline, branch="b3", fusion=True)
    assert fused.stats["cache"]["nodes_executed"] == 0


def test_failed_audit_leaves_legacy_adoption_unapplied(runner, catalog, fmt, rng):
    """Write-after-audit covers re-keying too: a failed run that matched a
    legacy stage entry during planning must leave the registry exactly as
    it found it — no node entries, legacy originals intact."""
    import time as _time

    # data whose mean passenger_count (~2) fails the threshold-10 audit
    data = make_taxi_data(800, rng, mean_count=2.0)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, data)
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})
    pipeline = build_taxi_pipeline()
    reg = NodeCacheRegistry(catalog.store)

    # legacy entry for the trips stage only (the part that would succeed)
    snap = fmt.load_snapshot(catalog.table_key("taxi_table"))
    logical = build_logical_plan(
        pipeline, external_schemas={"taxi_table": snap.schema}
    )
    plan = build_physical_plan(
        logical, {"taxi_table": snap},
        config=PlannerConfig(fusion=False, pushdown=False),
        ctx=RunContext("main", 1, {}),
    )
    trips_stage = next(s for s in plan.stages if s.node_names == ("trips",))
    # a real trips artifact (the trips node is identical across threshold
    # variants — the threshold lives in the expectation's closure), from a
    # run whose relaxed audit passes
    ok = _run(runner, build_taxi_pipeline(threshold=1.0), "ok", cache=False)
    trips_key = ok.artifacts["trips"]
    reg.put_legacy(NodeCacheEntry(
        fingerprint=trips_stage.transitive_fingerprint,
        outputs={"trips": trips_key},
        checks={},
        output_bytes=64,
        run_id=1,
        created_at=_time.time(),
    ))

    with pytest.raises(ExpectationFailed):
        _run(runner, pipeline, "main")
    # no nodecache refs appeared, the legacy entry survived untouched
    assert catalog.store.list_refs("nodecache") == {}
    assert len(catalog.store.list_refs("stagecache")) == 1


# ------------------------------------------------------------ fingerprints
def test_node_fingerprints_ignore_fusion_config(catalog, fmt, seeded):
    pipeline = build_taxi_pipeline()
    snap = fmt.load_snapshot(catalog.table_key("taxi_table"))
    logical = build_logical_plan(
        pipeline, external_schemas={"taxi_table": snap.schema}
    )
    fps = [
        build_physical_plan(
            logical, {"taxi_table": snap}, config=cfg,
            ctx=RunContext("main", 1, {}),
        ).node_fingerprints
        for cfg in (
            PlannerConfig(fusion=True),
            PlannerConfig(fusion=False, pushdown=False),
            PlannerConfig(fusion=True, max_stage_nodes=1),
        )
    ]
    assert fps[0] == fps[1] == fps[2]
    assert len(set(fps[0].values())) == 3  # distinct nodes, distinct keys


def _stage_fingerprints(catalog, fmt, params=None):
    pipeline = build_taxi_pipeline()
    key = catalog.table_key("taxi_table")
    snap = fmt.load_snapshot(key)
    logical = build_logical_plan(
        pipeline, external_schemas={"taxi_table": snap.schema}
    )
    ctx = RunContext("main", 1, dict(params or {}))
    plan = build_physical_plan(
        logical, {"taxi_table": snap},
        config=PlannerConfig(fusion=False, pushdown=False), ctx=ctx,
    )
    return [s.transitive_fingerprint for s in plan.stages]


def test_fingerprints_ignore_run_identity(catalog, fmt, seeded):
    pipeline = build_taxi_pipeline()
    key = catalog.table_key("taxi_table")
    snap = fmt.load_snapshot(key)
    logical = build_logical_plan(
        pipeline, external_schemas={"taxi_table": snap.schema}
    )
    plans = [
        build_physical_plan(
            logical, {"taxi_table": snap},
            config=PlannerConfig(fusion=False, pushdown=False),
            ctx=RunContext(branch, run_id, {}),
        )
        for branch, run_id in [("main", 1), ("feat", 99)]
    ]
    a = [s.transitive_fingerprint for s in plans[0].stages]
    b = [s.transitive_fingerprint for s in plans[1].stages]
    assert a == b  # branch/run_id must not bust the cache
    assert len(set(a)) == len(a)  # distinct stages, distinct identities


def test_fingerprint_stable_across_processes(catalog, fmt, seeded, tmp_path):
    """The cache key must be identity-free: a fresh interpreter building
    the same pipeline over the same lake derives the same fingerprints."""
    local = _stage_fingerprints(catalog, fmt)
    lake_root = catalog.store.root
    script = f"""
import sys
sys.path.insert(0, {str(REPO_ROOT / 'src')!r})
sys.path.insert(0, {str(REPO_ROOT)!r})
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
from repro.catalog import Catalog
from repro.io import ObjectStore
from repro.table import TableFormat
from tests.test_differential_cache import _stage_fingerprints
store = ObjectStore({str(lake_root)!r})
print("\\n".join(_stage_fingerprints(Catalog(store), TableFormat(store))))
"""
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=str(REPO_ROOT), timeout=240,
    )
    assert proc.returncode == 0, proc.stderr
    remote = proc.stdout.strip().splitlines()
    assert remote == local


# -------------------------------------------------------------- registry
def test_registry_roundtrip_and_invalidate(store):
    reg = StageCacheRegistry(store)
    entry = StageCacheEntry(
        fingerprint="abc123", outputs={"t": "key1"}, checks={"c": True},
        output_bytes=42, run_id=7, created_at=0.0,
    )
    reg.put(entry)
    assert reg.get("abc123") == entry
    assert reg.entries() == {"abc123": entry}
    reg.invalidate("abc123")
    assert reg.get("abc123") is None
    assert reg.get("missing") is None
