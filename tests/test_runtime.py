"""Serverless runtime: warm cache, retries, speculation, elasticity."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (
    CostModel,
    ExecutorConfig,
    FaultInjector,
    FunctionSpec,
    ServerlessExecutor,
    TaskFailure,
    WarmFunctionCache,
)
from repro.runtime.resources import tier_histogram


def test_warm_cache_cold_then_warm():
    cache = WarmFunctionCache()
    spec = FunctionSpec(name="square", fn=lambda x: x * x)
    x = jnp.arange(8.0)
    f1 = cache.get_or_compile(spec, x)
    np.testing.assert_allclose(np.asarray(f1(x)), np.arange(8.0) ** 2)
    f2 = cache.get_or_compile(spec, x)
    assert f1 is f2
    assert cache.stats.cold_starts == 1 and cache.stats.warm_hits == 1


def test_warm_cache_new_shape_is_cold():
    cache = WarmFunctionCache()
    spec = FunctionSpec(name="sum", fn=lambda x: x.sum())
    cache.get_or_compile(spec, jnp.ones(4))
    cache.get_or_compile(spec, jnp.ones(8))  # different shape -> cold
    assert cache.stats.cold_starts == 2


def test_fingerprint_distinguishes_config():
    f = lambda x: x + 1
    a = FunctionSpec(name="n", fn=f, static_config={"k": 1})
    b = FunctionSpec(name="n", fn=f, static_config={"k": 2})
    assert a.fingerprint != b.fingerprint


def test_executor_runs_and_records():
    with ServerlessExecutor(ExecutorConfig(max_workers=2)) as ex:
        spec = FunctionSpec(name="add", fn=lambda a, b: a + b)
        out = ex.run(spec, jnp.ones(4), jnp.ones(4))
        np.testing.assert_allclose(np.asarray(out), 2.0)
        assert ex.stats()["tasks"] == 1


def test_executor_retries_after_injected_crash():
    inj = FaultInjector(failures={"flaky": 2})
    with ServerlessExecutor(
        ExecutorConfig(max_retries=3, retry_backoff_s=0.001),
        fault_injector=inj,
    ) as ex:
        spec = FunctionSpec(name="flaky", fn=lambda x: x * 2)
        out = ex.run(spec, jnp.ones(2))
        np.testing.assert_allclose(np.asarray(out), 2.0)
        assert ex.stats()["retries"] == 2  # two crashed attempts


def test_executor_exhausted_retries_fail():
    inj = FaultInjector(failures={"doomed": 99})
    with ServerlessExecutor(
        ExecutorConfig(max_retries=1, retry_backoff_s=0.001),
        fault_injector=inj,
    ) as ex:
        spec = FunctionSpec(name="doomed", fn=lambda x: x)
        with pytest.raises(TaskFailure):
            ex.run(spec, jnp.ones(2))


def test_straggler_speculation_first_result_wins():
    calls = {"n": 0}

    def slow_once(x):
        # non-jit python fn: first call sleeps (straggler), duplicate is fast
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.4)
        return np.asarray(x) + 1

    cfg = ExecutorConfig(
        max_workers=4,
        speculation_factor=2.0,
        speculation_min_samples=3,
    )
    with ServerlessExecutor(cfg) as ex:
        specs = [
            (FunctionSpec(name=f"t{i}", fn=slow_once if i == 0 else (lambda x: np.asarray(x) + 1), jit=False), (np.ones(2),))
            for i in range(6)
        ]
        results = ex.map_with_speculation(specs)
        for r in results:
            np.testing.assert_allclose(r, 2.0)
        # the straggler was speculated (or finished first — either way all done)
        assert len(results) == 6


def test_speculation_duplicate_and_original_both_fail():
    """Regression: when a speculated duplicate AND the original both exhaust
    retries, exactly one TaskFailure surfaces and the attempt ledger counts
    attempts across both containers (no double-retry, no lost failure)."""
    # generous crash delay: speculation must launch within the original's
    # first attempt (2 x 0.3s window) even on a loaded CI machine
    inj = FaultInjector(
        failures={"doomed": 99}, crash_delay_s={"doomed": 0.3}
    )
    cfg = ExecutorConfig(
        max_workers=4,
        max_retries=1,  # 2 attempts per racer
        retry_backoff_s=0.001,
        speculation_factor=1.5,
        speculation_min_samples=2,
    )
    ok = FunctionSpec(name="ok", fn=lambda x: np.asarray(x) + 1, jit=False)
    with ServerlessExecutor(cfg, fault_injector=inj) as ex:
        specs = [
            (FunctionSpec(name="doomed", fn=lambda x: x, jit=False), (np.ones(2),))
        ] + [(ok, (np.ones(2),)) for _ in range(4)]
        with pytest.raises(TaskFailure):
            ex.map_with_speculation(specs)
        doomed_records = [r for r in ex.records if r.name == "doomed"]
        # the doomed task was speculated: original + duplicate both recorded
        assert len(doomed_records) == 2
        assert ex.stats()["speculated"] == 1
        # attempts accounted across duplicates: 2 racers x 2 attempts each,
        # and the injector's shared per-name ledger agrees
        assert sum(r.attempts for r in doomed_records) == 4
        assert inj.seen["doomed"] == 4


def test_speculation_duplicate_succeeds_after_original_fails():
    """Regression: a racer failing must not sink the task while its twin can
    still succeed — first *successful* finisher wins."""
    # generous crash delay: the duplicate must launch + succeed within the
    # original's single slow failure even on a loaded CI machine
    inj = FaultInjector(
        failures={"flaky": 1}, crash_delay_s={"flaky": 0.5}
    )
    cfg = ExecutorConfig(
        max_workers=4,
        max_retries=0,  # single attempt per racer: original fails, dup wins
        retry_backoff_s=0.001,
        speculation_factor=1.5,
        speculation_min_samples=2,
    )
    ok = FunctionSpec(name="ok", fn=lambda x: np.asarray(x) + 1, jit=False)
    with ServerlessExecutor(cfg, fault_injector=inj) as ex:
        specs = [
            (FunctionSpec(name="flaky", fn=lambda x: np.asarray(x) + 1, jit=False),
             (np.ones(2),))
        ] + [(ok, (np.ones(2),)) for _ in range(4)]
        results = ex.map_with_speculation(specs)
        for r in results:
            np.testing.assert_allclose(r, 2.0)
        assert inj.seen["flaky"] == 2  # failed original + successful duplicate


class _CallState:
    """Captured by test task closures.  A plain class reference has a
    stable repr (unlike a mutated dict/list), so mutating its attributes
    does not perturb the FunctionSpec fingerprint — which is exactly what
    lets the executor accumulate latency history across calls."""

    calls = 0


def test_single_task_speculation_from_latency_history():
    """The submit()/run() path has no siblings; after enough completed
    runs of the same fingerprint, a straggler gets a backup request based
    on its own latency history and the fast duplicate wins."""
    _CallState.calls = 0

    def task(x):
        _CallState.calls += 1
        if _CallState.calls == 4:  # the 4th invocation stalls (straggler)
            time.sleep(0.8)
        return np.asarray(x) + 1

    cfg = ExecutorConfig(
        max_workers=2, speculation_factor=3.0, speculation_min_samples=3
    )
    spec = FunctionSpec(name="stage", fn=task, jit=False)
    with ServerlessExecutor(cfg) as ex:
        for _ in range(3):  # prior runs build the per-fingerprint baseline
            ex.run(spec, np.ones(2))
        assert ex.stats()["speculated"] == 0
        t0 = time.perf_counter()
        out = ex.run(spec, np.ones(2))
        elapsed = time.perf_counter() - t0
        np.testing.assert_allclose(np.asarray(out), 2.0)
        assert ex.stats()["speculated"] == 1
        # the duplicate finished long before the 0.8 s straggler would have
        assert elapsed < 0.5


def test_single_task_without_history_never_speculates():
    def slowish(x):
        time.sleep(0.05)
        return np.asarray(x) + 1

    cfg = ExecutorConfig(
        max_workers=2, speculation_factor=1.01, speculation_min_samples=3
    )
    spec = FunctionSpec(name="fresh", fn=slowish, jit=False)
    with ServerlessExecutor(cfg) as ex:
        ex.run(spec, np.ones(2))
        ex.run(spec, np.ones(2))  # still below min_samples
        assert ex.stats()["speculated"] == 0


def test_single_task_speculation_all_racers_fail():
    """When the speculated duplicate AND the original both exhaust their
    retries on the run() path, exactly one TaskFailure surfaces with the
    attempt ledger accounted across both containers."""
    inj = FaultInjector(crash_delay_s={"flaky": 0.3})
    cfg = ExecutorConfig(
        max_workers=2,
        max_retries=1,  # 2 attempts per racer
        retry_backoff_s=0.001,
        speculation_factor=1.5,
        speculation_min_samples=2,
    )
    spec = FunctionSpec(name="flaky", fn=lambda x: np.asarray(x) + 1, jit=False)
    with ServerlessExecutor(cfg, fault_injector=inj) as ex:
        for _ in range(2):  # healthy warm-up runs build the baseline
            ex.run(spec, np.ones(2))
        inj.failures["flaky"] = 99  # now every attempt crashes (slowly)
        with pytest.raises(TaskFailure):
            ex.run(spec, np.ones(2))
        assert ex.stats()["speculated"] == 1
        failed = [r for r in ex.records if r.name == "flaky" and r.duration_s == 0.0]
        assert sum(r.attempts for r in failed) == 4  # 2 racers x 2 attempts


def test_submit_speculative_future_api_and_concurrent_speculation():
    """The wave scheduler's primitive: a future-returning run() whose
    straggler backup is a timer, so MANY concurrently submitted tasks
    each keep their own speculation (no blocking wait per task)."""
    _CallState.calls = 0

    def task(x):
        _CallState.calls += 1
        if _CallState.calls == 4:  # one straggler among the submissions
            time.sleep(0.8)
        return np.asarray(x) + 1

    cfg = ExecutorConfig(
        max_workers=4, speculation_factor=3.0, speculation_min_samples=3
    )
    spec = FunctionSpec(name="stage", fn=task, jit=False)
    with ServerlessExecutor(cfg) as ex:
        for _ in range(3):  # build the per-fingerprint baseline
            ex.submit_speculative(spec, np.ones(2)).result()
        t0 = time.perf_counter()
        futs = [ex.submit_speculative(spec, np.ones(2)) for _ in range(3)]
        for f in futs:
            np.testing.assert_allclose(np.asarray(f.result()), 2.0)
        # the straggler's backup won: nobody waited out the 0.8 s sleep
        assert time.perf_counter() - t0 < 0.6
        assert ex.stats()["speculated"] >= 1


def test_submit_stage_lane_does_not_starve_containers():
    """Stage drivers block on container futures from their own lane — a
    full wave of drivers must still make progress."""
    cfg = ExecutorConfig(max_workers=2, max_concurrent_stages=8)
    spec = FunctionSpec(name="leaf", fn=lambda x: np.asarray(x) * 2, jit=False)
    with ServerlessExecutor(cfg) as ex:

        def driver(i):
            return np.asarray(ex.run(spec, np.full(4, i))).sum()

        futs = [ex.submit_stage(driver, i) for i in range(8)]
        assert [f.result(timeout=30) for f in futs] == [i * 8 for i in range(8)]


def test_cost_model_tiers():
    cm = CostModel()
    small = cm.request_for_scan(10 << 20)  # 10MB scan
    big = cm.request_for_scan(20 << 30)  # 20GB scan
    assert small.memory_gb == 1
    assert big.memory_gb > small.memory_gb
    hist = tier_histogram([small, small, big])
    assert hist[small.memory_gb] == 2


def test_cost_model_param_jobs_scale_with_devices():
    cm = CostModel()
    one = cm.request_for_params(4 << 30, 1 << 30, devices=1)
    many = cm.request_for_params(4 << 30, 1 << 30, devices=16)
    assert many.memory_gb < one.memory_gb  # sharding shrinks per-device need
    assert many.devices == 16
