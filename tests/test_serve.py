"""Serving engine: batched generation, slot reuse, greedy correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import LM
from repro.serve import Request, ServeConfig, ServeEngine


@pytest.fixture(scope="module")
def served():
    cfg = get_smoke_config("yi_6b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _greedy_oracle(model, params, prompt, n_new):
    """Greedy generation via full forward passes (slow but exact)."""
    toks = list(prompt)
    for _ in range(n_new):
        logits = model.forward(params, jnp.asarray([toks], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_single_request_matches_forward_oracle(served):
    model, params = served
    prompt = np.array([1, 2, 3], np.int32)
    engine = ServeEngine(model, params, ServeConfig(max_batch=2, max_len=32))
    req = Request(prompt=prompt, max_new_tokens=5)
    engine.generate([req])
    oracle = _greedy_oracle(model, params, prompt.tolist(), 5)
    assert req.generated == oracle


def test_batched_requests_isolated(served):
    """Concurrent requests must produce the same outputs as solo runs."""
    model, params = served
    prompts = [np.array(p, np.int32) for p in ([5, 6], [9, 8, 7], [11])]
    solo = []
    for p in prompts:
        engine = ServeEngine(model, params, ServeConfig(max_batch=2, max_len=32))
        r = Request(prompt=p, max_new_tokens=4)
        engine.generate([r])
        solo.append(r.generated)
    engine = ServeEngine(model, params, ServeConfig(max_batch=2, max_len=32))
    batched = [Request(prompt=p, max_new_tokens=4) for p in prompts]
    engine.generate(batched)  # 3 requests, 2 slots -> queueing + slot reuse
    for r, s in zip(batched, solo):
        assert r.generated == s


def test_slot_reuse_after_completion(served):
    model, params = served
    engine = ServeEngine(model, params, ServeConfig(max_batch=1, max_len=32))
    a = Request(prompt=np.array([1], np.int32), max_new_tokens=3)
    b = Request(prompt=np.array([2], np.int32), max_new_tokens=3)
    engine.generate([a, b])
    assert len(a.generated) == 3 and len(b.generated) == 3
    # b through a fresh engine must match (slot state fully reset)
    engine2 = ServeEngine(model, params, ServeConfig(max_batch=1, max_len=32))
    b2 = Request(prompt=np.array([2], np.int32), max_new_tokens=3)
    engine2.generate([b2])
    assert b.generated == b2.generated


def test_recurrent_arch_single_slot():
    cfg = get_smoke_config("xlstm_350m")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        ServeEngine(model, params, ServeConfig(max_batch=2, max_len=16))
    engine = ServeEngine(model, params, ServeConfig(max_batch=1, max_len=16))
    r = Request(prompt=np.array([3, 4], np.int32), max_new_tokens=3)
    engine.generate([r])
    assert len(r.generated) == 3
