"""Chunked online-softmax ("flash in XLA") vs dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline CI: deterministic fallback shim
    from tests._hypothesis_compat import given, settings
    from tests._hypothesis_compat import strategies as st

from repro.models.attention import _sdpa, _sdpa_chunked


def qkv(rng, b, h, hkv, s, d):
    return (
        jnp.asarray(rng.standard_normal((b, h, s, d)).astype(np.float32)),
        jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32)),
        jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32)),
    )


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_chunked_equals_dense_causal(chunk, rng):
    q, k, v = qkv(rng, 2, 4, 2, 256, 16)
    dense = _sdpa(q, k, v, causal=True, window=None)
    chunked = _sdpa_chunked(q, k, v, causal=True, window=None, chunk=chunk)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), rtol=2e-3, atol=2e-3)


def test_chunked_with_window(rng):
    q, k, v = qkv(rng, 1, 2, 1, 192, 16)
    dense = _sdpa(q, k, v, causal=True, window=50)
    chunked = _sdpa_chunked(q, k, v, causal=True, window=50, chunk=64)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), rtol=2e-3, atol=2e-3)


def test_chunked_pads_non_divisible(rng):
    q, k, v = qkv(rng, 1, 2, 2, 100, 16)  # 100 % 64 != 0 -> padded
    dense = _sdpa(q, k, v, causal=True, window=None)
    chunked = _sdpa_chunked(q, k, v, causal=True, window=None, chunk=64)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), rtol=2e-3, atol=2e-3)


def test_mla_chunked_equals_dense(rng):
    import dataclasses

    from repro.models.mla import MLAConfig, init_mla, mla_train

    cfg_dense = MLAConfig(
        d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, chunk=None,
        compute_dtype=jnp.float32,
    )
    cfg_chunk = dataclasses.replace(cfg_dense, chunk=32)
    p = init_mla(jax.random.PRNGKey(0), cfg_dense)
    x = jnp.asarray(rng.standard_normal((2, 96, 64)).astype(np.float32))
    pos = jnp.arange(96)
    a = mla_train(p, cfg_dense, x, pos)
    b = mla_train(p, cfg_chunk, x, pos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-3, atol=3e-3)


@given(
    s=st.integers(16, 200),
    chunk=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_property_chunked_matches_dense(s, chunk, seed):
    rng = np.random.default_rng(seed)
    q, k, v = qkv(rng, 1, 2, 2, s, 8)
    dense = _sdpa(q, k, v, causal=True, window=None)
    chunked = _sdpa_chunked(q, k, v, causal=True, window=None, chunk=chunk)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), rtol=5e-3, atol=5e-3)
