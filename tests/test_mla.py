"""MLA: cache compression ratio + weight-absorbed decode correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mla import (
    MLAConfig,
    init_mla,
    init_mla_cache,
    mla_decode_step,
    mla_train,
)

CFG = MLAConfig(
    d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, chunk=None,
    compute_dtype=jnp.float32,
)


def test_absorbed_decode_matches_train_attention(rng):
    """Stepping token-by-token through the absorbed decode reproduces the
    train-path attention outputs exactly (pure MLA, no MoE drops)."""
    p = init_mla(jax.random.PRNGKey(0), CFG)
    b, s = 2, 7
    x = jnp.asarray(rng.standard_normal((b, s, 64)).astype(np.float32))
    full = mla_train(p, CFG, x, jnp.arange(s))
    cache = init_mla_cache(CFG, b, 16, dtype=jnp.float32)
    for t in range(s):
        out, cache = mla_decode_step(
            p, CFG, x[:, t : t + 1], cache, jnp.full((b,), t, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(out[:, 0]), np.asarray(full[:, t]), rtol=2e-4, atol=2e-5
        )


def test_cache_is_compressed():
    """The decode cache stores rank-(dkv + rope) per token, NOT per-head
    K/V — the MLA selling point (~14x at the deepseek config)."""
    cache = init_mla_cache(CFG, batch=1, max_len=10, dtype=jnp.float32)
    latent = cache["c_kv"].size + cache["k_rope"].size
    per_head_kv = 2 * CFG.n_heads * 10 * (CFG.qk_nope_dim + CFG.qk_rope_dim)
    assert latent < per_head_kv / 2
    # deepseek-scale ratio: (512+64) vs 2*128*(128+64) -> 85x
    ds = MLAConfig(d_model=7168, n_heads=128)
    ds_latent = ds.kv_lora_rank + ds.qk_rope_dim
    ds_mha = 2 * ds.n_heads * (ds.qk_nope_dim + ds.qk_rope_dim)
    assert ds_mha / ds_latent > 50


def test_decode_ragged_lengths(rng):
    """Different sequences at different lengths stay independent."""
    p = init_mla(jax.random.PRNGKey(1), CFG)
    x = jnp.asarray(rng.standard_normal((2, 1, 64)).astype(np.float32))
    cache = init_mla_cache(CFG, 2, 8, dtype=jnp.float32)
    lengths = jnp.array([0, 3], jnp.int32)
    out, cache2 = mla_decode_step(p, CFG, x, cache, lengths)
    assert bool(jnp.isfinite(out).all())
    # row 0 wrote at position 0; row 1 at position 3
    assert float(jnp.abs(cache2["c_kv"][0, 0]).sum()) > 0
    assert float(jnp.abs(cache2["c_kv"][0, 3]).sum()) == 0
    assert float(jnp.abs(cache2["c_kv"][1, 3]).sum()) > 0
    assert float(jnp.abs(cache2["c_kv"][1, 0]).sum()) == 0
