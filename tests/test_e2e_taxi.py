"""End-to-end: the paper's working example through the full system.

Covers Fig. 3 (three abstraction layers), Fig. 4 (branch semantics),
4.4.2 (fusion + pushdown), 4.4.1/4.6 (replay), and the audit rollback.
"""
import numpy as np
import pytest

from repro.core import ExpectationFailed, Runner
from repro.runtime import ExecutorConfig, ServerlessExecutor
from tests.helpers_taxi import (
    APRIL_1,
    TAXI_SCHEMA,
    build_taxi_pipeline,
    make_taxi_data,
)


@pytest.fixture
def runner(catalog, fmt):
    with ServerlessExecutor(ExecutorConfig(max_workers=2)) as ex:
        yield Runner(catalog, fmt, ex)


@pytest.fixture
def seeded(catalog, fmt, rng):
    data = make_taxi_data(2000, rng)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, data)
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)}, message="seed")
    return data


def _expected_pickups(data):
    mask = data["pickup_at"] >= APRIL_1
    src = data["pickup_location_id"][mask]
    dst = data["dropoff_location_id"][mask]
    pairs, counts = np.unique(np.stack([src, dst]), axis=1, return_counts=True)
    return pairs, counts


def test_full_run_on_feature_branch(runner, catalog, fmt, seeded):
    pipeline = build_taxi_pipeline()
    result = runner.run(pipeline, branch="feat_1")
    assert result.ok
    assert result.checks == {"trips_expectation": True}
    # pickups visible on feat_1, absent from main (sandboxing)
    assert "pickups" in catalog.tables(branch="feat_1")
    assert "pickups" not in catalog.tables(branch="main")
    # ephemeral branch cleaned up
    assert all(not b.startswith("run_") for b in catalog.branches())
    # correctness vs numpy oracle
    out = fmt.read(fmt.load_snapshot(result.artifacts["pickups"]))
    pairs, counts = _expected_pickups(seeded)
    assert len(out["counts"]) == pairs.shape[1]
    assert (np.sort(out["counts"])[::-1] == out["counts"]).all()  # ORDER BY DESC
    got = {
        (int(a), int(b)): int(c)
        for a, b, c in zip(
            out["pickup_location_id"], out["dropoff_location_id"], out["counts"]
        )
    }
    expect = {
        (int(pairs[0, i]), int(pairs[1, i])): int(counts[i])
        for i in range(pairs.shape[1])
    }
    assert got == expect


def test_fused_plan_is_single_stage(runner, seeded):
    result = runner.run(build_taxi_pipeline(), branch="f2")
    assert len(result.plan.stages) == 1  # trips+expectation+pickups fused
    stage = result.plan.stages[0]
    assert set(stage.node_names) == {"trips", "trips_expectation", "pickups"}
    # only the terminal artifact materializes; trips stays in memory...
    assert stage.outputs == ("trips",) or "pickups" in stage.outputs


def test_pushdown_prunes_shards(runner, seeded):
    result = runner.run(build_taxi_pipeline(), branch="f3")
    scan = result.plan.stages[0].scans["taxi_table"]
    assert scan.predicates  # pickup_at >= '2019-04-01' was pushed
    assert scan.plan.pruned_shards > 0  # date-sorted shards pruned
    assert scan.plan.rows_to_read < 2000


def test_isomorphic_equals_fused_results(catalog, fmt, seeded):
    # cache=False: this test is about genuine recompute equivalence — with
    # the (default) node cache on, the second run would plan around the
    # first run's cached nodes instead of re-executing them
    with ServerlessExecutor(ExecutorConfig(max_workers=2)) as ex:
        runner = Runner(catalog, fmt, ex)
        fused = runner.run(
            build_taxi_pipeline(), branch="fa", fusion=True, cache=False
        )
        naive = runner.run(
            build_taxi_pipeline(), branch="fb", fusion=False, pushdown=False,
            cache=False,
        )
    assert len(naive.plan.stages) == 3  # the "three separate executions"
    assert len(fused.plan.stages) == 1
    a = fmt.read(fmt.load_snapshot(fused.artifacts["pickups"]))
    b = fmt.read(fmt.load_snapshot(naive.artifacts["pickups"]))
    for col in a:
        np.testing.assert_array_equal(a[col], b[col])
    # fusion avoids spillover: fewer bytes through the object store
    assert fused.stats["io"]["bytes_written"] < naive.stats["io"]["bytes_written"]


def test_expectation_failure_rolls_back(runner, catalog, fmt, rng):
    # passenger_count mean ~2 < threshold 10 -> audit must fail
    data = make_taxi_data(500, rng, mean_count=2.0)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, data)
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})
    before = catalog.head("main").commit_id
    with pytest.raises(ExpectationFailed):
        runner.run(build_taxi_pipeline(), branch="main")
    # nothing merged, no ephemeral branches left behind
    assert catalog.head("main").commit_id == before
    assert "pickups" not in catalog.tables(branch="main")
    assert all(not b.startswith("run_") for b in catalog.branches())


def test_replay_is_bit_identical(runner, catalog, fmt, seeded):
    pipeline = build_taxi_pipeline()
    first = runner.run(pipeline, branch="feat_r")
    # new data lands on the branch after the run...
    rng2 = np.random.default_rng(99)
    newer = fmt.write("taxi_table", TAXI_SCHEMA, make_taxi_data(100, rng2))
    catalog.commit("feat_r", {"taxi_table": fmt.manifest_key(newer)})
    # ...but replay pins the ORIGINAL base commit: identical snapshot ids
    again = runner.replay(pipeline, first.run_id)
    assert again.artifacts == first.artifacts  # content-addressed equality
    assert again.merged_commit is None  # replay never moves branches


def test_replay_rejects_changed_code(runner, catalog, fmt, seeded):
    first = runner.run(build_taxi_pipeline(), branch="feat_c")
    changed = build_taxi_pipeline(threshold=25.0)  # different expectation
    with pytest.raises(ValueError):
        runner.replay(changed, first.run_id)


def test_sync_query_interface(runner, catalog, fmt, seeded):
    out = runner.query(
        "SELECT pickup_location_id, COUNT(*) AS n FROM taxi_table "
        "GROUP BY pickup_location_id ORDER BY n DESC LIMIT 3"
    )
    keys, counts = np.unique(seeded["pickup_location_id"], return_counts=True)
    np.testing.assert_array_equal(out["n"], np.sort(counts)[::-1][:3])


def test_query_time_travel(runner, catalog, fmt, rng):
    d1 = make_taxi_data(100, rng)
    s1 = fmt.write("taxi_table", TAXI_SCHEMA, d1)
    c1 = catalog.commit("main", {"taxi_table": fmt.manifest_key(s1)})
    d2 = make_taxi_data(300, rng)
    s2 = fmt.write("taxi_table", TAXI_SCHEMA, d2)
    catalog.commit("main", {"taxi_table": fmt.manifest_key(s2)})
    now = runner.query("SELECT COUNT(*) AS n FROM taxi_table")
    then = runner.query(
        "SELECT COUNT(*) AS n FROM taxi_table", commit_id=c1.commit_id
    )
    assert now["n"][0] == 300 and then["n"][0] == 100
