"""The bauplan-style CLI (paper 4.6)."""
import numpy as np
import pytest

from repro.catalog import Catalog
from repro.cli import main
from repro.io import ObjectStore
from repro.table import TableFormat
from tests.helpers_taxi import TAXI_SCHEMA, make_taxi_data

PIPELINE_SRC = '''
from repro.core import Pipeline

PIPELINE = Pipeline("cli_demo")
PIPELINE.sql(
    "trips",
    "SELECT pickup_location_id, passenger_count as count FROM taxi_table "
    "WHERE pickup_at >= '2019-04-01'",
)

@PIPELINE.python
def trips_expectation(ctx, trips):
    return trips.mean("count") > 1.0

PIPELINE.sql(
    "pickups",
    "SELECT pickup_location_id, COUNT(*) AS counts FROM trips "
    "GROUP BY pickup_location_id ORDER BY counts DESC",
)
'''


@pytest.fixture
def lake(tmp_path, rng):
    root = tmp_path / "lake"
    store = ObjectStore(root)
    catalog = Catalog(store)
    fmt = TableFormat(store)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, make_taxi_data(500, rng))
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})
    pipeline_file = tmp_path / "pipeline.py"
    pipeline_file.write_text(PIPELINE_SRC)
    return root, pipeline_file


def test_cli_query(lake, capsys):
    root, _ = lake
    main(["--lake", str(root), "query", "-q",
          "SELECT COUNT(*) AS n FROM taxi_table"])
    out = capsys.readouterr().out
    assert "500" in out


def test_cli_run_then_query_and_log(lake, capsys):
    root, pipeline_file = lake
    main(["--lake", str(root), "run", str(pipeline_file), "-b", "feat_1"])
    out = capsys.readouterr().out
    assert "merged to 'feat_1'" in out
    main(["--lake", str(root), "query", "-q",
          "SELECT pickup_location_id, counts FROM pickups LIMIT 3",
          "-b", "feat_1"])
    out = capsys.readouterr().out
    assert "counts" in out
    main(["--lake", str(root), "log", "-b", "feat_1"])
    out = capsys.readouterr().out
    assert "run 1" in out
    main(["--lake", str(root), "branch"])
    out = capsys.readouterr().out
    assert "feat_1" in out and "main" in out


def test_cli_run_reports_node_hit_rate(lake, capsys):
    root, pipeline_file = lake
    main(["--lake", str(root), "run", str(pipeline_file), "-b", "dev"])
    cold = capsys.readouterr().out
    assert "0/3 nodes hit" in cold  # cache on by default, cold lake
    # warm fused re-run: pickups rehydrates, the audited check is skipped,
    # and interior trips (never materialized by the fused cold run) elides
    main(["--lake", str(root), "run", str(pipeline_file), "-b", "dev"])
    warm = capsys.readouterr().out
    assert "2/2 nodes hit" in warm and "0 executed" in warm
    # a fusion flip stays warm (node-granular keys) ...
    main(["--lake", str(root), "run", str(pipeline_file), "-b", "dev",
          "--no-fusion"])
    flipped = capsys.readouterr().out
    assert "0 executed" in flipped
    # ... and --no-cache is the explicit opt-out
    main(["--lake", str(root), "run", str(pipeline_file), "-b", "dev",
          "--no-cache"])
    out = capsys.readouterr().out
    assert "nodes hit" not in out
    main(["--lake", str(root), "cache", "stats"])
    out = capsys.readouterr().out
    assert "pickups" in out and "artifact" in out and "check" in out


def test_cli_tables_and_replay(lake, capsys):
    root, pipeline_file = lake
    main(["--lake", str(root), "run", str(pipeline_file), "-b", "dev"])
    capsys.readouterr()
    main(["--lake", str(root), "tables", "-b", "dev"])
    out = capsys.readouterr().out
    assert "pickups" in out and "taxi_table" in out
    main(["--lake", str(root), "run", str(pipeline_file), "--replay",
          "--run-id", "1"])
    out = capsys.readouterr().out
    assert "replayed run 1" in out
