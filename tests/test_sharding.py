"""Sharding rules: divisibility fallbacks, spec assignment, MoE invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline CI: deterministic fallback shim
    from tests._hypothesis_compat import given, settings
    from tests._hypothesis_compat import strategies as st
from jax.sharding import PartitionSpec as P

from repro.distribution.sharding import DEFAULT_RULES
from repro.models.moe import MoEConfig, init_moe, moe_apply


class FakeMesh:
    """Just enough mesh interface for spec_for (shape lookup)."""

    def __init__(self, **axes):
        self.shape = dict(axes)


def test_attention_params_column_row_parallel():
    mesh = FakeMesh(data=16, model=16)
    spec = DEFAULT_RULES.spec_for("seg0/b0/attn/wq/w", (88, 4096, 4096), mesh)
    assert spec == P(None, "data", "model")  # stacked dim unsharded
    spec = DEFAULT_RULES.spec_for("seg0/b0/attn/wo/w", (88, 4096, 4096), mesh)
    assert spec == P(None, "model", "data")


def test_experts_prefer_ep_then_fall_back_to_tp():
    mesh = FakeMesh(data=16, model=16)
    # 256 experts % 16 == 0 -> EP
    spec = DEFAULT_RULES.spec_for(
        "seg1/b0/moe/experts/gate", (58, 256, 7168, 2048), mesh
    )
    assert spec == P(None, "model", "data", None)
    # 60 experts % 16 != 0 -> expert-internal TP on d_ff
    spec = DEFAULT_RULES.spec_for(
        "seg0/b0/moe/experts/gate", (24, 60, 2048, 1408), mesh
    )
    assert spec == P(None, None, "data", "model")


def test_vocab_sharding_falls_back_when_indivisible():
    mesh = FakeMesh(data=16, model=16)
    ok = DEFAULT_RULES.spec_for("embed/table", (129280, 7168), mesh)
    assert ok == P("model", "data")
    # 92553 is not divisible by 16 -> vocab replicated, d over data
    fallback = DEFAULT_RULES.spec_for("embed/table", (92553, 2048), mesh)
    assert fallback == P(None, "data")


def test_norms_replicated():
    mesh = FakeMesh(data=16, model=16)
    assert DEFAULT_RULES.spec_for("seg0/b0/norm1/scale", (24, 4096), mesh) == P()


def test_kv_heads_small_dims():
    mesh = FakeMesh(data=16, model=16)
    # MQA: kv proj output dim 1*128=128 divides 16 -> still column-sharded
    spec = DEFAULT_RULES.spec_for("seg0/b0/attn/wk/w", (88, 6144, 128), mesh)
    assert spec == P(None, "data", "model")


# ------------------------------------------------------------ MoE behaviour
def _moe_setup(e=8, k=2, d=32, f=16, shared=0):
    cfg = MoEConfig(
        d_model=d, d_ff=f, num_experts=e, top_k=k, num_shared=shared,
        compute_dtype=jnp.float32,
    )
    params = init_moe(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_moe_output_shape_and_finite(rng):
    cfg, params = _moe_setup(shared=1)
    x = jnp.asarray(rng.standard_normal((2, 64, 32)).astype(np.float32))
    out, aux = moe_apply(params, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux["balance_loss"]) >= 0
    assert float(aux["z_loss"]) >= 0


def test_moe_capacity_drops_are_bounded(rng):
    """With capacity_factor >= 1 and perfectly uniform routing nothing
    drops; with adversarially-skewed routing outputs stay finite."""
    cfg, params = _moe_setup(e=4, k=1)
    x = jnp.asarray(np.tile(rng.standard_normal((1, 1, 32)), (1, 64, 1)).astype(np.float32))
    out, _ = moe_apply(params, cfg, x)  # identical tokens -> one expert hot
    assert bool(jnp.isfinite(out).all())


def test_moe_permutation_equivariance(rng):
    """Permuting tokens permutes outputs identically when capacity is
    large enough that nothing drops (dropping is slot-order-dependent by
    design — GShard locality semantics)."""
    cfg = MoEConfig(
        d_model=16, d_ff=8, num_experts=4, top_k=1,
        capacity_factor=4.0,  # no drops -> equivariance is exact
        compute_dtype=jnp.float32,
    )
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = rng.standard_normal((1, 16, 16)).astype(np.float32)
    out1, _ = moe_apply(params, cfg, jnp.asarray(x))
    perm = rng.permutation(16)
    out2, _ = moe_apply(params, cfg, jnp.asarray(x[:, perm]))
    np.testing.assert_allclose(
        np.asarray(out1)[:, perm], np.asarray(out2), rtol=1e-4, atol=1e-5
    )


@given(
    e=st.sampled_from([4, 8]),
    k=st.integers(1, 3),
    s=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_property_moe_matches_dense_oracle(e, k, s, seed):
    """Sort-based dispatch == brute-force per-token expert loop (when no
    token exceeds capacity)."""
    rng = np.random.default_rng(seed)
    cfg = MoEConfig(
        d_model=16, d_ff=8, num_experts=e, top_k=k,
        capacity_factor=float(e),  # capacity >= all tokens: nothing drops
        compute_dtype=jnp.float32,
    )
    params = init_moe(jax.random.PRNGKey(seed % 1000), cfg)
    x = jnp.asarray(rng.standard_normal((1, s, 16)).astype(np.float32))
    got, _ = moe_apply(params, cfg, x)

    # oracle: dense routing
    from repro.models.common import linear

    logits = (x @ params["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    we = params["experts"]
    expect = np.zeros((1, s, 16), np.float32)
    for t in range(s):
        for j in range(k):
            eid = int(top_e[0, t, j])
            xin = np.asarray(x[0, t])
            g = xin @ np.asarray(we["gate"][eid])
            u = xin @ np.asarray(we["up"][eid])
            h = (g / (1 + np.exp(-g))) * u
            expect[0, t] += float(top_p[0, t, j]) * (h @ np.asarray(we["down"][eid]))
    np.testing.assert_allclose(np.asarray(got), expect, rtol=5e-3, atol=5e-4)
