"""The SDK facade: Client/BranchHandle/RunHandle, decorators, parity.

The acceptance matrix (ISSUE 4): ``Client.run()`` and the legacy
``Runner.run()`` must be *the same engine behind different doors* —
identical artifact manifests (content-addressed), identical checks,
identical node-cache hit accounting, across the cache/fusion config
matrix; plus the typed AUDIT_FAILED rollback path, branch-scoped
sessions, decorator-registered projects, and the persisted speculation
latency history.
"""
import warnings

import numpy as np
import pytest

import repro
from repro.api import Client, RunState
from repro.catalog import Catalog
from repro.core import Runner
from repro.io import ObjectStore
from repro.runtime import ExecutorConfig, ServerlessExecutor
from repro.table import TableFormat
from tests.helpers_taxi import TAXI_SCHEMA, build_taxi_pipeline, make_taxi_data

_CFG = ExecutorConfig(max_workers=2)


def _seed(client: Client, n: int = 2000, *, mean_count: float = 30.0,
          seed: int = 0) -> None:
    client.write_table(
        "taxi_table",
        make_taxi_data(n, np.random.default_rng(seed), mean_count=mean_count),
        schema=TAXI_SCHEMA,
    )


@pytest.fixture
def client(tmp_path):
    with Client(tmp_path / "lake", shard_rows=128,
                executor_config=_CFG) as c:
        yield c


# ------------------------------------------------------------- public API
def test_public_api_surface():
    assert repro.Client is Client
    assert callable(repro.model)
    assert callable(repro.expectation)
    assert callable(repro.requirements)
    assert callable(repro.sql)
    assert repro.RunState.SUCCESS.value == "SUCCESS"
    assert isinstance(repro.__version__, str)


def test_runner_shim_warns_but_works():
    import repro as r
    r.__dict__.pop("Runner", None)  # undo any cached resolution
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        shim = r.Runner
    assert shim is Runner
    assert any(w.category is DeprecationWarning for w in caught)


# ----------------------------------------------------- Client/Runner parity
@pytest.mark.parametrize("cache", [True, False])
@pytest.mark.parametrize("fusion", [True, False])
def test_client_runner_parity_matrix(tmp_path, cache, fusion):
    """Same pipeline, same data, two construction paths — identical runs.

    Two cold runs then one warm re-run per path: artifacts, checks, node
    cache hit counts and branch-head table mappings must all agree
    (commit *ids* differ — they hash wall-clock timestamps — so parity is
    asserted on the content-addressed tables a commit points at).
    """
    # SDK path
    api = Client(tmp_path / "api", shard_rows=128, executor_config=_CFG)
    _seed(api)
    h1 = api.run(build_taxi_pipeline(), branch="feat",
                 fusion=fusion, pushdown=fusion, cache=cache)
    h2 = api.run(build_taxi_pipeline(), branch="feat",
                 fusion=fusion, pushdown=fusion, cache=cache)

    # legacy engine path
    store = ObjectStore(tmp_path / "legacy")
    catalog = Catalog(store)
    fmt = TableFormat(store, shard_rows=128)
    snap = fmt.write(
        "taxi_table", TAXI_SCHEMA, make_taxi_data(2000, np.random.default_rng(0))
    )
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})
    with ServerlessExecutor(_CFG) as ex:
        runner = Runner(catalog, fmt, ex)
        r1 = runner.run(build_taxi_pipeline(), branch="feat",
                        fusion=fusion, pushdown=fusion, cache=cache)
        r2 = runner.run(build_taxi_pipeline(), branch="feat",
                        fusion=fusion, pushdown=fusion, cache=cache)

    for h, r in ((h1, r1), (h2, r2)):
        assert h.state is RunState.SUCCESS and r.ok
        assert h.artifacts == r.artifacts  # content-addressed equality
        assert h.checks == r.checks
        assert h.stats["cache"] == r.stats["cache"]  # hit counts included
        assert len(h.plan.stages) == len(r.plan.stages)
    # warm-run accounting matches: same hits/rehydrated/elided/executed
    if cache:
        assert h2.cache["hits"] == r2.stats["cache"]["hits"] > 0
        assert h2.cache["nodes_executed"] == 0
    else:
        assert h2.cache["hits"] == 0 and h2.cache["enabled"] is False
    # the branch heads point at the same content
    assert api.tables("feat") == catalog.tables(branch="feat")
    api.close()


# ------------------------------------------------------------- RunHandle
def test_audit_failure_is_typed_and_rolled_back(client):
    _seed(client, 500, mean_count=1.0)  # mean ~1 < threshold 10
    before = client.catalog.head("main").commit_id
    handle = client.run(build_taxi_pipeline(), branch="main")
    assert handle.state is RunState.AUDIT_FAILED
    assert not handle.ok
    assert handle.merged_commit is None
    assert handle.failed_checks == ["trips_expectation"]
    assert handle.run_id > 0  # the rolled-back run is still recorded
    with pytest.raises(repro.RunFailed):
        handle.raise_for_state()
    # rollback: head unmoved, no artifacts visible, no ephemeral branches
    assert client.catalog.head("main").commit_id == before
    assert "pickups" not in client.tables("main")
    assert all(not b.startswith("run_") for b in client.branches())


def test_run_error_state_captured_when_asked(client):
    # no taxi_table seeded -> the engine raises KeyError at planning
    with pytest.raises(KeyError):
        client.run(build_taxi_pipeline(), branch="main")
    handle = client.run(build_taxi_pipeline(), branch="main",
                        raise_errors=False)
    assert handle.state is RunState.ERROR
    assert isinstance(handle.error, KeyError)
    with pytest.raises(repro.RunFailed):
        handle.raise_for_state()


def test_runhandle_lazy_artifact_read(client):
    _seed(client)
    handle = client.run(build_taxi_pipeline(), branch="feat")
    out = handle.artifact("pickups")
    assert set(out) == {"pickup_location_id", "dropoff_location_id", "counts"}
    assert (np.sort(out["counts"])[::-1] == out["counts"]).all()
    with pytest.raises(KeyError):
        handle.artifact("nope")


def test_replay_through_client(client):
    _seed(client)
    first = client.run(build_taxi_pipeline(), branch="feat")
    again = client.replay(first.run_id, build_taxi_pipeline())
    assert again.state is RunState.SUCCESS
    assert again.replay_of == first.run_id
    assert again.merged_commit is None  # replay never moves branches
    assert again.artifacts == first.artifacts


# ----------------------------------------------------------- BranchHandle
def test_branch_merges_on_success(client):
    _seed(client)
    with client.branch("feat_1") as branch:
        handle = branch.run(build_taxi_pipeline())
        assert handle.ok
        assert "pickups" in branch.tables()
        assert "pickups" not in client.tables("main")  # not yet
    # clean exit: merged into main, branch gone
    assert "pickups" in client.tables("main")
    assert "feat_1" not in client.branches()


def test_branch_rolls_back_on_audit_failure(client):
    _seed(client)
    with client.branch("feat_bad") as branch:
        branch.write_table(
            "taxi_table",
            make_taxi_data(300, np.random.default_rng(7), mean_count=1.0),
            schema=TAXI_SCHEMA,
        )
        handle = branch.run(build_taxi_pipeline())
        assert handle.state is RunState.AUDIT_FAILED
    # rollback: branch deleted, nothing merged
    assert "feat_bad" not in client.branches()
    assert "pickups" not in client.tables("main")


def test_branch_rolls_back_on_exception(client):
    _seed(client)
    with pytest.raises(RuntimeError, match="boom"):
        with client.branch("feat_exc") as branch:
            branch.write_table(
                "extra",
                {"x": np.arange(4, dtype=np.int32)},
            )
            raise RuntimeError("boom")
    assert "feat_exc" not in client.branches()
    assert "extra" not in client.tables("main")


def test_preexisting_branch_is_not_ephemeral(client):
    _seed(client)
    client.create_branch("longlived")
    with client.branch("longlived") as branch:
        branch.run(build_taxi_pipeline()).raise_for_state()
    # attached handle: exit leaves the branch (and main) untouched
    assert "longlived" in client.branches()
    assert "pickups" in client.tables("longlived")
    assert "pickups" not in client.tables("main")


def test_branch_scoped_query_log_tag(client):
    _seed(client)
    feat = client.branch("feat_q", ephemeral=False)
    feat.run(build_taxi_pipeline()).raise_for_state()
    out = feat.query("SELECT COUNT(*) AS n FROM pickups")
    assert out["n"][0] > 0
    assert any("run 1" in c.message for c in feat.log())
    tagged = feat.tag("v1")
    assert client.tags()["v1"] == tagged == feat.head().commit_id


# ----------------------------------------------- decorators + discovery
def test_decorator_project_matches_legacy_pipeline(client):
    _seed(client)
    proj = repro.project("taxi_decorated")
    proj.clear()  # test isolation: module-level registry is global
    proj.sql(
        "trips",
        "SELECT pickup_location_id, passenger_count as count, "
        "dropoff_location_id FROM taxi_table WHERE pickup_at >= '2019-04-01'",
    )

    @proj.expectation(name="trips_expectation")
    @repro.requirements({"pandas": "2.0.0"})
    def trips_are_plausible(ctx, trips):
        return trips.mean("count") > 10.0

    proj.sql(
        "pickups",
        "SELECT pickup_location_id, dropoff_location_id, COUNT(*) AS counts "
        "FROM trips GROUP BY pickup_location_id, dropoff_location_id "
        "ORDER BY counts DESC",
    )
    decorated = client.run(proj, branch="dec", cache=False)
    legacy = client.run(build_taxi_pipeline(), branch="leg", cache=False)
    assert decorated.state is RunState.SUCCESS
    assert decorated.artifacts == legacy.artifacts
    assert decorated.checks == legacy.checks


def test_expectation_name_needs_no_suffix(client):
    _seed(client)
    proj = repro.project("taxi_free_names")
    proj.clear()
    proj.sql(
        "trips",
        "SELECT pickup_location_id, passenger_count as count FROM taxi_table",
    )

    @proj.expectation()
    def trips_have_riders(ctx, trips):  # no _expectation suffix
        return trips.mean("count") > 10.0

    handle = client.run(proj, branch="free")
    assert handle.checks == {"trips_have_riders": True}
    pipeline = proj.pipeline()
    assert pipeline.expectations == ["trips_have_riders"]


def test_redefinition_overwrites_not_collides(client):
    _seed(client)
    proj = repro.project("taxi_redef")
    proj.clear()
    proj.sql("trips", "SELECT pickup_location_id FROM taxi_table")
    proj.sql("trips", "SELECT dropoff_location_id FROM taxi_table")
    assert len(proj) == 1
    pipeline = proj.pipeline()
    assert pipeline.nodes["trips"].query.projections[0][0] == (
        "dropoff_location_id"
    )


def test_discover_module_file(client, tmp_path):
    _seed(client)
    mod = tmp_path / "my_pipeline.py"
    mod.write_text(
        "import repro\n"
        "repro.sql('trips', \"SELECT pickup_location_id, passenger_count as "
        "count FROM taxi_table\")\n"
        "@repro.expectation()\n"
        "def sane(ctx, trips):\n"
        "    return trips.count() > 0\n"
    )
    handle = client.run(str(mod), branch="disc")
    assert handle.state is RunState.SUCCESS
    assert handle.checks == {"sane": True}
    # loading the same file again re-registers without colliding
    handle2 = client.run(str(mod), branch="disc")
    assert handle2.state is RunState.SUCCESS


def test_same_stem_files_get_distinct_projects(client, tmp_path):
    """Two pipeline files sharing a stem must not leak nodes into each
    other's DAG (discovery keys default projects by resolved path)."""
    _seed(client)
    a = tmp_path / "pa" / "pipe.py"
    b = tmp_path / "pb" / "pipe.py"
    a.parent.mkdir()
    b.parent.mkdir()
    a.write_text(
        "import repro\n"
        "repro.sql('a_node', 'SELECT pickup_location_id FROM taxi_table')\n"
    )
    b.write_text(
        "import repro\n"
        "repro.sql('b_node', 'SELECT dropoff_location_id FROM taxi_table')\n"
    )
    ha = client.run(str(a), branch="pa")
    hb = client.run(str(b), branch="pb")
    assert sorted(ha.artifacts) == ["a_node"]
    assert sorted(hb.artifacts) == ["b_node"]
    # and paths that only differ in separator-vs-underscore ("a_b.py" vs
    # "a/b.py") must not collide either (module names hash the full path)
    c = tmp_path / "a_b.py"
    d = tmp_path / "a" / "b.py"
    d.parent.mkdir()
    c.write_text(
        "import repro\n"
        "repro.sql('c_node', 'SELECT pickup_location_id FROM taxi_table')\n"
    )
    d.write_text(
        "import repro\n"
        "repro.sql('d_node', 'SELECT dropoff_location_id FROM taxi_table')\n"
    )
    assert sorted(client.run(str(c), branch="pc").artifacts) == ["c_node"]
    assert sorted(client.run(str(d), branch="pd").artifacts) == ["d_node"]
    assert sorted(client.run(str(c), branch="pc2").artifacts) == ["c_node"]


def test_rediscovery_drops_deleted_nodes(client, tmp_path):
    """Editing a file and re-running it must not resurrect removed nodes."""
    _seed(client)
    mod = tmp_path / "evolving.py"
    mod.write_text(
        "import repro\n"
        "repro.sql('old_node', 'SELECT pickup_location_id FROM taxi_table')\n"
    )
    assert sorted(client.run(str(mod), branch="v1").artifacts) == ["old_node"]
    mod.write_text(
        "import repro\n"
        "repro.sql('new_node', 'SELECT dropoff_location_id FROM taxi_table')\n"
    )
    assert sorted(client.run(str(mod), branch="v2").artifacts) == ["new_node"]


def test_legacy_pipeline_global_still_loads(client, tmp_path):
    _seed(client)
    mod = tmp_path / "legacy_pipeline.py"
    mod.write_text(
        "from repro.core import Pipeline\n"
        "PIPELINE = Pipeline('legacy')\n"
        "PIPELINE.sql('trips', 'SELECT pickup_location_id FROM taxi_table')\n"
    )
    handle = client.run(str(mod), branch="old")
    assert handle.state is RunState.SUCCESS
    assert "trips" in handle.artifacts


# ------------------------------------------------- latency history (lake)
def test_latency_history_survives_process_restart(tmp_path):
    """ROADMAP item: a fresh process inherits speculation baselines."""
    lake = tmp_path / "lake"
    with Client(lake, shard_rows=128, executor_config=_CFG) as c1:
        _seed(c1)
        # cache=False so every run genuinely executes (and times) the stage
        for i in range(3):
            c1.run(build_taxi_pipeline(), branch=f"b{i}", cache=False)
        history = c1.executor.latency_history()
    assert history, "executor recorded no durations"
    fp, durations = max(history.items(), key=lambda kv: len(kv[1]))
    assert len(durations) >= 3  # enough samples to form a median baseline

    # a brand-new Client (fresh process stand-in) inherits the baselines
    with Client(lake, shard_rows=128, executor_config=_CFG) as c2:
        inherited = c2.executor.latency_history()
        assert inherited[fp] == pytest.approx(durations)
        # locally-observed durations are preferred over stale seeds
        c2.executor.seed_latency_history({fp: [999.0]})
        assert c2.executor.latency_history()[fp] == pytest.approx(durations)


def test_replay_of_failing_run_reports_audit_failed(client):
    """Replay re-executes without an audit gate — a reproduced failing
    check must surface as AUDIT_FAILED on the handle, not SUCCESS."""
    _seed(client, 500, mean_count=1.0)
    failed = client.run(build_taxi_pipeline(), branch="main")
    assert failed.state is RunState.AUDIT_FAILED
    again = client.replay(failed.run_id, build_taxi_pipeline())
    assert again.state is RunState.AUDIT_FAILED
    assert again.replay_of == failed.run_id
    assert again.checks == {"trips_expectation": False}
    assert again.merged_commit is None


def test_gc_prunes_stale_latency_baselines(client):
    _seed(client)
    client.run(build_taxi_pipeline(), branch="b", cache=False)
    client._save_latency_history()
    fresh = client.store.list_refs("latencyhist")
    assert fresh
    client.store.set_ref(
        "latencyhist", "deadbeef_stale",
        {"durations": [0.5], "updated_at": 1.0},  # epoch — long expired
    )
    report = client.gc(grace_s=0.0, latency_ttl_s=3600.0)
    assert report.swept_latency_refs == 1
    left = client.store.list_refs("latencyhist")
    assert "deadbeef_stale" not in left
    assert set(fresh) <= set(left)  # fresh baselines survive
    # and latency_ttl_s=None disables the pruning stage entirely
    client.store.set_ref(
        "latencyhist", "deadbeef_stale",
        {"durations": [0.5], "updated_at": 1.0},
    )
    report = client.gc(grace_s=0.0, latency_ttl_s=None)
    assert report.swept_latency_refs == 0


def test_write_table_infers_schema_and_appends(client):
    client.write_table(
        "events", {"ts": np.arange(10, dtype=np.int64).astype(np.int32),
                   "value": np.ones(10, dtype=np.float32)},
    )
    client.write_table(
        "events", {"ts": np.arange(10, 20, dtype=np.int32),
                   "value": np.zeros(10, dtype=np.float32)},
        append=True,
    )
    out = client.query("SELECT COUNT(*) AS n FROM events")
    assert out["n"][0] == 20
