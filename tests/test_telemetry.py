"""repro.telemetry — event bus, run tracing, metrics, runlog GC, CLI.

The observability contract under test:

* the **event set** of a run is a function of the pipeline + data, not of
  the parallelism knob — runs at parallelism 1/2/8 emit the same multiset
  of events once timestamps/sequence numbers/durations are stripped;
* **spans nest**: every span sits inside the run span, scan/node spans
  inside their stage's exec window, and queue hands off exactly where
  exec picks up;
* a mid-DAG **audit failure still closes the run span** — RunFinished is
  emitted with the failure state and the trace is persisted;
* warm runs surface as **rehydrate spans** and the trace accounts for
  ≥95% of wall-clock;
* **runlog traces are GC roots only within the TTL** — expired traces
  lose ref and blob in one pass, live traces keep their bytes pinned.
"""
import json

import numpy as np
import pytest

from repro.api import Client, RunState
from repro.cli import main
from repro.core import Pipeline
from repro.examples_data import TAXI_SCHEMA, make_taxi_data
from repro.runtime import ExecutorConfig
from repro.telemetry import (
    EVENT_TYPES,
    EventBus,
    MetricsRegistry,
    RunFinished,
    ScanShardRead,
    StageQueued,
    event_from_json_dict,
    read_spool,
)

N_ROWS = 2_000
PARALLELISMS = (1, 2, 8)

#: wall-clock fields stripped before comparing event sets across
#: parallelism levels (everything timing-dependent, nothing semantic).
#: StageScheduled's admission_wait_s/admission/warm describe scheduler
#: state at dispatch time (how long the gate held the stage, whether a
#: compiled executable already existed) — concurrency-dependent by
#: nature; its cost-model fields (est_cost_s, cp_rank, schedule,
#: streaming) stay under the invariance contract.
_TIMING_FIELDS = {
    "ts", "seq", "wall_s", "exec_s", "commit_s", "dur_s",
    "baseline_s", "deadline_s",
    "admission_wait_s", "admission", "warm",
}
#: timer-driven events — whether a straggler deadline fires depends on
#: scheduling noise, so they are excluded from the determinism contract
_TIMER_KINDS = {"SpeculationArmed", "SpeculationFired", "SpeculationWon"}


def _client(parallelism: int = 4) -> Client:
    return Client.ephemeral(
        shard_rows=512,
        executor_config=ExecutorConfig(
            max_workers=8, max_concurrent_stages=parallelism
        ),
    )


def build_fanout_pipeline(threshold: float = 10.0) -> Pipeline:
    """source -> (m0, m1) -> combine plus an audit: enough structure for
    real queue/exec overlap and a two-parent dependency edge."""
    p = Pipeline("telemetry_parity")
    p.sql(
        "trips",
        "SELECT pickup_location_id, passenger_count as count FROM taxi_table"
        " WHERE pickup_at >= '2019-04-01'",
    )

    @p.python
    def trips_expectation(ctx, trips):
        return trips.mean("count") > threshold

    for i in range(2):

        def make_model(i):
            def fn(ctx, trips):
                import jax.numpy as jnp

                col = trips.column("count").astype(jnp.float32)
                return {"stat": jnp.sort(col) * (i + 1)}

            fn.__name__ = f"m{i}"
            return fn

        p.python(make_model(i))

    @p.python
    def combine(ctx, m0, m1):
        import jax.numpy as jnp

        return {"delta": m1.column("stat") - m0.column("stat")}

    return p


def _write_taxi(client: Client, seed: int = 7) -> None:
    rng = np.random.default_rng(seed)
    client.write_table(
        "taxi_table", make_taxi_data(N_ROWS, rng), schema=TAXI_SCHEMA
    )


def _normalize(events):
    out = []
    for e in events:
        d = e.to_json_dict()
        if d["kind"] in _TIMER_KINDS:
            continue
        for f in _TIMING_FIELDS:
            d.pop(f, None)
        out.append(json.dumps(d, sort_keys=True))
    return sorted(out)


# --------------------------------------------------------------- event bus
def test_bus_bounded_buffer_drop_accounting():
    bus = EventBus()
    slow = bus.subscribe(maxlen=4)
    fast = bus.subscribe(maxlen=100)
    for i in range(10):
        bus.publish(StageQueued(run_id=1, stage_id=i))
    # the slow consumer lost ITS oldest six; the fast one lost nothing
    kept = slow.poll()
    assert [e.stage_id for e in kept] == [6, 7, 8, 9]
    assert slow.dropped == 6
    assert len(fast.poll()) == 10 and fast.dropped == 0
    stats = bus.stats()
    assert stats["published"] == 10 and stats["dropped"] == 6
    slow.close()
    assert bus.stats()["subscribers"] == 1


def test_bus_seq_is_monotonic_per_run_scope():
    bus = EventBus()
    sub = bus.subscribe()
    for run_id in (1, 2, 1, None, 2, 1, None):
        bus.publish(StageQueued(run_id=run_id))
    by_scope = {}
    for e in sub.poll():
        by_scope.setdefault(e.run_id, []).append(e.seq)
    assert by_scope[1] == [1, 2, 3]
    assert by_scope[2] == [1, 2]
    assert by_scope[None] == [1, 2]  # global scope for run-less events


def test_event_json_roundtrip_all_kinds():
    for kind, cls in EVENT_TYPES.items():
        ev = cls(run_id=3)
        back = event_from_json_dict(ev.to_json_dict())
        assert type(back) is cls and back.run_id == 3
    # unknown kinds / fields degrade instead of failing the reader
    degraded = event_from_json_dict(
        {"kind": "FromTheFuture", "run_id": 9, "novel_field": 1}
    )
    assert type(degraded).__name__ == "Event" and degraded.run_id == 9
    known = event_from_json_dict(
        {"kind": "RunFinished", "state": "ERROR", "novel_field": 1}
    )
    assert isinstance(known, RunFinished) and known.state == "ERROR"


def test_spool_survives_rotation_and_filters_by_run(tmp_path):
    spool = tmp_path / "events.jsonl"
    bus = EventBus(spool_path=spool, spool_max_bytes=600)
    for i in range(12):
        bus.publish(ScanShardRead(run_id=i % 2, shard_index=i))
    bus.close()
    assert spool.with_name(spool.name + ".1").exists()  # rotated at 600B
    # retention is bounded (live file + one rotated predecessor), so the
    # readable window is a contiguous SUFFIX of the stream — never a gap
    got = [e.shard_index for e in read_spool(spool)]
    assert got == list(range(12))[-len(got):] and got[-1] == 11
    only_run1 = [e.shard_index for e in read_spool(spool, run_id=1)]
    assert only_run1 == [i for i in got if i % 2 == 1]
    assert len(read_spool(spool, limit=2)) == 2


def test_metrics_registry_counters_gauges_histograms():
    m = MetricsRegistry()
    m.counter("executor.tasks").inc()
    m.counter("executor.tasks").inc(4)
    m.gauge("pool.size").set(8)
    for v in range(100):
        m.histogram("lat").observe(float(v))
    snap = m.snapshot()
    assert snap["counters"]["executor.tasks"] == 5
    assert snap["gauges"]["pool.size"] == 8
    hist = snap["histograms"]["lat"]
    assert hist["count"] == 100
    assert hist["p50"] == pytest.approx(49.5, abs=2.0)
    assert hist["max"] == 99.0


# ----------------------------------------------- determinism across knobs
def test_event_set_is_parallelism_invariant():
    """Parallelism 1 (sequential baseline) vs 2 vs 8 on fresh lakes: the
    same multiset of events modulo timestamps/seq/interleaving."""
    normalized = {}
    for p in PARALLELISMS:
        with _client(p) as client:
            _write_taxi(client)
            handle = client.run(
                build_fanout_pipeline(), fusion=False, pushdown=False,
                parallelism=p,
            ).raise_for_state()
            normalized[p] = _normalize(client.runlog.get(handle.run_id))
    base = normalized[PARALLELISMS[0]]
    assert len(base) > 10  # a real stream, not a trivial pass
    for p in PARALLELISMS[1:]:
        assert normalized[p] == base


# ------------------------------------------------------------ span nesting
def test_trace_spans_nest_and_cover_the_run():
    with _client(8) as client:
        _write_taxi(client)
        handle = client.run(
            build_fanout_pipeline(), fusion=False, pushdown=False,
            parallelism=8,
        ).raise_for_state()
        trace = handle.trace()
    root = trace.root
    assert root.kind == "run" and trace.state == "SUCCESS"
    eps = 0.05  # time.time() starts vs perf_counter durations
    for span in root.walk():
        assert span.start >= root.start - eps
        assert span.end <= root.end + eps
        assert span.end >= span.start
    for sid, spans in trace.stage_spans.items():
        q, ex = spans["queue"], spans["exec"]
        # queue hands off exactly where exec picks up
        assert q.end == ex.start
        for child in ex.children:
            assert child.kind in ("scan", "node")
            assert child.start >= ex.start - eps
            assert child.end <= ex.end + eps
        # every logical node appears inside its stage's exec window
        nodes = {c.name for c in ex.children if c.kind == "node"}
        assert nodes == {f"node {n}" for n in q.attrs["nodes"]}
    # the two-parent stage's dependency edges survived into the trace
    assert any(len(ps) >= 2 for ps in trace.stage_parents.values())
    assert trace.critical_path(), "critical path must be non-empty"
    assert trace.coverage() >= 0.90
    # Chrome export is self-consistent
    chrome = trace.to_chrome_trace()
    names = {e["name"] for e in chrome["traceEvents"] if e["ph"] == "X"}
    assert f"run {trace.run_id}" in names
    assert all(
        {"name", "ph", "ts", "dur", "pid", "tid"} <= set(e)
        for e in chrome["traceEvents"] if e["ph"] == "X"
    )


def test_warm_run_traces_as_rehydrate_spans():
    """Acceptance bar: a warm run's cache hits appear as rehydrate spans
    and the trace still accounts for >=95% of wall-clock."""
    with _client(4) as client:
        _write_taxi(client)
        p = build_fanout_pipeline()
        client.run(p, fusion=False, pushdown=False).raise_for_state()
        warm = client.run(p, fusion=False, pushdown=False).raise_for_state()
        assert warm.cache["rehydrated"] >= 1  # it genuinely hit the cache
        trace = warm.trace()
    rehydrate = [s for s in trace.root.walk() if s.kind == "rehydrate"]
    assert len(rehydrate) == warm.cache["rehydrated"]
    assert all(s.attrs["bytes"] > 0 for s in rehydrate)
    assert trace.coverage() >= 0.95


# ------------------------------------------------------- failure semantics
def test_audit_failure_still_emits_run_finished():
    """A mid-DAG expectation failure rolls the run back — but the trace
    is still persisted and RunFinished carries the failure."""
    with _client(8) as client:
        _write_taxi(client)
        handle = client.run(
            build_fanout_pipeline(threshold=10_000.0),
            fusion=False, pushdown=False, parallelism=8, raise_errors=False,
        )
        assert handle.state is RunState.AUDIT_FAILED
        events = client.runlog.get(handle.run_id)
        trace = handle.trace()
    finished = [e for e in events if isinstance(e, RunFinished)]
    assert len(finished) == 1
    assert finished[0].state == "AUDIT_FAILED"
    assert finished[0].failed_checks == ["trips_expectation"]
    assert trace.state == "AUDIT_FAILED"


def test_infra_error_still_emits_run_finished():
    with _client(2) as client:
        p = Pipeline("missing_source")
        p.sql("x", "SELECT pickup_at FROM no_such_table")
        handle = client.run(p, raise_errors=False)
        assert handle.state is RunState.ERROR
        # the captured exception still addresses its run (and its trace)
        assert handle.run_id > 0
        events = client.runlog.get(handle.run_id)
        assert handle.trace().state == "ERROR"
    finished = [e for e in events if isinstance(e, RunFinished)]
    assert len(finished) == 1 and finished[0].state == "ERROR"


def test_telemetry_off_is_supported_and_runs_still_work():
    with Client.ephemeral(telemetry=False) as client:
        _write_taxi(client)
        handle = client.run(
            build_fanout_pipeline(), fusion=False, pushdown=False
        ).raise_for_state()
        assert client.bus is None
        with pytest.raises(RuntimeError):
            client.events(follow=True)
        # no bus -> no collected events -> no persisted trace
        assert not client.runlog.has(handle.run_id)


# ------------------------------------------------------------- query path
def test_query_emits_scan_and_query_events():
    with _client(2) as client:
        _write_taxi(client)
        sub = client.events(follow=True)
        rows = client.query("SELECT COUNT(*) AS n FROM taxi_table")
        assert int(rows["n"][0]) == N_ROWS
        events = sub.poll()
        sub.close()
    scans = [e for e in events if e.kind == "ScanShardRead"]
    queries = [e for e in events if e.kind == "QueryExecuted"]
    assert scans and all(s.source == "query" for s in scans)
    assert len(queries) == 1
    assert queries[0].table == "taxi_table"
    assert queries[0].shards_read == len(scans)


# ------------------------------------------------------------- runlog GC
def _backdate_runlog_ref(client: Client, run_id: int, by_s: float) -> str:
    """Age a trace ref in place; returns its blob key."""
    ref = client.store.get_ref("runlog", f"run_{run_id}")
    ref["created_at"] -= by_s
    client.store.set_ref("runlog", f"run_{run_id}", ref)
    return ref["blob"]


def test_runlog_gc_ttl_sweeps_expired_keeps_live():
    with _client(2) as client:
        _write_taxi(client)
        p = build_fanout_pipeline()
        old = client.run(p, fusion=False, pushdown=False).raise_for_state()
        live = client.run(p, fusion=False, pushdown=False).raise_for_state()
        old_blob = _backdate_runlog_ref(client, old.run_id, 30 * 86400.0)
        live_blob = client.store.get_ref("runlog", f"run_{live.run_id}")["blob"]

        # dry run reports but does not touch
        report = client.gc(
            runlog_ttl_s=7 * 86400.0, grace_s=0.0, dry_run=True
        )
        assert report.swept_runlog_refs == 1
        assert client.runlog.has(old.run_id)

        report = client.gc(runlog_ttl_s=7 * 86400.0, grace_s=0.0)
        assert report.swept_runlog_refs == 1
        # expired: ref gone AND blob reclaimed on the same pass
        assert not client.runlog.has(old.run_id)
        with pytest.raises(KeyError):
            client.runlog.get(old.run_id)
        assert not client.store.exists(old_blob)
        # live: still readable, bytes still pinned
        assert client.store.exists(live_blob)
        assert client.trace(live.run_id).state == "SUCCESS"

        # ttl=None retains everything
        report = client.gc(runlog_ttl_s=None, grace_s=0.0)
        assert report.swept_runlog_refs == 0
        assert client.runlog.has(live.run_id)


# -------------------------------------------------------------------- CLI
PIPELINE_SRC = '''
from repro.core import Pipeline

PIPELINE = Pipeline("cli_telemetry")
PIPELINE.sql(
    "trips",
    "SELECT pickup_location_id, passenger_count as count FROM taxi_table "
    "WHERE pickup_at >= '2019-04-01'",
)

@PIPELINE.python
def trips_expectation(ctx, trips):
    return trips.mean("count") > 1.0
'''


@pytest.fixture
def cli_lake(tmp_path, rng):
    from repro.catalog import Catalog
    from repro.io import ObjectStore
    from repro.table import TableFormat

    root = tmp_path / "lake"
    store = ObjectStore(root)
    fmt = TableFormat(store)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, make_taxi_data(500, rng))
    Catalog(store).commit("main", {"taxi_table": fmt.manifest_key(snap)})
    pipeline_file = tmp_path / "pipeline.py"
    pipeline_file.write_text(PIPELINE_SRC)
    return root, pipeline_file


def _json_payload(out: str) -> dict:
    return json.loads(out[out.index("{"):])


def test_cli_run_json_summary(cli_lake, capsys):
    root, pipeline_file = cli_lake
    main(["--lake", str(root), "run", str(pipeline_file), "--json"])
    payload = _json_payload(capsys.readouterr().out)
    assert payload["state"] == "SUCCESS"
    assert payload["run_id"] == 1 and payload["failed_checks"] == []
    assert payload["checks"] == {"trips_expectation": True}
    assert "trips" in payload["artifacts"]
    timings = payload["stage_timings"]
    assert timings and all(
        {"queue_s", "exec_s", "commit_s"} <= set(v) for v in timings.values()
    )
    assert {"hits", "rehydrated"} <= set(payload["cache"])
    assert payload["io"]["puts"] > 0 and payload["wall_s"] > 0


def test_cli_run_json_audit_failure_exits_2(cli_lake, tmp_path, capsys):
    root, _ = cli_lake
    failing = tmp_path / "failing.py"
    failing.write_text(PIPELINE_SRC.replace("> 1.0", "> 10_000.0"))
    with pytest.raises(SystemExit) as exc:
        main(["--lake", str(root), "run", str(failing), "--json"])
    assert exc.value.code == 2
    payload = _json_payload(capsys.readouterr().out)
    assert payload["state"] == "AUDIT_FAILED"
    assert payload["failed_checks"] == ["trips_expectation"]


def test_cli_trace_and_chrome_export(cli_lake, tmp_path, capsys):
    root, pipeline_file = cli_lake
    main(["--lake", str(root), "run", str(pipeline_file)])
    capsys.readouterr()
    chrome_path = tmp_path / "trace.json"
    main(["--lake", str(root), "trace", "1", "--chrome", str(chrome_path)])
    out = capsys.readouterr().out
    assert "run 1" in out and "critical path" in out and "coverage" in out
    chrome = json.loads(chrome_path.read_text())
    assert any(e["ph"] == "X" for e in chrome["traceEvents"])
    assert chrome["otherData"]["state"] == "SUCCESS"
    # unknown run id -> clean error, not a stack trace
    with pytest.raises(SystemExit):
        main(["--lake", str(root), "trace", "999"])


def test_cli_events_reads_spool(cli_lake, capsys):
    root, pipeline_file = cli_lake
    main(["--lake", str(root), "run", str(pipeline_file)])
    capsys.readouterr()
    main(["--lake", str(root), "events"])
    out = capsys.readouterr().out
    assert "RunStarted" in out and "RunFinished" in out
    main(["--lake", str(root), "events", "--limit", "2"])
    limited = capsys.readouterr().out.strip().splitlines()
    assert len(limited) == 2
    main(["--lake", str(root), "gc", "--dry-run", "--runlog-ttl", "0.001"])
    out = capsys.readouterr().out
    assert "1 run traces" in out
