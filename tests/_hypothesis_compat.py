"""Minimal deterministic stand-in for ``hypothesis`` in offline CI.

The real hypothesis package is an optional ``[test]`` extra (see
pyproject.toml) and is not installable in the air-gapped CI image, but 7
test modules are property-based.  This module implements exactly the
surface those modules use — ``given`` (keyword strategies only),
``settings(max_examples=..., deadline=...)`` and the ``strategies``
namespace (``integers``, ``floats``, ``sampled_from``, ``binary``,
``lists``, ``tuples``) — with two deliberate simplifications:

* **deterministic**: every test draws from a ``random.Random`` seeded by
  the test's qualified name, so failures are reproducible run-to-run;
* **boundary-first**: the first examples are the strategy's boundary
  values (min/max, empty collections) before random draws, which is where
  most of hypothesis's bug-finding power for this codebase lives (n=0
  tables, empty blobs, min/max thresholds).

No shrinking, no database, no stateful testing — modules import it only
when ``import hypothesis`` fails, so installing the real package
transparently upgrades the suite.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib
from typing import Any, Callable, List, Optional, Sequence

DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    """A value generator: fixed boundary examples, then seeded random draws."""

    def __init__(
        self,
        draw: Callable[[random.Random], Any],
        boundaries: Sequence[Any] = (),
        label: str = "strategy",
    ):
        self._draw = draw
        self._boundaries = tuple(boundaries)
        self._label = label

    @property
    def boundaries(self) -> tuple:
        return self._boundaries

    def example_at(self, index: int, rng: random.Random) -> Any:
        if index < len(self._boundaries):
            return self._boundaries[index]
        return self._draw(rng)

    def example(self, rng: Optional[random.Random] = None) -> Any:
        return self._draw(rng or random.Random(0))

    def __repr__(self) -> str:
        return f"<{self._label}>"


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: rng.randint(min_value, max_value),
        boundaries=(min_value, max_value),
        label=f"integers({min_value}, {max_value})",
    )


def floats(
    min_value: float, max_value: float, *, allow_nan: bool = False,
    allow_infinity: bool = False,
) -> SearchStrategy:
    # NaN/inf are never produced — callers here always pass allow_nan=False.
    lo, hi = float(min_value), float(max_value)
    return SearchStrategy(
        lambda rng: rng.uniform(lo, hi),
        boundaries=(lo, hi),
        label=f"floats({lo}, {hi})",
    )


def sampled_from(options: Sequence[Any]) -> SearchStrategy:
    opts = list(options)
    if not opts:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(
        lambda rng: opts[rng.randrange(len(opts))],
        boundaries=(opts[0],),
        label=f"sampled_from({opts!r})",
    )


def binary(*, min_size: int = 0, max_size: int = 64) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: rng.randbytes(rng.randint(min_size, max_size)),
        boundaries=(bytes(min_size), bytes(max_size)),
        label=f"binary({min_size}, {max_size})",
    )


def lists(
    elements: SearchStrategy, *, min_size: int = 0, max_size: int = 10
) -> SearchStrategy:
    def draw(rng: random.Random) -> List[Any]:
        n = rng.randint(min_size, max_size)
        return [elements.example_at(len(elements.boundaries), rng) for _ in range(n)]

    boundaries = []
    if elements.boundaries:
        boundaries.append([elements.boundaries[0]] * min_size)
        boundaries.append([elements.boundaries[-1]] * max_size)
    return SearchStrategy(
        draw, boundaries=boundaries, label=f"lists({elements!r})"
    )


def tuples(*elements: SearchStrategy) -> SearchStrategy:
    def draw(rng: random.Random) -> tuple:
        return tuple(
            e.example_at(len(e.boundaries), rng) for e in elements
        )

    boundaries = []
    if all(e.boundaries for e in elements):
        boundaries.append(tuple(e.boundaries[0] for e in elements))
    return SearchStrategy(draw, boundaries=boundaries, label="tuples(...)")


class settings:
    """Decorator mirroring ``hypothesis.settings`` — only ``max_examples``
    matters here; ``deadline`` and anything else is accepted and ignored."""

    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 deadline: Any = None, **_ignored: Any):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn: Callable) -> Callable:
        fn._compat_settings = self  # read by the given() wrapper
        return fn


def given(**strategy_kwargs: SearchStrategy) -> Callable:
    """Keyword-strategy ``@given`` that stays pytest-fixture friendly.

    The wrapper's signature drops the strategy-supplied parameters so
    pytest injects only the remaining fixtures (e.g. tmp_path_factory).
    """
    if not strategy_kwargs:
        raise TypeError("given() requires at least one keyword strategy")

    def deco(fn: Callable) -> Callable:
        sig = inspect.signature(fn)
        unknown = set(strategy_kwargs) - set(sig.parameters)
        if unknown:
            raise TypeError(f"given() got unexpected arguments {sorted(unknown)}")
        fixture_params = [
            p for name, p in sig.parameters.items() if name not in strategy_kwargs
        ]

        @functools.wraps(fn)
        def wrapper(**fixture_args: Any):
            cfg = getattr(wrapper, "_compat_settings", None)
            max_examples = cfg.max_examples if cfg else DEFAULT_MAX_EXAMPLES
            rng = random.Random(zlib.crc32(fn.__qualname__.encode("utf-8")))
            for i in range(max_examples):
                drawn = {
                    name: strat.example_at(i, rng)
                    for name, strat in strategy_kwargs.items()
                }
                try:
                    fn(**fixture_args, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} for {fn.__qualname__}: {drawn!r}"
                    ) from e

        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        wrapper._property_test = True  # conftest marks these as slow
        return wrapper

    return deco


#: importable as ``from tests._hypothesis_compat import strategies as st``
strategies = types.SimpleNamespace(
    integers=integers,
    floats=floats,
    sampled_from=sampled_from,
    binary=binary,
    lists=lists,
    tuples=tuples,
    SearchStrategy=SearchStrategy,
)
