"""Compatibility re-export — the taxi fixture now ships with the package
(``repro.examples_data``) so examples/benchmarks run without the test
tree on ``sys.path``."""
from repro.examples_data import (  # noqa: F401
    APRIL_1,
    TAXI_SCHEMA,
    build_taxi_pipeline,
    make_taxi_data,
)

__all__ = ["APRIL_1", "TAXI_SCHEMA", "build_taxi_pipeline", "make_taxi_data"]
