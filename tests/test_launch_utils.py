"""Launch-layer utilities: HLO collective parsing, extrapolation, shapes."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config, resolve
from repro.configs.shapes import SHAPES, cells, input_specs, shape_applicable
from repro.launch.dryrun import parse_collectives
from repro.launch.roofline import extrapolate, model_flops


def test_parse_collectives_basic():
    hlo = """
  %ag = f32[4096,256]{1,0} all-gather(%x), channel_id=1, replica_groups=[32,16]<=[512], dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%y), replica_groups=[16,32]<=[512], to_apply=%sum
  %rs = f32[64,64]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %other = f32[10]{0} add(%a, %b)
"""
    out = parse_collectives(hlo)
    ag = out["all-gather"]
    assert ag["count"] == 1
    assert ag["result_bytes"] == 4096 * 256 * 4
    np.testing.assert_allclose(ag["wire_bytes"], 4096 * 256 * 4 * 15 / 16)
    ar = out["all-reduce"]
    assert ar["result_bytes"] == 1024 * 2
    np.testing.assert_allclose(ar["wire_bytes"], 2 * 1024 * 2 * 31 / 32)
    rs = out["reduce-scatter"]
    assert rs["count"] == 1
    np.testing.assert_allclose(rs["wire_bytes"], 64 * 64 * 4 * 3)
    assert out["collective-permute"]["wire_bytes"] == 8 * 8 * 2
    assert out["all-to-all"]["count"] == 0


def test_extrapolate_linear_depth():
    var = {
        "counts": [10, 3],
        "v0": {"flops": 100.0},
        "v1": {"flops": 130.0},  # +30 per unit of segment 0
        "v2": {"flops": 120.0},  # +20 per unit of segment 1
    }
    # 100 + 9*30 + 2*20 = 410
    assert extrapolate(var, "flops") == pytest.approx(410.0)


def test_model_flops_train_vs_decode():
    cfg = get_config("yi_6b")
    train = model_flops(cfg, SHAPES["train_4k"], "train")
    decode = model_flops(cfg, SHAPES["decode_32k"], "decode")
    # train: 6*N*B*S ; decode: 2*N*B
    assert train / decode == pytest.approx(
        3 * SHAPES["train_4k"].global_batch * SHAPES["train_4k"].seq_len
        / SHAPES["decode_32k"].global_batch
    )


def test_moe_active_params_fraction():
    from repro.launch.roofline import active_params

    total, active = active_params(get_config("deepseek_v3_671b"))
    assert 600e9 < total < 750e9  # ~671B
    assert 30e9 < active < 60e9  # ~37B active
    t2, a2 = active_params(get_config("yi_6b"))
    assert t2 == a2  # dense: all params active


def test_cells_and_skips():
    live, skipped = cells(all_configs())
    assert len(live) == 33  # 10*3 + 3 long_500k
    assert len(skipped) == 7
    skipped_archs = {a for a, s, _ in skipped}
    assert "h2o_danube_3_4b" not in skipped_archs  # SWA runs long_500k
    assert "xlstm_350m" not in skipped_archs
    assert "recurrentgemma_9b" not in skipped_archs


def test_input_specs_shapes():
    cfg = get_config("musicgen_medium")
    spec = input_specs(cfg, SHAPES["train_4k"])
    assert spec["tokens"].shape == (256, 4096, 4)  # codebooks
    vlm = get_config("internvl2_2b")
    spec = input_specs(vlm, SHAPES["train_4k"])
    assert spec["tokens"].shape == (256, 4096 - 256)
    assert spec["patch_embeds"].shape == (256, 256, 2048)
    dec = input_specs(cfg, SHAPES["decode_32k"])
    assert dec["tokens"].shape == (128, 1, 4)
    assert dec["lengths"].shape == (128,)


def test_registry_aliases():
    assert resolve("yi-6b") == "yi_6b"
    assert resolve("deepseek-v3-671b") == "deepseek_v3_671b"
    with pytest.raises(KeyError):
        resolve("gpt-5")
    assert len(ARCH_IDS) == 10
