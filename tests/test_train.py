"""Optimizers, train step, compression, checkpointing, restartable loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.tokens import TokenDataset, write_token_table
from repro.distribution.compression import compress_decompress, init_compression
from repro.models import LM
from repro.train import (
    AdamWConfig,
    CheckpointManager,
    TrainLoop,
    TrainLoopConfig,
    TrainStepConfig,
    adamw_init,
    adamw_update,
    make_train_step,
    warmup_cosine,
)
from repro.train.step import make_train_state


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, state = adamw_update(params, grads, state, cfg, jnp.float32(0.05))
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adafactor_minimizes_quadratic():
    from repro.train.optimizer import adafactor_init, adafactor_update

    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.full((4, 3), 3.0)}
    state = adafactor_init(params, cfg)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state = adafactor_update(params, grads, state, cfg, jnp.float32(0.05))
    assert float(jnp.abs(params["w"]).max()) < 5e-2


def test_warmup_cosine_shape():
    lrs = [
        float(warmup_cosine(jnp.int32(s), peak_lr=1.0, warmup_steps=10, total_steps=100))
        for s in [0, 5, 10, 50, 100]
    ]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)


def test_compression_error_feedback_unbiased():
    """Sum of dequantized grads + final residual == sum of true grads."""
    rng = np.random.default_rng(0)
    grads_seq = [
        {"w": jnp.asarray(rng.standard_normal(64).astype(np.float32))}
        for _ in range(20)
    ]
    state = init_compression(grads_seq[0])
    total_deq = jnp.zeros(64)
    for g in grads_seq:
        dq, state = compress_decompress(g, state)
        total_deq = total_deq + dq["w"]
    total_true = sum(g["w"] for g in grads_seq)
    # EF: residual bounded by one quantization step, not accumulated
    np.testing.assert_allclose(
        np.asarray(total_deq + state["w"]), np.asarray(total_true), rtol=1e-5, atol=1e-5
    )
    err = float(jnp.abs(total_deq - total_true).max())
    assert err < 0.1  # residual stays small, independent of sequence length


def test_train_step_reduces_loss_tiny_lm():
    cfg = get_smoke_config("yi_6b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    scfg = TrainStepConfig(peak_lr=3e-3, warmup_steps=5, total_steps=60)
    state = make_train_state(model, params, scfg)
    step_fn = jax.jit(make_train_step(model, scfg))
    rng = np.random.default_rng(0)
    # tiny repetitive corpus → loss must drop fast
    base = rng.integers(0, 64, 128).astype(np.int32)
    tokens = np.tile(base, 20)
    first = last = None
    for step in range(40):
        start = rng.integers(0, len(tokens) - 33, 4)
        batch = {
            "tokens": jnp.asarray(
                np.stack([tokens[s : s + 33] for s in start]).astype(np.int32)
            )
        }
        params, state, metrics = step_fn(params, state, batch)
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 0.5, (first, last)


def test_grad_accumulation_matches_big_batch():
    cfg = get_smoke_config("yi_6b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, 256, (8, 17)).astype(np.int32))

    one = TrainStepConfig(accum_steps=1, peak_lr=1e-3, grad_clip=1e9)
    acc = TrainStepConfig(accum_steps=4, peak_lr=1e-3, grad_clip=1e9)
    s1 = make_train_state(model, params, one)
    s2 = make_train_state(model, params, acc)
    p1, _, m1 = jax.jit(make_train_step(model, one))(params, s1, {"tokens": tokens})
    p2, _, m2 = jax.jit(make_train_step(model, acc))(
        params, s2, {"tokens": tokens.reshape(4, 2, 17)}
    )
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-3, atol=2e-5
        )


def test_checkpoint_roundtrip_and_atomicity(catalog, fmt):
    model = LM(get_smoke_config("yi_6b"))
    params = model.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(catalog, prefix="models/test")
    mgr.save(params, branch="main", step=7)
    like = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params
    )
    restored, step = mgr.restore(like, branch="main")
    assert step == 7
    for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(restored)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected(catalog, fmt):
    model = LM(get_smoke_config("yi_6b"))
    params = model.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(catalog, prefix="models/test")
    mgr.save(params, branch="main", step=1)
    other = LM(get_smoke_config("granite_34b")).init(jax.random.PRNGKey(0))
    with pytest.raises((ValueError, KeyError)):
        mgr.restore(other, branch="main")


def _setup_loop(catalog, fmt, total_steps, ckpt_every=5, sched_steps=15):
    rng = np.random.default_rng(0)
    tokens = np.tile(rng.integers(0, 64, 256), 10)
    key = write_token_table(fmt, catalog, "corpus", tokens)
    cfg = get_smoke_config("yi_6b")
    model = LM(cfg)
    ds = TokenDataset(fmt, key, batch_size=2, seq_len=16, seed=0)
    loop = TrainLoop(
        model, ds, catalog,
        branch="train_branch",
        config=TrainLoopConfig(
            total_steps=total_steps,
            checkpoint_every=ckpt_every,
            log_every=100,
            async_checkpoint=False,
            # schedule horizon pinned independently of how far this
            # invocation runs — an interrupted run must see the same LR
            step=TrainStepConfig(peak_lr=1e-3, warmup_steps=2, total_steps=sched_steps),
        ),
    )
    return loop


def test_loop_restart_is_exact(catalog, fmt):
    """Uninterrupted run == run killed at step 10 and resumed."""
    loop_a = _setup_loop(catalog, fmt, total_steps=15, ckpt_every=5)
    full = loop_a.run()

    # fresh catalog for the interrupted version
    import tempfile

    from repro.catalog import Catalog
    from repro.io import ObjectStore
    from repro.table import TableFormat

    store2 = ObjectStore(tempfile.mkdtemp())
    catalog2 = Catalog(store2)
    fmt2 = TableFormat(store2, shard_rows=128)
    loop_b = _setup_loop(catalog2, fmt2, total_steps=10, ckpt_every=5)
    loop_b.run()  # "crashes" after 10 steps (checkpoint at 10 exists)
    loop_c = _setup_loop(catalog2, fmt2, total_steps=15, ckpt_every=5)
    resumed = loop_c.run()
    assert resumed["steps_run"] == 5  # resumed from step 10
    for a, b in zip(
        jax.tree_util.tree_leaves(full["params"]),
        jax.tree_util.tree_leaves(resumed["params"]),
    ):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-5, atol=1e-6,
        )


def test_loop_async_checkpoint(catalog, fmt):
    loop = _setup_loop(catalog, fmt, total_steps=6, ckpt_every=3)
    loop.config.async_checkpoint = True
    out = loop.run()
    assert out["steps_run"] == 6
    assert loop.ckpt.latest_step(branch="train_branch") == 6
