"""Unit + property tests for the content-addressed object store."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline CI: deterministic fallback shim
    from tests._hypothesis_compat import given, settings
    from tests._hypothesis_compat import strategies as st

from repro.io import ObjectStore, array_to_bytes, bytes_to_array


def test_put_get_roundtrip(store):
    key = store.put(b"hello lakehouse")
    assert store.get(key) == b"hello lakehouse"
    assert store.exists(key)


def test_put_is_idempotent(store):
    k1 = store.put(b"same bytes")
    bytes_before = store.stats.bytes_written
    k2 = store.put(b"same bytes")
    assert k1 == k2
    # second put counts in telemetry but file already existed
    assert store.stats.puts == 2
    assert store.stats.bytes_written == 2 * bytes_before / 2 + len(b"same bytes")


def test_corruption_detected(store, tmp_path):
    key = store.put(b"precious")
    path = store._object_path(key)
    path.write_bytes(b"tampered")
    with pytest.raises(IOError):
        store.get(key)


def test_refs_cas(store):
    store.set_ref("branches", "main", {"commit": "a"})
    assert store.compare_and_set_ref("branches", "main", {"commit": "a"}, {"commit": "b"})
    assert not store.compare_and_set_ref("branches", "main", {"commit": "a"}, {"commit": "c"})
    assert store.get_ref("branches", "main") == {"commit": "b"}


def test_ref_listing_and_delete(store):
    store.set_ref("ns", "x/y", {"v": 1})
    store.set_ref("ns", "z", {"v": 2})
    assert store.list_refs("ns") == {"x/y": {"v": 1}, "z": {"v": 2}}
    store.delete_ref("ns", "x/y")
    assert store.list_refs("ns") == {"z": {"v": 2}}


@given(
    data=st.binary(min_size=0, max_size=2048),
)
@settings(max_examples=50, deadline=None)
def test_property_content_addressing(tmp_path_factory, data):
    store = ObjectStore(tmp_path_factory.mktemp("prop"))
    key = store.put(data)
    assert store.get(key) == data


@given(
    shape=st.lists(st.integers(0, 7), min_size=1, max_size=3),
    dtype=st.sampled_from(["float32", "int32", "uint16", "float64", "bool"]),
)
@settings(max_examples=50, deadline=None)
def test_property_tensor_serialization(shape, dtype):
    rng = np.random.default_rng(42)
    arr = (rng.standard_normal(shape) * 10).astype(dtype)
    out = bytes_to_array(array_to_bytes(arr))
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


# ----------------------------------------------------- delete + sweep (GC)
def test_delete_blob_idempotent(store):
    key = store.put(b"ephemeral")
    size = store.delete(key)
    assert size == len(b"ephemeral")
    assert not store.exists(key)
    # second delete is a safe no-op (retryable sweeps)
    assert store.delete(key) == 0


def test_delete_ref_idempotent(store):
    """Regression (ISSUE 2): delete_ref must no-op on a missing ref so
    eviction/GC sweeps can retry safely after a crash."""
    store.set_ref("ns", "victim", {"v": 1})
    assert store.delete_ref("ns", "victim") is True
    assert store.get_ref("ns", "victim") is None
    assert store.delete_ref("ns", "victim") is False
    # a ref that never existed is equally fine
    assert store.delete_ref("ns", "never_there") is False
    assert store.delete_ref("empty_namespace", "nope") is False


def test_sweep_keeps_live_objects(store):
    live = store.put(b"live data")
    dead1 = store.put(b"dead one")
    dead2 = store.put(b"dead two")
    result = store.sweep({live}, grace_s=0.0)
    assert result.swept == 2
    assert result.bytes_reclaimed == len(b"dead one") + len(b"dead two")
    assert store.exists(live)
    assert not store.exists(dead1) and not store.exists(dead2)
    assert store.stats.gc_objects_swept == 2
    assert store.stats.gc_bytes_reclaimed == result.bytes_reclaimed


def test_sweep_dry_run_reports_without_deleting(store):
    store.put(b"live")
    dead = store.put(b"doomed")
    result = store.sweep(set(), grace_s=0.0, dry_run=True)
    assert result.dry_run and result.swept == 2
    assert store.exists(dead)
    assert store.stats.gc_objects_swept == 0


def test_object_size_and_age(store):
    key = store.put(b"12345")
    assert store.object_size(key) == 5
    assert store.object_age_s(key) is not None
    assert store.object_size("00" * 16) is None
    assert store.object_age_s("00" * 16) is None
