"""Unit + property tests for the content-addressed object store."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline CI: deterministic fallback shim
    from tests._hypothesis_compat import given, settings
    from tests._hypothesis_compat import strategies as st

from repro.io import ObjectStore, array_to_bytes, bytes_to_array


def test_put_get_roundtrip(store):
    key = store.put(b"hello lakehouse")
    assert store.get(key) == b"hello lakehouse"
    assert store.exists(key)


def test_put_is_idempotent(store):
    k1 = store.put(b"same bytes")
    bytes_before = store.stats.bytes_written
    k2 = store.put(b"same bytes")
    assert k1 == k2
    # second put counts in telemetry but file already existed
    assert store.stats.puts == 2
    assert store.stats.bytes_written == 2 * bytes_before / 2 + len(b"same bytes")


def test_corruption_detected(store, tmp_path):
    key = store.put(b"precious")
    path = store._object_path(key)
    path.write_bytes(b"tampered")
    with pytest.raises(IOError):
        store.get(key)


def test_refs_cas(store):
    store.set_ref("branches", "main", {"commit": "a"})
    assert store.compare_and_set_ref("branches", "main", {"commit": "a"}, {"commit": "b"})
    assert not store.compare_and_set_ref("branches", "main", {"commit": "a"}, {"commit": "c"})
    assert store.get_ref("branches", "main") == {"commit": "b"}


def test_ref_listing_and_delete(store):
    store.set_ref("ns", "x/y", {"v": 1})
    store.set_ref("ns", "z", {"v": 2})
    assert store.list_refs("ns") == {"x/y": {"v": 1}, "z": {"v": 2}}
    store.delete_ref("ns", "x/y")
    assert store.list_refs("ns") == {"z": {"v": 2}}


@given(
    data=st.binary(min_size=0, max_size=2048),
)
@settings(max_examples=50, deadline=None)
def test_property_content_addressing(tmp_path_factory, data):
    store = ObjectStore(tmp_path_factory.mktemp("prop"))
    key = store.put(data)
    assert store.get(key) == data


@given(
    shape=st.lists(st.integers(0, 7), min_size=1, max_size=3),
    dtype=st.sampled_from(["float32", "int32", "uint16", "float64", "bool"]),
)
@settings(max_examples=50, deadline=None)
def test_property_tensor_serialization(shape, dtype):
    rng = np.random.default_rng(42)
    arr = (rng.standard_normal(shape) * 10).astype(dtype)
    out = bytes_to_array(array_to_bytes(arr))
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)
