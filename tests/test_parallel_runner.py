"""Wave-parallel DAG execution: determinism parity, rollback, run_async.

The scheduler contract under test: **parallelism, ordering mode and
streaming are throughput knobs, never semantics knobs**.  A run at
parallelism 1 in stage_id order with streaming off (which degenerates to
the old sequential stage loop) and runs across the full matrix —
schedule ∈ {stage_id, critical_path} × streaming ∈ {off, on} ×
parallelism ∈ {1, 2, 8} — must produce byte-identical artifact manifests,
identical check verdicts, identical node-cache entries and fingerprints —
and a mid-DAG audit failure must roll back identically in every mode.
"""
import threading
import time

import numpy as np
import pytest

from repro.api import AsyncRunHandle, Client, RunState
from repro.core import Pipeline
from repro.examples_data import TAXI_SCHEMA, make_taxi_data
from repro.io import ObjectStore, StoreStats
from repro.runtime import ExecutorConfig

N_ROWS = 4_000
PARALLELISMS = (1, 2, 8)


def _client(parallelism: int) -> Client:
    return Client.ephemeral(
        shard_rows=512,
        executor_config=ExecutorConfig(
            max_workers=8, max_concurrent_stages=parallelism
        ),
    )


def build_fanout_pipeline(threshold: float = 10.0) -> Pipeline:
    """A diamond with an 3-way fan-out middle: source -> (m0, m1, m2) ->
    combine, plus an audit — enough structure that waves genuinely
    overlap and a dependent stage must wait for two parents."""
    p = Pipeline("parallel_parity")
    p.sql(
        "trips",
        """
        SELECT pickup_location_id, passenger_count as count,
               dropoff_location_id
        FROM taxi_table
        WHERE pickup_at >= '2019-04-01'
        """,
    )

    @p.python
    def trips_expectation(ctx, trips):
        return trips.mean("count") > threshold

    for i in range(3):

        def make_model(i):
            def fn(ctx, trips):
                import jax.numpy as jnp

                col = trips.column("count").astype(jnp.float32)
                return {"stat": jnp.sort(col) * (i + 1)}

            fn.__name__ = f"m{i}"
            return fn

        p.python(make_model(i))

    @p.python
    def combine(ctx, m0, m1):
        import jax.numpy as jnp

        return {"delta": m1.column("stat") - m0.column("stat")}

    return p


def _run_once(
    parallelism: int,
    *,
    threshold: float = 10.0,
    schedule: str = "critical_path",
    streaming=None,
):
    rng = np.random.default_rng(7)
    with _client(parallelism) as client:
        client.write_table(
            "taxi_table", make_taxi_data(N_ROWS, rng), schema=TAXI_SCHEMA
        )
        handle = client.run(
            build_fanout_pipeline(threshold),
            fusion=False,
            pushdown=False,
            parallelism=parallelism,
            raise_errors=False,
            schedule=schedule,
            streaming=streaming,
        )
        cache_entries = {
            fp: dict(e.outputs)
            for fp, e in client.cache_registry.entries().items()
        }
        return {
            "state": handle.state,
            "artifacts": dict(handle.artifacts),
            "checks": dict(handle.checks),
            "cache_entries": cache_entries,
            "node_fps": dict(handle.plan.node_fingerprints),
            "parallelism": handle.stats.get("parallelism"),
            "scheduler": handle.stats.get("scheduler", {}),
            "branches": client.branches(),
            "head_tables": client.tables(),
        }


#: the full determinism matrix: ordering mode × streaming × parallelism.
#: (stage_id, False, 1) is the sequential PR-5 baseline everything else
#: must match byte-for-byte.
SCHEDULE_MATRIX = [
    (schedule, streaming, p)
    for schedule in ("stage_id", "critical_path")
    for streaming in (False, True)
    for p in PARALLELISMS
]


def test_parallelism_parity_matrix():
    """The full scheduler matrix vs the sequential baseline (stage_id,
    streaming off, parallelism 1): byte-identical artifact manifests
    (content-addressed keys), identical verdicts, identical node-cache
    entries and fingerprints — ordering mode, streaming handoff and
    parallelism change throughput only."""
    base = _run_once(1, schedule="stage_id", streaming=False)
    assert base["state"] is RunState.SUCCESS
    assert base["parallelism"] == 1
    for schedule, streaming, p in SCHEDULE_MATRIX:
        if (schedule, streaming, p) == ("stage_id", False, 1):
            continue
        got = _run_once(p, schedule=schedule, streaming=streaming)
        label = f"{schedule} streaming={streaming} parallelism={p}"
        assert got["state"] is RunState.SUCCESS, label
        assert got["parallelism"] == p, label
        assert got["artifacts"] == base["artifacts"], label
        assert got["checks"] == base["checks"], label
        assert got["cache_entries"] == base["cache_entries"], label
        assert got["node_fps"] == base["node_fps"], label
        assert got["head_tables"] == base["head_tables"], label
        assert got["scheduler"]["schedule"] == schedule, label
        assert got["scheduler"]["streaming"] is streaming, label
    # something actually fanned out: 6 nodes -> 6 isomorphic stages
    assert len(base["artifacts"]) == 5  # trips, m0..m2, combine


def test_parallel_audit_failure_rolls_back_identically():
    """Mid-DAG audit failure under concurrency, in both ordering modes
    with and without streaming: AUDIT_FAILED handle, head unmoved,
    ephemeral branch gone, zero cache entries persisted — same as the
    sequential rollback."""
    for schedule, streaming, parallelism in [
        ("stage_id", False, 1),
        ("stage_id", True, 8),
        ("critical_path", False, 8),
        ("critical_path", True, 8),
    ]:
        res = _run_once(
            parallelism, threshold=10_000.0,  # audit must fail
            schedule=schedule, streaming=streaming,
        )
        label = f"{schedule} streaming={streaming} parallelism={parallelism}"
        assert res["state"] is RunState.AUDIT_FAILED, label
        assert res["checks"]["trips_expectation"] is False, label
        # rollback: nothing merged, nothing cached, no run_* branch leaked
        assert res["head_tables"] == {
            "taxi_table": res["head_tables"]["taxi_table"]
        }, label
        assert res["cache_entries"] == {}, label
        assert [b for b in res["branches"] if b.startswith("run_")] == []


def test_parallel_commit_history_is_linear_and_ordered():
    """The commit queue applies per-stage commits in stage-id order: the
    merged run's ephemeral lineage reads 'stage 0, stage 1, ...' whatever
    order the stages actually finished in."""
    rng = np.random.default_rng(3)
    with _client(8) as client:
        client.write_table(
            "taxi_table", make_taxi_data(N_ROWS, rng), schema=TAXI_SCHEMA
        )
        handle = client.run(
            build_fanout_pipeline(), fusion=False, pushdown=False,
            parallelism=8,
        ).raise_for_state()
        merge = client.catalog.get_commit(handle.merged_commit)
        # walk the ephemeral side of the merge: stage commits, newest first
        messages = []
        cur = client.catalog.get_commit_opt(merge.extra_parent_id)
        while cur is not None and cur.author == "runner":
            messages.append(cur.message)
            cur = client.catalog.get_commit_opt(cur.parent_id)
        stage_messages = [
            m for m in reversed(messages)
            if f"run {handle.run_id} stage" in m
        ]
        expected = [
            f"run {handle.run_id} stage {sid}"
            for sid in range(len(handle.plan.stages))
            if handle.plan.stages[sid].outputs
        ]
        assert stage_messages == expected


def test_dependent_stage_waits_for_both_parents():
    """`combine` consumes m0 and m1 — the wave scheduler must not launch
    it until both complete; the output proves it saw real inputs."""
    rng = np.random.default_rng(5)
    with _client(8) as client:
        client.write_table(
            "taxi_table", make_taxi_data(N_ROWS, rng), schema=TAXI_SCHEMA
        )
        handle = client.run(
            build_fanout_pipeline(), fusion=False, pushdown=False,
            parallelism=8,
        ).raise_for_state()
        delta = handle.artifact("combine")["delta"]
        m0 = handle.artifact("m0")["stat"]
        np.testing.assert_allclose(delta, m0)  # m1 = 2*m0, so delta = m0


def test_run_async_resolves_to_same_handle_semantics():
    rng = np.random.default_rng(7)  # same fixture as _run_once (parity)
    with _client(4) as client:
        client.write_table(
            "taxi_table", make_taxi_data(N_ROWS, rng), schema=TAXI_SCHEMA
        )
        async_handle = client.run_async(
            build_fanout_pipeline(), fusion=False, pushdown=False
        )
        assert isinstance(async_handle, AsyncRunHandle)
        assert async_handle.state in (RunState.RUNNING, RunState.SUCCESS)
        resolved = async_handle.result(timeout=120)
        assert resolved.state is RunState.SUCCESS
        assert async_handle.state is RunState.SUCCESS
        assert async_handle.done() and not async_handle.running
        assert async_handle.poll() is resolved
        # the async run merged for real
        assert "combine" in client.tables()
        # parity with a synchronous run on a fresh lake
        sync = _run_once(4)
        assert dict(resolved.artifacts) == sync["artifacts"]


def test_run_async_audit_failure_and_error_capture():
    rng = np.random.default_rng(13)
    with _client(4) as client:
        client.write_table(
            "taxi_table", make_taxi_data(N_ROWS, rng), schema=TAXI_SCHEMA
        )
        failed = client.run_async(
            build_fanout_pipeline(threshold=10_000.0),
            fusion=False, pushdown=False,
        ).result(timeout=120)
        assert failed.state is RunState.AUDIT_FAILED
        assert client.branches() == ["main"]  # rolled back, nothing leaked

        # infra error (missing source table): captured, not raised
        p = Pipeline("missing_source")
        p.sql("x", "SELECT pickup_at FROM no_such_table")
        err = client.run_async(p).result(timeout=120)
        assert err.state is RunState.ERROR
        assert isinstance(err.error, KeyError)


def test_run_async_poll_is_nonblocking():
    """poll() returns None while the run is in flight (a slow stage keeps
    it busy long enough to observe RUNNING deterministically)."""
    p = Pipeline("slow")
    evt = threading.Event()

    @p.python
    def slow_model(ctx, taxi_table):
        import jax

        def wait_host(x):
            evt.wait(10.0)
            return np.float32(0.0)

        import jax.numpy as jnp

        score = jax.pure_callback(
            wait_host, jax.ShapeDtypeStruct((), jnp.float32),
            taxi_table.column("passenger_count"),
        )
        return {"score": score[None]}

    rng = np.random.default_rng(17)
    with _client(2) as client:
        client.write_table(
            "taxi_table", make_taxi_data(512, rng), schema=TAXI_SCHEMA
        )
        handle = client.run_async(p)
        try:
            assert handle.poll() is None
            assert handle.state is RunState.RUNNING
        finally:
            evt.set()
        assert handle.result(timeout=120).state is RunState.SUCCESS


def test_branch_handle_run_async_rolls_back_on_failure():
    rng = np.random.default_rng(19)
    with _client(4) as client:
        client.write_table(
            "taxi_table", make_taxi_data(N_ROWS, rng), schema=TAXI_SCHEMA
        )
        with client.branch("feat_async") as branch:
            h = branch.run_async(
                build_fanout_pipeline(threshold=10_000.0),
                fusion=False, pushdown=False,
            )
            assert h.result(timeout=120).state is RunState.AUDIT_FAILED
        # ephemeral branch rolled back (deleted, not merged)
        assert client.branches() == ["main"]
        assert "combine" not in client.tables()


def test_branch_handle_exit_joins_inflight_async_run():
    """Leaving the `with` block while an async run is still in flight
    must JOIN it first — the merge/rollback decision sees the outcome,
    and the run's merge never races the branch's deletion."""
    rng = np.random.default_rng(23)
    with _client(4) as client:
        client.write_table(
            "taxi_table", make_taxi_data(N_ROWS, rng), schema=TAXI_SCHEMA
        )
        with client.branch("feat_join") as branch:
            handle = branch.run_async(
                build_fanout_pipeline(), fusion=False, pushdown=False
            )
            # deliberately no result(): __exit__ must join for us
        assert handle.result(timeout=1).state is RunState.SUCCESS
        assert client.branches() == ["main"]  # merged + deleted
        assert "combine" in client.tables()


def test_store_stats_bump_is_atomic_under_threads():
    """The satellite regression: hammer one counter from many threads;
    no increment may be lost."""
    stats = StoreStats()
    threads = [
        threading.Thread(
            target=lambda: [stats.bump(puts=1, bytes_written=3) for _ in range(500)]
        )
        for _ in range(8)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = stats.snapshot()
    assert snap["puts"] == 8 * 500
    assert snap["bytes_written"] == 8 * 500 * 3


def test_object_store_io_accounting_from_concurrent_writers(tmp_path):
    store = ObjectStore(tmp_path / "lake")
    payloads = [bytes([i]) * 1000 for i in range(32)]

    def write_all():
        for b in payloads:
            store.put(b)

    threads = [threading.Thread(target=write_all) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = store.stats.snapshot()
    assert snap["puts"] == 4 * len(payloads)
    assert snap["bytes_written"] == 4 * sum(len(b) for b in payloads)
