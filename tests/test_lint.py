"""The static preflight pass — golden reports for every lint rule.

Each test seeds one specific defect into a small pipeline and asserts
the exact rule fires (with a usable file:line), plus the negative space:
clean pipelines come back clean, noqa suppresses, and ``client.lint``
touches the store read-only.
"""
import json

import numpy as np
import pytest

import repro
from repro.analysis import Finding, LintFailed, LintReport, Severity, lint_pipeline
from repro.api import RedefinitionWarning
from repro.api.project import Project
from repro.cli import main
from repro.core import Pipeline
from repro.engine.sql import SqlError, parse_sql
from repro.table.schema import Schema
from tests.helpers_taxi import TAXI_SCHEMA, make_taxi_data

TAXI = {
    "taxi_table": Schema.of(
        pickup_at="int32",
        pickup_location_id="int32",
        passenger_count="int32",
        dropoff_location_id="int32",
    )
}


def lint(pipeline, schemas=TAXI) -> LintReport:
    return lint_pipeline(pipeline, external_schemas=schemas)


def rules(report: LintReport):
    return {f.rule for f in report.findings}


# ------------------------------------------------------------ lineage (L)
def test_l001_missing_column_sql():
    p = Pipeline("t")
    p.sql("trips", "SELECT total_fare FROM taxi_table")
    report = lint(p)
    (f,) = report.by_rule("L001")
    assert f.severity is Severity.ERROR
    assert "total_fare" in f.message and "taxi_table" in f.message
    assert f.file and f.file.endswith("test_lint.py") and f.line
    assert "total_fare" in (f.snippet or "")


def test_l001_missing_column_python_ast():
    proj = Project("l001_py")
    proj.sql("trips", "SELECT pickup_at, passenger_count FROM taxi_table")

    @proj.model()
    def doubled(ctx, trips):
        return {"x": trips["fare_amount"] * 2}  # not a trips column

    report = lint(proj.pipeline())
    (f,) = report.by_rule("L001")
    assert f.node == "doubled"
    assert "fare_amount" in f.message
    assert f.file.endswith("test_lint.py")
    assert "fare_amount" in f.snippet


def test_l001_python_columnar_method_arg():
    proj = Project("l001_method")

    @proj.model()
    def stats(ctx, taxi_table):
        return {"m": np.asarray([taxi_table.mean("nonexistent")])}

    report = lint(proj.pipeline())
    assert "L001" in rules(report)


def test_l002_group_key_type_mismatch():
    p = Pipeline("t")
    p.sql("by_amount", "SELECT amount, COUNT(*) AS n FROM orders GROUP BY amount")
    report = lint(p, {"orders": Schema.of(amount="float32")})
    (f,) = report.by_rule("L002")
    assert f.severity is Severity.ERROR
    assert "float32" in f.message


def test_l003_order_by_not_in_outputs():
    p = Pipeline("t")
    p.sql(
        "pickups",
        "SELECT pickup_location_id, COUNT(*) AS counts FROM taxi_table "
        "GROUP BY pickup_location_id ORDER BY passenger_count DESC",
    )
    report = lint(p)
    (f,) = report.by_rule("L003")
    assert "passenger_count" in f.message


def test_l004_unknown_table():
    p = Pipeline("t")
    p.sql("trips", "SELECT x FROM no_such_table")
    report = lint(p)
    (f,) = report.by_rule("L004")
    assert f.severity is Severity.ERROR
    assert "no_such_table" in f.message
    # without a catalog context, existence cannot be judged — no finding
    assert "L004" not in rules(lint_pipeline(p))


def test_clean_pipeline_is_clean():
    p = Pipeline("t")
    p.sql(
        "pickups",
        "SELECT pickup_location_id, COUNT(*) AS counts FROM taxi_table "
        "GROUP BY pickup_location_id ORDER BY counts DESC",
    )
    report = lint(p)
    assert report.findings == []
    assert report.ok(strict=True)


def test_schema_propagates_through_sql_chain():
    # stage 2 references a column stage 1 dropped — caught via propagation
    p = Pipeline("t")
    p.sql("narrow", "SELECT pickup_at FROM taxi_table")
    p.sql("later", "SELECT passenger_count FROM narrow")
    report = lint(p)
    (f,) = report.by_rule("L001")
    assert f.node == "later" and "passenger_count" in f.message


# ------------------------------------------------------- cache poison (D)
def _d_findings(fn_body_project):
    return lint(fn_body_project.pipeline())


def test_d101_wall_clock():
    proj = Project("d101")

    @proj.model()
    def stamped(ctx, taxi_table):
        import time

        return {"t": np.asarray([time.time()], dtype=np.float32)}

    report = _d_findings(proj)
    (f,) = report.by_rule("D101")
    assert f.severity is Severity.WARNING
    assert "time.time" in f.message


def test_d102_unseeded_random():
    proj = Project("d102")

    @proj.model()
    def noisy(ctx, taxi_table):
        rng = np.random.default_rng()
        return {"x": rng.random(4).astype(np.float32)}

    report = _d_findings(proj)
    assert len(report.by_rule("D102")) == 1

    seeded = Project("d102_ok")

    @seeded.model()
    def quiet(ctx, taxi_table):
        rng = np.random.default_rng(7)
        return {"x": rng.random(4).astype(np.float32)}

    assert _d_findings(seeded).by_rule("D102") == []


def test_d102_legacy_global_stream():
    proj = Project("d102_legacy")

    @proj.model()
    def legacy(ctx, taxi_table):
        return {"x": np.random.rand(4).astype(np.float32)}

    assert len(_d_findings(proj).by_rule("D102")) == 1


def test_d103_uuid():
    proj = Project("d103")

    @proj.model()
    def tagged(ctx, taxi_table):
        import uuid

        run_tag = uuid.uuid4()
        return {"x": np.asarray([run_tag.int % 7], dtype=np.int32)}

    assert len(_d_findings(proj).by_rule("D103")) == 1


def test_d104_environment_read():
    proj = Project("d104")

    @proj.model()
    def configured(ctx, taxi_table):
        import os

        mode = os.environ.get("MODE", "fast")
        return {"x": np.asarray([len(mode)], dtype=np.int32)}

    found = _d_findings(proj).by_rule("D104")
    assert len(found) == 1  # call + attribute matchers dedup to one


def test_d105_file_io():
    proj = Project("d105")

    @proj.model()
    def sneaky(ctx, taxi_table):
        with open("side.csv") as fh:
            n = len(fh.read())
        return {"x": np.asarray([n], dtype=np.int32)}

    assert len(_d_findings(proj).by_rule("D105")) == 1


def test_d106_global_mutation():
    proj = Project("d106")

    @proj.model()
    def leaky(ctx, taxi_table):
        global _COUNTER  # noqa: PLW0603
        _COUNTER = 1
        return {"x": np.asarray([_COUNTER], dtype=np.int32)}

    assert len(_d_findings(proj).by_rule("D106")) == 1


def test_d107_input_table_mutation():
    proj = Project("d107")

    @proj.model()
    def mutator(ctx, taxi_table):
        taxi_table.columns["pickup_at"] = np.zeros(1, dtype=np.int32)
        return {"x": np.zeros(1, dtype=np.int32)}

    (f,) = _d_findings(proj).by_rule("D107")
    assert "taxi_table" in f.message


# ------------------------------------------------------------- noqa
def test_noqa_rule_scoped_suppression():
    proj = Project("noqa_scoped")

    @proj.model()
    def noisy(ctx, taxi_table):
        rng = np.random.default_rng()  # repro: noqa[D102]
        return {"x": rng.random(4).astype(np.float32)}

    report = _d_findings(proj)
    assert report.by_rule("D102") == []
    assert report.suppressed == 1


def test_noqa_bare_suppresses_all():
    proj = Project("noqa_bare")

    @proj.model()
    def noisy(ctx, taxi_table):
        import time
        t = time.time()  # repro: noqa
        return {"x": np.asarray([t], dtype=np.float32)}

    report = _d_findings(proj)
    assert report.by_rule("D101") == []
    assert report.suppressed == 1


def test_noqa_wrong_rule_does_not_suppress():
    proj = Project("noqa_wrong")

    @proj.model()
    def noisy(ctx, taxi_table):
        rng = np.random.default_rng()  # repro: noqa[D101]
        return {"x": rng.random(4).astype(np.float32)}

    report = _d_findings(proj)
    assert len(report.by_rule("D102")) == 1
    assert report.suppressed == 0


# ----------------------------------------------------- plan diagnostics (G)
def test_g301_orphan_expectation():
    proj = Project("orphan")
    proj.sql("trips", "SELECT pickup_at FROM taxi_table")

    @proj.expectation()
    def check(ctx, taxi_table):  # audits the raw input, not an artifact
        return True

    report = lint(proj.pipeline())
    (f,) = report.by_rule("G301")
    assert f.severity is Severity.WARNING
    assert f.node == "check"


def test_g302_cycle_with_locations():
    p = Pipeline("cyclic")
    p.sql("a", "SELECT x FROM b")
    p.sql("b", "SELECT x FROM a")
    report = lint_pipeline(p)
    (f,) = report.by_rule("G302")
    assert f.severity is Severity.ERROR
    assert "a" in f.message and "b" in f.message
    assert "test_lint.py" in f.message  # file:line chain in the message
    assert not report.ok()
    assert report.blast_radius == {}  # no meaningful radius on a cycle


def test_g303_unreachable_behind_cycle():
    p = Pipeline("cyclic2")
    p.sql("a", "SELECT x FROM b")
    p.sql("b", "SELECT x FROM a")
    p.sql("c", "SELECT x FROM a")  # schedulable never: parent in the cycle
    report = lint_pipeline(p)
    assert {f.node for f in report.by_rule("G303")} == {"c"}


def test_g304_redefinition_warns_and_reports():
    proj = Project("redef_g304")
    proj.sql("trips", "SELECT pickup_at FROM taxi_table")
    with pytest.warns(RedefinitionWarning):
        proj.sql("trips", "SELECT passenger_count FROM taxi_table")
    report = lint(proj.pipeline())
    (f,) = report.by_rule("G304")
    assert "trips" in f.message and "replaced" in f.message


def test_identical_reregistration_is_silent(recwarn):
    proj = Project("redef_same")
    proj.sql("trips", "SELECT pickup_at FROM taxi_table")
    proj.sql("trips", "SELECT pickup_at FROM taxi_table")  # same code
    assert not [w for w in recwarn if w.category is RedefinitionWarning]
    assert lint(proj.pipeline()).by_rule("G304") == []


# ------------------------------------------------------------ blast radius
def test_blast_radius_chain():
    p = Pipeline("chain")
    p.sql("a", "SELECT pickup_at FROM taxi_table")
    p.sql("b", "SELECT pickup_at FROM a")
    p.sql("c", "SELECT pickup_at FROM b")
    radius = lint(p).blast_radius
    assert radius["a"] == ["b", "c"]
    assert radius["b"] == ["c"]
    assert radius["c"] == []


# ----------------------------------------------------------- SQL positions
def test_sql_error_carries_position_and_fragment():
    with pytest.raises(SqlError) as ei:
        parse_sql("SELECT pickup_at FROM taxi_table WHERE pickup_at >")
    err = ei.value
    assert isinstance(err, SyntaxError)  # legacy except-clauses keep working
    assert isinstance(err.pos, int) and err.pos > 0
    assert err.fragment
    assert "position" in str(err)


def test_sql_tokenize_error_position():
    with pytest.raises(SqlError) as ei:
        parse_sql("SELECT pickup_at $ FROM taxi_table")
    assert ei.value.pos == len("SELECT pickup_at ")


def test_parsed_query_keeps_raw_sql_out_of_fingerprint():
    q1 = parse_sql("SELECT pickup_at FROM taxi_table")
    q2 = parse_sql("SELECT  pickup_at  FROM  taxi_table")
    assert q1.raw_sql != q2.raw_sql
    assert q1 == q2  # raw_sql is compare=False
    assert "raw_sql" not in q1.to_json_dict()  # fingerprints unaffected


# --------------------------------------------------------- client surface
@pytest.fixture
def client(tmp_path, rng):
    with repro.Client(tmp_path / "lake") as c:
        c.write_table("taxi_table", make_taxi_data(500, rng), schema=TAXI_SCHEMA)
        yield c


def _broken_pipeline() -> Pipeline:
    p = Pipeline("broken")
    p.sql("trips", "SELECT total_fare FROM taxi_table")
    return p


def _clean_pipeline() -> Pipeline:
    p = Pipeline("clean")
    p.sql("trips", "SELECT pickup_at FROM taxi_table WHERE passenger_count > 1")
    return p


def test_client_lint_zero_store_writes(client):
    puts_before = client.store.stats.puts
    report = client.lint(_broken_pipeline())
    assert not report.ok()
    assert client.store.stats.puts == puts_before  # read-only pass
    assert client._executor is None  # no fleet was ever constructed


def test_client_lint_resolves_catalog_schemas(client):
    report = client.lint(_clean_pipeline())
    assert report.ok(strict=True)
    # a table the catalog doesn't have is an L004 through the client too
    p = Pipeline("ghost")
    p.sql("x", "SELECT a FROM phantom_table")
    assert "L004" in rules(client.lint(p))


def test_preflight_refuses_broken_run(client):
    with pytest.raises(LintFailed) as ei:
        client.run(_broken_pipeline(), preflight=True)
    assert ei.value.report.by_rule("L001")
    # nothing ran, nothing merged
    assert "trips" not in client.tables("main")

    handle = client.run(_broken_pipeline(), preflight=True, raise_errors=False)
    assert handle.state is repro.RunState.ERROR
    assert isinstance(handle.error, LintFailed)


def test_preflight_clean_run_proceeds(client):
    handle = client.run(_clean_pipeline(), preflight=True)
    assert handle.state is repro.RunState.SUCCESS
    assert "trips" in client.tables("main")


def test_preflight_warnings_do_not_block(client):
    proj = Project("warn_only")

    @proj.model()
    def noisy(ctx, taxi_table):
        rng = np.random.default_rng()
        return {"x": rng.random(taxi_table.capacity).astype(np.float32)}

    report = client.lint(proj.pipeline())
    assert report.errors == [] and report.warnings
    handle = client.run(proj.pipeline(), preflight=True)
    assert handle.state is repro.RunState.SUCCESS


# ------------------------------------------------------------------- CLI
CLEAN_SRC = """
import repro

clean = repro.project("cli_lint_clean")
clean.sql("trips", "SELECT pickup_at FROM taxi_table WHERE passenger_count > 1")
"""


@pytest.fixture
def lake(tmp_path, rng):
    with repro.Client(tmp_path / "lake") as c:
        c.write_table("taxi_table", make_taxi_data(200, rng), schema=TAXI_SCHEMA)
    return tmp_path / "lake"


def test_cli_lint_clean(lake, tmp_path, capsys):
    f = tmp_path / "clean_pipe.py"
    f.write_text(CLEAN_SRC)
    main(["--lake", str(lake), "lint", str(f)])
    out = capsys.readouterr().out
    assert "preflight clean" in out


def test_cli_lint_broken_exits_nonzero(lake, capsys):
    with pytest.raises(SystemExit) as ei:
        main(["--lake", str(lake), "lint", "tests/fixtures/lint_broken_pipeline.py"])
    assert ei.value.code == 1
    out = capsys.readouterr().out
    assert "L001" in out and "D102" in out
    assert "lint_broken_pipeline.py" in out  # file:line surfaced


def test_cli_lint_json_report(lake, tmp_path, capsys):
    report_path = tmp_path / "report.json"
    with pytest.raises(SystemExit):
        main([
            "--lake", str(lake), "lint",
            "tests/fixtures/lint_broken_pipeline.py",
            "--json", str(report_path),
        ])
    data = json.loads(report_path.read_text())
    assert data["errors"] >= 1 and data["warnings"] >= 1
    assert {f["rule"] for f in data["findings"]} >= {"L001", "D102"}
    assert all("file" in f and "line" in f for f in data["findings"])


def test_cli_lint_strict_promotes_warnings(lake, tmp_path, capsys):
    f = tmp_path / "warn_pipe.py"
    f.write_text(
        CLEAN_SRC.replace("cli_lint_clean", "cli_lint_warn")
        + """
import numpy as np

@repro.model(project="cli_lint_warn")
def noisy(ctx, trips):
    rng = np.random.default_rng()
    return {"x": rng.random(4).astype(np.float32)}
"""
    )
    main(["--lake", str(lake), "lint", str(f)])  # warnings alone pass
    with pytest.raises(SystemExit) as ei:
        main(["--lake", str(lake), "lint", str(f), "--strict"])
    assert ei.value.code == 1


def test_cli_run_preflight_refuses(lake, capsys):
    with pytest.raises(SystemExit) as ei:
        main([
            "--lake", str(lake), "run",
            "tests/fixtures/lint_broken_pipeline.py", "--preflight",
        ])
    assert "PREFLIGHT FAILED" in str(ei.value.code)


# ------------------------------------------------------- shipped examples
def test_examples_lint_clean(tmp_path, rng, capsys):
    with repro.Client(tmp_path / "lake") as c:
        c.write_table("taxi_table", make_taxi_data(200, rng), schema=TAXI_SCHEMA)
        c.write_table(
            "orders",
            {
                "user_id": rng.integers(0, 100, 500).astype(np.int32),
                "amount": (rng.random(500) * 200).astype(np.float32),
                "country": rng.integers(0, 30, 500).astype(np.int32),
            },
        )
    for example in ("examples/taxi_pipeline.py", "examples/quickstart.py"):
        main(["--lake", str(tmp_path / "lake"), "lint", example, "--strict"])
        assert "preflight clean" in capsys.readouterr().out
