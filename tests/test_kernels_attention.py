"""flash_attention + decode_attention Pallas kernels vs jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.flash_attention import attention_ref, flash_attention


def qkv(rng, b, h, hkv, s, d, dtype=np.float32):
    q = rng.standard_normal((b, h, s, d)).astype(dtype)
    k = rng.standard_normal((b, hkv, s, d)).astype(dtype)
    v = rng.standard_normal((b, hkv, s, d)).astype(dtype)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


TOL = dict(rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize(
    "b,h,hkv,s,d",
    [
        (1, 2, 2, 128, 32),   # MHA
        (1, 4, 2, 128, 32),   # GQA 2:1
        (2, 4, 1, 256, 64),   # MQA
        (1, 2, 2, 192, 32),   # seq not multiple of default blocks
    ],
)
def test_flash_causal_shapes(b, h, hkv, s, d, rng):
    q, k, v = qkv(rng, b, h, hkv, s, d)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    exp = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), **TOL)


def test_flash_noncausal(rng):
    q, k, v = qkv(rng, 1, 2, 2, 128, 32)
    got = flash_attention(q, k, v, causal=False, block_q=64, block_k=64, interpret=True)
    exp = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), **TOL)


@pytest.mark.parametrize("window", [32, 64, 100])
def test_flash_sliding_window(window, rng):
    q, k, v = qkv(rng, 1, 2, 1, 256, 32)
    got = flash_attention(
        q, k, v, causal=True, window=window, block_q=64, block_k=64, interpret=True
    )
    exp = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), **TOL)


def test_flash_bf16(rng):
    q, k, v = qkv(rng, 1, 2, 2, 128, 32, dtype=np.float32)
    q, k, v = q.astype(jnp.bfloat16), k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    exp = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(exp, np.float32), rtol=3e-2, atol=3e-2
    )


def test_flash_block_shape_independence(rng):
    """Block size must not change the math."""
    q, k, v = qkv(rng, 1, 2, 2, 256, 32)
    a = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    b = flash_attention(q, k, v, block_q=128, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- decode
@pytest.mark.parametrize(
    "b,h,hkv,s,d",
    [
        (1, 2, 2, 256, 32),
        (2, 4, 2, 512, 64),
        (3, 4, 1, 384, 32),
    ],
)
def test_decode_shapes(b, h, hkv, s, d, rng):
    q = jnp.asarray(rng.standard_normal((b, h, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    lengths = jnp.asarray(rng.integers(1, s + 1, b).astype(np.int32))
    got = decode_attention(q, k, v, lengths, block_s=128, interpret=True)
    exp = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), **TOL)


def test_decode_full_cache(rng):
    b, h, hkv, s, d = 2, 2, 2, 256, 32
    q = jnp.asarray(rng.standard_normal((b, h, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    lengths = jnp.full((b,), s, jnp.int32)
    got = decode_attention(q, k, v, lengths, block_s=64, interpret=True)
    exp = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), **TOL)


def test_decode_tiny_length(rng):
    """Only the first cache entry is valid — masking must be exact."""
    b, h, hkv, s, d = 1, 2, 2, 128, 32
    q = jnp.asarray(rng.standard_normal((b, h, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    lengths = jnp.ones((b,), jnp.int32)
    got = decode_attention(q, k, v, lengths, block_s=64, interpret=True)
    exp = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), **TOL)
    # attending to 1 token == that token's value
    np.testing.assert_allclose(
        np.asarray(got[0, 0]), np.asarray(v[0, 0, 0]), **TOL
    )


def test_decode_bf16(rng):
    b, h, hkv, s, d = 2, 4, 2, 256, 32
    q = jnp.asarray(rng.standard_normal((b, h, d))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d))).astype(jnp.bfloat16)
    lengths = jnp.full((b,), s, jnp.int32)
    got = decode_attention(q, k, v, lengths, block_s=128, interpret=True)
    exp = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(exp, np.float32), rtol=3e-2, atol=3e-2
    )
