"""Property-based catalog invariants (hypothesis)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline CI: deterministic fallback shim
    from tests._hypothesis_compat import given, settings
    from tests._hypothesis_compat import strategies as st

from repro.catalog import Catalog, MergeConflict
from repro.io import ObjectStore

table_names = st.sampled_from(["a", "b", "c", "d", "e"])
ops = st.lists(
    st.tuples(table_names, st.integers(0, 99)), min_size=1, max_size=8
)


@given(main_ops=ops, feat_ops=ops)
@settings(max_examples=25, deadline=None)
def test_property_disjoint_merges_never_conflict(tmp_path_factory, main_ops, feat_ops):
    """Two branches editing DISJOINT table sets always merge, and the
    merge result is exactly the union of both branches' final states."""
    catalog = Catalog(ObjectStore(tmp_path_factory.mktemp("cat")))
    main_tables = {f"m_{t}" for t, _ in main_ops}
    feat_tables = {f"f_{t}" for t, _ in feat_ops}
    catalog.create_branch("feat")
    for t, v in main_ops:
        catalog.commit("main", {f"m_{t}": f"v{v}"})
    for t, v in feat_ops:
        catalog.commit("feat", {f"f_{t}": f"v{v}"})
    catalog.merge("feat", "main")
    merged = catalog.tables(branch="main")
    assert set(merged) == main_tables | feat_tables
    # last-writer-wins within each branch
    for t, v in main_ops:
        pass
    final_main = {f"m_{t}": f"v{v}" for t, v in main_ops}
    final_feat = {f"f_{t}": f"v{v}" for t, v in feat_ops}
    # (later ops overwrite earlier ones in insertion order)
    for t, v in main_ops:
        final_main[f"m_{t}"] = f"v{v}"
    for t, v in feat_ops:
        final_feat[f"f_{t}"] = f"v{v}"
    for k, v in {**final_main, **final_feat}.items():
        assert merged[k] == v


@given(edits=ops)
@settings(max_examples=25, deadline=None)
def test_property_time_travel_is_total_history(tmp_path_factory, edits):
    """Every historical commit resolves every table to exactly the value
    it had at that commit (no retroactive mutation)."""
    catalog = Catalog(ObjectStore(tmp_path_factory.mktemp("tt")))
    snapshots = []
    state = {}
    for t, v in edits:
        state[t] = f"v{v}"
        c = catalog.commit("main", {t: f"v{v}"})
        snapshots.append((c.commit_id, dict(state)))
    for cid, expected in snapshots:
        for t, v in expected.items():
            assert catalog.table_key(t, commit_id=cid) == v


@given(shared=table_names, v1=st.integers(0, 9), v2=st.integers(10, 19))
@settings(max_examples=15, deadline=None)
def test_property_conflicts_always_detected(tmp_path_factory, shared, v1, v2):
    catalog = Catalog(ObjectStore(tmp_path_factory.mktemp("cf")))
    catalog.commit("main", {shared: "base"})
    catalog.create_branch("feat")
    catalog.commit("feat", {shared: f"v{v1}"})
    catalog.commit("main", {shared: f"v{v2}"})
    with pytest.raises(MergeConflict):
        catalog.merge("feat", "main")
    # and main's value is untouched after the failed merge
    assert catalog.table_key(shared) == f"v{v2}"
