"""fused_filter_agg Pallas kernel vs jnp oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline CI: deterministic fallback shim
    from tests._hypothesis_compat import given, settings
    from tests._hypothesis_compat import strategies as st

from repro.kernels.fused_filter_agg import fused_filter_agg, fused_filter_agg_ref


def make_inputs(n, num_groups, rng, dtype=np.float32):
    return (
        rng.integers(0, num_groups, n).astype(np.int32),
        rng.standard_normal(n).astype(dtype),
        (rng.random(n) * 100).astype(dtype),
    )


@pytest.mark.parametrize("n", [128, 1024, 1000, 4096, 5000])
@pytest.mark.parametrize("num_groups", [64, 256])
def test_shapes_sweep(n, num_groups, rng):
    keys, vals, filt = make_inputs(n, num_groups, rng)
    got_s, got_c = fused_filter_agg(
        jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(filt),
        op="ge", threshold=50.0, num_groups=num_groups, interpret=True,
    )
    exp_s, exp_c = fused_filter_agg_ref(
        jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(filt),
        op="ge", threshold=50.0, num_groups=num_groups,
    )
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(exp_s), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(exp_c))


@pytest.mark.parametrize("op", ["ge", "gt", "le", "lt", "eq", "ne"])
def test_ops_sweep(op, rng):
    keys, vals, filt = make_inputs(2048, 128, rng)
    filt = np.round(filt)  # make eq/ne meaningful
    got_s, got_c = fused_filter_agg(
        jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(filt),
        op=op, threshold=42.0, num_groups=128, interpret=True,
    )
    exp_s, exp_c = fused_filter_agg_ref(
        jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(filt),
        op=op, threshold=42.0, num_groups=128,
    )
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(exp_s), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(exp_c))


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_dtypes_sweep(dtype, rng):
    keys = rng.integers(0, 64, 1024).astype(np.int32)
    vals = rng.integers(-5, 5, 1024).astype(dtype)
    filt = rng.integers(0, 10, 1024).astype(np.float32)
    got_s, got_c = fused_filter_agg(
        jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(filt),
        op="gt", threshold=4.0, num_groups=64, interpret=True,
    )
    exp_s, exp_c = fused_filter_agg_ref(
        jnp.asarray(keys), jnp.asarray(vals).astype(jnp.float32), jnp.asarray(filt),
        op="gt", threshold=4.0, num_groups=64,
    )
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(exp_s), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(exp_c))


def test_empty_selection(rng):
    keys, vals, filt = make_inputs(512, 128, rng)
    got_s, got_c = fused_filter_agg(
        jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(filt),
        op="ge", threshold=1e9, num_groups=128, interpret=True,
    )
    assert np.asarray(got_s).sum() == 0 and np.asarray(got_c).sum() == 0


def test_matches_query_engine_groupby(rng):
    """Cross-check: kernel == engine's sort-based groupby on the same data."""
    from repro.engine import Columnar, Query, col, execute_query

    keys, vals, filt = make_inputs(2000, 32, rng)
    rel = Columnar.from_numpy({"k": keys, "v": vals, "f": filt})
    q = Query("t").where(col("f") >= 50.0).group_by("k").agg("sum", col("v"), "s").count("n")
    eng = execute_query(q, rel).to_numpy()
    got_s, got_c = fused_filter_agg(
        jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(filt),
        op="ge", threshold=50.0, num_groups=32, interpret=True,
    )
    got_s, got_c = np.asarray(got_s), np.asarray(got_c)
    for i, key in enumerate(eng["k"]):
        np.testing.assert_allclose(got_s[key], eng["s"][i], rtol=1e-4, atol=1e-4)
        assert got_c[key] == eng["n"][i]


@given(
    n=st.integers(1, 3000),
    g=st.sampled_from([128, 256]),
    threshold=st.floats(-2, 2, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_property_kernel_equals_oracle(n, g, threshold, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, g, n).astype(np.int32)
    vals = rng.standard_normal(n).astype(np.float32)
    filt = rng.standard_normal(n).astype(np.float32)
    got_s, got_c = fused_filter_agg(
        jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(filt),
        op="lt", threshold=threshold, num_groups=g, interpret=True,
    )
    exp_s, exp_c = fused_filter_agg_ref(
        jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(filt),
        op="lt", threshold=threshold, num_groups=g,
    )
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(exp_s), rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(exp_c))
