"""Query engine: operators vs numpy oracles, SQL front-end, jit stability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline CI: deterministic fallback shim
    from tests._hypothesis_compat import given, settings
    from tests._hypothesis_compat import strategies as st

from repro.engine import Columnar, Query, col, compile_query, execute_query, parse_sql


def make_rel(n, rng):
    return Columnar.from_numpy(
        {
            "loc": rng.integers(0, 16, n).astype(np.int32),
            "dst": rng.integers(0, 8, n).astype(np.int32),
            "count": rng.integers(0, 10, n).astype(np.int32),
            "fare": (rng.random(n) * 50).astype(np.float32),
        }
    )


def test_filter_project(rng):
    rel = make_rel(100, rng)
    q = Query("t").where(col("count") > 4).select("fare", double=col("fare") * 2)
    out = execute_query(q, rel).to_numpy()
    fare = np.asarray(rel.columns["fare"])
    cnt = np.asarray(rel.columns["count"])
    np.testing.assert_allclose(out["fare"], fare[cnt > 4], rtol=1e-6)
    np.testing.assert_allclose(out["double"], 2 * fare[cnt > 4], rtol=1e-6)


def test_groupby_sum_count_vs_numpy(rng):
    rel = make_rel(500, rng)
    q = (
        Query("t")
        .group_by("loc")
        .agg("sum", col("fare"), "fare_sum")
        .count("n")
    )
    out = execute_query(q, rel).to_numpy()
    loc = np.asarray(rel.columns["loc"])
    fare = np.asarray(rel.columns["fare"])
    order = np.argsort(out["loc"])
    for k in ("loc", "fare_sum", "n"):
        out[k] = out[k][order]
    expected_keys = np.unique(loc)
    np.testing.assert_array_equal(out["loc"], expected_keys)
    for i, key in enumerate(expected_keys):
        np.testing.assert_allclose(out["fare_sum"][i], fare[loc == key].sum(), rtol=1e-5)
        assert out["n"][i] == (loc == key).sum()


def test_groupby_multikey_min_max_mean(rng):
    rel = make_rel(400, rng)
    q = (
        Query("t")
        .group_by("loc", "dst")
        .agg("min", col("fare"), "lo")
        .agg("max", col("fare"), "hi")
        .agg("mean", col("fare"), "avg")
    )
    out = execute_query(q, rel).to_numpy()
    loc = np.asarray(rel.columns["loc"])
    dst = np.asarray(rel.columns["dst"])
    fare = np.asarray(rel.columns["fare"])
    assert len(out["loc"]) == len(np.unique(loc * 8 + dst))
    for i in range(len(out["loc"])):
        m = (loc == out["loc"][i]) & (dst == out["dst"][i])
        np.testing.assert_allclose(out["lo"][i], fare[m].min(), rtol=1e-6)
        np.testing.assert_allclose(out["hi"][i], fare[m].max(), rtol=1e-6)
        np.testing.assert_allclose(out["avg"][i], fare[m].mean(), rtol=1e-5)


def test_sort_desc_and_limit(rng):
    rel = make_rel(64, rng)
    q = Query("t").select("fare").sort("fare", desc=True).take(10)
    out = execute_query(q, rel).to_numpy()
    fare = np.sort(np.asarray(rel.columns["fare"]))[::-1][:10]
    np.testing.assert_allclose(out["fare"], fare, rtol=1e-6)


def test_filter_then_groupby_pipeline(rng):
    """The paper's fused shape: WHERE + GROUP BY + ORDER BY in one program."""
    rel = make_rel(1000, rng)
    q = (
        Query("t")
        .where(col("count") > 2)
        .group_by("loc")
        .count("counts")
        .sort("counts", desc=True)
    )
    out = execute_query(q, rel).to_numpy()
    loc = np.asarray(rel.columns["loc"])
    cnt = np.asarray(rel.columns["count"])
    kept = loc[cnt > 2]
    keys, counts = np.unique(kept, return_counts=True)
    order = np.argsort(-counts, kind="stable")
    np.testing.assert_array_equal(np.sort(out["counts"])[::-1], out["counts"])
    np.testing.assert_array_equal(np.sort(out["counts"]), np.sort(counts))
    # counts per key must match exactly
    d = dict(zip(out["loc"].tolist(), out["counts"].tolist()))
    assert d == dict(zip(keys.tolist(), counts.tolist()))


def test_jit_compile_query_matches_eager(rng):
    rel = make_rel(256, rng)
    q = Query("t").where(col("fare") < 25.0).group_by("dst").agg("sum", col("fare"), "s")
    eager = execute_query(q, rel).to_numpy()
    compiled = compile_query(q)
    jitted = compiled(rel).to_numpy()
    for k in eager:
        np.testing.assert_allclose(eager[k], jitted[k], rtol=1e-6)
    # cache hit returns the same callable (warm container analogy)
    assert compile_query(q) is compiled


def test_empty_and_all_filtered(rng):
    rel = make_rel(32, rng)
    q = Query("t").where(col("fare") < -1.0).group_by("loc").count("n")
    out = execute_query(q, rel).to_numpy()
    assert len(out["n"]) == 0


# ------------------------------------------------------------------ SQL
def test_sql_paper_step1():
    q = parse_sql(
        """
        SELECT
         pickup_location_id,
         passenger_count as count,
         dropoff_location_id
        FROM
         taxi_table
        WHERE
         pickup_at >= '2019-04-01'
        """
    )
    assert q.source == "taxi_table"
    assert [a for a, _ in q.projections] == [
        "pickup_location_id", "count", "dropoff_location_id",
    ]
    pushed, residual = q.filter_expr.as_pushdown_conjuncts()
    assert residual is None
    assert pushed[0].column == "pickup_at" and pushed[0].op == ">="
    assert pushed[0].value == float((np.datetime64("2019-04-01") - np.datetime64("1970-01-01")) / np.timedelta64(1, "D"))


def test_sql_paper_step3():
    q = parse_sql(
        """
        SELECT
         pickup_location_id,
         dropoff_location_id,
         COUNT(*) AS counts
        FROM
         trips
        GROUP BY
         pickup_location_id,
         dropoff_location_id
        ORDER BY
         counts DESC
        """
    )
    assert q.source == "trips"
    assert q.group_keys == ("pickup_location_id", "dropoff_location_id")
    assert q.aggregates[0].fn == "count" and q.aggregates[0].name == "counts"
    assert q.order_by == (("counts", True),)


def test_sql_execution_end_to_end(rng):
    rel = make_rel(300, rng)
    q = parse_sql("SELECT loc, SUM(fare) AS total FROM t WHERE count > 3 GROUP BY loc ORDER BY total DESC LIMIT 5")
    out = execute_query(q, rel).to_numpy()
    loc = np.asarray(rel.columns["loc"])
    cnt = np.asarray(rel.columns["count"])
    fare = np.asarray(rel.columns["fare"])
    mask = cnt > 3
    totals = {k: fare[mask & (loc == k)].sum() for k in np.unique(loc[mask])}
    expect = sorted(totals.values(), reverse=True)[:5]
    np.testing.assert_allclose(out["total"], expect, rtol=1e-5)


def test_sql_errors():
    with pytest.raises(SyntaxError):
        parse_sql("SELECT a FROM")
    with pytest.raises(SyntaxError):
        parse_sql("SELECT a, SUM(b) AS s FROM t")  # bare col with agg, no GROUP BY


@given(
    n=st.integers(1, 300),
    threshold=st.floats(0, 50, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_property_masked_filter_equals_compact_numpy(n, threshold, seed):
    rng = np.random.default_rng(seed)
    rel = make_rel(n, rng)
    q = Query("t").where(col("fare") >= threshold).select("fare")
    out = execute_query(q, rel).to_numpy()
    fare = np.asarray(rel.columns["fare"])
    np.testing.assert_allclose(out["fare"], fare[fare >= threshold], rtol=1e-6)


@given(
    n=st.integers(1, 200),
    nkeys=st.integers(1, 30),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_property_groupby_sum_invariant(n, nkeys, seed):
    """Sum of per-group sums == global sum of filtered values."""
    rng = np.random.default_rng(seed)
    rel = Columnar.from_numpy(
        {
            "k": rng.integers(0, nkeys, n).astype(np.int32),
            "v": rng.standard_normal(n).astype(np.float32),
        }
    )
    q = Query("t").group_by("k").agg("sum", col("v"), "s").count("n")
    out = execute_query(q, rel).to_numpy()
    np.testing.assert_allclose(
        out["s"].sum(), np.asarray(rel.columns["v"]).sum(), rtol=2e-4, atol=1e-4
    )
    assert out["n"].sum() == n
