"""Lakekeeper: mark-and-sweep GC, cache eviction, shard compaction.

The invariants pinned here are the maintenance analog of the paper's
correctness story: reclamation must be invisible to every reader that
matters — branch heads, tags, time travel within retained history,
replay of surviving runs, and warm cache re-runs.
"""
import numpy as np
import pytest

from repro.catalog import Catalog
from repro.cli import main as cli_main
from repro.core import Pipeline, Runner, StageCacheRegistry, requirements
from repro.core.snapshot import RunRegistry, StageCacheEntry
from repro.io import ObjectStore
from repro.maintenance import (
    EvictionPolicy,
    collect_garbage,
    compact_table,
    mark,
    prune_cache,
)
from repro.runtime import ExecutorConfig, ServerlessExecutor
from repro.table import Predicate, TableFormat
from repro.table.scan import plan_scan, pruning_effectiveness
from tests.helpers_taxi import TAXI_SCHEMA, build_taxi_pipeline, make_taxi_data


@pytest.fixture
def runner(catalog, fmt):
    with ServerlessExecutor(ExecutorConfig(max_workers=2)) as ex:
        yield Runner(catalog, fmt, ex)


@pytest.fixture
def seeded(catalog, fmt, rng):
    data = make_taxi_data(2000, rng)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, data)
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)}, message="seed")
    return data


def build_dated_pipeline(since: str = "2019-04-01") -> Pipeline:
    """Taxi pipeline whose trips filter date is the 'edit' knob — unlike a
    threshold edit, a date edit changes the *data* each run writes, so
    successive runs genuinely create garbage for GC to find."""
    p = Pipeline("taxi_demo")
    p.sql(
        "trips",
        f"""
        SELECT pickup_location_id, passenger_count as count, dropoff_location_id
        FROM taxi_table WHERE pickup_at >= '{since}'
        """,
    )

    @p.python
    @requirements({"pandas": "2.0.0"})
    def trips_expectation(ctx, trips):
        return trips.mean("count") > 10.0

    p.sql(
        "pickups",
        """
        SELECT pickup_location_id, dropoff_location_id, COUNT(*) AS counts
        FROM trips GROUP BY pickup_location_id, dropoff_location_id
        ORDER BY counts DESC
        """,
    )
    return p


def _store_bytes(store):
    return sum(store.object_size(k) or 0 for k in store.keys())


def _run(runner, pipeline, branch="main", **kw):
    kw.setdefault("fusion", False)
    kw.setdefault("pushdown", False)
    kw.setdefault("cache", True)
    return runner.run(pipeline, branch=branch, **kw)


# ------------------------------------------------------------------- mark
def test_mark_roots_cover_branches_tags_cache_pins(runner, catalog, fmt, seeded):
    store = catalog.store
    res = _run(runner, build_taxi_pipeline())
    catalog.tag("v1", res.merged_commit)
    RunRegistry(store).pin_run(999, res.merged_commit)
    live = mark(store, catalog, fmt)
    assert live.roots == {
        "branches": 1, "tags": 1, "pinned_runs": 1,
        "cache_entries": len(StageCacheRegistry(store).entries()),
        "runlogs": 0,  # bare Runner has no bus -> no traces recorded
    }
    # every blob the head references is in the live set
    for key in catalog.tables().values():
        assert fmt.snapshot_object_keys(key) <= live.objects


def test_mark_history_bound_drops_old_commits(runner, catalog, fmt, seeded):
    r1 = _run(runner, build_dated_pipeline("2019-04-01"))
    r2 = _run(runner, build_dated_pipeline("2019-04-05"))
    full = mark(catalog.store, catalog, fmt)
    heads_only = mark(catalog.store, catalog, fmt, history=1)
    assert heads_only.commits < full.commits
    assert catalog.head("main").commit_id in heads_only.commits


# --------------------------------------------------------------------- gc
def test_gc_default_keeps_all_history(runner, catalog, fmt, seeded):
    _run(runner, build_dated_pipeline("2019-04-01"))
    _run(runner, build_dated_pipeline("2019-04-05"))
    report = collect_garbage(catalog.store, catalog, fmt)
    # full-history gc: every commit ever merged stays live, and every
    # object is referenced by some retained commit or cache entry
    assert report.swept_objects == 0
    assert report.swept_commits == 0


def test_gc_reclaims_failed_run_artifacts(runner, catalog, fmt, rng):
    from repro.core import ExpectationFailed

    # mean count ~2 < threshold 10 -> audit fails, ephemeral branch dropped
    data = make_taxi_data(800, rng, mean_count=2.0)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, data)
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})
    with pytest.raises(ExpectationFailed):
        _run(runner, build_taxi_pipeline())
    before = _store_bytes(catalog.store)
    report = collect_garbage(catalog.store, catalog, fmt)
    # the failed run's trips artifact (written before the audit) is
    # unreachable from any root and gets swept; the seed table survives
    assert report.swept_objects > 0
    assert report.bytes_reclaimed > 0
    assert _store_bytes(catalog.store) < before
    out = fmt.read(fmt.load_snapshot(catalog.table_key("taxi_table")))
    assert len(out["pickup_at"]) == 800


def test_gc_dry_run_deletes_nothing(runner, catalog, fmt, rng):
    from repro.core import ExpectationFailed

    data = make_taxi_data(800, rng, mean_count=2.0)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, data)
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})
    with pytest.raises(ExpectationFailed):
        _run(runner, build_taxi_pipeline())
    before = set(catalog.store.keys())
    report = collect_garbage(catalog.store, catalog, fmt, dry_run=True)
    assert report.dry_run and report.swept_objects > 0
    assert set(catalog.store.keys()) == before
    assert catalog.store.stats.gc_objects_swept == 0
    # the real pass reclaims exactly what the dry run promised
    real = collect_garbage(catalog.store, catalog, fmt)
    assert real.swept_objects == report.swept_objects
    assert real.bytes_reclaimed == report.bytes_reclaimed


def test_gc_grace_period_spares_young_objects(store):
    live_key = store.put(b"still referenced")
    garbage = store.put(b"unreachable but fresh")
    result = store.sweep({live_key}, grace_s=3600.0)
    assert result.swept == 0 and result.kept_young == 1
    assert store.exists(garbage)
    result = store.sweep({live_key}, grace_s=0.0)
    assert result.swept == 1 and store.exists(live_key)
    assert not store.exists(garbage)


def test_gc_respects_run_pins_until_ttl(runner, catalog, fmt, seeded):
    store = catalog.store
    pinned_commit = catalog.head("main").commit_id
    _run(runner, build_dated_pipeline("2019-04-05"))  # head moves on
    RunRegistry(store).pin_run(123, pinned_commit)
    collect_garbage(store, catalog, fmt, history=1)
    # the pinned base commit's table is still readable...
    key = catalog.table_key("taxi_table", commit_id=pinned_commit)
    assert len(fmt.read(fmt.load_snapshot(key))["pickup_at"]) == 2000
    # ...until the pin ages out (ttl 0 = every pin is stale)
    collect_garbage(store, catalog, fmt, history=1, pin_ttl_s=0.0)
    assert catalog.get_commit_opt(pinned_commit) is None


def test_runner_unpins_after_run_and_replay(runner, catalog, fmt, seeded):
    res = _run(runner, build_taxi_pipeline())
    runner.replay(build_taxi_pipeline(), res.run_id)
    assert RunRegistry(catalog.store).pinned_commits() == {}


# ------------------------------------------------- gc roots across catalog
def test_tagged_commit_survives_history_expiry(runner, catalog, fmt, seeded):
    r1 = _run(runner, build_dated_pipeline("2019-04-01"))
    catalog.tag("release", r1.merged_commit)
    _run(runner, build_dated_pipeline("2019-04-05"))
    _run(runner, build_dated_pipeline("2019-04-09"))
    collect_garbage(catalog.store, catalog, fmt, history=1)
    # the tagged commit and every blob it references stay alive
    tagged = catalog.get_commit(catalog.resolve_tag("release"))
    for key in tagged.tables.values():
        snap = fmt.load_snapshot(key)
        assert fmt.read(snap)  # all shards readable
    out = runner.query(
        "SELECT pickup_location_id, counts FROM pickups",
        commit_id=r1.merged_commit,
    )
    assert len(out["counts"]) > 0


def test_merged_then_deleted_branch_keeps_blobs(runner, catalog, fmt, seeded):
    res = _run(runner, build_taxi_pipeline(), branch="feat")
    catalog.merge("feat", "main", delete_source=True)
    assert not catalog.has_branch("feat")
    report = collect_garbage(catalog.store, catalog, fmt)
    # the run's artifacts reached main via the merge: nothing to sweep
    out = runner.query("SELECT pickup_location_id, counts FROM pickups")
    assert len(out["counts"]) > 0
    for key in catalog.tables().values():
        assert fmt.snapshot_object_keys(key)


def test_replay_on_surviving_branch_works_after_gc(runner, catalog, fmt, seeded):
    pipeline = build_taxi_pipeline()
    first = _run(runner, pipeline)
    collect_garbage(catalog.store, catalog, fmt)
    again = runner.replay(pipeline, first.run_id)
    assert again.artifacts == first.artifacts  # bit-identical re-execution


def test_unmerged_deleted_branch_is_reclaimed(runner, catalog, fmt, seeded):
    res = _run(runner, build_dated_pipeline("2019-03-01"), branch="scratch")
    scratch_artifacts = dict(res.artifacts)
    catalog.delete_branch("scratch")
    prune_cache(StageCacheRegistry(catalog.store), EvictionPolicy(max_bytes=0))
    report = collect_garbage(catalog.store, catalog, fmt)
    assert report.swept_objects > 0
    # the abandoned branch's artifacts are gone, main's table is intact
    assert not catalog.store.exists(scratch_artifacts["trips"])
    assert len(fmt.read(fmt.load_snapshot(catalog.table_key("taxi_table"))))


# ------------------------------------------------------- acceptance: taxi
def test_gc_acceptance_reclaims_half_while_readers_survive(
    runner, catalog, fmt, seeded
):
    """ISSUE 2 acceptance: >=3 runs with edits, then gc reclaims >=50% of
    store bytes while every branch head, tag and cached warm re-run stays
    readable."""
    store = catalog.store
    dates = ["2019-02-01", "2019-02-05", "2019-02-09", "2019-02-13"]
    for since in dates:
        res = _run(runner, build_dated_pipeline(since))
    catalog.tag("latest", res.merged_commit)
    baseline = runner.query("SELECT pickup_location_id, counts FROM pickups")

    before = _store_bytes(store)
    # evict cache entries of the superseded pipeline versions (LRU keeps
    # the most recent run's entries within budget)...
    last_run_bytes = sum(
        e.output_bytes
        for e in StageCacheRegistry(store).entries().values()
        if e.run_id == res.run_id
    )
    prune_cache(
        StageCacheRegistry(store), EvictionPolicy(max_bytes=last_run_bytes)
    )
    # ...then expire history to the branch heads and sweep
    report = collect_garbage(store, catalog, fmt, history=1, grace_s=0.0)
    after = _store_bytes(store)

    assert report.bytes_reclaimed > 0
    reclaimed_frac = 1.0 - after / before
    assert reclaimed_frac >= 0.5, f"only reclaimed {reclaimed_frac:.1%}"

    # branch head still queryable, bit-identical
    out = runner.query("SELECT pickup_location_id, counts FROM pickups")
    assert np.array_equal(out["counts"], baseline["counts"])
    # tag still resolvable and readable
    tagged = catalog.get_commit(catalog.resolve_tag("latest"))
    assert fmt.read(fmt.load_snapshot(tagged.tables["pickups"]))
    # a warm re-run of the surviving pipeline version restores from cache
    warm = _run(runner, build_dated_pipeline(dates[-1]))
    assert warm.stats["cache"]["hits"] >= 2
    assert warm.stats["cache"]["stages_executed"] <= 1


# --------------------------------------------------------------- eviction
def _entry(fp, *, bytes_=100, used=0.0, outputs=None):
    return StageCacheEntry(
        fingerprint=fp, outputs=outputs or {}, checks={},
        output_bytes=bytes_, run_id=1, created_at=used, last_used_at=used,
    )


def test_eviction_ttl(store):
    reg = StageCacheRegistry(store)
    reg.put(_entry("old", used=100.0))
    reg.put(_entry("fresh", used=900.0))
    report = prune_cache(reg, EvictionPolicy(ttl_s=500.0), now=1000.0)
    assert report.entries_evicted == 1
    assert set(reg.entries()) == {"fresh"}


def test_eviction_lru_under_byte_budget(store):
    reg = StageCacheRegistry(store)
    for i in range(5):
        reg.put(_entry(f"e{i}", bytes_=100, used=float(i)))
    report = prune_cache(reg, EvictionPolicy(max_bytes=250))
    # oldest three evicted; most-recently-used two survive
    assert report.entries_evicted == 3
    assert set(reg.entries()) == {"e3", "e4"}
    assert reg.total_bytes() == 200
    assert store.stats.cache_entries_evicted == 3


def test_eviction_dry_run(store):
    reg = StageCacheRegistry(store)
    reg.put(_entry("a", bytes_=100))
    report = prune_cache(reg, EvictionPolicy(max_bytes=0), dry_run=True)
    assert report.entries_evicted == 1 and report.dry_run
    assert set(reg.entries()) == {"a"}
    assert store.stats.cache_entries_evicted == 0


def test_cache_hit_touches_lru_clock(runner, catalog, fmt, seeded):
    reg = StageCacheRegistry(catalog.store)
    _run(runner, build_taxi_pipeline())
    before = reg.entries()
    warm = _run(runner, build_taxi_pipeline())
    assert warm.stats["cache"]["hits"] > 0
    after = reg.entries()
    assert any(after[fp].last_used_at > before[fp].last_used_at for fp in before)
    # created_at is preserved — only the LRU clock moves
    assert all(after[fp].created_at == before[fp].created_at for fp in before)


def test_evicted_entries_release_blobs_to_sweeper(runner, catalog, fmt, seeded):
    """Eviction -> GC is a two-step hand-off: prune drops the registry
    roots, the next sweep reclaims any blobs nothing else references."""
    store = catalog.store
    res = _run(runner, build_dated_pipeline("2019-03-01"), branch="scratch")
    catalog.delete_branch("scratch")  # artifacts now only rooted by cache
    assert collect_garbage(store, catalog, fmt, dry_run=True).swept_objects == 0
    prune_cache(StageCacheRegistry(store), EvictionPolicy(max_bytes=0))
    report = collect_garbage(store, catalog, fmt)
    assert report.swept_objects > 0
    assert not store.exists(res.artifacts["trips"])


# ------------------------------------------------------------- compaction
@pytest.fixture
def fragmented(catalog, fmt, rng):
    """taxi_table built from many small appends -> many small shards."""
    data = make_taxi_data(2000, rng)
    snap = None
    for start in range(0, 2000, 100):
        chunk = {c: v[start:start + 100] for c, v in data.items()}
        snap = fmt.write(
            "taxi_table", TAXI_SCHEMA, chunk, parent=snap, append=snap is not None
        )
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})
    return data


def test_compaction_fewer_shards_identical_rows(catalog, fmt, fragmented):
    before = fmt.load_snapshot(catalog.table_key("taxi_table"))
    assert len(before.shards) == 20
    report = compact_table(catalog, fmt, "taxi_table", target_rows=1000)
    assert report.shards_merged == 20
    assert report.shards_after < report.shards_before
    after = fmt.load_snapshot(catalog.table_key("taxi_table"))
    assert len(after.shards) == report.shards_after
    # bit-identical full scan, row order preserved
    a, b = fmt.read(before), fmt.read(after)
    for col in TAXI_SCHEMA.names:
        np.testing.assert_array_equal(a[col], b[col])
    assert catalog.store.stats.compact_shards_merged == 20


def test_compaction_preserves_stats_and_predicate_results(
    catalog, fmt, fragmented
):
    pred = Predicate("pickup_at", ">=", float(fragmented["pickup_at"][1200]))
    before = fmt.load_snapshot(catalog.table_key("taxi_table"))
    compact_table(catalog, fmt, "taxi_table", target_rows=500,
                  guard_predicates=[pred])
    after = fmt.load_snapshot(catalog.table_key("taxi_table"))
    # stats are exact on the merged shards: min/max equal the data
    for shard in after.shards:
        lo = shard.column_stats["pickup_at"]["min"]
        hi = shard.column_stats["pickup_at"]["max"]
        col = fmt.read_shard(shard, ["pickup_at"])["pickup_at"]
        assert lo == float(col.min()) and hi == float(col.max())
    # pushdown still prunes (data is sorted by pickup_at) and results match
    from repro.table.scan import execute_scan

    plan_b = plan_scan(before, predicates=[pred])
    plan_a = plan_scan(after, predicates=[pred])
    assert plan_a.pruned_shards > 0
    assert pruning_effectiveness(after, [pred]) > 0.0
    np.testing.assert_array_equal(
        execute_scan(fmt, plan_b)["pickup_at"],
        execute_scan(fmt, plan_a)["pickup_at"],
    )


def test_compaction_noop_on_compact_table(catalog, fmt, rng):
    snap = fmt.write("t", TAXI_SCHEMA, make_taxi_data(1000, rng))
    catalog.commit("main", {"t": fmt.manifest_key(snap)})
    report = compact_table(catalog, fmt, "t", target_rows=100)
    assert report.shards_merged == 0 and report.commit_id is None
    # no new commit was created
    assert catalog.table_key("t") == fmt.manifest_key(snap)


def test_compaction_dry_run_plans_without_writing(catalog, fmt, fragmented):
    head_before = catalog.head("main").commit_id
    puts_before = catalog.store.stats.puts
    report = compact_table(
        catalog, fmt, "taxi_table", target_rows=1000, dry_run=True
    )
    assert report.dry_run and report.shards_merged == 20
    assert catalog.head("main").commit_id == head_before
    assert catalog.store.stats.puts == puts_before


def test_old_snapshot_readable_until_expired(catalog, fmt, fragmented):
    old_key = catalog.table_key("taxi_table")
    compact_table(catalog, fmt, "taxi_table", target_rows=1000)
    # time travel to the pre-compaction commit still works...
    parent = catalog.head("main").parent_id
    assert catalog.table_key("taxi_table", commit_id=parent) == old_key
    assert fmt.read(fmt.load_snapshot(old_key))
    # ...until snapshot expiry collects it
    collect_garbage(catalog.store, catalog, fmt, history=1)
    assert not catalog.store.exists(old_key)
    new = fmt.read(fmt.load_snapshot(catalog.table_key("taxi_table")))
    np.testing.assert_array_equal(new["pickup_at"], fragmented["pickup_at"])


# -------------------------------------------------------------------- cli
def test_cli_maintenance_verbs(tmp_path, rng, capsys):
    root = tmp_path / "lake"
    store = ObjectStore(root)
    catalog = Catalog(store)
    fmt = TableFormat(store, shard_rows=128)
    data = make_taxi_data(1000, rng)
    snap = None
    for start in range(0, 1000, 100):
        chunk = {c: v[start:start + 100] for c, v in data.items()}
        snap = fmt.write(
            "taxi_table", TAXI_SCHEMA, chunk, parent=snap, append=snap is not None
        )
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})
    orphan = store.put(b"orphan blob")

    cli_main(["--lake", str(root), "gc", "--dry-run", "--grace", "0"])
    out = capsys.readouterr().out
    assert "would reclaim" in out
    assert store.exists(orphan)

    cli_main(["--lake", str(root), "gc", "--grace", "0"])
    out = capsys.readouterr().out
    assert "reclaimed" in out
    assert not store.exists(orphan)

    cli_main(["--lake", str(root), "compact", "taxi_table", "--target-rows", "500"])
    out = capsys.readouterr().out
    assert "rewrote" in out and "shards merged" in out

    cli_main(["--lake", str(root), "cache", "stats"])
    out = capsys.readouterr().out
    assert "0 entries" in out

    cli_main(["--lake", str(root), "cache", "prune", "--max-bytes", "0"])
    out = capsys.readouterr().out
    assert "evicted 0/0" in out


def test_gc_prunes_stale_content_fingerprint_memos(runner, catalog, fmt, rng):
    """Cached runs memoize each input snapshot's content hash as a ref;
    gc must prune memos whose snapshot has been expired or the ref space
    grows one entry per table version forever."""
    s1 = fmt.write("taxi_table", TAXI_SCHEMA, make_taxi_data(1000, rng))
    catalog.commit("main", {"taxi_table": fmt.manifest_key(s1)})
    _run(runner, build_taxi_pipeline())  # memoizes s1's content hash
    s2 = fmt.write("taxi_table", TAXI_SCHEMA, make_taxi_data(1500, rng))
    catalog.commit("main", {"taxi_table": fmt.manifest_key(s2)})
    _run(runner, build_taxi_pipeline())  # memoizes s2's content hash
    assert set(catalog.store.list_refs("contenthash")) == {
        s1.snapshot_id, s2.snapshot_id,
    }
    # prune stale cache entries, expire history to heads: s1 is gone
    prune_cache(StageCacheRegistry(catalog.store), EvictionPolicy(max_bytes=0))
    report = collect_garbage(catalog.store, catalog, fmt, history=1, grace_s=0.0)
    assert report.swept_content_refs == 1
    assert set(catalog.store.list_refs("contenthash")) == {s2.snapshot_id}


# ---------------------------------------------------- review regressions
def test_gc_history_zero_refuses_to_brick_the_lake(runner, catalog, fmt, seeded):
    """Regression: history=0 would mark nothing live; the sweep against
    that empty live set would destroy every branch head's data."""
    with pytest.raises(ValueError, match="history"):
        collect_garbage(catalog.store, catalog, fmt, history=0)
    with pytest.raises(ValueError, match="history"):
        mark(catalog.store, catalog, fmt, history=-1)
    # nothing was deleted by the refused calls
    assert catalog.head("main")
    assert fmt.read(fmt.load_snapshot(catalog.table_key("taxi_table")))


def test_compaction_aborts_on_concurrent_table_change(catalog, fmt, fragmented, rng):
    """Regression: compaction's commit is CAS'd against the exact table
    version it read — a concurrent writer's rows must not be lost."""
    from repro.catalog.nessie import MergeConflict

    old_key = catalog.table_key("taxi_table")
    # a concurrent run replaces the table between load and publish
    newer = fmt.write("taxi_table", TAXI_SCHEMA, make_taxi_data(50, rng))
    newer_key = fmt.manifest_key(newer)

    original_load = fmt.load_snapshot

    def racy_load(key):
        snap = original_load(key)
        if key == old_key:
            catalog.commit("main", {"taxi_table": newer_key})
        return snap

    fmt.load_snapshot = racy_load
    try:
        with pytest.raises(MergeConflict):
            compact_table(catalog, fmt, "taxi_table", target_rows=1000)
    finally:
        fmt.load_snapshot = original_load
    # the concurrent writer's version survived
    assert catalog.table_key("taxi_table") == newer_key


def test_put_rearms_grace_on_dedup(store):
    """Regression: re-putting existing content must refresh the blob's
    mtime, or the gc grace period can't protect an in-flight writer that
    deduped onto an old unreachable blob."""
    import os

    key = store.put(b"shared content")
    path = store._object_path(key)
    os.utime(path, (1.0, 1.0))  # pretend it was written long ago
    assert store.object_age_s(key) > 3600
    store.put(b"shared content")  # in-flight run dedups onto it
    assert store.object_age_s(key) < 60
    # young again -> a grace-period sweep spares it
    result = store.sweep(set(), grace_s=3600.0)
    assert result.swept == 0 and store.exists(key)


def test_gc_grace_spares_young_commit_refs(runner, catalog, fmt, seeded):
    """Regression: a concurrent run writes its commit ref before CAS-ing
    the branch head, so unreachable-looking *young* commit refs must ride
    out the grace period just like young blobs."""
    res = _run(runner, build_dated_pipeline("2019-03-01"), branch="scratch")
    catalog.delete_branch("scratch")  # commits now unreachable, but young
    prune_cache(StageCacheRegistry(catalog.store), EvictionPolicy(max_bytes=0))
    report = collect_garbage(catalog.store, catalog, fmt, grace_s=3600.0)
    assert report.swept_commits == 0
    report = collect_garbage(catalog.store, catalog, fmt, grace_s=0.0)
    assert report.swept_commits > 0
