"""TensorTable format: snapshots, sharding, stats, scan pruning."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline CI: deterministic fallback shim
    from tests._hypothesis_compat import given, settings
    from tests._hypothesis_compat import strategies as st

from repro.io import ObjectStore
from repro.table import Predicate, Schema, TableFormat, execute_scan, plan_scan


def make_table(n, rng):
    return {
        "pickup_location_id": rng.integers(0, 256, n).astype(np.int32),
        "passenger_count": rng.integers(0, 8, n).astype(np.int32),
        "fare": (rng.random(n) * 100).astype(np.float32),
    }


SCHEMA = Schema.of(
    pickup_location_id="int32", passenger_count="int32", fare="float32"
)


def test_write_read_roundtrip(fmt, rng):
    data = make_table(1000, rng)
    snap = fmt.write("taxi_table", SCHEMA, data)
    assert snap.num_rows == 1000
    assert len(snap.shards) == 8  # 1000 rows / 128 shard_rows
    out = fmt.read(snap)
    for col in data:
        np.testing.assert_array_equal(out[col], data[col])


def test_append_shares_parent_shards(fmt, rng):
    d1 = make_table(256, rng)
    s1 = fmt.write("t", SCHEMA, d1)
    d2 = make_table(128, rng)
    s2 = fmt.write("t", SCHEMA, d2, parent=s1, append=True)
    assert s2.num_rows == 384
    assert s2.parent_id == s1.snapshot_id
    assert list(s2.shards[: len(s1.shards)]) == list(s1.shards)  # structural sharing
    out = fmt.read(s2)
    np.testing.assert_array_equal(
        out["fare"], np.concatenate([d1["fare"], d2["fare"]])
    )


def test_time_travel_via_manifest_keys(fmt, rng):
    d1 = make_table(64, rng)
    s1 = fmt.write("t", SCHEMA, d1)
    k1 = fmt.manifest_key(s1)
    d2 = make_table(64, rng)
    s2 = fmt.write("t", SCHEMA, d2)
    old = fmt.load_snapshot(k1)
    np.testing.assert_array_equal(fmt.read(old)["fare"], d1["fare"])
    assert old.snapshot_id == s1.snapshot_id != s2.snapshot_id


def test_scan_column_pruning(fmt, rng):
    snap = fmt.write("t", SCHEMA, make_table(512, rng))
    plan = plan_scan(snap, columns=["fare"])
    assert plan.columns == ["fare"]
    assert plan.pruned_columns == 2
    out = execute_scan(fmt, plan)
    assert set(out) == {"fare"}


def test_scan_shard_pruning_with_sorted_column(fmt):
    n = 1024
    data = {
        "pickup_location_id": np.arange(n, dtype=np.int32),
        "passenger_count": np.ones(n, dtype=np.int32),
        "fare": np.ones(n, dtype=np.float32),
    }
    snap = fmt.write("t", SCHEMA, data)  # 8 shards of 128 sorted ids
    plan = plan_scan(
        snap, predicates=[Predicate("pickup_location_id", ">=", 900)]
    )
    assert plan.pruned_shards == 7  # only the last shard can match
    out = execute_scan(fmt, plan)
    assert (out["pickup_location_id"] >= 900).all()
    assert len(out["pickup_location_id"]) == n - 900


def test_scan_returns_only_projection(fmt, rng):
    """Regression: predicate columns are read for filtering but must NOT
    leak into the result when the caller didn't project them."""
    data = make_table(300, rng)
    snap = fmt.write("t", SCHEMA, data)
    plan = plan_scan(
        snap,
        columns=["fare"],
        predicates=[Predicate("passenger_count", ">", 3)],
    )
    assert "passenger_count" in plan.columns  # read for filtering...
    assert plan.projection == ["fare"]
    out = execute_scan(fmt, plan)
    assert set(out) == {"fare"}  # ...but dropped from the result
    np.testing.assert_array_equal(
        out["fare"], data["fare"][data["passenger_count"] > 3]
    )
    # the all-shards-pruned path honours the projection too
    empty = execute_scan(
        fmt,
        plan_scan(
            snap,
            columns=["fare"],
            predicates=[Predicate("passenger_count", ">", 1000)],
        ),
    )
    assert set(empty) == {"fare"} and len(empty["fare"]) == 0


def test_parallel_shard_reads_match_serial(fmt, rng):
    """execute_scan(pool=...) preserves shard order: byte-identical
    output to the serial read, residual filter included."""
    from concurrent.futures import ThreadPoolExecutor

    data = make_table(1500, rng)  # ~12 shards at 128 rows
    snap = fmt.write("t", SCHEMA, data)
    plan = plan_scan(snap, predicates=[Predicate("fare", "<", 50.0)])
    serial = execute_scan(fmt, plan)
    with ThreadPoolExecutor(max_workers=4) as pool:
        pooled = execute_scan(fmt, plan, pool=pool)
    assert set(serial) == set(pooled)
    for c in serial:
        np.testing.assert_array_equal(serial[c], pooled[c])


def test_scan_residual_predicate_exact(fmt, rng):
    data = make_table(300, rng)
    snap = fmt.write("t", SCHEMA, data)
    plan = plan_scan(
        snap,
        columns=["fare"],
        predicates=[Predicate("passenger_count", ">", 3)],
    )
    out = execute_scan(fmt, plan)
    expected = data["fare"][data["passenger_count"] > 3]
    np.testing.assert_array_equal(out["fare"], expected)


def test_content_fingerprint_invariant_to_shard_layout(fmt, rng):
    """The differential-cache input identity: same rows in the same order
    -> same content fingerprint, regardless of shard boundaries (what
    keeps the cache warm across `repro compact`)."""
    data = make_table(1000, rng)
    snap = fmt.write("t", SCHEMA, data)
    wide = TableFormat(fmt.store, shard_rows=1000)
    resharded = wide.write("t", SCHEMA, data)
    assert resharded.snapshot_id != snap.snapshot_id  # layout differs...
    assert fmt.content_fingerprint(resharded) == fmt.content_fingerprint(snap)
    # ...but content identity is the same; compaction is the same story
    compacted, merged = wide.compact_snapshot(snap, target_rows=500)
    assert merged > 0
    assert fmt.content_fingerprint(compacted) == fmt.content_fingerprint(snap)
    # different data (or order) is a different identity
    reordered = {c: v[::-1].copy() for c, v in data.items()}
    other = fmt.write("t", SCHEMA, reordered)
    assert fmt.content_fingerprint(other) != fmt.content_fingerprint(snap)
    # memoized: the second call is a ref read, not a table scan
    gets_before = fmt.store.stats.gets
    fmt.content_fingerprint(snap)
    assert fmt.store.stats.gets == gets_before


def test_schema_validation_errors(fmt, rng):
    data = make_table(10, rng)
    bad = dict(data)
    bad["fare"] = bad["fare"].astype(np.float64)
    with pytest.raises(TypeError):
        fmt.write("t", SCHEMA, bad)
    with pytest.raises(ValueError):
        fmt.write("t", SCHEMA, {k: v[:5] if k == "fare" else v for k, v in data.items()})


@given(
    n=st.integers(0, 500),
    threshold=st.integers(-5, 260),
    op=st.sampled_from(["<", "<=", ">", ">=", "==", "!="]),
)
@settings(max_examples=40, deadline=None)
def test_property_pushdown_equals_posthoc_filter(tmp_path_factory, n, threshold, op):
    """Pushdown (stats pruning + residual) == filtering after a full read."""
    fmt = TableFormat(ObjectStore(tmp_path_factory.mktemp("pp")), shard_rows=64)
    # threshold may be negative; keep the seed non-negative
    rng = np.random.default_rng(1000 + n + threshold + len(op))
    data = make_table(n, rng)
    snap = fmt.write("t", SCHEMA, data)
    pred = Predicate("pickup_location_id", op, threshold)
    out = execute_scan(fmt, plan_scan(snap, predicates=[pred]))
    full = fmt.read(snap)
    mask = pred.mask(full["pickup_location_id"]) if n else np.zeros(0, bool)
    for col in SCHEMA.names:
        np.testing.assert_array_equal(out[col], full[col][mask])
