"""Reproduce Fig. 1 (Reasonable-Scale hypothesis) as terminal output.

Left panel: CCDF of SQL query times (log-log) for three companies.
Right panel: cumulative cost share vs bytes-scanned percentile.

Run: PYTHONPATH=src:. python examples/reasonable_scale.py
"""
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.bench_reasonable_scale import _fit_alpha


def ascii_loglog_ccdf(samples_by_name, *, width=60, height=14):
    lines = []
    xs = np.logspace(-0.3, 2.5, width)
    for name, s in samples_by_name.items():
        ccdf = [(s > x).mean() for x in xs]
        lines.append((name, ccdf))
    grid = [[" "] * width for _ in range(height)]
    markers = "*+o"
    for i, (name, ccdf) in enumerate(lines):
        for xi, p in enumerate(ccdf):
            if p <= 1e-4:
                continue
            y = int((np.log10(p) + 4) / 4 * (height - 1))
            grid[height - 1 - y][xi] = markers[i % len(markers)]
    out = ["CCDF P(T > t), log-log (x: 0.5s .. 300s, y: 1e-4 .. 1)"]
    out += ["|" + "".join(r) for r in grid]
    out.append("+" + "-" * width)
    out.append("legend: " + ", ".join(f"{m}={n}" for (n, _), m in
                                      zip(samples_by_name.items(), markers)))
    return "\n".join(out)


def main() -> None:
    rng = np.random.default_rng(7)
    companies = {"startup": 2.4, "scaleup": 2.1, "public": 1.9}
    samples = {
        name: 0.5 * (1 + rng.pareto(alpha - 1, 20000))
        for name, alpha in companies.items()
    }
    print(ascii_loglog_ccdf(samples))
    for name, s in samples.items():
        print(
            f"{name}: alpha_fit={_fit_alpha(s, 0.5):.2f} "
            f"median={np.median(s):.1f}s p95={np.quantile(s, .95):.1f}s "
            f"P(>10s)={(s > 10).mean():.3f}"
        )

    # right panel: cumulative cost vs percentile (billing floors make
    # spend track query count — see benchmarks/bench_reasonable_scale.py)
    b = 1e6 * (1 + rng.pareto(1.2, 50000))
    b *= 750e6 / np.quantile(b, 0.80)
    cost = np.maximum(b, 10e9)
    order = np.argsort(b)
    csum = np.cumsum(cost[order]) / cost.sum()
    print("\ncumulative cost share by bytes-scanned percentile:")
    for pct in (50, 60, 70, 80, 90, 95, 99):
        print(f"  p{pct}: {csum[int(pct / 100 * len(csum)) - 1]:.2f}")
    print(f"  (paper: ~0.80 at p80; p80 bytes = "
          f"{np.quantile(b, .8) / 1e6:.0f} MB ≈ 750 MB)")


if __name__ == "__main__":
    main()
