"""The paper's Appendix pipeline, end to end (Fig. 3 + Fig. 4).

SQL text is verbatim from the paper; the Python expectation uses the
`@requirements` decorator exactly as printed.  Demonstrates: implicit
DAG, filter pushdown + fusion (compare the two plans), ephemeral-branch
atomicity on audit failure, and run replay.

Run: PYTHONPATH=src:. python examples/taxi_pipeline.py
"""
import sys
import tempfile
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from repro.catalog import Catalog
from repro.core import ExpectationFailed, Runner
from repro.io import ObjectStore
from repro.runtime import ServerlessExecutor
from repro.table import TableFormat
from tests.helpers_taxi import TAXI_SCHEMA, build_taxi_pipeline, make_taxi_data


def main() -> None:
    store = ObjectStore(tempfile.mkdtemp())
    catalog = Catalog(store)
    fmt = TableFormat(store, shard_rows=8192)
    rng = np.random.default_rng(0)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, make_taxi_data(100_000, rng))
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})

    with ServerlessExecutor() as ex:
        runner = Runner(catalog, fmt, ex)

        # fused run (the paper's optimized physical plan)
        res = runner.run(build_taxi_pipeline(), branch="feat_1")
        print("== fused plan ==")
        print(res.plan.describe())
        print(f"io: {res.stats['io']}")

        # naive isomorphic plan (the paper's first version) for contrast —
        # cache=False so the comparison measures genuine recompute (the
        # default-on node cache would plan around the fused run's outputs)
        res_naive = runner.run(
            build_taxi_pipeline(), branch="feat_naive", fusion=False,
            pushdown=False, cache=False,
        )
        print("== isomorphic plan ==")
        print(res_naive.plan.describe())
        print(f"io: {res_naive.stats['io']}")
        ratio = res_naive.stats["io"]["bytes_written"] / max(
            res.stats["io"]["bytes_written"], 1
        )
        print(f"fusion avoided {ratio:.1f}x object-store writes")

        # audit failure → rollback (nothing merges)
        low = make_taxi_data(5_000, rng, mean_count=1.0)
        bad = fmt.write("taxi_table", TAXI_SCHEMA, low)
        catalog.commit("main", {"taxi_table": fmt.manifest_key(bad)})
        try:
            runner.run(build_taxi_pipeline(), branch="main")
        except ExpectationFailed as e:
            print(f"audit failed as expected: {e}")
        assert "pickups" not in catalog.tables(branch="main")

        # replay: same code, same data version, identical artifacts
        again = runner.replay(build_taxi_pipeline(), res.run_id)
        assert again.artifacts == res.artifacts
        print(f"replay of run {res.run_id} is bit-identical "
              f"({len(again.artifacts)} artifacts)")


if __name__ == "__main__":
    main()
