"""The paper's Appendix pipeline, end to end — SDK edition (Fig. 3 + 4).

SQL text is verbatim from the paper; the expectation uses the
``@repro.requirements`` decorator exactly as printed.  The whole platform
is constructed through ``repro.Client`` and the DAG is assembled from
decorator registrations — no ObjectStore/Catalog/Runner wiring, exactly
the "functions are all you need" surface of 4.1.

Demonstrates: decorator-declared models, branch-scoped sessions
(merge-on-success / rollback-on-audit-failure), fusion + pushdown
(compare the two plans), typed RunHandles, and run replay.

Run: PYTHONPATH=src python examples/taxi_pipeline.py
"""
import numpy as np

import repro
from repro.examples_data import TAXI_SCHEMA, make_taxi_data

# ----------------------------------------------------------------- the DAG
taxi = repro.project("taxi_demo")

taxi.sql(
    "trips",
    """
    SELECT
     pickup_location_id,
     passenger_count as count,
     dropoff_location_id
    FROM
     taxi_table
    WHERE
     pickup_at >= '2019-04-01'
    """,
)


@taxi.expectation()
@repro.requirements({"pandas": "2.0.0"})
def trips_expectation(ctx, trips):
    return trips.mean("count") > 10.0


taxi.sql(
    "pickups",
    """
    SELECT
     pickup_location_id,
     dropoff_location_id,
     COUNT(*) AS counts
    FROM
     trips
    GROUP BY
     pickup_location_id,
     dropoff_location_id
    ORDER BY
     counts DESC
    """,
)


def main() -> None:
    rng = np.random.default_rng(0)
    with repro.Client.ephemeral(shard_rows=8192) as client:
        client.write_table(
            "taxi_table", make_taxi_data(100_000, rng), schema=TAXI_SCHEMA
        )

        # fused run on a feature branch (the paper's optimized plan);
        # the branch handle merges into main on clean exit
        with client.branch("feat_1") as branch:
            res = branch.run(taxi).raise_for_state()
            print("== fused plan ==")
            print(res.plan.describe())
            print(f"io: {res.io}")
        assert "pickups" in client.tables("main")  # merged on success

        # naive isomorphic plan (the paper's first version) for contrast —
        # cache=False so the comparison measures genuine recompute (the
        # default-on node cache would plan around the fused run's outputs)
        res_naive = client.run(
            taxi, branch="feat_naive", fusion=False, pushdown=False,
            cache=False,
        )
        print("== isomorphic plan ==")
        print(res_naive.plan.describe())
        print(f"io: {res_naive.io}")
        ratio = res_naive.io["bytes_written"] / max(res.io["bytes_written"], 1)
        print(f"fusion avoided {ratio:.1f}x object-store writes")

        # audit failure → typed AUDIT_FAILED handle, branch rolled back
        low = make_taxi_data(5_000, rng, mean_count=1.0)
        main_head = client.catalog.head("main").commit_id
        with client.branch("feat_bad") as bad_branch:
            bad_branch.write_table("taxi_table", low, schema=TAXI_SCHEMA)
            failed = bad_branch.run(taxi)
            assert failed.state is repro.RunState.AUDIT_FAILED
            print(f"audit failed as expected: {failed.failed_checks}")
        # rollback: the branch is gone and main never saw the bad data —
        # its head did not move and taxi_table still has the full 100k rows
        assert "feat_bad" not in client.branches()
        assert client.catalog.head("main").commit_id == main_head
        assert client.query("SELECT COUNT(*) AS n FROM taxi_table")["n"][0] == 100_000

        # replay: same code, same data version, identical artifacts
        again = client.replay(res.run_id, taxi)
        assert again.artifacts == res.artifacts
        print(f"replay of run {res.run_id} is bit-identical "
              f"({len(again.artifacts)} artifacts)")


if __name__ == "__main__":
    main()
