"""Quickstart: the lakehouse in 60 seconds — one client, three decorators.

Builds a lake, seeds a table, runs a two-node pipeline with an
expectation on a feature branch, queries the result with time travel.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro

# --- declare a pipeline: implicit DAG, one artifact per node
revenue = repro.project("revenue_report")

revenue.sql(
    "big_orders",
    "SELECT user_id, country, amount FROM orders WHERE amount >= 100",
)


@revenue.expectation()
def big_orders_expectation(ctx, big_orders):
    return big_orders.min("amount") >= 100.0  # audit the artifact


revenue.sql(
    "revenue_by_country",
    "SELECT country, SUM(amount) AS revenue, COUNT(*) AS n "
    "FROM big_orders GROUP BY country ORDER BY revenue DESC",
)


def main() -> None:
    rng = np.random.default_rng(0)
    with repro.Client.ephemeral() as client:
        # --- seed raw data on main
        client.write_table(
            "orders",
            {
                "user_id": rng.integers(0, 1000, 50_000).astype(np.int32),
                "amount": (rng.random(50_000) * 200).astype(np.float32),
                "country": rng.integers(0, 30, 50_000).astype(np.int32),
            },
            message="seed",
        )

        # --- transform-audit-write on a feature branch (kept, not merged)
        feat = client.branch("feat_revenue", ephemeral=False)
        result = feat.run(revenue).raise_for_state()
        print(f"run {result.run_id}: state={result.state} "
              f"checks={result.checks}")
        print(result.plan.describe())

        # --- synchronous Query+Wrangle against the new artifact
        top = feat.query("SELECT country, revenue FROM revenue_by_country LIMIT 3")
        print("top countries:", dict(zip(top["country"].tolist(),
                                         np.round(top["revenue"]).tolist())))

        # --- production (main) never saw any of it
        assert "revenue_by_country" not in client.tables("main")
        print("main untouched:", sorted(client.tables("main")))


if __name__ == "__main__":
    main()
