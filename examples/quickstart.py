"""Quickstart: the lakehouse in 60 seconds.

Builds a lake, seeds a table, runs a two-node pipeline with an
expectation on a feature branch, queries the result with time travel.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

from repro.catalog import Catalog
from repro.core import Pipeline, Runner
from repro.io import ObjectStore
from repro.runtime import ServerlessExecutor
from repro.table import Schema, TableFormat


def main() -> None:
    # --- a lake, a catalog, a serverless executor
    store = ObjectStore(tempfile.mkdtemp())
    catalog = Catalog(store)
    fmt = TableFormat(store)
    rng = np.random.default_rng(0)

    # --- seed raw data on main
    schema = Schema.of(user_id="int32", amount="float32", country="int32")
    snap = fmt.write(
        "orders",
        schema,
        {
            "user_id": rng.integers(0, 1000, 50_000).astype(np.int32),
            "amount": (rng.random(50_000) * 200).astype(np.float32),
            "country": rng.integers(0, 30, 50_000).astype(np.int32),
        },
    )
    catalog.commit("main", {"orders": fmt.manifest_key(snap)}, message="seed")

    # --- declare a pipeline: implicit DAG, one artifact per node
    p = Pipeline("revenue_report")
    p.sql(
        "big_orders",
        "SELECT user_id, country, amount FROM orders WHERE amount >= 100",
    )

    @p.python
    def big_orders_expectation(ctx, big_orders):
        return big_orders.min("amount") >= 100.0  # audit the artifact

    p.sql(
        "revenue_by_country",
        "SELECT country, SUM(amount) AS revenue, COUNT(*) AS n "
        "FROM big_orders GROUP BY country ORDER BY revenue DESC",
    )

    with ServerlessExecutor() as ex:
        runner = Runner(catalog, fmt, ex)
        result = runner.run(p, branch="feat_revenue")  # transform-audit-write
        print(f"run {result.run_id}: merged={result.ok} checks={result.checks}")
        print(result.plan.describe())

        # --- synchronous Query+Wrangle against the new artifact
        top = runner.query(
            "SELECT country, revenue FROM revenue_by_country LIMIT 3",
            branch="feat_revenue",
        )
        print("top countries:", dict(zip(top["country"].tolist(),
                                         np.round(top["revenue"]).tolist())))

        # --- production (main) never saw any of it
        assert "revenue_by_country" not in catalog.tables(branch="main")
        print("main untouched:", sorted(catalog.tables(branch='main')))


if __name__ == "__main__":
    main()
