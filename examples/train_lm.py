"""End-to-end driver: train a ~100M-parameter LM through the lakehouse.

* tokens live in a versioned TensorTable (data commit pinned);
* checkpoints commit to a catalog branch (async, atomic);
* the run is killed halfway and RESUMED to demonstrate restart-exactness;
* the audited final checkpoint is promoted to main (transform-audit-write).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]
"""
import argparse
import tempfile

import numpy as np

from repro.catalog import Catalog
from repro.data.tokens import TokenDataset, write_token_table
from repro.io import ObjectStore
from repro.models import LM
from repro.models.lm import LMConfig, ModelFamily
from repro.table import TableFormat
from repro.train import TrainLoop, TrainLoopConfig, TrainStepConfig


def make_model(tiny: bool) -> LM:
    if tiny:
        return LM(
            LMConfig(
                name="lm-3m", family=ModelFamily.DENSE, n_layers=2,
                d_model=128, n_heads=4, n_kv_heads=2, d_ff=512, vocab=2048,
                segments=((("attn",), 2),), tie_embeddings=True,
            )
        )
    # ~100M params: 12L, d=768, llama-style
    return LM(
        LMConfig(
            name="lm-100m", family=ModelFamily.DENSE, n_layers=12,
            d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000,
            segments=((("attn",), 12),), tie_embeddings=True,
        )
    )


def synth_corpus(rng: np.random.Generator, n: int = 2_000_000, vocab: int = 32000):
    """Zipf-ish synthetic corpus with local structure (learnable)."""
    base = rng.zipf(1.3, n).clip(1, vocab - 1)
    # inject repeated phrases so the loss has something to learn
    phrase = rng.integers(1, vocab, 64)
    for start in range(0, n - 64, 997):
        if rng.random() < 0.3:
            base[start : start + 64] = phrase
    return base.astype(np.int32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true", help="3M params for CI")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    store = ObjectStore(tempfile.mkdtemp())
    catalog = Catalog(store)
    fmt = TableFormat(store)
    rng = np.random.default_rng(0)

    model = make_model(args.tiny)
    vocab = model.cfg.vocab
    key = write_token_table(
        fmt, catalog, "corpus", synth_corpus(rng, vocab=vocab)
    )
    ds = TokenDataset(fmt, key, batch_size=args.batch, seq_len=args.seq, seed=0)

    cfg = TrainLoopConfig(
        total_steps=args.steps,
        checkpoint_every=max(args.steps // 6, 10),
        log_every=max(args.steps // 15, 5),
        async_checkpoint=True,
        max_final_loss=np.log(vocab),  # audit: must beat uniform
        step=TrainStepConfig(
            peak_lr=3e-4, warmup_steps=args.steps // 10,
            total_steps=args.steps, grad_clip=1.0,
        ),
    )

    # ---- phase 1: run just over half, then "crash"
    half = args.steps // 2 + 1
    loop = TrainLoop(model, ds, catalog, branch="train_main", config=cfg)
    loop.config.total_steps = half
    out1 = loop.run()
    print(f"[phase1] crashed at step {half}, loss {out1['final_loss']:.3f}")

    # ---- phase 2: restart — resumes from the last committed checkpoint
    loop2 = TrainLoop(model, ds, catalog, branch="train_main", config=cfg)
    loop2.config.total_steps = args.steps
    out2 = loop2.run()
    print(
        f"[phase2] resumed, ran {out2['steps_run']} more steps, "
        f"final loss {out2['final_loss']:.3f} (uniform={np.log(vocab):.3f})"
    )
    assert out2["audit_ok"], "final loss failed the audit gate"

    # ---- write: promote the audited checkpoint to main
    loop2.promote("main")
    head = catalog.head("main")
    print(f"promoted checkpoint to main @ {head.commit_id[:12]}: "
          f"{sorted(catalog.tables(branch='main'))}")


if __name__ == "__main__":
    main()
