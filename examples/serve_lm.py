"""Serve a model from a catalog branch with batched requests.

Trains a tiny LM for a few steps, commits the checkpoint, then checks it
out and serves a batch of prompts through the continuous-batching engine
(Query+Wrangle mode for models).

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import tempfile

import jax
import numpy as np

from repro.catalog import Catalog
from repro.data.tokens import TokenDataset, write_token_table
from repro.io import ObjectStore
from repro.models import LM
from repro.models.lm import LMConfig, ModelFamily
from repro.serve import Request, ServeConfig, ServeEngine
from repro.table import TableFormat
from repro.train import CheckpointManager, TrainLoop, TrainLoopConfig, TrainStepConfig
from repro.train.step import make_train_state


def main() -> None:
    store = ObjectStore(tempfile.mkdtemp())
    catalog = Catalog(store)
    fmt = TableFormat(store)
    rng = np.random.default_rng(0)

    model = LM(
        LMConfig(
            name="srv-lm", family=ModelFamily.DENSE, n_layers=2, d_model=128,
            n_heads=4, n_kv_heads=2, d_ff=512, vocab=512,
            segments=((("attn",), 2),), tie_embeddings=True, max_decode_len=64,
        )
    )
    tokens = np.tile(rng.integers(1, 512, 512), 50).astype(np.int32)
    key = write_token_table(fmt, catalog, "corpus", tokens)
    ds = TokenDataset(fmt, key, batch_size=4, seq_len=32, seed=0)
    loop = TrainLoop(
        model, ds, catalog, branch="main",
        config=TrainLoopConfig(
            total_steps=30, checkpoint_every=15, log_every=10,
            step=TrainStepConfig(peak_lr=1e-3, warmup_steps=3, total_steps=30),
        ),
    )
    loop.run()

    # ---- check the artifact out of the catalog and serve it
    mgr = CheckpointManager(catalog, prefix=f"models/{model.cfg.name}")
    like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    state_like = jax.eval_shape(
        lambda p: make_train_state(model, p, TrainStepConfig()), like
    )
    (params, _), step = mgr.restore((like, state_like), branch="main")
    print(f"serving checkpoint from step {step}")

    engine = ServeEngine(model, params, ServeConfig(max_batch=3, max_len=64))
    prompts = [
        np.array([5, 6, 7], np.int32),
        np.array([100, 101], np.int32),
        np.array([200], np.int32),
        np.array([1, 2, 3, 4], np.int32),  # queues for a free slot
    ]
    reqs = [Request(prompt=p, max_new_tokens=8) for p in prompts]
    engine.generate(reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: prompt={r.prompt.tolist()} -> {r.generated}")


if __name__ == "__main__":
    main()
