"""Training substrate: optimizers, schedules, steps, checkpoints, loop."""
from repro.train.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    adafactor_init,
    adafactor_update,
)
from repro.train.schedule import warmup_cosine
from repro.train.step import TrainStepConfig, make_train_step
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import TrainLoop, TrainLoopConfig

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "adafactor_init",
    "adafactor_update",
    "warmup_cosine",
    "TrainStepConfig",
    "make_train_step",
    "CheckpointManager",
    "TrainLoop",
    "TrainLoopConfig",
]
