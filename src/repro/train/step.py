"""The jitted train step: loss → grads → clip → (compress) → optimizer.

Built as a closure over (model, optimizer config) so the same factory
serves the smoke tests (1 device), the end-to-end example (~100M model)
and the 512-chip dry-run — only shardings differ at jit time.

Microbatching (gradient accumulation) runs as a ``lax.scan`` over the
leading microbatch axis, with the DP gradient reduction deferred to the
end of the scan — on hardware this is what lets the per-microbatch
backward overlap with the previous microbatch's reduce-scatter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.compression import compress_decompress, init_compression
from repro.models.lm import LM
from repro.train.optimizer import OPTIMIZERS, AdamWConfig
from repro.train.schedule import warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    optimizer: str = "adamw"
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0
    #: microbatches per step (1 = no accumulation)
    accum_steps: int = 1
    #: int8 error-feedback gradient compression (DCN-crossing DP traffic)
    compress_grads: bool = False
    #: cast f32 master params to this dtype at the TOP of the step, so
    #: FSDP all-gathers move half the bytes (§Perf: collective term).
    #: None disables (params used at their stored dtype).
    compute_cast: Any = jnp.bfloat16
    adam: AdamWConfig = AdamWConfig()


def global_norm(tree: Any) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(tree)
    ]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree
    ), norm


def make_train_state(model: LM, params: Any, cfg: TrainStepConfig) -> Dict[str, Any]:
    init_fn, _ = OPTIMIZERS[cfg.optimizer]
    state: Dict[str, Any] = {
        "opt": init_fn(params, cfg.adam),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compress_grads:
        state["ef"] = init_compression(params)
    return state


def make_train_step(
    model: LM, cfg: TrainStepConfig
) -> Callable[[Any, Dict[str, Any], Dict[str, jax.Array]], Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]]:
    _, update_fn = OPTIMIZERS[cfg.optimizer]

    def loss_fn(params, batch):
        if cfg.compute_cast is not None:
            # cast BEFORE use: the sharded->gathered boundary then moves
            # compute_cast bytes, not f32 (the cast is linear, so grads
            # flow back to the f32 master exactly)
            params = jax.tree_util.tree_map(
                lambda p: p.astype(cfg.compute_cast)
                if p.dtype == jnp.float32 and p.ndim >= 2
                else p,
                params,
            )
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, state, batch):
        """batch leaves: (accum, micro_batch, ...) when accum_steps > 1,
        else (batch, ...)."""
        if cfg.accum_steps > 1:

            def micro(carry, mb):
                g_acc, loss_acc = carry
                (loss, metrics), grads = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                )
                return (g_acc, loss_acc + loss), metrics

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (g_sum, loss_sum), metrics = jax.lax.scan(
                micro, (zeros, jnp.zeros(())), batch
            )
            grads = jax.tree_util.tree_map(lambda g: g / cfg.accum_steps, g_sum)
            loss = loss_sum / cfg.accum_steps
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        new_state = dict(state)
        if cfg.compress_grads:
            grads, new_state["ef"] = compress_decompress(grads, state["ef"])
        lr = warmup_cosine(
            state["step"],
            peak_lr=cfg.peak_lr,
            warmup_steps=cfg.warmup_steps,
            total_steps=cfg.total_steps,
        )
        params, new_state["opt"] = update_fn(
            params, grads, state["opt"], cfg.adam, lr
        )
        new_state["step"] = state["step"] + 1
        out_metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "lr": lr,
            **{k: v for k, v in metrics.items() if k != "loss"},
        }
        return params, new_state, out_metrics

    return train_step
