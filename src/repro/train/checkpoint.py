"""Checkpointing INTO the lakehouse catalog — transform-audit-write for
model state (DESIGN.md §2).

A checkpoint is a content-addressed manifest {param_path: blob_key}
committed to a catalog branch like any table.  Properties inherited from
the data layer for free:

* **atomicity** — the commit lands only after every blob is durably in
  the store (a crashed save can never leave a half-checkpoint visible);
* **dedup** — unchanged leaves (frozen embeddings, optimizer count)
  re-use their blobs across checkpoints (content addressing);
* **mesh-agnostic restore** — leaves are stored as host numpy and
  re-placed with whatever shardings the restoring mesh wants: restart on
  a different topology = elastic scaling;
* **lineage/time travel** — every checkpoint is a commit; rollback is a
  branch reset; runs record which commit they started from.

Saves can run asynchronously (serialize + upload on a background thread),
overlapping the next training steps — the async path is the default in
the training loop.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.catalog.nessie import Catalog
from repro.io.objectstore import ObjectStore
from repro.io.serialization import array_to_bytes, bytes_to_array, dumps_json, loads_json
from repro.utils.logging import get_logger
from repro.utils.tree import flatten_with_paths

log = get_logger("train.checkpoint")


@dataclass
class CheckpointManager:
    catalog: Catalog
    prefix: str = "models/default"

    def _artifact(self) -> str:
        return f"{self.prefix}/checkpoint"

    # ----------------------------------------------------------------- save
    def save(
        self,
        tree: Any,
        *,
        branch: str,
        step: int,
        extra_meta: Optional[Dict[str, Any]] = None,
    ) -> str:
        """Synchronous save: blobs → manifest → catalog commit."""
        store = self.catalog.store
        flat = flatten_with_paths(tree)
        manifest: Dict[str, Any] = {"leaves": {}, "step": step,
                                    "saved_at": time.time(),
                                    "meta": extra_meta or {}}
        for path, leaf in flat.items():
            host = np.asarray(jax.device_get(leaf))
            manifest["leaves"][path] = store.put(array_to_bytes(host))
        key = store.put(dumps_json(manifest))
        self.catalog.commit(
            branch,
            {self._artifact(): key},
            message=f"checkpoint step={step}",
            author="trainer",
        )
        return key

    def save_async(
        self,
        tree: Any,
        *,
        branch: str,
        step: int,
        extra_meta: Optional[Dict[str, Any]] = None,
    ) -> threading.Thread:
        """Fetch to host now (cheap), serialize+upload in the background."""
        flat = {
            path: np.asarray(jax.device_get(leaf))
            for path, leaf in flatten_with_paths(tree).items()
        }

        def work():
            store = self.catalog.store
            manifest: Dict[str, Any] = {"leaves": {}, "step": step,
                                        "saved_at": time.time(),
                                        "meta": extra_meta or {}}
            for path, host in flat.items():
                manifest["leaves"][path] = store.put(array_to_bytes(host))
            key = store.put(dumps_json(manifest))
            self.catalog.commit(
                branch, {self._artifact(): key},
                message=f"checkpoint step={step} (async)", author="trainer",
            )
            log.info("async checkpoint step=%d committed on %r", step, branch)

        t = threading.Thread(target=work, name=f"ckpt-{step}", daemon=True)
        t.start()
        return t

    # -------------------------------------------------------------- restore
    def latest_step(self, *, branch: str) -> Optional[int]:
        try:
            key = self.catalog.table_key(self._artifact(), branch=branch)
        except Exception:
            return None
        manifest = loads_json(self.catalog.store.get(key))
        return int(manifest["step"])

    def restore(
        self,
        tree_like: Any,
        *,
        branch: str,
        commit_id: Optional[str] = None,
        shardings: Optional[Any] = None,
    ) -> Tuple[Any, int]:
        """Restore into the structure of ``tree_like`` (shapes validated).

        ``shardings``: optional matching tree of NamedShardings — leaves
        are device_put with them (elastic restore onto any mesh).
        """
        store = self.catalog.store
        key = self.catalog.table_key(
            self._artifact(), branch=branch, commit_id=commit_id
        )
        manifest = loads_json(store.get(key))
        flat_like = flatten_with_paths(tree_like)
        flat_sh = flatten_with_paths(shardings) if shardings is not None else {}
        missing = set(flat_like) - set(manifest["leaves"])
        if missing:
            raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]} ...")
        out: Dict[str, Any] = {}
        for path, like in flat_like.items():
            host = bytes_to_array(store.get(manifest["leaves"][path]))
            if tuple(host.shape) != tuple(like.shape):
                raise ValueError(
                    f"shape mismatch at {path}: ckpt {host.shape} vs "
                    f"expected {like.shape} — incompatible architecture"
                )
            host = host.astype(like.dtype)
            if path in flat_sh:
                out[path] = jax.device_put(host, flat_sh[path])
            else:
                out[path] = jax.device_put(host)
        rebuilt = _unflatten_like(tree_like, out)
        return rebuilt, int(manifest["step"])


def _unflatten_like(tree_like: Any, flat: Dict[str, Any]) -> Any:
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(tree_like)
    treedef = paths_and_leaves[1]
    from repro.utils.tree import _path_elem

    leaves = []
    for path, _ in paths_and_leaves[0]:
        key = "/".join(_path_elem(p) for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)
