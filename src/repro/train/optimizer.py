"""Optimizers, built from scratch (no optax): AdamW + low-memory Adafactor.

State layout mirrors param sharding (ZeRO: because each moment tensor has
the same shape/sharding as its parameter, sharding params over "data"
automatically shards optimizer state the same way — no separate machinery).

Adafactor (factored second moment, bf16 first moment) exists for the
671B-class dry-runs where full f32 Adam moments would not fit HBM; the
choice is a config knob surfaced in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    #: moment dtypes — bf16 moments halve optimizer memory
    m_dtype: Any = jnp.float32
    v_dtype: Any = jnp.float32


# -------------------------------------------------------------------- AdamW
def adamw_init(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    return {
        "m": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, cfg.m_dtype), params
        ),
        "v": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, cfg.v_dtype), params
        ),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params: Any, grads: Any, state: Dict[str, Any], cfg: AdamWConfig, lr: jax.Array
) -> Tuple[Any, Dict[str, Any]]:
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**c
    bc2 = 1.0 - cfg.b2**c

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * (g32 * g32)
        step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, cgrp = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(cgrp)
    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        {
            "m": jax.tree_util.tree_unflatten(treedef, new_m),
            "v": jax.tree_util.tree_unflatten(treedef, new_v),
            "count": count,
        },
    )


# ---------------------------------------------------------------- Adafactor
def adafactor_init(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    """Factored v for rank>=2 leaves (rows+cols vectors), bf16 m."""

    def v_like(p):
        if p.ndim >= 2:
            return {
                "row": jnp.zeros(p.shape[:-1], jnp.float32),
                "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"full": jnp.zeros(p.shape, jnp.float32)}

    return {
        "m": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.bfloat16), params
        ),
        "v": jax.tree_util.tree_map(v_like, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adafactor_update(
    params: Any, grads: Any, state: Dict[str, Any], cfg: AdamWConfig, lr: jax.Array
) -> Tuple[Any, Dict[str, Any]]:
    count = state["count"] + 1

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + 1e-30
        if p.ndim >= 2:
            row = cfg.b2 * v["row"] + (1 - cfg.b2) * jnp.mean(g2, axis=-1)
            col = cfg.b2 * v["col"] + (1 - cfg.b2) * jnp.mean(g2, axis=-2)
            v_new = {"row": row, "col": col}
            denom_r = row / jnp.maximum(
                jnp.mean(row, axis=-1, keepdims=True), 1e-30
            )
            vhat = denom_r[..., None] * col[..., None, :]
        else:
            full = cfg.b2 * v["full"] + (1 - cfg.b2) * g2
            v_new = {"full": full}
            vhat = full
        update = g32 / jnp.sqrt(vhat + cfg.eps)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * update
        step = m_new + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return p_new.astype(p.dtype), m_new.astype(jnp.bfloat16), v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        {
            "m": jax.tree_util.tree_unflatten(treedef, new_m),
            "v": jax.tree_util.tree_unflatten(treedef, new_v),
            "count": count,
        },
    )


OPTIMIZERS = {
    "adamw": (adamw_init, adamw_update),
    "adafactor": (adafactor_init, adafactor_update),
}
