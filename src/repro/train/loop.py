"""The restartable training loop — training as a lakehouse pipeline.

Fault-tolerance contract (tested in tests/test_train_loop.py):

* state = (params, opt) checkpoints into the catalog (async, atomic);
* data sampling is stateless in (seed, step);
* → killing the process at ANY step and calling ``TrainLoop.run`` again
  resumes from the last committed checkpoint and produces the same
  parameters as an uninterrupted run (modulo the steps re-done since the
  last checkpoint — bit-exact because batches are step-keyed).

Audit-before-write: the loop trains on a working branch; eval
"expectations" (loss finite, ≤ threshold) gate the merge of the final
checkpoint into the target branch — the paper's transform-audit-write
applied to model artifacts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.catalog.nessie import Catalog
from repro.data.tokens import TokenDataset
from repro.models.lm import LM
from repro.train.checkpoint import CheckpointManager
from repro.train.step import TrainStepConfig, make_train_state, make_train_step
from repro.utils.logging import get_logger

log = get_logger("train.loop")


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    log_every: int = 10
    async_checkpoint: bool = True
    #: audit gates for the final merge
    max_final_loss: float = float("inf")
    step: TrainStepConfig = dataclasses.field(default_factory=TrainStepConfig)


class TrainLoop:
    def __init__(
        self,
        model: LM,
        dataset: TokenDataset,
        catalog: Catalog,
        *,
        branch: str,
        config: TrainLoopConfig,
        ckpt_prefix: Optional[str] = None,
    ) -> None:
        self.model = model
        self.dataset = dataset
        self.catalog = catalog
        self.branch = branch
        self.config = config
        self.ckpt = CheckpointManager(
            catalog, prefix=ckpt_prefix or f"models/{model.cfg.name}"
        )
        self._train_step = jax.jit(
            make_train_step(model, config.step), donate_argnums=(0, 1)
        )

    def run(self, *, init_key: int = 0) -> Dict[str, Any]:
        cfg = self.config
        if not self.catalog.has_branch(self.branch):
            self.catalog.create_branch(self.branch)

        # ---- restore or init (elastic restart point)
        params = self.model.init(jax.random.PRNGKey(init_key))
        state = make_train_state(self.model, params, cfg.step)
        start_step = 0
        latest = self.ckpt.latest_step(branch=self.branch)
        if latest is not None:
            (params, state), start_step = self.ckpt.restore(
                (params, state), branch=self.branch
            )
            log.info("resumed from checkpoint at step %d", start_step)

        losses: List[float] = []
        pending: List[Any] = []
        t0 = time.perf_counter()
        for step in range(start_step, cfg.total_steps):
            batch = {
                k: jnp.asarray(v) for k, v in self.dataset.batch_at(step).items()
            }
            params, state, metrics = self._train_step(params, state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % cfg.log_every == 0:
                log.info(
                    "step %d loss %.4f lr %.2e gnorm %.2f",
                    step, loss, float(metrics["lr"]), float(metrics["grad_norm"]),
                )
            if (step + 1) % cfg.checkpoint_every == 0:
                if cfg.async_checkpoint:
                    pending.append(
                        self.ckpt.save_async(
                            (params, state), branch=self.branch, step=step + 1
                        )
                    )
                else:
                    self.ckpt.save((params, state), branch=self.branch, step=step + 1)
        for t in pending:
            t.join()

        # ---- audit: final expectations gate the terminal checkpoint
        final_loss = float(np.mean(losses[-5:])) if losses else float("inf")
        audit_ok = np.isfinite(final_loss) and final_loss <= cfg.max_final_loss
        if losses:  # may be empty when fully resumed at total_steps
            self.ckpt.save(
                (params, state),
                branch=self.branch,
                step=cfg.total_steps,
                extra_meta={"final_loss": final_loss, "audit_ok": bool(audit_ok)},
            )
        wall = time.perf_counter() - t0
        return {
            "params": params,
            "state": state,
            "losses": losses,
            "final_loss": final_loss,
            "audit_ok": audit_ok,
            "steps_run": len(losses),
            "wall_s": wall,
        }

    def promote(self, target_branch: str) -> None:
        """Merge the audited checkpoint into the target branch (write)."""
        self.catalog.merge(
            self.branch, target_branch,
            message=f"promote {self.ckpt.prefix}", author="trainer",
        )
