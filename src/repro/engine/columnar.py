"""The engine's relation type: fixed-shape columns + validity mask."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclass
class Columnar:
    """A columnar relation with masked-row semantics.

    ``valid`` marks live rows; operators never change column length, they
    only flip validity — this keeps every op shape-stable under ``jit`` and
    lets XLA fuse chains of them without materialization (the engine-level
    mirror of the paper's "avoid spillover to object storage").
    """

    columns: Dict[str, jax.Array]
    valid: jax.Array  # bool[n]

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        names = sorted(self.columns)
        return ([self.columns[n] for n in names] + [self.valid], names)

    @classmethod
    def tree_unflatten(cls, names, leaves):
        return cls(dict(zip(names, leaves[:-1])), leaves[-1])

    # ------------------------------------------------------------ helpers
    @staticmethod
    def from_arrays(columns: Dict[str, jax.Array]) -> "Columnar":
        if not columns:
            raise ValueError("empty relation")
        n = len(next(iter(columns.values())))
        for name, arr in columns.items():
            if len(arr) != n:
                raise ValueError(f"ragged column {name!r}")
        return Columnar(
            {k: jnp.asarray(v) for k, v in columns.items()},
            jnp.ones((n,), dtype=bool),
        )

    @staticmethod
    def from_numpy(columns: Dict[str, np.ndarray]) -> "Columnar":
        return Columnar.from_arrays({k: jnp.asarray(v) for k, v in columns.items()})

    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    @property
    def names(self) -> List[str]:
        return sorted(self.columns)

    def num_valid(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))

    def column(self, name: str) -> jax.Array:
        if name not in self.columns:
            raise KeyError(f"no column {name!r}; have {self.names}")
        return self.columns[name]

    def __getitem__(self, name: str) -> jax.Array:
        return self.column(name)

    # ---------------------------------------- masked statistics (for
    # expectations — the paper's trips['count'].mean() > 10 pattern)
    def sum(self, name: str) -> jax.Array:
        vals = self.column(name)
        return jnp.sum(jnp.where(self.valid, vals, 0))

    def count(self) -> jax.Array:
        return self.num_valid()

    def mean(self, name: str) -> jax.Array:
        total = self.sum(name).astype(jnp.float32)
        return total / jnp.maximum(self.num_valid(), 1).astype(jnp.float32)

    def min(self, name: str) -> jax.Array:
        vals = self.column(name)
        big = jnp.array(jnp.inf, vals.dtype) if vals.dtype.kind == "f" else jnp.iinfo(vals.dtype).max
        return jnp.min(jnp.where(self.valid, vals, big))

    def max(self, name: str) -> jax.Array:
        vals = self.column(name)
        small = jnp.array(-jnp.inf, vals.dtype) if vals.dtype.kind == "f" else jnp.iinfo(vals.dtype).min
        return jnp.max(jnp.where(self.valid, vals, small))

    def with_columns(self, new: Dict[str, jax.Array]) -> "Columnar":
        cols = dict(self.columns)
        cols.update(new)
        return Columnar(cols, self.valid)

    def select(self, names: List[str]) -> "Columnar":
        return Columnar({n: self.column(n) for n in names}, self.valid)

    def mask_where(self, keep: jax.Array) -> "Columnar":
        return Columnar(self.columns, self.valid & keep)

    # --------------------------------------------------- host-side export
    def to_numpy(self, *, compact: bool = True) -> Dict[str, np.ndarray]:
        """Pull to host; ``compact`` drops invalid rows (data-dependent
        shape — host-side only, never inside jit)."""
        valid = np.asarray(self.valid)
        out = {}
        for name, arr in self.columns.items():
            host = np.asarray(arr)
            out[name] = host[valid] if compact else host
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Columnar(cols={self.names}, capacity={self.capacity})"
