"""A minimal SQL front-end — enough to run the paper's Appendix verbatim.

Supported grammar (case-insensitive keywords)::

    SELECT item [, item ...]
    FROM table
    [WHERE conjunct [AND conjunct ...]]
    [GROUP BY col [, col ...]]
    [ORDER BY col [ASC|DESC] [, ...]]
    [LIMIT n]

    item     := expr [AS alias] | COUNT(*) [AS alias] | fn(expr) [AS alias]
    conjunct := expr cmp expr
    expr     := col | number | string-date | expr (+|-|*|/) expr | (expr)

String literals that look like ISO dates ('2019-04-01') are converted to
integer days-since-epoch, matching how the synthetic taxi dataset stores
``pickup_at`` — a pragmatic "spare part" standing in for full date types.
"""
from __future__ import annotations

import datetime as _dt
import re
from typing import List, Optional, Tuple

from repro.engine.expr import Expr, col, lit
from repro.engine.query import Agg, Query

_TOKEN_RE = re.compile(
    r"""
    \s*(
        '[^']*'            # string literal
      | [A-Za-z_][\w.]*    # identifier / keyword
      | \d+\.\d+ | \d+     # numbers
      | >= | <= | != | <> | = | > | <
      | [(),*+\-/]
    )
    """,
    re.VERBOSE,
)

_KEYWORDS = {"select", "from", "where", "group", "by", "order", "limit",
             "and", "as", "asc", "desc", "count", "sum", "min", "max", "avg"}
_AGG_KEYWORDS = {"count", "sum", "min", "max", "avg"}
_CMP = {">=": "ge", "<=": "le", "!=": "ne", "<>": "ne", "=": "eq", ">": "gt", "<": "lt"}


class SqlError(SyntaxError):
    """A SQL parse error that knows *where* it happened.

    Subclasses ``SyntaxError`` so existing ``except SyntaxError`` callers
    keep working; adds the character position and the offending fragment
    so lint findings (and humans) can point at the exact spot."""

    def __init__(self, message: str, sql: str, pos: int):
        self.sql = sql
        self.pos = pos
        lo, hi = max(0, pos - 8), min(len(sql), pos + 16)
        self.fragment = sql[lo:hi].replace("\n", " ")
        super().__init__(
            f"{message} at position {pos}: "
            f"{'...' if lo > 0 else ''}{self.fragment}"
            f"{'...' if hi < len(sql) else ''}"
        )


def _tokenize(sql: str) -> List[Tuple[str, int]]:
    """``[(token, char_position), ...]`` over the cleaned SQL text."""
    pos, out = 0, []
    while pos < len(sql):
        m = _TOKEN_RE.match(sql, pos)
        if not m:
            bad = pos + (len(sql[pos:]) - len(sql[pos:].lstrip()))
            raise SqlError("cannot tokenize SQL", sql, bad)
        out.append((m.group(1), m.start(1)))
        pos = m.end()
    return out


class _Parser:
    def __init__(self, tokens: List[Tuple[str, int]], sql: str):
        self.toks = [t for t, _ in tokens]
        self.positions = [p for _, p in tokens]
        self.sql = sql
        self.i = 0

    def pos(self) -> int:
        """Character position of the current token (end of SQL if spent)."""
        if self.i < len(self.positions):
            return self.positions[self.i]
        return len(self.sql)

    def error(self, message: str) -> SqlError:
        return SqlError(message, self.sql, self.pos())

    def peek(self) -> Optional[str]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def peek_kw(self) -> Optional[str]:
        t = self.peek()
        return t.lower() if t and t.lower() in _KEYWORDS else None

    def next(self) -> str:
        t = self.peek()
        if t is None:
            raise self.error("unexpected end of SQL")
        self.i += 1
        return t

    def expect_kw(self, kw: str) -> None:
        if self.peek() is None:
            raise self.error(f"expected {kw.upper()}, got end of SQL")
        if self.peek().lower() != kw:
            raise self.error(f"expected {kw.upper()}, got {self.peek()!r}")
        self.i += 1

    def accept_kw(self, kw: str) -> bool:
        if self.peek() is not None and self.peek().lower() == kw:
            self.i += 1
            return True
        return False

    def error_at_last(self, message: str) -> SqlError:
        """An error pointing at the most recently consumed token."""
        pos = self.positions[self.i - 1] if self.i > 0 else 0
        return SqlError(message, self.sql, pos)

    # ------------------------------------------------------------- exprs
    def parse_expr(self) -> Expr:
        node = self.parse_term()
        while self.peek() in ("+", "-"):
            op = self.next()
            rhs = self.parse_term()
            node = Expr("add" if op == "+" else "sub", (node, rhs))
        return node

    def parse_term(self) -> Expr:
        node = self.parse_atom()
        while self.peek() in ("*", "/"):
            op = self.next()
            rhs = self.parse_atom()
            node = Expr("mul" if op == "*" else "div", (node, rhs))
        return node

    def parse_atom(self) -> Expr:
        t = self.next()
        if t == "(":
            e = self.parse_expr()
            if self.next() != ")":
                raise self.error_at_last("expected )")
            return e
        if t.startswith("'"):
            try:
                return lit(_string_literal_value(t[1:-1]))
            except SqlError:
                raise
            except SyntaxError as e:
                raise self.error_at_last(str(e)) from e
        if re.fullmatch(r"\d+\.\d+", t):
            return lit(float(t))
        if re.fullmatch(r"\d+", t):
            return lit(int(t))
        if re.fullmatch(r"[A-Za-z_][\w.]*", t):
            # agg keywords double as identifiers unless followed by "("
            # (the paper's own SQL aliases a column `AS count`)
            if t.lower() not in _KEYWORDS:
                return col(t)
            if t.lower() in _AGG_KEYWORDS and self.peek() != "(":
                return col(t)
        raise self.error_at_last(f"unexpected token {t!r} in expression")

    def parse_comparison(self) -> Expr:
        lhs = self.parse_expr()
        op = self.next()
        if op not in _CMP:
            raise self.error_at_last(f"expected comparison, got {op!r}")
        rhs = self.parse_expr()
        return Expr(_CMP[op], (lhs, rhs))

    # ------------------------------------------------------- select items
    def parse_select_item(self) -> Tuple[str, object]:
        """Return (alias, Expr | Agg)."""
        t = self.peek()
        is_agg_call = (
            t is not None
            and t.lower() in _AGG_KEYWORDS
            and self.i + 1 < len(self.toks)
            and self.toks[self.i + 1] == "("
        )
        if is_agg_call:
            fn = self.next().lower()
            if self.next() != "(":
                raise self.error_at_last(f"expected ( after {fn}")
            if fn == "count" and self.peek() == "*":
                self.next()
                inner: Optional[Expr] = None
            else:
                inner = self.parse_expr()
            if self.next() != ")":
                raise self.error_at_last("expected )")
            alias = self._maybe_alias() or fn
            fn = {"avg": "mean"}.get(fn, fn)
            return alias, Agg(fn, inner, alias)
        e = self.parse_expr()
        default = e.args[0] if e.op == "col" else "expr"
        alias = self._maybe_alias() or default
        return alias, e

    def _maybe_alias(self) -> Optional[str]:
        if self.accept_kw("as"):
            return self.next()
        # bare alias (SELECT x y) is not supported to keep grammar simple
        return None


def _string_literal_value(s: str) -> float:
    """Dates → integer days since epoch; everything else must be numeric."""
    try:
        d = _dt.date.fromisoformat(s)
        return float((d - _dt.date(1970, 1, 1)).days)
    except ValueError:
        pass
    try:
        return float(s)
    except ValueError as e:
        raise SyntaxError(
            f"string literal {s!r} is neither a date nor a number; "
            "the numeric engine needs encodable literals"
        ) from e


def parse_sql(sql: str) -> Query:
    cleaned = sql.strip().rstrip(";")
    p = _Parser(_tokenize(cleaned), cleaned)
    p.expect_kw("select")
    items: List[Tuple[str, object]] = [p.parse_select_item()]
    while p.accept_kw(","):  # pragma: no cover - comma is not a keyword
        items.append(p.parse_select_item())
    while p.peek() == ",":
        p.next()
        items.append(p.parse_select_item())
    p.expect_kw("from")
    source = p.next()

    q = Query(source=source)
    projections = []
    for alias, item in items:
        if isinstance(item, Agg):
            q = Query(**{**q.__dict__, "aggregates": q.aggregates + (item,)})
        else:
            projections.append((alias, item))

    if p.accept_kw("where"):
        e = p.parse_comparison()
        while p.accept_kw("and"):
            e = Expr("and", (e, p.parse_comparison()))
        q = q.where(e)

    if p.accept_kw("group"):
        p.expect_kw("by")
        keys = [p.next()]
        while p.peek() == ",":
            p.next()
            keys.append(p.next())
        q = q.group_by(*keys)
        # group keys are implicitly projected; drop redundant projections
        projections = [(a, e) for a, e in projections
                       if not (e.op == "col" and e.args[0] in keys and a == e.args[0])]
        if projections:
            raise p.error_at_last(
                "non-key, non-aggregate projections in GROUP BY query: "
                f"{[a for a, _ in projections]}"
            )
    elif projections:
        if q.aggregates and len(projections) != len(items):
            raise p.error_at_last(
                "mixing aggregates and plain columns needs GROUP BY"
            )
        q = Query(**{**q.__dict__, "projections": tuple(projections)})

    if p.accept_kw("order"):
        p.expect_kw("by")
        while True:
            name = p.next()
            desc = False
            if p.accept_kw("desc"):
                desc = True
            elif p.accept_kw("asc"):
                desc = False
            q = q.sort(name, desc=desc)
            if p.peek() == ",":
                p.next()
                continue
            break

    if p.accept_kw("limit"):
        q = q.take(int(p.next()))

    if p.peek() is not None:
        raise p.error(f"trailing tokens: {p.toks[p.i:]}")
    return Query(**{**q.__dict__, "raw_sql": cleaned})
