"""The SQL front-end — multi-table SELECT with a predictable v2 grammar.

Supported grammar (case-insensitive keywords)::

    SELECT item [, item ...]
    FROM table [[AS] alias]
    [[INNER|LEFT [OUTER]] JOIN table [[AS] alias] ON colref = colref] ...
    [WHERE condition]
    [GROUP BY colref [, colref ...]]
    [ORDER BY col [ASC|DESC] [, ...]]
    [LIMIT n]

    item      := expr [AS alias] | COUNT(*) [AS alias] | fn(expr) [AS alias]
    condition := boolean expression over AND / OR / NOT, comparisons,
                 expr [NOT] IN (lit, ...), expr [NOT] BETWEEN lo AND hi
    expr      := colref | number | string-date
               | expr (+|-|*|/) expr | (condition)
    colref    := col | qualifier.col   (qualifier = table name or alias)

Precedence, loosest to tightest: OR < AND < NOT < comparison/IN/BETWEEN
< +,- < *,/ < atom.  ``IN`` lowers to an OR of equalities and ``BETWEEN``
to ``>= AND <=``, so both reuse the engine's existing operators (and
BETWEEN's conjuncts push down to the scan layer for free).

String literals that look like ISO dates ('2019-04-01') are converted to
integer days-since-epoch, matching how the synthetic taxi dataset stores
``pickup_at`` — a pragmatic "spare part" standing in for full date types.

Exactly one statement is parsed: an optional trailing ``;`` is consumed,
and anything after it — or any token left over after the clauses above —
is a :class:`SqlError` with the offending position, never a silent
truncation.  Reserved words used as aliases are likewise reported with a
position (aggregate names stay legal as aliases: the paper's own SQL
writes ``AS count``).
"""
from __future__ import annotations

import datetime as _dt
import re
from typing import List, Optional, Tuple

from repro.engine.expr import Expr, col, lit
from repro.engine.query import Agg, Join, Query

_TOKEN_RE = re.compile(
    r"""
    \s*(
        '[^']*'            # string literal
      | [A-Za-z_][\w.]*    # identifier / keyword
      | \d+\.\d+ | \d+     # numbers
      | >= | <= | != | <> | = | > | <
      | [(),*+\-/;]
    )
    """,
    re.VERBOSE,
)

_KEYWORDS = {"select", "from", "where", "group", "by", "order", "limit",
             "and", "or", "not", "in", "between", "as", "asc", "desc",
             "join", "inner", "left", "outer", "on",
             "count", "sum", "min", "max", "avg"}
_AGG_KEYWORDS = {"count", "sum", "min", "max", "avg"}
_CMP = {">=": "ge", "<=": "le", "!=": "ne", "<>": "ne", "=": "eq", ">": "gt", "<": "lt"}
_IDENT_RE = re.compile(r"[A-Za-z_][\w.]*")


class SqlError(SyntaxError):
    """A SQL parse error that knows *where* it happened.

    Subclasses ``SyntaxError`` so existing ``except SyntaxError`` callers
    keep working; adds the character position and the offending fragment
    so lint findings (and humans) can point at the exact spot."""

    def __init__(self, message: str, sql: str, pos: int):
        self.sql = sql
        self.pos = pos
        lo, hi = max(0, pos - 8), min(len(sql), pos + 16)
        self.fragment = sql[lo:hi].replace("\n", " ")
        super().__init__(
            f"{message} at position {pos}: "
            f"{'...' if lo > 0 else ''}{self.fragment}"
            f"{'...' if hi < len(sql) else ''}"
        )


def find_token(sql: Optional[str], token: str) -> Optional[int]:
    """Character position of ``token`` as a whole word in ``sql``.

    The shared locator behind positioned diagnostics that point at a
    *name* rather than a parse state — :class:`RouteError` quoting the
    clause that made ``engine='kernel'`` ineligible, lineage findings
    quoting the missing column.  Qualified references (``t.zone``) match
    literally; returns None when the SQL text is unavailable or the
    token does not occur.
    """
    if not sql or not token:
        return None
    m = re.search(rf"\b{re.escape(token)}\b", sql)
    return m.start() if m else None


def _tokenize(sql: str) -> List[Tuple[str, int]]:
    """``[(token, char_position), ...]`` over the cleaned SQL text."""
    pos, out = 0, []
    while pos < len(sql):
        m = _TOKEN_RE.match(sql, pos)
        if not m:
            bad = pos + (len(sql[pos:]) - len(sql[pos:].lstrip()))
            raise SqlError("cannot tokenize SQL", sql, bad)
        out.append((m.group(1), m.start(1)))
        pos = m.end()
    return out


class _Parser:
    def __init__(self, tokens: List[Tuple[str, int]], sql: str):
        self.toks = [t for t, _ in tokens]
        self.positions = [p for _, p in tokens]
        self.sql = sql
        self.i = 0

    def pos(self) -> int:
        """Character position of the current token (end of SQL if spent)."""
        if self.i < len(self.positions):
            return self.positions[self.i]
        return len(self.sql)

    def error(self, message: str) -> SqlError:
        return SqlError(message, self.sql, self.pos())

    def peek(self) -> Optional[str]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def peek_kw(self) -> Optional[str]:
        t = self.peek()
        return t.lower() if t and t.lower() in _KEYWORDS else None

    def peek2(self) -> Optional[str]:
        return self.toks[self.i + 1] if self.i + 1 < len(self.toks) else None

    def next(self) -> str:
        t = self.peek()
        if t is None:
            raise self.error("unexpected end of SQL")
        self.i += 1
        return t

    def expect_kw(self, kw: str) -> None:
        if self.peek() is None:
            raise self.error(f"expected {kw.upper()}, got end of SQL")
        if self.peek().lower() != kw:
            raise self.error(f"expected {kw.upper()}, got {self.peek()!r}")
        self.i += 1

    def accept_kw(self, kw: str) -> bool:
        if self.peek() is not None and self.peek().lower() == kw:
            self.i += 1
            return True
        return False

    def error_at_last(self, message: str) -> SqlError:
        """An error pointing at the most recently consumed token."""
        pos = self.positions[self.i - 1] if self.i > 0 else 0
        return SqlError(message, self.sql, pos)

    # -------------------------------------------------------- identifiers
    def identifier(self, what: str) -> str:
        """A plain identifier; reserved words are rejected with position."""
        t = self.peek()
        if t is None or not _IDENT_RE.fullmatch(t):
            raise self.error(f"expected {what}, got {t!r}")
        if t.lower() in _KEYWORDS and t.lower() not in _AGG_KEYWORDS:
            raise self.error(
                f"reserved word {t!r} cannot be used as {what}"
            )
        return self.next()

    def _maybe_table_alias(self) -> Optional[str]:
        if self.accept_kw("as"):
            return self.identifier("a table alias")
        t = self.peek()
        # bare alias: FROM trips t — any non-keyword identifier
        if t is not None and _IDENT_RE.fullmatch(t) and t.lower() not in _KEYWORDS:
            return self.next()
        return None

    # ---------------------------------------------------- boolean grammar
    def parse_condition(self) -> Expr:
        node = self.parse_and()
        while self.accept_kw("or"):
            node = Expr("or", (node, self.parse_and()))
        return node

    def parse_and(self) -> Expr:
        node = self.parse_not()
        while self.accept_kw("and"):
            node = Expr("and", (node, self.parse_not()))
        return node

    def parse_not(self) -> Expr:
        if self.accept_kw("not"):
            return Expr("not", (self.parse_not(),))
        return self.parse_predicate()

    def parse_predicate(self) -> Expr:
        lhs = self.parse_expr()
        negate = False
        if (
            self.peek_kw() == "not"
            and self.peek2() is not None
            and self.peek2().lower() in ("in", "between")
        ):
            self.next()
            negate = True
        if self.accept_kw("in"):
            node = self._parse_in(lhs)
        elif self.accept_kw("between"):
            lo = self.parse_expr()
            self.expect_kw("and")
            hi = self.parse_expr()
            node = Expr("and", (Expr("ge", (lhs, lo)), Expr("le", (lhs, hi))))
        elif self.peek() in _CMP:
            op = self.next()
            node = Expr(_CMP[op], (lhs, self.parse_expr()))
        else:
            return lhs  # a bare (boolean-valued) expression
        return Expr("not", (node,)) if negate else node

    def _parse_in(self, lhs: Expr) -> Expr:
        if self.next() != "(":
            raise self.error_at_last("expected ( after IN")
        values = [self.parse_expr()]
        while self.peek() == ",":
            self.next()
            values.append(self.parse_expr())
        if self.next() != ")":
            raise self.error_at_last("expected ) closing IN list")
        node = Expr("eq", (lhs, values[0]))
        for v in values[1:]:
            node = Expr("or", (node, Expr("eq", (lhs, v))))
        return node

    # ------------------------------------------------------------- exprs
    def parse_expr(self) -> Expr:
        node = self.parse_term()
        while self.peek() in ("+", "-"):
            op = self.next()
            rhs = self.parse_term()
            node = Expr("add" if op == "+" else "sub", (node, rhs))
        return node

    def parse_term(self) -> Expr:
        node = self.parse_atom()
        while self.peek() in ("*", "/"):
            op = self.next()
            rhs = self.parse_atom()
            node = Expr("mul" if op == "*" else "div", (node, rhs))
        return node

    def parse_atom(self) -> Expr:
        t = self.next()
        if t == "(":
            # parens admit a full boolean condition — on plain arithmetic
            # content the boolean levels fall straight through to parse_expr
            e = self.parse_condition()
            if self.next() != ")":
                raise self.error_at_last("expected )")
            return e
        if t.startswith("'"):
            try:
                return lit(_string_literal_value(t[1:-1]))
            except SqlError:
                raise
            except SyntaxError as e:
                raise self.error_at_last(str(e)) from e
        if re.fullmatch(r"\d+\.\d+", t):
            return lit(float(t))
        if re.fullmatch(r"\d+", t):
            return lit(int(t))
        if _IDENT_RE.fullmatch(t):
            # agg keywords double as identifiers unless followed by "("
            # (the paper's own SQL aliases a column `AS count`)
            if t.lower() not in _KEYWORDS:
                return col(t)
            if t.lower() in _AGG_KEYWORDS and self.peek() != "(":
                return col(t)
        raise self.error_at_last(f"unexpected token {t!r} in expression")

    # ------------------------------------------------------------- joins
    def parse_join(self) -> Tuple[str, Optional[str], str, str, str]:
        """One join clause → (table, alias, left_on, right_on, how).
        Caller has already consumed the leading INNER/LEFT, if any."""
        how = "inner"
        if self.accept_kw("inner"):
            pass
        elif self.accept_kw("left"):
            self.accept_kw("outer")
            how = "left"
        self.expect_kw("join")
        table = self.identifier("a table name")
        alias = self._maybe_table_alias()
        self.expect_kw("on")
        a = self.parse_expr()
        if self.peek() != "=":
            raise self.error("JOIN ... ON supports a single equality (col = col)")
        self.next()
        b = self.parse_expr()
        if a.op != "col" or b.op != "col":
            raise self.error_at_last(
                "JOIN ... ON condition must compare two columns"
            )
        if self.peek_kw() == "and":
            raise self.error(
                "composite join conditions are not supported; move residual "
                "predicates to WHERE"
            )
        qual = alias or table
        a_ref, b_ref = a.args[0], b.args[0]
        # orient the equality: the side qualified with the joined table's
        # qualifier is right_on; unqualified sides resolve at execution
        if b_ref.split(".")[0] == qual:
            left_on, right_on = a_ref, b_ref
        elif a_ref.split(".")[0] == qual:
            left_on, right_on = b_ref, a_ref
        else:
            left_on, right_on = a_ref, b_ref
        return table, alias, left_on, right_on, how

    # ------------------------------------------------------- select items
    def parse_select_item(self) -> Tuple[str, object]:
        """Return (alias, Expr | Agg)."""
        t = self.peek()
        is_agg_call = (
            t is not None
            and t.lower() in _AGG_KEYWORDS
            and self.i + 1 < len(self.toks)
            and self.toks[self.i + 1] == "("
        )
        if is_agg_call:
            fn = self.next().lower()
            if self.next() != "(":
                raise self.error_at_last(f"expected ( after {fn}")
            if fn == "count" and self.peek() == "*":
                self.next()
                inner: Optional[Expr] = None
            else:
                inner = self.parse_expr()
            if self.next() != ")":
                raise self.error_at_last("expected )")
            alias = self._maybe_alias() or fn
            fn = {"avg": "mean"}.get(fn, fn)
            return alias, Agg(fn, inner, alias)
        e = self.parse_expr()
        # a plain column's default output name is its unqualified tail
        default = e.args[0].split(".")[-1] if e.op == "col" else "expr"
        alias = self._maybe_alias() or default
        return alias, e

    def _maybe_alias(self) -> Optional[str]:
        if self.accept_kw("as"):
            return self.identifier("an alias")
        # bare alias (SELECT x y) is not supported to keep grammar simple
        return None


def _string_literal_value(s: str) -> float:
    """Dates → integer days since epoch; everything else must be numeric."""
    try:
        d = _dt.date.fromisoformat(s)
        return float((d - _dt.date(1970, 1, 1)).days)
    except ValueError:
        pass
    try:
        return float(s)
    except ValueError as e:
        raise SyntaxError(
            f"string literal {s!r} is neither a date nor a number; "
            "the numeric engine needs encodable literals"
        ) from e


def parse_sql(sql: str) -> Query:
    cleaned = sql.strip().rstrip(";").rstrip()
    p = _Parser(_tokenize(cleaned), cleaned)
    p.expect_kw("select")
    items: List[Tuple[str, object]] = []
    if p.peek() == "*":
        p.next()  # SELECT *: no projections; output schema = input schema
    else:
        items.append(p.parse_select_item())
        while p.peek() == ",":
            p.next()
            items.append(p.parse_select_item())
    p.expect_kw("from")
    source = p.identifier("a table name")
    source_alias = p._maybe_table_alias()

    joins: List[Join] = []
    seen_quals = {source_alias or source}
    while p.peek_kw() in ("join", "inner", "left"):
        join_pos = p.pos()
        table, alias, left_on, right_on, how = p.parse_join()
        qual = alias or table
        if qual in seen_quals:
            raise SqlError(
                f"duplicate table qualifier {qual!r}; alias one side",
                cleaned, join_pos,
            )
        seen_quals.add(qual)
        joins.append(Join(table=table, left_on=left_on, right_on=right_on,
                          how=how, alias=alias))

    q = Query(source=source, source_alias=source_alias, joins=tuple(joins))
    projections = []
    for alias, item in items:
        if isinstance(item, Agg):
            q = Query(**{**q.__dict__, "aggregates": q.aggregates + (item,)})
        else:
            projections.append((alias, item))

    if p.accept_kw("where"):
        q = q.where(p.parse_condition())

    if p.accept_kw("group"):
        p.expect_kw("by")
        keys = [p.identifier("a GROUP BY column")]
        while p.peek() == ",":
            p.next()
            keys.append(p.identifier("a GROUP BY column"))
        q = q.group_by(*keys)
        # group keys are implicitly projected; drop redundant projections
        # (the key itself, or the key aliased to its output tail)
        def _is_key_proj(a: str, e) -> bool:
            return (
                e.op == "col"
                and e.args[0] in keys
                and a in (e.args[0], e.args[0].split(".")[-1])
            )
        projections = [(a, e) for a, e in projections if not _is_key_proj(a, e)]
        if projections:
            raise p.error_at_last(
                "non-key, non-aggregate projections in GROUP BY query: "
                f"{[a for a, _ in projections]}"
            )
    elif projections:
        if q.aggregates and len(projections) != len(items):
            raise p.error_at_last(
                "mixing aggregates and plain columns needs GROUP BY"
            )
        q = Query(**{**q.__dict__, "projections": tuple(projections)})

    if p.accept_kw("order"):
        p.expect_kw("by")
        while True:
            name = p.identifier("an ORDER BY column")
            desc = False
            if p.accept_kw("desc"):
                desc = True
            elif p.accept_kw("asc"):
                desc = False
            q = q.sort(name, desc=desc)
            if p.peek() == ",":
                p.next()
                continue
            break

    if p.accept_kw("limit"):
        tok = p.next()
        if not re.fullmatch(r"\d+", tok):
            raise p.error_at_last(f"LIMIT expects an integer, got {tok!r}")
        q = q.take(int(tok))

    if p.peek() == ";":
        p.next()
        if p.peek() is not None:
            raise p.error("multiple SQL statements; parse_sql takes exactly one")
    if p.peek() is not None:
        raise p.error(f"trailing tokens after statement: {p.toks[p.i:]}")
    return Query(**{**q.__dict__, "raw_sql": cleaned})
