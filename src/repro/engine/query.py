"""Declarative query description — the logical form of one SQL node.

A `Query` is data, not execution: the code-intelligence layer stores it in
the logical plan, extracts pushdown predicates from it, and the executor
compiles it (engine/exec.py).  One Query == one artifact, per the paper's
one-query-one-artifact pattern (4.1).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.engine.expr import Expr, col

_AGG_FNS = {"sum", "count", "mean", "min", "max"}


@dataclass(frozen=True)
class Agg:
    """One aggregation: ``fn(expr) AS name`` (``count`` ignores expr)."""

    fn: str
    expr: Optional[Expr]
    name: str

    def __post_init__(self) -> None:
        if self.fn not in _AGG_FNS:
            raise ValueError(f"unsupported aggregate {self.fn!r}")

    def to_json_dict(self) -> Dict:
        return {
            "fn": self.fn,
            "expr": self.expr.to_json_dict() if self.expr else None,
            "name": self.name,
        }


@dataclass(frozen=True)
class Query:
    """SELECT projections FROM source WHERE filter
    GROUP BY group_keys ORDER BY order_by LIMIT limit."""

    source: str  # logical table name (a catalog table or a parent node)
    projections: Tuple[Tuple[str, Expr], ...] = ()  # (alias, expr); () = *
    filter_expr: Optional[Expr] = None
    group_keys: Tuple[str, ...] = ()
    aggregates: Tuple[Agg, ...] = ()
    order_by: Tuple[Tuple[str, bool], ...] = ()  # (column, descending)
    limit: Optional[int] = None
    #: the SQL text this query was parsed from, when it came from the SQL
    #: front-end — diagnostics only, excluded from equality and from
    #: ``to_json_dict`` so node fingerprints stay formatting-independent
    raw_sql: Optional[str] = field(default=None, compare=False, repr=False)

    # ------------------------------------------------------------- builders
    def select(self, *names: str, **named_exprs: Expr) -> "Query":
        proj = tuple((n, col(n)) for n in names) + tuple(named_exprs.items())
        return replace(self, projections=self.projections + proj)

    def where(self, expr: Expr) -> "Query":
        combined = expr if self.filter_expr is None else Expr("and", (self.filter_expr, expr))
        return replace(self, filter_expr=combined)

    def group_by(self, *keys: str) -> "Query":
        return replace(self, group_keys=self.group_keys + keys)

    def agg(self, fn: str, expr: Optional[Expr], name: str) -> "Query":
        return replace(self, aggregates=self.aggregates + (Agg(fn, expr, name),))

    def count(self, name: str = "counts") -> "Query":
        return self.agg("count", None, name)

    def sort(self, column: str, *, desc: bool = False) -> "Query":
        return replace(self, order_by=self.order_by + ((column, desc),))

    def take(self, n: int) -> "Query":
        return replace(self, limit=n)

    # ------------------------------------------------------------- analysis
    @property
    def is_aggregation(self) -> bool:
        return bool(self.aggregates) or bool(self.group_keys)

    def referenced_columns(self) -> List[str]:
        cols: List[str] = []
        for _, e in self.projections:
            cols.extend(e.referenced_columns())
        if self.filter_expr is not None:
            cols.extend(self.filter_expr.referenced_columns())
        cols.extend(self.group_keys)
        for a in self.aggregates:
            if a.expr is not None:
                cols.extend(a.expr.referenced_columns())
        return list(dict.fromkeys(cols))

    def output_columns(self) -> List[str]:
        if self.is_aggregation:
            return list(self.group_keys) + [a.name for a in self.aggregates]
        if self.projections:
            return [alias for alias, _ in self.projections]
        return []  # "*": depends on input schema

    def to_json_dict(self) -> Dict:
        return {
            "source": self.source,
            "projections": [(a, e.to_json_dict()) for a, e in self.projections],
            "filter": self.filter_expr.to_json_dict() if self.filter_expr else None,
            "group_keys": list(self.group_keys),
            "aggregates": [a.to_json_dict() for a in self.aggregates],
            "order_by": [list(o) for o in self.order_by],
            "limit": self.limit,
        }
