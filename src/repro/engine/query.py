"""Declarative query description — the logical form of one SQL node.

A `Query` is data, not execution: the code-intelligence layer stores it in
the logical plan, extracts pushdown predicates from it, and the executor
compiles it (engine/exec.py).  One Query == one artifact, per the paper's
one-query-one-artifact pattern (4.1).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.engine.expr import Expr, col

_AGG_FNS = {"sum", "count", "mean", "min", "max"}
_JOIN_KINDS = {"inner", "left"}


@dataclass(frozen=True)
class Join:
    """One ``JOIN table [alias] ON left_on = right_on`` clause.

    ``left_on`` refers to a column of the accumulated left side (the FROM
    table plus earlier joins); ``right_on`` to a column of ``table``.
    Either side may be qualified (``t.col``).  The engine compiles joins
    as a shape-stable first-match gather — the right side is expected to
    be key-unique (dimension-table shape); duplicate right keys resolve
    deterministically to the first matching row in storage order.
    """

    table: str
    left_on: str
    right_on: str
    how: str = "inner"
    alias: Optional[str] = None

    def __post_init__(self) -> None:
        if self.how not in _JOIN_KINDS:
            raise ValueError(f"unsupported join kind {self.how!r}")

    @property
    def qualifier(self) -> str:
        return self.alias or self.table

    def to_json_dict(self) -> Dict:
        return {
            "table": self.table,
            "left_on": self.left_on,
            "right_on": self.right_on,
            "how": self.how,
            "alias": self.alias,
        }


@dataclass(frozen=True)
class Agg:
    """One aggregation: ``fn(expr) AS name`` (``count`` ignores expr)."""

    fn: str
    expr: Optional[Expr]
    name: str

    def __post_init__(self) -> None:
        if self.fn not in _AGG_FNS:
            raise ValueError(f"unsupported aggregate {self.fn!r}")

    def to_json_dict(self) -> Dict:
        return {
            "fn": self.fn,
            "expr": self.expr.to_json_dict() if self.expr else None,
            "name": self.name,
        }


@dataclass(frozen=True)
class Query:
    """SELECT projections FROM source WHERE filter
    GROUP BY group_keys ORDER BY order_by LIMIT limit."""

    source: str  # logical table name (a catalog table or a parent node)
    projections: Tuple[Tuple[str, Expr], ...] = ()  # (alias, expr); () = *
    filter_expr: Optional[Expr] = None
    #: additional sources gathered onto the FROM table, in clause order
    joins: Tuple[Join, ...] = ()
    #: SQL alias of the FROM table (qualifies its columns in references)
    source_alias: Optional[str] = None
    group_keys: Tuple[str, ...] = ()
    aggregates: Tuple[Agg, ...] = ()
    order_by: Tuple[Tuple[str, bool], ...] = ()  # (column, descending)
    limit: Optional[int] = None
    #: the SQL text this query was parsed from, when it came from the SQL
    #: front-end — diagnostics only, excluded from equality and from
    #: ``to_json_dict`` so node fingerprints stay formatting-independent
    raw_sql: Optional[str] = field(default=None, compare=False, repr=False)

    # ------------------------------------------------------------- builders
    def select(self, *names: str, **named_exprs: Expr) -> "Query":
        proj = tuple((n, col(n)) for n in names) + tuple(named_exprs.items())
        return replace(self, projections=self.projections + proj)

    def where(self, expr: Expr) -> "Query":
        combined = expr if self.filter_expr is None else Expr("and", (self.filter_expr, expr))
        return replace(self, filter_expr=combined)

    def join(
        self,
        table: str,
        *,
        left_on: str,
        right_on: str,
        how: str = "inner",
        alias: Optional[str] = None,
    ) -> "Query":
        j = Join(table=table, left_on=left_on, right_on=right_on, how=how, alias=alias)
        return replace(self, joins=self.joins + (j,))

    def group_by(self, *keys: str) -> "Query":
        return replace(self, group_keys=self.group_keys + keys)

    def agg(self, fn: str, expr: Optional[Expr], name: str) -> "Query":
        return replace(self, aggregates=self.aggregates + (Agg(fn, expr, name),))

    def count(self, name: str = "counts") -> "Query":
        return self.agg("count", None, name)

    def sort(self, column: str, *, desc: bool = False) -> "Query":
        return replace(self, order_by=self.order_by + ((column, desc),))

    def take(self, n: int) -> "Query":
        return replace(self, limit=n)

    # ------------------------------------------------------------- analysis
    @property
    def is_aggregation(self) -> bool:
        return bool(self.aggregates) or bool(self.group_keys)

    def source_tables(self) -> List[str]:
        """Every logical table this query reads, FROM table first,
        deduplicated in clause order (a self-join appears once)."""
        return list(dict.fromkeys([self.source] + [j.table for j in self.joins]))

    def qualifiers(self) -> List[Tuple[str, str]]:
        """``(qualifier, table)`` per source in clause order; the qualifier
        is the SQL alias when one was given, else the table name."""
        out = [(self.source_alias or self.source, self.source)]
        out.extend((j.qualifier, j.table) for j in self.joins)
        return out

    def referenced_columns(self) -> List[str]:
        cols: List[str] = []
        for _, e in self.projections:
            cols.extend(e.referenced_columns())
        if self.filter_expr is not None:
            cols.extend(self.filter_expr.referenced_columns())
        cols.extend(self.group_keys)
        for a in self.aggregates:
            if a.expr is not None:
                cols.extend(a.expr.referenced_columns())
        for j in self.joins:
            cols.extend([j.left_on, j.right_on])
        return list(dict.fromkeys(cols))

    def group_key_output_names(self) -> List[str]:
        """Output column name per group key: the unqualified tail
        (``t.loc`` groups out as ``loc``), falling back to the full
        qualified name when two keys' tails collide."""
        names: List[str] = []
        seen: set = set()
        for k in self.group_keys:
            tail = k.split(".")[-1]
            out = tail if tail not in seen else k
            names.append(out)
            seen.add(out)
        return names

    def output_columns(self) -> List[str]:
        if self.is_aggregation:
            return self.group_key_output_names() + [a.name for a in self.aggregates]
        if self.projections:
            return [alias for alias, _ in self.projections]
        return []  # "*": depends on input schema

    def to_json_dict(self) -> Dict:
        d = {
            "source": self.source,
            "projections": [(a, e.to_json_dict()) for a, e in self.projections],
            "filter": self.filter_expr.to_json_dict() if self.filter_expr else None,
            "group_keys": list(self.group_keys),
            "aggregates": [a.to_json_dict() for a in self.aggregates],
            "order_by": [list(o) for o in self.order_by],
            "limit": self.limit,
        }
        # joins/alias keys appear only when used so pre-existing node
        # fingerprints (hashes of this dict) are unchanged for the whole
        # single-table query population — cache entries stay warm
        if self.joins:
            d["joins"] = [j.to_json_dict() for j in self.joins]
        if self.source_alias is not None:
            d["source_alias"] = self.source_alias
        return d
