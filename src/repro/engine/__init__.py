"""Columnar query engine in JAX — the "duckdb from spare parts" (paper 4.5).

A deliberately small analytical engine whose operators are pure JAX
functions over fixed-shape columnar batches, so that the code-intelligence
layer can FUSE a whole pipeline stage chain (scan → filter → aggregate →
python expectation) into one XLA program — the paper's 4.4.2 optimization.

Key design point for JIT stability: a relation is a `Columnar` — columns of
identical length plus a validity mask.  Filters flip validity bits instead
of shrinking arrays, so every operator is shape-preserving and fusable.
"""
from repro.engine.columnar import Columnar
from repro.engine.expr import Expr, col, lit
from repro.engine.query import Agg, Join, Query
from repro.engine.route import RouteDecision, RouteError, plan_route
from repro.engine.exec import execute_query, compile_query
from repro.engine.sql import SqlError, parse_sql

__all__ = [
    "Columnar",
    "Expr",
    "col",
    "lit",
    "Agg",
    "Join",
    "Query",
    "RouteDecision",
    "RouteError",
    "plan_route",
    "execute_query",
    "compile_query",
    "parse_sql",
    "SqlError",
]
