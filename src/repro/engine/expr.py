"""Expression DSL: the common "dialect over tuples" (paper 4.4.1).

Both front-ends — SQL text (engine/sql.py) and Python pipeline functions —
lower to these `Expr` trees, which evaluate to JAX arrays over a
`Columnar`.  The physical planner additionally inspects trees to extract
pushdown-able conjuncts (``col <op> literal``) for the scan layer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.table.scan import Predicate

_CMP_OPS = {"lt": "<", "le": "<=", "gt": ">", "ge": ">=", "eq": "==", "ne": "!="}


@dataclass(frozen=True)
class Expr:
    """An expression tree node."""

    op: str  # "col" | "lit" | cmp | "add"|"sub"|"mul"|"div" | "and"|"or"|"not"
    args: Tuple[Any, ...]

    # ------------------------------------------------------------- builders
    def _bin(self, op: str, other: Any) -> "Expr":
        return Expr(op, (self, _wrap(other)))

    def __lt__(self, o): return self._bin("lt", o)
    def __le__(self, o): return self._bin("le", o)
    def __gt__(self, o): return self._bin("gt", o)
    def __ge__(self, o): return self._bin("ge", o)
    def __eq__(self, o): return self._bin("eq", o)  # type: ignore[override]
    def __ne__(self, o): return self._bin("ne", o)  # type: ignore[override]
    def __add__(self, o): return self._bin("add", o)
    def __radd__(self, o): return _wrap(o)._bin("add", self)
    def __sub__(self, o): return self._bin("sub", o)
    def __rsub__(self, o): return _wrap(o)._bin("sub", self)
    def __mul__(self, o): return self._bin("mul", o)
    def __rmul__(self, o): return _wrap(o)._bin("mul", self)
    def __truediv__(self, o): return self._bin("div", o)
    def __and__(self, o): return self._bin("and", o)
    def __or__(self, o): return self._bin("or", o)
    def __invert__(self): return Expr("not", (self,))
    def __hash__(self):  # frozen dataclass w/ overridden __eq__ needs this
        return hash((self.op, self.args))

    # ------------------------------------------------------------ analysis
    def referenced_columns(self) -> List[str]:
        if self.op == "col":
            return [self.args[0]]
        if self.op == "lit":
            return []
        out: List[str] = []
        for a in self.args:
            out.extend(a.referenced_columns())
        return list(dict.fromkeys(out))

    def as_pushdown_conjuncts(self) -> Tuple[List[Predicate], Optional["Expr"]]:
        """Split an AND-tree into (scan-pushable predicates, residual expr).

        A conjunct is pushable when it is ``col <cmp> literal`` — the shape
        the shard min/max stats can prune on.  Everything else stays as a
        residual expression evaluated in the fused program.
        """
        conjuncts = self._flatten_and()
        pushed: List[Predicate] = []
        residual: List[Expr] = []
        for c in conjuncts:
            p = c._as_simple_predicate()
            if p is not None:
                pushed.append(p)
            else:
                residual.append(c)
        res: Optional[Expr] = None
        for r in residual:
            res = r if res is None else Expr("and", (res, r))
        return pushed, res

    def _flatten_and(self) -> List["Expr"]:
        if self.op == "and":
            out: List[Expr] = []
            for a in self.args:
                out.extend(a._flatten_and())
            return out
        return [self]

    def _as_simple_predicate(self) -> Optional[Predicate]:
        if self.op not in _CMP_OPS:
            return None
        lhs, rhs = self.args
        if lhs.op == "col" and rhs.op == "lit":
            return Predicate(lhs.args[0], _CMP_OPS[self.op], float(rhs.args[0]))
        if lhs.op == "lit" and rhs.op == "col":
            flipped = {"<": ">", "<=": ">=", ">": "<", ">=": "<=", "==": "==", "!=": "!="}
            return Predicate(rhs.args[0], flipped[_CMP_OPS[self.op]], float(lhs.args[0]))
        return None

    # ----------------------------------------------------------- evaluation
    def evaluate(self, columns: Dict[str, jax.Array]) -> jax.Array:
        op = self.op
        if op == "col":
            name = self.args[0]
            if name not in columns:
                raise KeyError(f"no column {name!r}; have {sorted(columns)}")
            return columns[name]
        if op == "lit":
            return jnp.asarray(self.args[0])
        vals = [a.evaluate(columns) for a in self.args]
        if op == "lt": return vals[0] < vals[1]
        if op == "le": return vals[0] <= vals[1]
        if op == "gt": return vals[0] > vals[1]
        if op == "ge": return vals[0] >= vals[1]
        if op == "eq": return vals[0] == vals[1]
        if op == "ne": return vals[0] != vals[1]
        if op == "add": return vals[0] + vals[1]
        if op == "sub": return vals[0] - vals[1]
        if op == "mul": return vals[0] * vals[1]
        if op == "div": return vals[0] / vals[1]
        if op == "and": return vals[0] & vals[1]
        if op == "or": return vals[0] | vals[1]
        if op == "not": return ~vals[0]
        raise ValueError(f"unknown expr op {op!r}")

    def to_json_dict(self) -> Dict:
        if self.op in ("col", "lit"):
            return {"op": self.op, "value": self.args[0]}
        return {"op": self.op, "args": [a.to_json_dict() for a in self.args]}


def _wrap(v: Any) -> Expr:
    if isinstance(v, Expr):
        return v
    if isinstance(v, (int, float, bool)):
        return Expr("lit", (v,))
    raise TypeError(f"cannot lift {type(v)} into Expr")


def col(name: str) -> Expr:
    return Expr("col", (name,))


def lit(value: Any) -> Expr:
    return Expr("lit", (value,))
