"""Kernel-routing eligibility: which engine executes a query's hot path.

The planner calls :func:`plan_route` per SQL node and stamps the
resulting :class:`RouteDecision` onto the compiled stage.  The decision
is **not** part of node fingerprints — both engines produce byte-identical
artifacts (that is what the eligibility guards prove), so the cache must
stay warm regardless of which path ran.

Routing rules (``engine="auto"``):

* the query is a single-key GROUP BY aggregation whose aggregates are all
  ``count`` / ``sum`` / ``mean`` over plain columns — the shape
  ``kernels/fused_filter_agg`` fuses;
* the group key is integer/bool with *known* min/max statistics (shard
  stats folded over the snapshot) spanning at most ``max_groups``
  distinct values — the kernel's dense one-hot group axis must fit VMEM;
* exactness is provable: the kernel accumulates in f32 (einsum on the
  MXU), so every aggregated column must be integer/bool with
  ``max(|min|, |max|) * rows < 2**24`` and the row count itself below
  ``2**24`` — then f32 sums/counts are exact integers and casting back
  reproduces the jnp path's int32 scatter-adds bit-for-bit.  Float
  columns always take the jnp path under ``auto``: float addition is
  non-associative and the two paths order it differently.

``engine="kernel"`` forces the kernel for structurally-eligible queries
(skipping the exactness guards — float results may then differ in the
last ulp) and raises when the query shape or missing key statistics make
the kernel impossible.  ``engine="jnp"`` always takes the reference path.

Every eligibility check evaluated is recorded as a :class:`RouteCheck`
on the decision's :class:`RouteTrace` — which passed, which bailed, and
a concrete fix hint for the failure — so ``repro explain`` can show the
kernel-vs-jnp verdict with evidence instead of one opaque reason string.
The trace is excluded from equality/hash: two decisions that route the
same way stay equal (and keep the compiled-query cache warm) regardless
of the evidence trail.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.engine.expr import Expr
from repro.engine.query import Query
from repro.engine.sql import find_token

#: aggregate fns expressible as the kernel's (sums, counts) outputs
FUSED_AGGS = frozenset({"count", "sum", "mean"})

#: largest integer magnitude f32 represents exactly (2**24); sums and
#: counts must stay below this for kernel/jnp byte-identity
EXACT_BOUND = 2 ** 24

#: default cap on the kernel's dense group axis (one-hot VMEM bound)
DEFAULT_MAX_GROUPS = 1024

_PRED_TO_KERNEL_OP = {"<": "lt", "<=": "le", ">": "gt", ">=": "ge", "==": "eq", "!=": "ne"}

#: the R-rule registry — one entry per eligibility check the router can
#: evaluate, id -> (slug, what the check verifies, generic fix hint).
#: ``repro explain`` and the README rule catalog are generated from this
#: table, so the ids in a RouteTrace always resolve to documentation.
ROUTE_CHECKS: Dict[str, Tuple[str, str, str]] = {
    "R200": (
        "engine-pinned",
        "engine was pinned explicitly, no eligibility to evaluate",
        "drop engine='jnp' to let auto routing consider the fused kernel",
    ),
    "R201": (
        "aggregation-shape",
        "query is a GROUP BY aggregation (the shape the fused kernel runs)",
        "only filter+GROUP BY aggregations fuse; plain scans/joins always "
        "run on the jnp path",
    ),
    "R202": (
        "single-group-key",
        "exactly one GROUP BY key (the kernel's dense group axis is 1-D)",
        "group by exactly one key, or split into per-key queries",
    ),
    "R203": (
        "fusable-aggregates",
        "every aggregate is COUNT/SUM/AVG (expressible as the kernel's "
        "sums+counts outputs)",
        "compute MIN/MAX with engine='jnp' (kernel extension pending)",
    ),
    "R204": (
        "plain-column-aggregates",
        "aggregates read plain columns, not computed expressions",
        "materialize the expression as a column in an upstream node, then "
        "aggregate the plain column",
    ),
    "R205": (
        "key-statistics",
        "integer min/max shard statistics exist for the group key",
        "cast the group key to int32 (float keys never route to the "
        "kernel; node-sourced inputs carry no shard statistics)",
    ),
    "R206": (
        "group-range",
        "the key's value range (left-join zero-fill included) fits the "
        "kernel's dense group axis",
        "bucket the key into a denser id space, or raise max_groups "
        "(VMEM permitting)",
    ),
    "R207": (
        "row-count-exactness",
        "row count is known and below 2**24 so f32 counts are exact "
        "(auto only)",
        "force engine='kernel' to skip the proof and accept last-ulp "
        "drift, or keep the jnp path",
    ),
    "R208": (
        "value-exactness",
        "aggregated-column bounds * rows stay below 2**24 so f32 sums "
        "are exact (auto only)",
        "cast the aggregated column to a narrower integer range, or "
        "force engine='kernel' to accept last-ulp drift",
    ),
    "R209": (
        "native-filter",
        "whether the WHERE clause evaluates in-register inside the "
        "kernel or precomputes to a mask input (never bails)",
        "a single col-cmp-literal over an f32-exact column filters "
        "in-register; anything else takes the mask path",
    ),
}


class RouteError(ValueError):
    """``engine="kernel"`` was forced but the kernel cannot run the query.

    Like :class:`repro.engine.sql.SqlError`, the error is positioned:
    when the query carries its raw SQL, ``pos``/``fragment`` quote the
    offending clause (the group key, aggregate, or column that made the
    kernel ineligible), ``hint`` carries the concrete fix, and ``trace``
    the full :class:`RouteTrace` of eligibility checks evaluated.
    """

    def __init__(
        self,
        message: str,
        *,
        sql: Optional[str] = None,
        token: Optional[str] = None,
        hint: Optional[str] = None,
        trace: Optional["RouteTrace"] = None,
    ):
        self.sql = sql
        self.hint = hint
        self.trace = trace
        self.pos: Optional[int] = None
        self.fragment: str = ""
        if sql and token:
            pos = find_token(sql, token)
            if pos is not None:
                self.pos = pos
                lo, hi = max(0, pos - 8), min(len(sql), pos + 16)
                self.fragment = sql[lo:hi].replace("\n", " ")
        if self.pos is not None:
            message = f"{message} at position {self.pos}: ...{self.fragment}..."
        if hint:
            message = f"{message} (fix: {hint})"
        super().__init__(message)


@dataclass(frozen=True)
class RouteCheck:
    """One eligibility check the router evaluated, with its evidence."""

    check: str   # registry id, e.g. "R203"
    name: str    # registry slug, e.g. "fusable-aggregates"
    passed: bool
    detail: str  # the concrete evidence for THIS query
    #: concrete fix for a failed check ("cast zone to int32"); None on pass
    hint: Optional[str] = None
    #: SQL token the evidence points at, for positioned diagnostics
    token: Optional[str] = None

    def describe(self) -> str:
        mark = "pass" if self.passed else "FAIL"
        out = f"[{mark}] {self.check} {self.name}: {self.detail}"
        if self.hint and not self.passed:
            out += f"\n       fix: {self.hint}"
        return out

    def to_json_dict(self) -> Dict[str, Any]:
        return {
            "check": self.check,
            "name": self.name,
            "passed": self.passed,
            "detail": self.detail,
            "hint": self.hint,
        }


@dataclass(frozen=True)
class RouteTrace:
    """Every eligibility check evaluated for one routing decision, in
    evaluation order.  The router short-circuits, so the last entry of a
    jnp-routed trace is the check that bailed (``failed``)."""

    checks: Tuple[RouteCheck, ...] = ()

    @property
    def failed(self) -> Optional[RouteCheck]:
        for c in self.checks:
            if not c.passed:
                return c
        return None

    def describe(self) -> str:
        return "\n".join(c.describe() for c in self.checks)

    def to_json_dict(self) -> Dict[str, Any]:
        return {"checks": [c.to_json_dict() for c in self.checks]}


@dataclass(frozen=True)
class RouteDecision:
    """Which engine runs a query's filter+group+agg pipeline, and why.

    Frozen/hashable so it can key the compiled-query cache alongside the
    Query itself.  ``num_groups``/``key_offset`` size the kernel's dense
    group axis (slot = key - offset); ``native_filter`` means the WHERE
    clause is a single ``col <cmp> literal`` the kernel evaluates
    in-register instead of taking a precomputed mask.  ``trace`` carries
    the evidence (every check evaluated) but is excluded from
    equality/hash — routing identity is the semantic fields only."""

    engine_path: str  # "kernel" | "jnp"
    reason: str
    num_groups: int = 0
    key_offset: int = 0
    native_filter: bool = False
    interpret: bool = True
    trace: Optional[RouteTrace] = field(default=None, compare=False, repr=False)

    def to_json_dict(self) -> Dict[str, Any]:
        return {
            "engine_path": self.engine_path,
            "reason": self.reason,
            "num_groups": self.num_groups,
            "key_offset": self.key_offset,
            "native_filter": self.native_filter,
            "trace": self.trace.to_json_dict() if self.trace else None,
        }


def _jnp(reason: str, trace: Optional[RouteTrace] = None) -> RouteDecision:
    return RouteDecision("jnp", reason, trace=trace)


def native_filter_of(expr: Optional[Expr]) -> Optional[Tuple[str, str, float]]:
    """``(column, kernel_op, threshold)`` when the whole filter is one
    ``col <cmp> literal`` conjunct, else None."""
    if expr is None:
        return None
    p = expr._as_simple_predicate()
    if p is None:
        return None
    return p.column, _PRED_TO_KERNEL_OP[p.op], float(p.value)


def column_stats_for_query(
    query: Query, snapshots: Dict[str, object]
) -> Tuple[Dict[str, Tuple[int, int]], Optional[int]]:
    """Fold shard statistics into per-reference (min, max) int bounds.

    ``snapshots`` maps table name -> Snapshot for every lake table the
    query reads (node-sourced inputs simply have no entry — their columns
    get no stats and ``auto`` routing falls back to jnp).  Bounds are
    recorded under both the qualified reference (``qual.col``) and, when
    exactly one source owns the plain name, the plain name — mirroring
    how the executor builds the combined relation.  Only integer/bool
    columns with finite stats are recorded, so a missing entry doubles as
    "not a kernel-safe dtype".  Returns ``(stats, primary_row_count)``;
    the row count is None when the FROM table has no snapshot.
    """
    quals = query.qualifiers()
    owners: Counter = Counter()
    for _, table in quals:
        snap = snapshots.get(table)
        if snap is not None:
            owners.update(snap.schema.names)

    stats: Dict[str, Tuple[int, int]] = {}
    for qual, table in quals:
        snap = snapshots.get(table)
        if snap is None:
            continue
        for col in snap.schema.columns:
            if np.dtype(col.dtype).kind not in ("i", "u", "b"):
                continue
            los = [s.column_stats[col.name]["min"] for s in snap.shards
                   if col.name in s.column_stats]
            his = [s.column_stats[col.name]["max"] for s in snap.shards
                   if col.name in s.column_stats]
            if not los or any(not np.isfinite(v) for v in los + his):
                continue
            bound = (int(min(los)), int(max(his)))
            stats[f"{qual}.{col.name}"] = bound
            if owners[col.name] == 1:
                stats[col.name] = bound
    primary = snapshots.get(query.source)
    return stats, (primary.num_rows if primary is not None else None)


def plan_route(
    query: Query,
    *,
    engine: str = "auto",
    stats: Optional[Dict[str, Tuple[int, int]]] = None,
    total_rows: Optional[int] = None,
    max_groups: int = DEFAULT_MAX_GROUPS,
    interpret: bool = True,
) -> RouteDecision:
    """Decide the engine for one query (see module docstring for rules)."""
    if engine not in ("auto", "kernel", "jnp"):
        raise ValueError(f"unknown engine {engine!r}; use auto|kernel|jnp")

    checks: List[RouteCheck] = []

    def record(
        cid: str,
        passed: bool,
        detail: str,
        hint: Optional[str] = None,
        token: Optional[str] = None,
    ) -> bool:
        name = ROUTE_CHECKS[cid][0]
        if not passed and hint is None:
            hint = ROUTE_CHECKS[cid][2]
        checks.append(RouteCheck(cid, name, passed, detail, hint, token))
        return passed

    if engine == "jnp":
        record("R200", True, "engine='jnp' requested — reference path pinned")
        return _jnp("engine=jnp requested", RouteTrace(tuple(checks)))
    forced = engine == "kernel"
    stats = stats or {}

    def bail(reason: str) -> RouteDecision:
        last = checks[-1]
        trace = RouteTrace(tuple(checks))
        if forced:
            raise RouteError(
                f"engine='kernel' forced but {reason}",
                sql=query.raw_sql,
                token=last.token,
                hint=last.hint,
                trace=trace,
            )
        return _jnp(reason, trace)

    # ---------------------------------------------------------- structure
    if not record(
        "R201", query.is_aggregation,
        "query is a GROUP BY aggregation" if query.is_aggregation
        else "query has no aggregation — nothing for the kernel to fuse",
    ):
        return bail("not an aggregation")
    nkeys = len(query.group_keys)
    if not record(
        "R202", nkeys == 1,
        f"{nkeys} group key(s): {list(query.group_keys)}",
        token=query.group_keys[-1] if query.group_keys else None,
    ):
        return bail(f"kernel supports exactly one group key, got {nkeys}")
    for a in query.aggregates:
        if not record(
            "R203", a.fn in FUSED_AGGS,
            f"aggregate {a.name!r} uses fn {a.fn!r}",
            hint=None if a.fn in FUSED_AGGS else (
                f"only COUNT/SUM/AVG fuse; compute {a.fn!r} with "
                "engine='jnp' (kernel extension pending)"
            ),
            token=a.name,
        ):
            return bail(f"aggregate {a.fn!r} is not kernel-fusable")
        plain = a.fn == "count" or (a.expr is not None and a.expr.op == "col")
        if not record(
            "R204", plain,
            f"aggregate {a.name!r} reads "
            + ("a plain column" if plain else "a computed expression"),
            token=a.name,
        ):
            return bail(f"aggregate {a.name!r} is over a computed expression")

    # ------------------------------------------------------- key geometry
    key = query.group_keys[0]
    if not record(
        "R205", key in stats,
        f"group key {key!r}: "
        + (f"stats {stats[key]}" if key in stats
           else "no integer shard statistics"),
        hint=None if key in stats else (
            f"cast {key!r} to int32 so shard statistics cover it (float "
            "keys and node-sourced inputs never carry integer stats)"
        ),
        token=key,
    ):
        return bail(f"no integer statistics for group key {key!r}")
    kmin, kmax = stats[key]
    # a left join zero-fills unmatched right-side rows, so a group key
    # that may come from a left-joined table must admit slot value 0
    # (an unqualified key's owner is unknown here — extend conservatively)
    widened = False
    left_quals = {j.qualifier for j in query.joins if j.how == "left"}
    if left_quals:
        owner = key.split(".")[0] if "." in key else None
        if owner is None or owner in left_quals:
            widened = (kmin, kmax) != (min(kmin, 0), max(kmax, 0))
            kmin, kmax = min(kmin, 0), max(kmax, 0)
    num_groups = kmax - kmin + 1
    if not record(
        "R206", num_groups <= max_groups,
        f"key range [{kmin}, {kmax}] -> {num_groups} groups "
        f"(max_groups={max_groups})"
        + (" — widened to include 0 for LEFT JOIN zero-fill" if widened else ""),
        hint=None if num_groups <= max_groups else (
            f"bucket {key!r} into a denser id space, or raise max_groups "
            "(VMEM permitting)"
        ),
        token=key,
    ):
        return bail(
            f"group key range {num_groups} exceeds max_groups={max_groups}"
        )

    # ------------------------------------------------- exactness (auto)
    if not forced:
        known = total_rows is not None
        if not record(
            "R207", known and total_rows < EXACT_BOUND,
            "row count unknown (no snapshot for the FROM table)" if not known
            else f"{total_rows} rows vs exact-f32 bound {EXACT_BOUND}",
        ):
            return bail(
                "row count unknown; f32 count exactness not provable"
                if not known
                else f"{total_rows} rows overflow exact f32 counts"
            )
        for a in query.aggregates:
            if a.fn == "count":
                continue
            vcol = a.expr.args[0]
            if not record(
                "R208", vcol in stats,
                f"aggregated column {vcol!r}: "
                + (f"stats {stats[vcol]}" if vcol in stats
                   else "no integer shard statistics (float or node-sourced)"),
                hint=None if vcol in stats else (
                    f"cast {vcol!r} to int32, or force engine='kernel' to "
                    "skip the exactness proof and accept last-ulp drift"
                ),
                token=vcol,
            ):
                return bail(f"no integer statistics for aggregated column {vcol!r}")
            vmin, vmax = stats[vcol]
            bound = max(abs(vmin), abs(vmax)) * max(total_rows, 1)
            if not record(
                "R208", bound < EXACT_BOUND,
                f"sum bound for {vcol!r}: max(|{vmin}|, |{vmax}|) * "
                f"{total_rows} rows = {bound} vs {EXACT_BOUND}",
                token=vcol,
            ):
                return bail(
                    f"sum bound for {vcol!r} overflows exact f32 accumulation"
                )

    # -------------------------------------------------------- the filter
    native = False
    nf = native_filter_of(query.filter_expr)
    if query.filter_expr is None:
        record("R209", True, "no WHERE clause — nothing to filter")
    elif nf is None:
        record(
            "R209", True,
            "WHERE is not a single col-cmp-literal — precomputed mask input",
        )
    else:
        fcol, _, _ = nf
        b = stats.get(fcol)
        # the kernel compares the filter column in f32; only use the
        # native path when the column provably fits f32 exactly
        native = b is not None and max(abs(b[0]), abs(b[1])) < EXACT_BOUND
        record(
            "R209", True,
            f"WHERE is a single comparison on {fcol!r} — "
            + ("evaluated in-register (f32-exact bounds "
               f"{b})" if native else
               "mask input (column bounds not provably f32-exact)"),
        )

    return RouteDecision(
        engine_path="kernel",
        reason="forced by engine='kernel'" if forced else (
            f"single-key agg, {num_groups} groups, exact f32 bounds hold"
        ),
        num_groups=num_groups,
        key_offset=kmin,
        native_filter=native,
        interpret=interpret,
        trace=RouteTrace(tuple(checks)),
    )
