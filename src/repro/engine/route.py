"""Kernel-routing eligibility: which engine executes a query's hot path.

The planner calls :func:`plan_route` per SQL node and stamps the
resulting :class:`RouteDecision` onto the compiled stage.  The decision
is **not** part of node fingerprints — both engines produce byte-identical
artifacts (that is what the eligibility guards prove), so the cache must
stay warm regardless of which path ran.

Routing rules (``engine="auto"``):

* the query is a single-key GROUP BY aggregation whose aggregates are all
  ``count`` / ``sum`` / ``mean`` over plain columns — the shape
  ``kernels/fused_filter_agg`` fuses;
* the group key is integer/bool with *known* min/max statistics (shard
  stats folded over the snapshot) spanning at most ``max_groups``
  distinct values — the kernel's dense one-hot group axis must fit VMEM;
* exactness is provable: the kernel accumulates in f32 (einsum on the
  MXU), so every aggregated column must be integer/bool with
  ``max(|min|, |max|) * rows < 2**24`` and the row count itself below
  ``2**24`` — then f32 sums/counts are exact integers and casting back
  reproduces the jnp path's int32 scatter-adds bit-for-bit.  Float
  columns always take the jnp path under ``auto``: float addition is
  non-associative and the two paths order it differently.

``engine="kernel"`` forces the kernel for structurally-eligible queries
(skipping the exactness guards — float results may then differ in the
last ulp) and raises when the query shape or missing key statistics make
the kernel impossible.  ``engine="jnp"`` always takes the reference path.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.engine.expr import Expr
from repro.engine.query import Query

#: aggregate fns expressible as the kernel's (sums, counts) outputs
FUSED_AGGS = frozenset({"count", "sum", "mean"})

#: largest integer magnitude f32 represents exactly (2**24); sums and
#: counts must stay below this for kernel/jnp byte-identity
EXACT_BOUND = 2 ** 24

#: default cap on the kernel's dense group axis (one-hot VMEM bound)
DEFAULT_MAX_GROUPS = 1024

_PRED_TO_KERNEL_OP = {"<": "lt", "<=": "le", ">": "gt", ">=": "ge", "==": "eq", "!=": "ne"}


class RouteError(ValueError):
    """``engine="kernel"`` was forced but the kernel cannot run the query."""


@dataclass(frozen=True)
class RouteDecision:
    """Which engine runs a query's filter+group+agg pipeline, and why.

    Frozen/hashable so it can key the compiled-query cache alongside the
    Query itself.  ``num_groups``/``key_offset`` size the kernel's dense
    group axis (slot = key - offset); ``native_filter`` means the WHERE
    clause is a single ``col <cmp> literal`` the kernel evaluates
    in-register instead of taking a precomputed mask."""

    engine_path: str  # "kernel" | "jnp"
    reason: str
    num_groups: int = 0
    key_offset: int = 0
    native_filter: bool = False
    interpret: bool = True


def _jnp(reason: str) -> RouteDecision:
    return RouteDecision("jnp", reason)


def native_filter_of(expr: Optional[Expr]) -> Optional[Tuple[str, str, float]]:
    """``(column, kernel_op, threshold)`` when the whole filter is one
    ``col <cmp> literal`` conjunct, else None."""
    if expr is None:
        return None
    p = expr._as_simple_predicate()
    if p is None:
        return None
    return p.column, _PRED_TO_KERNEL_OP[p.op], float(p.value)


def column_stats_for_query(
    query: Query, snapshots: Dict[str, object]
) -> Tuple[Dict[str, Tuple[int, int]], Optional[int]]:
    """Fold shard statistics into per-reference (min, max) int bounds.

    ``snapshots`` maps table name -> Snapshot for every lake table the
    query reads (node-sourced inputs simply have no entry — their columns
    get no stats and ``auto`` routing falls back to jnp).  Bounds are
    recorded under both the qualified reference (``qual.col``) and, when
    exactly one source owns the plain name, the plain name — mirroring
    how the executor builds the combined relation.  Only integer/bool
    columns with finite stats are recorded, so a missing entry doubles as
    "not a kernel-safe dtype".  Returns ``(stats, primary_row_count)``;
    the row count is None when the FROM table has no snapshot.
    """
    quals = query.qualifiers()
    owners: Counter = Counter()
    for _, table in quals:
        snap = snapshots.get(table)
        if snap is not None:
            owners.update(snap.schema.names)

    stats: Dict[str, Tuple[int, int]] = {}
    for qual, table in quals:
        snap = snapshots.get(table)
        if snap is None:
            continue
        for col in snap.schema.columns:
            if np.dtype(col.dtype).kind not in ("i", "u", "b"):
                continue
            los = [s.column_stats[col.name]["min"] for s in snap.shards
                   if col.name in s.column_stats]
            his = [s.column_stats[col.name]["max"] for s in snap.shards
                   if col.name in s.column_stats]
            if not los or any(not np.isfinite(v) for v in los + his):
                continue
            bound = (int(min(los)), int(max(his)))
            stats[f"{qual}.{col.name}"] = bound
            if owners[col.name] == 1:
                stats[col.name] = bound
    primary = snapshots.get(query.source)
    return stats, (primary.num_rows if primary is not None else None)


def plan_route(
    query: Query,
    *,
    engine: str = "auto",
    stats: Optional[Dict[str, Tuple[int, int]]] = None,
    total_rows: Optional[int] = None,
    max_groups: int = DEFAULT_MAX_GROUPS,
    interpret: bool = True,
) -> RouteDecision:
    """Decide the engine for one query (see module docstring for rules)."""
    if engine not in ("auto", "kernel", "jnp"):
        raise ValueError(f"unknown engine {engine!r}; use auto|kernel|jnp")
    if engine == "jnp":
        return _jnp("engine=jnp requested")
    forced = engine == "kernel"
    stats = stats or {}

    def bail(reason: str) -> RouteDecision:
        if forced:
            raise RouteError(f"engine='kernel' forced but {reason}")
        return _jnp(reason)

    # ---------------------------------------------------------- structure
    if not query.is_aggregation:
        return bail("not an aggregation")
    if len(query.group_keys) != 1:
        return bail(f"kernel supports exactly one group key, got {len(query.group_keys)}")
    for a in query.aggregates:
        if a.fn not in FUSED_AGGS:
            return bail(f"aggregate {a.fn!r} is not kernel-fusable")
        if a.fn != "count" and (a.expr is None or a.expr.op != "col"):
            return bail(f"aggregate {a.name!r} is over a computed expression")

    # ------------------------------------------------------- key geometry
    key = query.group_keys[0]
    if key not in stats:
        return bail(f"no integer statistics for group key {key!r}")
    kmin, kmax = stats[key]
    # a left join zero-fills unmatched right-side rows, so a group key
    # that may come from a left-joined table must admit slot value 0
    # (an unqualified key's owner is unknown here — extend conservatively)
    left_quals = {j.qualifier for j in query.joins if j.how == "left"}
    if left_quals:
        owner = key.split(".")[0] if "." in key else None
        if owner is None or owner in left_quals:
            kmin, kmax = min(kmin, 0), max(kmax, 0)
    num_groups = kmax - kmin + 1
    if num_groups > max_groups:
        return bail(
            f"group key range {num_groups} exceeds max_groups={max_groups}"
        )

    # ------------------------------------------------- exactness (auto)
    if not forced:
        if total_rows is None:
            return bail("row count unknown; f32 count exactness not provable")
        if total_rows >= EXACT_BOUND:
            return bail(f"{total_rows} rows overflow exact f32 counts")
        for a in query.aggregates:
            if a.fn == "count":
                continue
            vcol = a.expr.args[0]
            if vcol not in stats:
                return bail(f"no integer statistics for aggregated column {vcol!r}")
            vmin, vmax = stats[vcol]
            if max(abs(vmin), abs(vmax)) * max(total_rows, 1) >= EXACT_BOUND:
                return bail(
                    f"sum bound for {vcol!r} overflows exact f32 accumulation"
                )

    # -------------------------------------------------------- the filter
    native = False
    nf = native_filter_of(query.filter_expr)
    if nf is not None:
        fcol, _, _ = nf
        b = stats.get(fcol)
        # the kernel compares the filter column in f32; only use the
        # native path when the column provably fits f32 exactly
        native = b is not None and max(abs(b[0]), abs(b[1])) < EXACT_BOUND

    return RouteDecision(
        engine_path="kernel",
        reason="forced by engine='kernel'" if forced else (
            f"single-key agg, {num_groups} groups, exact f32 bounds hold"
        ),
        num_groups=num_groups,
        key_offset=kmin,
        native_filter=native,
        interpret=interpret,
    )
