"""Vectorized, jit-able execution of Query objects over Columnar batches.

Every operator is shape-stable (masked-row semantics), so a full query —
and, via core/physical.py, a *chain* of queries plus Python expectations —
compiles to a single XLA program.  Group-by uses a sort + segment-scatter
formulation (radix-style grouping adapted to TPU-friendly dense ops: sort,
cumsum, scatter-add are all well-supported lax primitives).  Joins compile
to a shape-stable first-match gather: the right side is sorted once
(valid rows first), probe keys binary-search into it, and misses either
invalidate the row (inner) or zero-fill the gathered columns (left) — no
data-dependent shapes anywhere, so joined queries still jit to one
program.

The Pallas kernel in kernels/fused_filter_agg IS wired in: when the
planner's eligibility pass (engine/route.py) stamps a ``RouteDecision``
with ``engine_path == "kernel"``, the scan→filter→agg pipeline of an
aggregation query executes as one fused kernel pass (filter evaluated
in-kernel for native column-vs-literal predicates, as a mask feed
otherwise) and the grouped output is re-assembled to match the jnp
path's layout byte-for-byte.  Queries without a route — or routed
``"jnp"`` because dtypes/statistics cannot prove kernel exactness — run
the pure-jnp operators below, which remain the reference semantics.
"""
from __future__ import annotations

import functools
from collections import Counter
from dataclasses import replace
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.engine.columnar import Columnar
from repro.engine.query import Agg, Query
from repro.engine.route import RouteDecision, native_filter_of

def apply_filter(rel: Columnar, query: Query) -> Columnar:
    if query.filter_expr is None:
        return rel
    keep = query.filter_expr.evaluate(rel.columns)
    return rel.mask_where(keep.astype(bool))


def apply_projection(rel: Columnar, query: Query) -> Columnar:
    if not query.projections:
        return rel
    out = {alias: expr.evaluate(rel.columns) for alias, expr in query.projections}
    return Columnar(out, rel.valid)


# --------------------------------------------------------------------- joins
def _combined_relation(
    query: Query, rel: Columnar, joined: Optional[Dict[str, Columnar]]
) -> Tuple[Columnar, Optional[List[str]]]:
    """Gather all join sources onto the FROM relation.

    The combined relation carries every column twice-addressable: under its
    qualified name (``qualifier.col``) always, and under its plain name
    when exactly one source owns that name — so expressions written either
    way evaluate against the same dict with no rewriting.  Returns the
    combined relation plus the *display* column list (plain-if-unique,
    qualified otherwise, in source order) used to resolve ``SELECT *``.

    Single-table queries with no alias and no dotted references pass
    through untouched (display ``None``) — the common path pays nothing.
    """
    dotted = any("." in c for c in query.referenced_columns())
    if not query.joins and query.source_alias is None and not dotted:
        return rel, None

    sources: List[Tuple[str, Columnar]] = [(query.source_alias or query.source, rel)]
    for j in query.joins:
        if not joined or j.table not in joined:
            raise KeyError(
                f"join table {j.table!r} was not provided to execute_query; "
                f"have {sorted(joined or {})}"
            )
        sources.append((j.qualifier, joined[j.table]))

    owners: Counter = Counter()
    for _, srel in sources:
        owners.update(srel.columns.keys())

    q0, rel0 = sources[0]
    combined: Dict[str, jax.Array] = {}
    display: List[str] = []
    for n, a in rel0.columns.items():
        combined[f"{q0}.{n}"] = a
        if owners[n] == 1:
            combined[n] = a
        display.append(n if owners[n] == 1 else f"{q0}.{n}")
    valid = rel0.valid

    for j, (jq, jrel) in zip(query.joins, sources[1:]):
        gathered, found = _first_match_gather(
            j, jq, combined, valid, jrel, sql=query.raw_sql
        )
        for n, g in gathered.items():
            combined[f"{jq}.{n}"] = g
            if owners[n] == 1:
                combined[n] = g
            display.append(n if owners[n] == 1 else f"{jq}.{n}")
        if j.how == "inner":
            valid = found
        # left join: validity unchanged, misses were zero-filled

    return Columnar(combined, valid), display


def _first_match_gather(join, jq, combined, valid, jrel, *, sql=None):
    """Probe the accumulated left side into one joined relation.

    Right side is sorted by key with invalid rows pushed to the tail
    (double stable argsort), probe keys ``searchsorted`` into it, and
    duplicate right keys resolve deterministically to the first matching
    row in storage order.  Returns (gathered right columns, found mask);
    misses are zero-filled so even non-compact outputs are deterministic.
    """
    def _orient(lref, rref):
        rtail = rref.split(".")[-1]
        rq = rref.split(".")[0] if "." in rref else None
        if rq is not None and rq != jq:
            return None
        if lref in combined and rtail in jrel.columns:
            return combined[lref], jrel.columns[rtail]
        return None

    pair = _orient(join.left_on, join.right_on) or _orient(join.right_on, join.left_on)
    if pair is None:
        raise KeyError(
            f"cannot resolve JOIN {join.table} ON {join.left_on} = "
            f"{join.right_on}: left side has {sorted(combined)}, "
            f"{join.qualifier!r} has {sorted(jrel.columns)}"
        )
    left_keys, right_keys = pair
    for side, arr in (("left", left_keys), ("right", right_keys)):
        if arr.dtype.kind not in ("i", "u", "b"):
            raise TypeError(
                f"join key on the {side} side of {join.left_on} = "
                f"{join.right_on} must be integer/bool, got {arr.dtype} "
                "(fix: cast the join key to int32 upstream — T401 flags "
                "this statically)"
            )

    cap_r = jrel.capacity
    if cap_r == 0:  # statically-empty right side: nothing ever matches
        found = jnp.zeros(valid.shape, bool)
        gathered = {
            n: jnp.zeros(valid.shape, a.dtype) for n, a in jrel.columns.items()
        }
        return gathered, found

    rk32 = right_keys.astype(jnp.int32)
    perm = jnp.argsort(rk32, stable=True)
    perm = perm[jnp.argsort((~jrel.valid[perm]).astype(jnp.int32), stable=True)]
    sorted_valid = jrel.valid[perm]
    # invalid tail carries the max sentinel; a *valid* key equal to the
    # sentinel still wins because searchsorted("left") lands on it first
    sorted_keys = jnp.where(sorted_valid, rk32[perm], jnp.iinfo(jnp.int32).max)

    lk32 = left_keys.astype(jnp.int32)
    idx = jnp.minimum(jnp.searchsorted(sorted_keys, lk32, side="left"), cap_r - 1)
    found = (sorted_keys[idx] == lk32) & sorted_valid[idx] & valid
    src = perm[idx]
    gathered = {
        n: jnp.where(found, a[src], jnp.zeros((), a.dtype))
        for n, a in jrel.columns.items()
    }
    return gathered, found


def _normalize_group_keys(rel: Columnar, query: Query) -> Tuple[Columnar, Query]:
    """Materialize qualified group keys under their output names.

    ``GROUP BY t.loc`` groups out as column ``loc`` (see
    Query.group_key_output_names); the plain single-table path is
    untouched."""
    out_names = query.group_key_output_names()
    if list(query.group_keys) == out_names:
        return rel, query
    new_cols = {
        out: rel.column(k)
        for k, out in zip(query.group_keys, out_names)
        if out != k
    }
    return rel.with_columns(new_cols), replace(query, group_keys=tuple(out_names))


def _lex_sort_perm(rel: Columnar, keys) -> jax.Array:
    """Permutation grouping equal key tuples, valid rows first.

    Lexicographic order via repeated *stable* argsort from least- to
    most-significant key; validity is the most significant key.  Avoids
    packing keys into one word (no x64 requirement, no range limits).
    """
    perm = jnp.arange(rel.capacity)
    for k in reversed(keys):
        kcol = rel.column(k)
        if kcol.dtype.kind not in ("i", "u", "b"):
            raise TypeError(f"group key {k!r} must be integer/bool, got {kcol.dtype}")
        order = jnp.argsort(kcol[perm].astype(jnp.int32), stable=True)
        perm = perm[order]
    order = jnp.argsort((~rel.valid[perm]).astype(jnp.int32), stable=True)
    return perm[order]


def apply_groupby(rel: Columnar, query: Query, *, capacity: Optional[int] = None) -> Columnar:
    """Sort-based grouping with static output capacity.

    Output relation has ``capacity`` rows (default: input capacity); rows
    beyond the number of distinct groups are invalid.  All ops are
    shape-stable → fully jit/fusion compatible.
    """
    cap = capacity or rel.capacity
    order = _lex_sort_perm(rel, query.group_keys)
    sorted_valid = rel.valid[order]
    if query.group_keys:
        diff = jnp.zeros((rel.capacity,), bool)
        for k in query.group_keys:
            kcol = rel.column(k)[order]
            diff = diff | jnp.concatenate(
                [jnp.ones((1,), bool), kcol[1:] != kcol[:-1]]
            )
        is_new = diff & sorted_valid
    else:
        # global aggregation: one group, opened by the first (valid) row
        is_new = sorted_valid & (jnp.arange(rel.capacity) == 0)
    seg_id = jnp.cumsum(is_new.astype(jnp.int32)) - 1  # -1 for invalid prefix
    seg_id = jnp.where(sorted_valid, seg_id, cap)  # route invalid to overflow slot
    seg_id = jnp.minimum(seg_id, cap)  # overflow slot is dropped

    out_cols: Dict[str, jax.Array] = {}
    # representative group-key columns
    for k in query.group_keys:
        src = rel.column(k)[order]
        out = jnp.zeros((cap + 1,), dtype=src.dtype).at[seg_id].set(src)
        out_cols[k] = out[:cap]

    counts = jnp.zeros((cap + 1,), jnp.int32).at[seg_id].add(
        sorted_valid.astype(jnp.int32)
    )[:cap]

    for agg in query.aggregates:
        out_cols[agg.name] = _apply_one_agg(rel, agg, order, seg_id, sorted_valid, counts, cap)

    group_valid = counts > 0
    return Columnar(out_cols, group_valid)


def _apply_one_agg(rel, agg: Agg, order, seg_id, sorted_valid, counts, cap):
    if agg.fn == "count":
        return counts
    vals = agg.expr.evaluate(rel.columns)[order]
    if agg.fn in ("sum", "mean"):
        # f32 accum for floats, i32 for ints (x64 is disabled jax-wide)
        acc_dtype = vals.dtype if vals.dtype.kind == "f" else jnp.int32
        zeroed = jnp.where(sorted_valid, vals.astype(acc_dtype), 0)
        total = jnp.zeros((cap + 1,), acc_dtype).at[seg_id].add(zeroed)[:cap]
        if agg.fn == "sum":
            return total
        return total.astype(jnp.float32) / jnp.maximum(counts, 1).astype(jnp.float32)
    if agg.fn == "min":
        big = _extreme(vals.dtype, +1)
        masked = jnp.where(sorted_valid, vals, big)
        return jnp.full((cap + 1,), big, vals.dtype).at[seg_id].min(masked)[:cap]
    if agg.fn == "max":
        small = _extreme(vals.dtype, -1)
        masked = jnp.where(sorted_valid, vals, small)
        return jnp.full((cap + 1,), small, vals.dtype).at[seg_id].max(masked)[:cap]
    raise ValueError(f"unsupported aggregate {agg.fn!r}")


def _extreme(dtype, sign: int):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(sign * jnp.inf, dtype)
    info = jnp.iinfo(dtype)
    return jnp.array(info.max if sign > 0 else info.min, dtype)


# --------------------------------------------------------------- kernel path
def _kernel_filter_agg(rel: Columnar, query: Query, route: RouteDecision) -> Columnar:
    """Filter + group + aggregate through kernels/fused_filter_agg.

    One kernel pass per distinct value column (counts ride along free);
    the grouped output is re-assembled into the jnp path's layout —
    present groups first in ascending key order, absent slots zeroed —
    so compacted results are byte-identical to apply_groupby's whenever
    the route's exactness guards hold (integer sums below 2**24).
    """
    from repro.kernels.fused_filter_agg import fused_filter_agg

    key_name = query.group_keys[0]
    out_key = query.group_key_output_names()[0]
    key_col = rel.column(key_name)
    # validity folds into the key stream: invalid rows carry key -1,
    # which matches no group lane inside the kernel
    keys_slot = jnp.where(
        rel.valid, key_col.astype(jnp.int32) - route.key_offset, jnp.int32(-1)
    )
    G = route.num_groups

    native = native_filter_of(query.filter_expr) if route.native_filter else None
    if native is not None:
        fcol, op, thr = native
        filt = rel.column(fcol).astype(jnp.float32)
    elif query.filter_expr is not None:
        # non-native predicate: evaluate to a mask and feed it as the
        # filter column — still one fused XLA program end to end
        filt = query.filter_expr.evaluate(rel.columns).astype(jnp.float32)
        op, thr = "ge", 0.5
    else:
        filt, op, thr = jnp.ones((rel.capacity,), jnp.float32), "ge", 0.0

    value_cols: Dict[str, jax.Array] = {}
    for agg in query.aggregates:
        if agg.fn != "count":
            value_cols.setdefault(agg.expr.args[0], rel.column(agg.expr.args[0]))

    sums_by_col: Dict[str, jax.Array] = {}
    counts_f = None
    if not value_cols:  # COUNT(*)-only (or bare GROUP BY): one zero-value pass
        _, counts_f = fused_filter_agg(
            keys_slot, jnp.zeros((rel.capacity,), jnp.float32), filt,
            op=op, threshold=thr, num_groups=G, interpret=route.interpret,
        )
    for cname, vals in value_cols.items():
        sums_f, counts_f = fused_filter_agg(
            keys_slot, vals, filt,
            op=op, threshold=thr, num_groups=G, interpret=route.interpret,
        )
        sums_by_col[cname] = sums_f

    counts_i = counts_f.astype(jnp.int32)
    present = counts_i > 0
    # jnp layout: present groups first, ascending key (slot index ==
    # key - offset, so ascending slot == ascending key)
    order = jnp.argsort((~present).astype(jnp.int32), stable=True)
    present_s = present[order]
    keys_out = (jnp.arange(G, dtype=jnp.int32) + route.key_offset)[order]
    out_cols: Dict[str, jax.Array] = {
        out_key: jnp.where(
            present_s, keys_out.astype(key_col.dtype), jnp.zeros((), key_col.dtype)
        )
    }
    counts_s = jnp.where(present_s, counts_i[order], 0)
    for agg in query.aggregates:
        if agg.fn == "count":
            out_cols[agg.name] = counts_s
            continue
        sums_s = jnp.where(present_s, sums_by_col[agg.expr.args[0]][order], 0.0)
        if agg.fn == "sum":
            vdtype = rel.column(agg.expr.args[0]).dtype
            out_cols[agg.name] = sums_s.astype(
                vdtype if vdtype.kind == "f" else jnp.int32
            )
        else:  # mean
            out_cols[agg.name] = sums_s / jnp.maximum(counts_s, 1).astype(jnp.float32)
    return Columnar(out_cols, present_s)


def apply_sort(rel: Columnar, query: Query) -> Columnar:
    if not query.order_by:
        return rel
    # stable multi-key sort: apply keys in reverse significance order,
    # then one final stable pass pushing invalid rows to the end
    perm = jnp.arange(rel.capacity)
    for column, desc in reversed(query.order_by):
        # after aggregation a qualified group key surfaces under its
        # unqualified tail (group_key_output_names) — resolve the same way
        if column not in rel.columns and "." in column:
            tail = column.split(".")[-1]
            if tail in rel.columns:
                column = tail
        vals = rel.column(column)[perm]
        if vals.dtype.kind == "b":
            vals = vals.astype(jnp.int32)
        order = jnp.argsort(-vals if desc else vals, stable=True)
        perm = perm[order]
    order = jnp.argsort((~rel.valid[perm]).astype(jnp.int32), stable=True)
    perm = perm[order]
    return Columnar({k: v[perm] for k, v in rel.columns.items()}, rel.valid[perm])


def apply_limit(rel: Columnar, query: Query) -> Columnar:
    if query.limit is None or query.limit >= rel.capacity:
        return rel
    n = query.limit
    return Columnar({k: v[:n] for k, v in rel.columns.items()}, rel.valid[:n])


def execute_query(
    query: Query,
    rel: Columnar,
    *,
    group_capacity: Optional[int] = None,
    joined: Optional[Dict[str, Columnar]] = None,
    route: Optional[RouteDecision] = None,
) -> Columnar:
    """Interpret a Query over a Columnar. Pure function of its inputs.

    ``joined`` maps each JOIN table name to its relation; ``route`` is an
    optional engine/route.py decision — ``"kernel"`` sends the
    filter+group+agg pipeline through the fused Pallas kernel, anything
    else (including no route at all) runs the reference jnp operators.
    """
    rel, display = _combined_relation(query, rel, joined)
    if route is not None and route.engine_path == "kernel" and query.is_aggregation:
        rel = _kernel_filter_agg(rel, query, route)
        if query.projections:
            rel = apply_projection(rel, query)
    else:
        rel = apply_filter(rel, query)
        if query.is_aggregation:
            grel, gquery = _normalize_group_keys(rel, query)
            rel = apply_groupby(grel, gquery, capacity=group_capacity)
            if query.projections:
                rel = apply_projection(rel, query)
        else:
            if query.projections:
                rel = apply_projection(rel, query)
            elif display is not None:
                rel = rel.select(display)  # SELECT * over joined sources
    rel = apply_sort(rel, query)
    rel = apply_limit(rel, query)
    return rel


@functools.lru_cache(maxsize=512)
def _compiled_for(
    query: Query, group_capacity: Optional[int], route: Optional[RouteDecision]
) -> Callable:
    @jax.jit
    def run(rel: Columnar, joined: Dict[str, Columnar]) -> Columnar:
        return execute_query(
            query, rel, group_capacity=group_capacity, joined=joined, route=route
        )

    def call(rel: Columnar, joined: Optional[Dict[str, Columnar]] = None) -> Columnar:
        return run(rel, joined or {})

    return call


def compile_query(
    query: Query,
    *,
    group_capacity: Optional[int] = None,
    route: Optional[RouteDecision] = None,
) -> Callable[..., Columnar]:
    """Return the jit-compiled executable for a query (cached — this cache
    is the engine-level face of the runtime's warm-container cache).

    The executable takes ``(rel, joined=None)``; single-table callers keep
    the old one-argument form."""
    return _compiled_for(query, group_capacity, route)
