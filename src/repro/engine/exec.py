"""Vectorized, jit-able execution of Query objects over Columnar batches.

Every operator is shape-stable (masked-row semantics), so a full query —
and, via core/physical.py, a *chain* of queries plus Python expectations —
compiles to a single XLA program.  Group-by uses a sort + segment-scatter
formulation (radix-style grouping adapted to TPU-friendly dense ops: sort,
cumsum, scatter-add are all well-supported lax primitives).

The Pallas kernel in kernels/fused_filter_agg covers the
filter+group+sum hot path and is validated against this module's
pure-jnp results in tests, but it is NOT wired into `execute_query` —
every query runs the jnp path below, so results stay platform-
independent.  Routing eligible scan→filter→agg stages through the
kernel is the ROADMAP "SQL v2" item; until then the kernel is a
benchmarked spare part, not an active code path.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.engine.columnar import Columnar
from repro.engine.query import Agg, Query

def apply_filter(rel: Columnar, query: Query) -> Columnar:
    if query.filter_expr is None:
        return rel
    keep = query.filter_expr.evaluate(rel.columns)
    return rel.mask_where(keep.astype(bool))


def apply_projection(rel: Columnar, query: Query) -> Columnar:
    if not query.projections:
        return rel
    out = {alias: expr.evaluate(rel.columns) for alias, expr in query.projections}
    return Columnar(out, rel.valid)


def _lex_sort_perm(rel: Columnar, keys) -> jax.Array:
    """Permutation grouping equal key tuples, valid rows first.

    Lexicographic order via repeated *stable* argsort from least- to
    most-significant key; validity is the most significant key.  Avoids
    packing keys into one word (no x64 requirement, no range limits).
    """
    perm = jnp.arange(rel.capacity)
    for k in reversed(keys):
        kcol = rel.column(k)
        if kcol.dtype.kind not in ("i", "u", "b"):
            raise TypeError(f"group key {k!r} must be integer/bool, got {kcol.dtype}")
        order = jnp.argsort(kcol[perm].astype(jnp.int32), stable=True)
        perm = perm[order]
    order = jnp.argsort((~rel.valid[perm]).astype(jnp.int32), stable=True)
    return perm[order]


def apply_groupby(rel: Columnar, query: Query, *, capacity: Optional[int] = None) -> Columnar:
    """Sort-based grouping with static output capacity.

    Output relation has ``capacity`` rows (default: input capacity); rows
    beyond the number of distinct groups are invalid.  All ops are
    shape-stable → fully jit/fusion compatible.
    """
    cap = capacity or rel.capacity
    order = _lex_sort_perm(rel, query.group_keys)
    sorted_valid = rel.valid[order]
    if query.group_keys:
        diff = jnp.zeros((rel.capacity,), bool)
        for k in query.group_keys:
            kcol = rel.column(k)[order]
            diff = diff | jnp.concatenate(
                [jnp.ones((1,), bool), kcol[1:] != kcol[:-1]]
            )
        is_new = diff & sorted_valid
    else:
        # global aggregation: one group, opened by the first (valid) row
        is_new = sorted_valid & (jnp.arange(rel.capacity) == 0)
    seg_id = jnp.cumsum(is_new.astype(jnp.int32)) - 1  # -1 for invalid prefix
    seg_id = jnp.where(sorted_valid, seg_id, cap)  # route invalid to overflow slot
    seg_id = jnp.minimum(seg_id, cap)  # overflow slot is dropped

    out_cols: Dict[str, jax.Array] = {}
    # representative group-key columns
    for k in query.group_keys:
        src = rel.column(k)[order]
        out = jnp.zeros((cap + 1,), dtype=src.dtype).at[seg_id].set(src)
        out_cols[k] = out[:cap]

    counts = jnp.zeros((cap + 1,), jnp.int32).at[seg_id].add(
        sorted_valid.astype(jnp.int32)
    )[:cap]

    for agg in query.aggregates:
        out_cols[agg.name] = _apply_one_agg(rel, agg, order, seg_id, sorted_valid, counts, cap)

    group_valid = counts > 0
    return Columnar(out_cols, group_valid)


def _apply_one_agg(rel, agg: Agg, order, seg_id, sorted_valid, counts, cap):
    if agg.fn == "count":
        return counts
    vals = agg.expr.evaluate(rel.columns)[order]
    if agg.fn in ("sum", "mean"):
        # f32 accum for floats, i32 for ints (x64 is disabled jax-wide)
        acc_dtype = vals.dtype if vals.dtype.kind == "f" else jnp.int32
        zeroed = jnp.where(sorted_valid, vals.astype(acc_dtype), 0)
        total = jnp.zeros((cap + 1,), acc_dtype).at[seg_id].add(zeroed)[:cap]
        if agg.fn == "sum":
            return total
        return total.astype(jnp.float32) / jnp.maximum(counts, 1).astype(jnp.float32)
    if agg.fn == "min":
        big = _extreme(vals.dtype, +1)
        masked = jnp.where(sorted_valid, vals, big)
        return jnp.full((cap + 1,), big, vals.dtype).at[seg_id].min(masked)[:cap]
    if agg.fn == "max":
        small = _extreme(vals.dtype, -1)
        masked = jnp.where(sorted_valid, vals, small)
        return jnp.full((cap + 1,), small, vals.dtype).at[seg_id].max(masked)[:cap]
    raise ValueError(f"unsupported aggregate {agg.fn!r}")


def _extreme(dtype, sign: int):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(sign * jnp.inf, dtype)
    info = jnp.iinfo(dtype)
    return jnp.array(info.max if sign > 0 else info.min, dtype)


def apply_sort(rel: Columnar, query: Query) -> Columnar:
    if not query.order_by:
        return rel
    # stable multi-key sort: apply keys in reverse significance order,
    # then one final stable pass pushing invalid rows to the end
    perm = jnp.arange(rel.capacity)
    for column, desc in reversed(query.order_by):
        vals = rel.column(column)[perm]
        if vals.dtype.kind == "b":
            vals = vals.astype(jnp.int32)
        order = jnp.argsort(-vals if desc else vals, stable=True)
        perm = perm[order]
    order = jnp.argsort((~rel.valid[perm]).astype(jnp.int32), stable=True)
    perm = perm[order]
    return Columnar({k: v[perm] for k, v in rel.columns.items()}, rel.valid[perm])


def apply_limit(rel: Columnar, query: Query) -> Columnar:
    if query.limit is None or query.limit >= rel.capacity:
        return rel
    n = query.limit
    return Columnar({k: v[:n] for k, v in rel.columns.items()}, rel.valid[:n])


def execute_query(
    query: Query, rel: Columnar, *, group_capacity: Optional[int] = None
) -> Columnar:
    """Interpret a Query over a Columnar. Pure function of its inputs."""
    rel = apply_filter(rel, query)
    if query.is_aggregation:
        rel = apply_groupby(rel, query, capacity=group_capacity)
        if query.projections:
            rel = apply_projection(rel, query)
    else:
        rel = apply_projection(rel, query)
    rel = apply_sort(rel, query)
    rel = apply_limit(rel, query)
    return rel


@functools.lru_cache(maxsize=512)
def _compiled_for(query: Query, group_capacity: Optional[int]) -> Callable:
    @jax.jit
    def run(rel: Columnar) -> Columnar:
        return execute_query(query, rel, group_capacity=group_capacity)

    return run


def compile_query(
    query: Query, *, group_capacity: Optional[int] = None
) -> Callable[[Columnar], Columnar]:
    """Return the jit-compiled executable for a query (cached — this cache
    is the engine-level face of the runtime's warm-container cache)."""
    return _compiled_for(query, group_capacity)
