"""``repro explain`` — static plan description with the routing verdict.

The explain plane answers "what will this query/pipeline *do*" without
executing anything: planned stages, pushdown and shard pruning, the
kernel-vs-jnp verdict with the full :class:`RouteTrace` of evidence, the
inferred output schema, and the typed-dataflow (T-rule) findings.

Agreement with the runtime is structural, not aspirational:

* interactive SQL — :func:`explain_query` calls the very same
  :func:`repro.core.physical.plan_interactive_query` that
  ``Runner.query`` executes, so the predicted ``engine_path`` (or the
  predicted :class:`RouteError`, byte-for-byte) IS the runtime decision;
* pipelines — :func:`explain_pipeline` routes each SQL node from the
  same ``(query, external snapshots)`` inputs ``build_physical_plan``
  stamps onto ``Stage.sql_routes``, so the two dictionaries compare
  equal (RouteDecision equality excludes the trace).

Nothing in this module reads shard data or writes to any store.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.lineage import (
    Unknown,
    combined_input_schema,
    infer_query_schema,
    propagate_schema,
)
from repro.analysis.report import Finding, LintReport
from repro.analysis.types import query_type_findings
from repro.core.pipeline import Pipeline
from repro.core.physical import plan_interactive_query
from repro.engine.expr import Expr
from repro.engine.query import Query
from repro.engine.route import (
    RouteDecision,
    RouteError,
    RouteTrace,
    column_stats_for_query,
    plan_route,
)
from repro.engine.sql import parse_sql
from repro.table.schema import Schema

_OP_SYMBOLS = {
    "lt": "<", "le": "<=", "gt": ">", "ge": ">=", "eq": "==", "ne": "!=",
    "add": "+", "sub": "-", "mul": "*", "div": "/",
    "and": "AND", "or": "OR",
}


def render_expr(e: Optional[Expr]) -> str:
    """Readable infix form of an expression tree (diagnostics only)."""
    if e is None:
        return ""
    if e.op == "col":
        return str(e.args[0])
    if e.op == "lit":
        return repr(e.args[0])
    if e.op == "not":
        return f"NOT ({render_expr(e.args[0])})"
    if e.op in _OP_SYMBOLS and len(e.args) == 2:
        return (
            f"{render_expr(e.args[0])} {_OP_SYMBOLS[e.op]} "
            f"{render_expr(e.args[1])}"
        )
    return f"{e.op}({', '.join(render_expr(a) for a in e.args)})"


def _schema_pairs(schema: Optional[Schema]) -> Optional[Tuple[Tuple[str, str], ...]]:
    if schema is Unknown:
        return None
    return tuple((c.name, str(c.dtype)) for c in schema.columns)


@dataclass
class ExplainedQuery:
    """One interactive query, fully described and never executed."""

    sql: Optional[str]
    #: engine the caller requested ("auto" | "kernel" | "jnp")
    engine: str
    #: the verdict — "kernel" | "jnp", or None when the prediction is a
    #: RouteError (forced kernel on an ineligible query)
    engine_path: Optional[str]
    route: Optional[RouteDecision] = None
    trace: Optional[RouteTrace] = None
    #: predicted RouteError message — byte-identical to what the runtime
    #: would raise, positioned fragment and fix hint included
    error: Optional[str] = None
    #: filter conjuncts pushed into the FROM table's scan, rendered
    pushdown: Tuple[str, ...] = ()
    #: filter remainder the engine evaluates post-scan, rendered
    residual: Optional[str] = None
    #: table -> {columns, shards, pruned_shards, rows}
    scans: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    output_schema: Optional[Tuple[Tuple[str, str], ...]] = None
    #: typed-dataflow (T-rule) findings for this query
    findings: Tuple[Finding, ...] = ()

    def describe(self) -> str:
        lines: List[str] = []
        if self.sql:
            lines.append(f"explain: {' '.join(self.sql.split())}")
        lines.append(f"  engine requested: {self.engine}")
        lines.append("  plan:")
        for table, s in self.scans.items():
            lines.append(
                f"    scan      {table}: {len(s['columns'])} column(s) "
                f"{s['columns']}, {s['shards']} shard(s) "
                f"({s['pruned_shards']} pruned), {s['rows']} row(s)"
            )
        for p in self.pushdown:
            lines.append(f"    pushdown  {p} (into the scan)")
        if self.residual:
            lines.append(f"    residual  {self.residual}")
        if self.error is not None:
            lines.append(f"    execute   REFUSED — {self.error}")
        elif self.route is not None:
            lines.append(
                f"    execute   {self.route.engine_path} — {self.route.reason}"
            )
        if self.trace is not None and self.trace.checks:
            lines.append("  route trace:")
            lines.extend(
                "    " + line
                for c in self.trace.checks
                for line in c.describe().splitlines()
            )
        if self.output_schema is not None:
            cols = ", ".join(f"{n} {d}" for n, d in self.output_schema)
            lines.append(f"  output schema: {cols}")
        if self.findings:
            lines.append(f"  typed checks: {len(self.findings)} finding(s)")
            for f in self.findings:
                lines.append("    " + f.describe().replace("\n", "\n    "))
        return "\n".join(lines)

    def to_json_dict(self) -> Dict[str, Any]:
        return {
            "sql": self.sql,
            "engine": self.engine,
            "engine_path": self.engine_path,
            "route": self.route.to_json_dict() if self.route else None,
            "trace": self.trace.to_json_dict() if self.trace else None,
            "error": self.error,
            "pushdown": list(self.pushdown),
            "residual": self.residual,
            "scans": self.scans,
            "output_schema": (
                [list(p) for p in self.output_schema]
                if self.output_schema is not None
                else None
            ),
            "findings": [f.to_json_dict() for f in self.findings],
        }


def explain_query(
    sql_or_query: Any,
    snapshots: Dict[str, Any],
    *,
    engine: str = "auto",
) -> ExplainedQuery:
    """Describe one interactive query exactly as ``Runner.query`` would
    run it.  ``snapshots`` maps every FROM/JOIN table to its Snapshot
    (``repro.core.physical.resolve_query_snapshots`` produces it — with
    the same positioned SqlError for unknown tables the runtime raises).

    A predicted :class:`RouteError` (forced kernel, ineligible query) is
    a *product* here, not an exception: it lands on ``.error`` with the
    trace of the checks that doomed it.
    """
    query: Query = (
        parse_sql(sql_or_query) if isinstance(sql_or_query, str) else sql_or_query
    )
    schemas = {
        t: snap.schema for t, snap in snapshots.items()
    }
    error: Optional[str] = None
    route: Optional[RouteDecision] = None
    trace: Optional[RouteTrace] = None
    pushed: Tuple = ()
    residual = None
    scans: Dict[str, Dict[str, Any]] = {}
    try:
        iq = plan_interactive_query(query, snapshots, engine=engine)
        route, trace = iq.route, iq.route.trace
        pushed, residual = iq.pushed, iq.residual
        scans = {
            t: {
                "columns": list(sp.output_columns),
                "shards": len(sp.shards),
                "pruned_shards": sp.pruned_shards,
                "rows": sp.rows_to_read,
            }
            for t, sp in iq.scans.items()
        }
        stats, total_rows = iq.stats, iq.total_rows
    except RouteError as e:
        error, trace = str(e), e.trace
        stats, total_rows = column_stats_for_query(query, snapshots)

    in_schema, display = combined_input_schema(query, schemas)
    out_schema = (
        infer_query_schema(query, in_schema, display)
        if in_schema is not Unknown
        else Unknown
    )
    findings, _sup = query_type_findings(
        query, schemas, stats=stats, total_rows=total_rows
    )
    return ExplainedQuery(
        sql=query.raw_sql,
        engine=engine,
        engine_path=route.engine_path if route is not None else None,
        route=route,
        trace=trace,
        error=error,
        pushdown=tuple(
            f"{p.column} {p.op} {p.value:g}" for p in pushed
        ),
        residual=render_expr(residual) or None,
        scans=scans,
        output_schema=_schema_pairs(out_schema),
        findings=tuple(findings),
    )


# ===================================================================
# pipeline-level explain
# ===================================================================
@dataclass
class ExplainedNode:
    """One pipeline node's static story: route verdict + schema."""

    name: str
    kind: str
    parents: Tuple[str, ...]
    #: routing verdict for SQL nodes (None for python/expectation nodes
    #: and for nodes whose forced-kernel route is predicted to fail)
    route: Optional[RouteDecision] = None
    trace: Optional[RouteTrace] = None
    error: Optional[str] = None
    output_schema: Optional[Tuple[Tuple[str, str], ...]] = None

    def describe(self) -> str:
        head = f"{self.name} [{self.kind}] <- {list(self.parents)}"
        lines = [head]
        if self.error is not None:
            lines.append(f"  route: REFUSED — {self.error}")
        elif self.route is not None:
            lines.append(
                f"  route: {self.route.engine_path} — {self.route.reason}"
            )
        if self.trace is not None and self.trace.checks:
            lines.extend(
                "    " + line
                for c in self.trace.checks
                for line in c.describe().splitlines()
            )
        if self.output_schema is not None:
            cols = ", ".join(f"{n} {d}" for n, d in self.output_schema)
            lines.append(f"  schema: {cols}")
        return "\n".join(lines)

    def to_json_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "kind": self.kind,
            "parents": list(self.parents),
            "engine_path": (
                self.route.engine_path if self.route is not None else None
            ),
            "route": self.route.to_json_dict() if self.route else None,
            "trace": self.trace.to_json_dict() if self.trace else None,
            "error": self.error,
            "output_schema": (
                [list(p) for p in self.output_schema]
                if self.output_schema is not None
                else None
            ),
        }


@dataclass
class PipelineExplanation:
    """The whole pipeline, statically explained, lint report included."""

    pipeline: str
    engine: str
    nodes: List[ExplainedNode]
    report: LintReport

    @property
    def routes(self) -> Dict[str, RouteDecision]:
        """Predicted per-SQL-node routes — directly comparable (dataclass
        equality) with the planner's ``Stage.sql_routes``."""
        return {n.name: n.route for n in self.nodes if n.route is not None}

    def describe(self) -> str:
        lines = [
            f"explain pipeline {self.pipeline!r} (engine={self.engine}): "
            f"{len(self.nodes)} node(s)"
        ]
        for n in self.nodes:
            lines.append("  " + n.describe().replace("\n", "\n  "))
        lines.append(self.report.describe())
        return "\n".join(lines)

    def to_json_dict(self) -> Dict[str, Any]:
        return {
            "pipeline": self.pipeline,
            "engine": self.engine,
            "nodes": [n.to_json_dict() for n in self.nodes],
            "lint": self.report.to_json_dict(),
        }


def explain_pipeline(
    pipeline: Pipeline,
    *,
    external_schemas: Optional[Dict[str, Optional[Schema]]] = None,
    snapshots: Optional[Dict[str, Any]] = None,
    engine: str = "auto",
    catalog_tables: Optional[set] = None,
) -> PipelineExplanation:
    """Statically explain every node of a pipeline.

    SQL nodes are routed from exactly the inputs the physical planner
    uses — the node's query plus *external* snapshot statistics
    (node-sourced parents carry no stats there either) — so
    ``PipelineExplanation.routes`` equals the union of the planner's
    ``Stage.sql_routes`` for the same engine setting.  The embedded
    :class:`LintReport` runs the full preflight (L/G/D/T/C rules).
    """
    from repro.analysis.lint import _toposort, lint_pipeline

    report = lint_pipeline(
        pipeline,
        external_schemas=external_schemas,
        external_snapshots=snapshots,
        catalog_tables=catalog_tables,
    )
    order, _ = _toposort(pipeline)
    if len(order) != len(pipeline.nodes):  # cyclic — explain what we can
        order += sorted(set(pipeline.nodes) - set(order))
    snapshots = snapshots or {}
    schemas: Dict[str, Optional[Schema]] = dict(external_schemas or {})
    explained: List[ExplainedNode] = []
    for name in order:
        node = pipeline.nodes[name]
        route: Optional[RouteDecision] = None
        trace: Optional[RouteTrace] = None
        error: Optional[str] = None
        if node.kind == "sql" and node.query is not None:
            stats, total_rows = column_stats_for_query(node.query, snapshots)
            try:
                route = plan_route(
                    node.query, engine=engine, stats=stats,
                    total_rows=total_rows,
                )
                trace = route.trace
            except RouteError as e:
                error, trace = str(e), e.trace
        out = propagate_schema(node, schemas)
        schemas[name] = out
        explained.append(
            ExplainedNode(
                name=name,
                kind=node.kind,
                parents=node.parents,
                route=route,
                trace=trace,
                error=error,
                output_schema=_schema_pairs(out),
            )
        )
    return PipelineExplanation(
        pipeline=pipeline.name,
        engine=engine,
        nodes=explained,
        report=report,
    )
