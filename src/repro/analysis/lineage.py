"""Column-level lineage + schema checking (L-rules).

The pass infers each node's *referenced input columns* — from the parsed
``Query`` for SQL nodes, from an AST walk for ``@repro.model`` /
``@repro.expectation`` functions — propagates inferred *output schemas*
topologically from the catalog's table schemas, and flags, before
anything executes:

* ``L001`` a referenced column missing from the (inferred) input schema;
* ``L002`` a GROUP BY key whose dtype the engine cannot group on
  (``engine/exec.py`` requires integer/bool keys — a float key dies with
  a TypeError mid-run);
* ``L003`` an ORDER BY column absent from the node's *output* columns
  (sorting runs after projection/aggregation);
* ``L004`` a referenced table neither produced by the pipeline nor
  present in the catalog at the lint branch.

The rules see JOINs: a multi-table node is checked against the same
*combined relation* the executor builds (every column addressable as
``qualifier.name``, plain when exactly one source owns it — see
``engine/exec._combined_relation``), so qualified references, join-table
columns, ambiguous plain names, and ``SELECT *`` display schemas over
joins all lint exactly as they execute.  L004 covers join tables for
free because ``Query.source_tables()`` feeds the node's parents.

Schema inference is conservative: a Python node's output schema is
unknown (opaque function), and any node whose inputs are unknown
propagates unknown — the pass under-reports instead of guessing.
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.astpass import column_references, load_fn_source
from repro.analysis.report import Finding, Severity
from repro.core.pipeline import Node
from repro.engine.expr import Expr
from repro.engine.query import Query
from repro.table.schema import Column, Schema

#: inferred-schema value meaning "statically unknown" (opaque python node)
Unknown = None


def expr_dtype(e: Expr, schema: Schema) -> Optional[np.dtype]:
    """Static dtype of an expression over ``schema`` (None = unknown,
    e.g. a missing column — reported separately as L001)."""
    if e.op == "col":
        return schema.dtype_of(e.args[0]) if schema.has(e.args[0]) else None
    if e.op == "lit":
        v = e.args[0]
        # the engine runs x64-disabled: literals land as 32-bit
        if isinstance(v, bool):
            return np.dtype("bool")
        if isinstance(v, int):
            return np.dtype("int32")
        return np.dtype("float32")
    if e.op in ("lt", "le", "gt", "ge", "eq", "ne", "and", "or", "not"):
        return np.dtype("bool")
    args = [expr_dtype(a, schema) for a in e.args]
    if any(a is None for a in args):
        return None
    if e.op == "div":
        return np.dtype("float32")
    return np.result_type(*args)


def _agg_dtype(fn: str, expr: Optional[Expr], schema: Schema) -> Optional[np.dtype]:
    if fn == "count":
        return np.dtype("int32")
    if fn == "mean":
        return np.dtype("float32")
    inner = expr_dtype(expr, schema) if expr is not None else None
    if inner is None:
        return None
    if fn == "sum":
        return inner if inner.kind == "f" else np.dtype("int32")
    return inner  # min/max keep the input dtype


def combined_input_schema(
    query: Query,
    input_schemas: Dict[str, Optional[Schema]],
) -> Tuple[Optional[Schema], Optional[List[str]]]:
    """The schema-level mirror of ``engine/exec._combined_relation``.

    Returns ``(schema, display)``: the schema the query's expressions
    evaluate against — every column addressable as ``qualifier.name``,
    plus the plain name when exactly one source owns it — and the
    ``SELECT *`` display column list (plain-if-unique, qualified
    otherwise, in source order).  Single-table queries with no alias and
    no dotted references pass through untouched (``display`` = None);
    Unknown propagates if any source table's schema is unknown.
    """
    dotted = any("." in c for c in query.referenced_columns())
    if not query.joins and query.source_alias is None and not dotted:
        return input_schemas.get(query.source, Unknown), None
    sources: List[Tuple[str, Schema]] = []
    for qual, table in query.qualifiers():
        s = input_schemas.get(table, Unknown)
        if s is Unknown:
            return Unknown, None
        sources.append((qual, s))
    owners = Counter(n for _, s in sources for n in s.names)
    cols: List[Column] = []
    display: List[str] = []
    for qual, s in sources:
        for c in s.columns:
            cols.append(Column(f"{qual}.{c.name}", c.dtype))
            if owners[c.name] == 1:
                cols.append(Column(c.name, c.dtype))
                display.append(c.name)
            else:
                display.append(f"{qual}.{c.name}")
    return Schema(tuple(cols)), display


def infer_query_schema(
    query: Query,
    input_schema: Schema,
    display: Optional[List[str]] = None,
) -> Optional[Schema]:
    """Output schema of a SQL node given its (combined) input schema
    (None when any needed dtype cannot be inferred — downstream checks
    then skip).  ``display`` is the SELECT-* column list for multi-source
    queries, as returned by :func:`combined_input_schema`."""
    cols: List[Column] = []
    if query.is_aggregation:
        for k, out in zip(query.group_keys, query.group_key_output_names()):
            if not input_schema.has(k):
                return Unknown
            cols.append(Column(out, str(input_schema.dtype_of(k))))
        for agg in query.aggregates:
            dt = _agg_dtype(agg.fn, agg.expr, input_schema)
            if dt is None:
                return Unknown
            cols.append(Column(agg.name, str(dt)))
        if query.projections:  # post-agg projection re-derives columns
            agg_schema = Schema(tuple(cols))
            cols = []
            for alias, e in query.projections:
                dt = expr_dtype(e, agg_schema)
                if dt is None:
                    return Unknown
                cols.append(Column(alias, str(dt)))
    elif query.projections:
        for alias, e in query.projections:
            dt = expr_dtype(e, input_schema)
            if dt is None:
                return Unknown
            cols.append(Column(alias, str(dt)))
    elif display is not None:  # SELECT * over joins/aliases
        try:
            return input_schema.select(display)
        except KeyError:
            return Unknown
    else:  # SELECT *
        return input_schema
    try:
        return Schema(tuple(cols))
    except TypeError:  # a dtype outside the engine's numeric kinds
        return Unknown


def _sql_fragment(query: Query, token: str) -> Tuple[Optional[str], str]:
    """Locate ``token`` in the node's raw SQL: (position note, fragment)."""
    raw = query.raw_sql
    if not raw:
        return None, ""
    m = re.search(rf"\b{re.escape(token)}\b", raw)
    if not m:
        return None, ""
    start = m.start()
    line = raw.count("\n", 0, start) + 1
    frag = raw[max(0, start - 20):start + len(token) + 20].replace("\n", " ")
    return f"sql line {line}, pos {start}", f"... {frag.strip()} ..."


def check_sql_node(
    node: Node,
    input_schemas: Dict[str, Optional[Schema]],
) -> List[Finding]:
    """L001/L002/L003 for one SQL node against its input schemas.

    ``input_schemas`` maps every table the node reads (FROM + JOINs) to
    its possibly-unknown schema; the checks run over the combined
    relation schema, so qualified references (``t.col``) and join-table
    columns are validated the same way the executor resolves them."""
    findings: List[Finding] = []
    query = node.query
    assert query is not None

    def finding(rule: str, message: str, token: str) -> Finding:
        pos, frag = _sql_fragment(query, token)
        if pos:
            message = f"{message} ({pos})"
        return Finding(
            rule=rule,
            severity=Severity.ERROR,
            message=message,
            node=node.name,
            file=node.source_file,
            line=node.source_line,
            snippet=frag or None,
        )

    input_schema, display = combined_input_schema(query, input_schemas)
    if input_schema is not Unknown:
        known = set(input_schema.names)
        qual_tables = dict(query.qualifiers())
        for c in query.referenced_columns():
            if c in known:
                continue
            if "." in c:
                qual = c.split(".")[0]
                table = qual_tables.get(qual)
                msg = (
                    f"column {c!r} is not in table {table!r}"
                    if table is not None
                    else f"unknown table qualifier {qual!r} in {c!r} "
                    f"(tables: {sorted(qual_tables)})"
                )
            else:
                tables = sorted(set(qual_tables.values()))
                where = (
                    f"table {tables[0]!r}" if len(tables) == 1
                    else f"any of tables {tables}"
                )
                msg = f"column {c!r} is not in {where}"
            findings.append(finding("L001", msg, c))
        for k in query.group_keys:
            if k in known and input_schema.dtype_of(k).kind not in ("i", "u", "b"):
                findings.append(
                    finding(
                        "L002",
                        f"GROUP BY key {k!r} has dtype "
                        f"{input_schema.dtype_of(k)} — the engine groups "
                        "integer/bool keys only (runtime TypeError)",
                        k,
                    )
                )

    # ORDER BY applies to the node's OUTPUT relation
    out_schema = (
        infer_query_schema(query, input_schema, display)
        if input_schema is not Unknown
        else Unknown
    )
    out_cols = query.output_columns() or (
        list(out_schema.names) if out_schema is not Unknown else []
    )
    if out_cols:
        for col_name, _desc in query.order_by:
            # a qualified sort key resolves to its unqualified tail after
            # aggregation/projection, exactly as apply_sort does
            if col_name not in out_cols and col_name.split(".")[-1] not in out_cols:
                findings.append(
                    finding(
                        "L003",
                        f"ORDER BY column {col_name!r} is not among the "
                        f"node's output columns {sorted(out_cols)}",
                        col_name,
                    )
                )
    return findings


def check_python_node(
    node: Node,
    input_schemas: Dict[str, Optional[Schema]],
) -> Tuple[List[Finding], int]:
    """L001 for statically-visible column access in a function body;
    returns ``(findings, suppressed)``."""
    findings: List[Finding] = []
    suppressed = 0
    if node.fn is None:
        return findings, suppressed
    src = load_fn_source(node.fn)
    if src is None:
        return findings, suppressed
    for parent, col_name, at in column_references(src, node.parents):
        schema = input_schemas.get(parent, Unknown)
        if schema is Unknown or schema.has(col_name):
            continue
        line = src.abs_line(at)
        if src.suppressed("L001", line):
            suppressed += 1
            continue
        findings.append(
            Finding(
                rule="L001",
                severity=Severity.ERROR,
                message=(
                    f"column {col_name!r} is not in input {parent!r} "
                    f"(has {sorted(schema.names)})"
                ),
                node=node.name,
                file=src.file,
                line=line,
                snippet=src.snippet(at),
            )
        )
    return findings, suppressed


def propagate_schema(
    node: Node,
    input_schemas: Dict[str, Optional[Schema]],
) -> Optional[Schema]:
    """The node's inferred output schema (Unknown for opaque python
    nodes and for SQL nodes whose input is unknown)."""
    if node.kind != "sql" or node.query is None:
        return Unknown
    src_schema, display = combined_input_schema(node.query, input_schemas)
    if src_schema is Unknown:
        return Unknown
    return infer_query_schema(node.query, src_schema, display)
