"""Cache-poison / nondeterminism rules over node function ASTs (D1xx).

Why these exist: the differential cache (PR 3) keys node results on
*code + inputs + params*.  "FaaS and Furious" shows that only pays off
when node functions are pure — a node that reads the wall clock, draws
unseeded randomness, or peeks at the environment produces different
output under the SAME fingerprint, so a warm cache silently serves stale
(or simply wrong) artifacts.  These rules flag the constructs *before*
a run instead of after a confusing replay mismatch.

Each rule is data (id, severity, summary, example) so the CLI/README rule
catalog is generated from the same table the engine matches against.
Suppress a deliberate use with ``# repro: noqa[D102]`` on the offending
line (see astpass.py).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import (
    Callable, Dict, Iterator, List, Optional, Set, Tuple, TYPE_CHECKING,
)

from repro.analysis.astpass import (
    FnSource, dotted_name, line_suppresses, load_fn_source, root_name,
)
from repro.analysis.report import Finding, Severity

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.pipeline import Pipeline


@dataclass(frozen=True)
class Rule:
    id: str
    severity: Severity
    summary: str
    example: str
    suppression: str = "# repro: noqa[<id>]"


FUNCTION_RULES: Tuple[Rule, ...] = (
    Rule(
        "D101", Severity.WARNING,
        "wall-clock read — time/datetime calls make node output "
        "run-dependent, poisoning its cache fingerprint",
        "ts = time.time()",
    ),
    Rule(
        "D102", Severity.WARNING,
        "unseeded randomness — random/np.random without an explicit seed "
        "produces different artifacts under the same fingerprint",
        "rng = np.random.default_rng()  # no seed",
    ),
    Rule(
        "D103", Severity.WARNING,
        "uuid generation — uuids are fresh every run; derive ids from "
        "content hashes instead",
        "uuid.uuid4()",
    ),
    Rule(
        "D104", Severity.WARNING,
        "environment read — os.environ/os.getenv smuggles config past the "
        "fingerprint; pass it through run params instead",
        "os.environ['MODE']",
    ),
    Rule(
        "D105", Severity.WARNING,
        "file I/O — reading/writing paths bypasses the versioned lake; "
        "inputs must come from parent tables",
        "open('side_channel.csv')",
    ),
    Rule(
        "D106", Severity.WARNING,
        "global-state mutation — global/nonlocal writes leak state "
        "between stages and across fused plans",
        "global counter",
    ),
    Rule(
        "D107", Severity.WARNING,
        "input-table mutation — writing into a parent relation corrupts "
        "siblings that fuse over the same in-memory input",
        "trips.columns['count'] = fixed",
    ),
)

RULES_BY_ID = {r.id: r for r in FUNCTION_RULES}

# ------------------------------------------------------------- matchers
_TIME_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
}
_TIME_ATTRS = {"now", "utcnow", "today"}  # datetime.now / date.today / ...
_SEEDLESS_OK = {"seed", "default_rng", "Generator", "SeedSequence", "PRNGKey"}
_UUID_CALLS = {"uuid1", "uuid3", "uuid4", "uuid5"}
_IO_METHODS = {"read_text", "read_bytes", "write_text", "write_bytes"}
_NP_IO = {"np.load", "np.save", "np.savez", "numpy.load", "numpy.save"}


def _call_findings(
    node: ast.Call, parents: Tuple[str, ...]
) -> Iterator[Tuple[str, str]]:
    """Yield ``(rule_id, detail)`` for one call site."""
    name = dotted_name(node.func)
    attr = node.func.attr if isinstance(node.func, ast.Attribute) else None

    if name in _TIME_CALLS or (
        name is not None
        and attr in _TIME_ATTRS
        and ("datetime" in name or name.split(".")[0] in ("date", "dt"))
    ):
        yield "D101", f"calls {name}()"
        return
    if name is not None:
        head, _, tail = name.partition(".")
        if head == "random" and tail and tail not in ("seed", "Random"):
            yield "D102", f"calls {name}() (seed the generator instead)"
            return
        if name.startswith(("np.random.", "numpy.random.", "jax.random.")):
            # np.random.<fn> legacy globals; a local Generator's .random()
            # is NOT matched — the seed (or lack of it) lives at its
            # default_rng() construction site, flagged there instead
            leaf = name.rsplit(".", 1)[-1]
            if leaf == "default_rng":
                if not node.args and not node.keywords:
                    yield "D102", f"{name}() called without a seed"
                return
            if leaf not in _SEEDLESS_OK:
                yield "D102", f"calls {name}() (global unseeded stream)"
                return
        if name.rsplit(".", 1)[-1] in _UUID_CALLS and head in ("uuid",):
            yield "D103", f"calls {name}()"
            return
        if name in ("os.getenv", "os.environ.get"):
            yield "D104", f"calls {name}()"
            return
        if name in _NP_IO:
            yield "D105", f"calls {name}()"
            return
    if isinstance(node.func, ast.Name) and node.func.id == "open":
        yield "D105", "calls open()"
        return
    if attr in _IO_METHODS:
        yield "D105", f"calls .{attr}()"


def _env_read(node: ast.AST) -> bool:
    """Bare ``os.environ`` access (subscript or attribute load)."""
    return dotted_name(node) == "os.environ"


def run_function_rules(
    src: FnSource,
    node_name: str,
    parents: Tuple[str, ...],
) -> Tuple[List[Finding], int]:
    """All D-rule findings for one node function; returns
    ``(findings, suppressed_count)``."""
    findings: List[Finding] = []
    suppressed = 0
    seen = set()  # (rule, line): os.environ.get fires call+attr matchers

    def emit(rule_id: str, detail: str, at: ast.AST) -> None:
        nonlocal suppressed
        line = src.abs_line(at)
        if (rule_id, line) in seen:
            return
        seen.add((rule_id, line))
        if src.suppressed(rule_id, line):
            suppressed += 1
            return
        rule = RULES_BY_ID[rule_id]
        findings.append(
            Finding(
                rule=rule.id,
                severity=rule.severity,
                message=f"{rule.summary.split(' — ')[0]}: {detail}",
                node=node_name,
                file=src.file,
                line=line,
                snippet=src.snippet(at),
            )
        )

    parent_set = set(parents)
    for stmt in ast.walk(src.fn_def):
        if isinstance(stmt, ast.Call):
            for rule_id, detail in _call_findings(stmt, parents):
                emit(rule_id, detail, stmt)
        elif isinstance(stmt, (ast.Global, ast.Nonlocal)):
            kw = "global" if isinstance(stmt, ast.Global) else "nonlocal"
            emit("D106", f"{kw} {', '.join(stmt.names)}", stmt)
        elif isinstance(stmt, ast.Subscript) and isinstance(
            stmt.ctx, (ast.Store, ast.Del)
        ):
            base = root_name(stmt)
            if base in parent_set:
                emit("D107", f"writes into input table {base!r}", stmt)
        elif isinstance(stmt, ast.Attribute):
            if isinstance(stmt.ctx, (ast.Store, ast.Del)):
                base = root_name(stmt)
                if base in parent_set:
                    emit("D107", f"writes attribute of input table {base!r}", stmt)
            elif _env_read(stmt):
                emit("D104", "reads os.environ", stmt)
    return findings, suppressed


# ===================================================================
# C-rules: concurrency hazards under the wave scheduler (parallelism>1)
# ===================================================================
#
# The async runner executes every node of a wave concurrently.  Two nodes
# are *co-schedulable* when neither is an ancestor of the other inside
# the pipeline — the scheduler is free to run them in the same wave, in
# either order, so any state they share outside the dataflow is a
# nondeterminism hazard the cache fingerprint cannot see.

CONCURRENCY_RULES: Tuple[Rule, ...] = (
    Rule(
        "C501", Severity.WARNING,
        "artifact shadows a lake table — a node materializes a name that "
        "already exists in the catalog, so parents elsewhere silently "
        "bind to the node output (or the table) depending on run order",
        'p.sql("orders", ...)  # "orders" is already a catalog table',
    ),
    Rule(
        "C502", Severity.WARNING,
        "co-schedulable nodes mutate the same global — at parallelism > 1 "
        "the fan-in order is scheduler-dependent, so the final state (and "
        "anything derived from it) is nondeterministic",
        "SEEN.append(...)  # in two nodes with no dependency path",
    ),
    Rule(
        "C503", Severity.WARNING,
        "co-schedulable global write/read — a node reads a global another "
        "node in the same wave mutates; the value observed depends on "
        "scheduling, not on the dataflow",
        "acc = TOTALS['x']  # while a sibling node writes TOTALS",
    ),
)

CONCURRENCY_RULES_BY_ID = {r.id: r for r in CONCURRENCY_RULES}

#: container-mutating method names — calling one on a *free* name whose
#: module-level binding is a mutable container counts as a global write
_MUTATOR_METHODS = {
    "append", "add", "update", "extend", "insert", "setdefault",
    "pop", "popitem", "clear", "remove", "discard",
}
_MUTABLE_CONTAINERS = (list, dict, set, bytearray)


def _local_names(fn_def: ast.FunctionDef) -> Set[str]:
    """Names bound inside the function — params plus every Name store
    (assignments, for targets, with-as, comprehensions, imports)."""
    a = fn_def.args
    out: Set[str] = {
        p.arg
        for p in (
            list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
            + ([a.vararg] if a.vararg else [])
            + ([a.kwarg] if a.kwarg else [])
        )
    }
    for n in ast.walk(fn_def):
        if isinstance(n, ast.Name) and isinstance(n.ctx, (ast.Store, ast.Del)):
            out.add(n.id)
        elif isinstance(n, (ast.Import, ast.ImportFrom)):
            for alias in n.names:
                out.add((alias.asname or alias.name).split(".")[0])
    # names declared ``global`` are explicitly NOT local
    for n in ast.walk(fn_def):
        if isinstance(n, ast.Global):
            out -= set(n.names)
    return out


@dataclass
class _GlobalUse:
    """Statically-visible shared-state traffic of one node function."""

    node: str
    fn: Callable
    src: FnSource
    writes: Dict[str, ast.AST] = field(default_factory=dict)
    reads: Dict[str, ast.AST] = field(default_factory=dict)


def _global_uses(name: str, fn: Callable) -> Optional[_GlobalUse]:
    src = load_fn_source(fn)
    if src is None:
        return None
    use = _GlobalUse(node=name, fn=fn, src=src)
    local = _local_names(src.fn_def)
    fglobals = getattr(fn, "__globals__", {})

    def free(n: str) -> bool:
        return n not in local

    for n in ast.walk(src.fn_def):
        if isinstance(n, ast.Global):
            for g in n.names:
                use.writes.setdefault(g, n)
        elif isinstance(n, (ast.Subscript, ast.Attribute)) and isinstance(
            n.ctx, (ast.Store, ast.Del)
        ):
            base = root_name(n)
            if base and free(base):
                use.writes.setdefault(base, n)
        elif isinstance(n, ast.Call):
            f = n.func
            if (
                isinstance(f, ast.Attribute)
                and f.attr in _MUTATOR_METHODS
                and isinstance(f.value, ast.Name)
                and free(f.value.id)
                and isinstance(
                    fglobals.get(f.value.id), _MUTABLE_CONTAINERS
                )
            ):
                use.writes.setdefault(f.value.id, n)
        elif isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load):
            if free(n.id):
                use.reads.setdefault(n.id, n)
    return use


def _pipeline_ancestors(pipeline: "Pipeline") -> Dict[str, Set[str]]:
    """Transitive in-pipeline ancestors per node (catalog parents are
    not edges; cycles — G302's problem — are guarded, not reported)."""
    anc: Dict[str, Set[str]] = {}

    def visit(name: str, stack: Set[str]) -> Set[str]:
        if name in anc:
            return anc[name]
        out: Set[str] = set()
        node = pipeline.nodes.get(name)
        if node is not None:
            for p in node.parents:
                if p in pipeline.nodes and p not in stack:
                    out.add(p)
                    out |= visit(p, stack | {name})
        anc[name] = out
        return out

    for n in pipeline.nodes:
        visit(n, {n})
    return anc


def _shares_binding(fa: Callable, fb: Callable, name: str) -> bool:
    """Do two functions see the SAME object under ``name``?  Identity
    when both modules bind it; same-module fallback otherwise (a name
    declared ``global`` may not be bound yet at lint time)."""
    ga = getattr(fa, "__globals__", {})
    gb = getattr(fb, "__globals__", {})
    if name in ga and name in gb:
        return ga[name] is gb[name]
    return ga is gb


def run_concurrency_rules(
    pipeline: "Pipeline",
    *,
    catalog_tables: Optional[Set[str]] = None,
) -> Tuple[List[Finding], int]:
    """All C-rule findings for a pipeline; ``(findings, suppressed)``.

    ``catalog_tables`` (names present at the lint branch head) powers
    C501; without it only the shared-global rules run.
    """
    findings: List[Finding] = []
    suppressed = 0

    def emit(
        rule_id: str,
        message: str,
        *,
        node: str,
        file: Optional[str],
        line: Optional[int],
        snippet: Optional[str],
        hint: str,
        src: Optional[FnSource] = None,
    ) -> None:
        nonlocal suppressed
        if src is not None and line is not None:
            if src.suppressed(rule_id, line):
                suppressed += 1
                return
        elif line_suppresses(file, line, rule_id):
            suppressed += 1
            return
        rule = CONCURRENCY_RULES_BY_ID[rule_id]
        findings.append(
            Finding(
                rule=rule.id,
                severity=rule.severity,
                message=message,
                node=node,
                file=file,
                line=line,
                snippet=snippet,
                hint=hint,
            )
        )

    # ------------------------------------------ C501: lake-table shadowing
    for node in pipeline.nodes.values():
        if node.is_expectation:
            continue
        if catalog_tables and node.name in catalog_tables:
            emit(
                "C501",
                f"artifact {node.name!r} shadows a lake table of the same "
                "name — siblings reading it bind to the node output, while "
                "anything planned before this node ran reads the table",
                node=node.name,
                file=node.source_file,
                line=node.source_line,
                snippet=None,
                hint=f"rename the artifact (e.g. {node.name + '_v2'!r}) or "
                "drop the catalog table first",
            )

    # ------------------------- C502/C503: shared globals across one wave
    uses = [
        u
        for n in pipeline.nodes.values()
        if n.fn is not None
        for u in (_global_uses(n.name, n.fn),)
        if u is not None
    ]
    if len(uses) < 2:
        return findings, suppressed
    anc = _pipeline_ancestors(pipeline)
    reported: Set[Tuple[frozenset, str]] = set()
    for i, ua in enumerate(uses):
        for ub in uses[i + 1:]:
            if ua.node in anc.get(ub.node, set()) or ub.node in anc.get(
                ua.node, set()
            ):
                continue  # ordered by the DAG — not co-schedulable
            pair = frozenset((ua.node, ub.node))
            # both write -> C502 (covers the read side too)
            for g in sorted(set(ua.writes) & set(ub.writes)):
                if not _shares_binding(ua.fn, ub.fn, g):
                    continue
                reported.add((pair, g))
                at = ua.writes[g]
                emit(
                    "C502",
                    f"nodes {ua.node!r} and {ub.node!r} both mutate shared "
                    f"global {g!r} and neither depends on the other — at "
                    "parallelism > 1 the final state depends on scheduler "
                    "fan-in order",
                    node=ua.node,
                    file=ua.src.file,
                    line=ua.src.abs_line(at),
                    snippet=ua.src.snippet(at),
                    hint=f"thread the state through an artifact (return it "
                    f"from one node, take it as a parent in the other) "
                    f"instead of module global {g!r}",
                    src=ua.src,
                )
            # one writes, the other reads -> C503
            for writer, reader in ((ua, ub), (ub, ua)):
                for g in sorted(set(writer.writes) & set(reader.reads)):
                    if (pair, g) in reported:
                        continue
                    if not _shares_binding(writer.fn, reader.fn, g):
                        continue
                    reported.add((pair, g))
                    at = writer.writes[g]
                    emit(
                        "C503",
                        f"node {reader.node!r} reads global {g!r} while "
                        f"co-schedulable node {writer.node!r} mutates it — "
                        "the value observed depends on scheduling, not on "
                        "the dataflow",
                        node=writer.node,
                        file=writer.src.file,
                        line=writer.src.abs_line(at),
                        snippet=writer.src.snippet(at),
                        hint=f"make {reader.node!r} a downstream consumer "
                        f"of the node that owns {g!r}, or freeze the value "
                        "into run params",
                        src=writer.src,
                    )
    return findings, suppressed
