"""Cache-poison / nondeterminism rules over node function ASTs (D1xx).

Why these exist: the differential cache (PR 3) keys node results on
*code + inputs + params*.  "FaaS and Furious" shows that only pays off
when node functions are pure — a node that reads the wall clock, draws
unseeded randomness, or peeks at the environment produces different
output under the SAME fingerprint, so a warm cache silently serves stale
(or simply wrong) artifacts.  These rules flag the constructs *before*
a run instead of after a confusing replay mismatch.

Each rule is data (id, severity, summary, example) so the CLI/README rule
catalog is generated from the same table the engine matches against.
Suppress a deliberate use with ``# repro: noqa[D102]`` on the offending
line (see astpass.py).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Callable, Iterator, List, Optional, Tuple

from repro.analysis.astpass import FnSource, dotted_name, root_name
from repro.analysis.report import Finding, Severity


@dataclass(frozen=True)
class Rule:
    id: str
    severity: Severity
    summary: str
    example: str
    suppression: str = "# repro: noqa[<id>]"


FUNCTION_RULES: Tuple[Rule, ...] = (
    Rule(
        "D101", Severity.WARNING,
        "wall-clock read — time/datetime calls make node output "
        "run-dependent, poisoning its cache fingerprint",
        "ts = time.time()",
    ),
    Rule(
        "D102", Severity.WARNING,
        "unseeded randomness — random/np.random without an explicit seed "
        "produces different artifacts under the same fingerprint",
        "rng = np.random.default_rng()  # no seed",
    ),
    Rule(
        "D103", Severity.WARNING,
        "uuid generation — uuids are fresh every run; derive ids from "
        "content hashes instead",
        "uuid.uuid4()",
    ),
    Rule(
        "D104", Severity.WARNING,
        "environment read — os.environ/os.getenv smuggles config past the "
        "fingerprint; pass it through run params instead",
        "os.environ['MODE']",
    ),
    Rule(
        "D105", Severity.WARNING,
        "file I/O — reading/writing paths bypasses the versioned lake; "
        "inputs must come from parent tables",
        "open('side_channel.csv')",
    ),
    Rule(
        "D106", Severity.WARNING,
        "global-state mutation — global/nonlocal writes leak state "
        "between stages and across fused plans",
        "global counter",
    ),
    Rule(
        "D107", Severity.WARNING,
        "input-table mutation — writing into a parent relation corrupts "
        "siblings that fuse over the same in-memory input",
        "trips.columns['count'] = fixed",
    ),
)

RULES_BY_ID = {r.id: r for r in FUNCTION_RULES}

# ------------------------------------------------------------- matchers
_TIME_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
}
_TIME_ATTRS = {"now", "utcnow", "today"}  # datetime.now / date.today / ...
_SEEDLESS_OK = {"seed", "default_rng", "Generator", "SeedSequence", "PRNGKey"}
_UUID_CALLS = {"uuid1", "uuid3", "uuid4", "uuid5"}
_IO_METHODS = {"read_text", "read_bytes", "write_text", "write_bytes"}
_NP_IO = {"np.load", "np.save", "np.savez", "numpy.load", "numpy.save"}


def _call_findings(
    node: ast.Call, parents: Tuple[str, ...]
) -> Iterator[Tuple[str, str]]:
    """Yield ``(rule_id, detail)`` for one call site."""
    name = dotted_name(node.func)
    attr = node.func.attr if isinstance(node.func, ast.Attribute) else None

    if name in _TIME_CALLS or (
        name is not None
        and attr in _TIME_ATTRS
        and ("datetime" in name or name.split(".")[0] in ("date", "dt"))
    ):
        yield "D101", f"calls {name}()"
        return
    if name is not None:
        head, _, tail = name.partition(".")
        if head == "random" and tail and tail not in ("seed", "Random"):
            yield "D102", f"calls {name}() (seed the generator instead)"
            return
        if name.startswith(("np.random.", "numpy.random.", "jax.random.")):
            # np.random.<fn> legacy globals; a local Generator's .random()
            # is NOT matched — the seed (or lack of it) lives at its
            # default_rng() construction site, flagged there instead
            leaf = name.rsplit(".", 1)[-1]
            if leaf == "default_rng":
                if not node.args and not node.keywords:
                    yield "D102", f"{name}() called without a seed"
                return
            if leaf not in _SEEDLESS_OK:
                yield "D102", f"calls {name}() (global unseeded stream)"
                return
        if name.rsplit(".", 1)[-1] in _UUID_CALLS and head in ("uuid",):
            yield "D103", f"calls {name}()"
            return
        if name in ("os.getenv", "os.environ.get"):
            yield "D104", f"calls {name}()"
            return
        if name in _NP_IO:
            yield "D105", f"calls {name}()"
            return
    if isinstance(node.func, ast.Name) and node.func.id == "open":
        yield "D105", "calls open()"
        return
    if attr in _IO_METHODS:
        yield "D105", f"calls .{attr}()"


def _env_read(node: ast.AST) -> bool:
    """Bare ``os.environ`` access (subscript or attribute load)."""
    return dotted_name(node) == "os.environ"


def run_function_rules(
    src: FnSource,
    node_name: str,
    parents: Tuple[str, ...],
) -> Tuple[List[Finding], int]:
    """All D-rule findings for one node function; returns
    ``(findings, suppressed_count)``."""
    findings: List[Finding] = []
    suppressed = 0
    seen = set()  # (rule, line): os.environ.get fires call+attr matchers

    def emit(rule_id: str, detail: str, at: ast.AST) -> None:
        nonlocal suppressed
        line = src.abs_line(at)
        if (rule_id, line) in seen:
            return
        seen.add((rule_id, line))
        if src.suppressed(rule_id, line):
            suppressed += 1
            return
        rule = RULES_BY_ID[rule_id]
        findings.append(
            Finding(
                rule=rule.id,
                severity=rule.severity,
                message=f"{rule.summary.split(' — ')[0]}: {detail}",
                node=node_name,
                file=src.file,
                line=line,
                snippet=src.snippet(at),
            )
        )

    parent_set = set(parents)
    for stmt in ast.walk(src.fn_def):
        if isinstance(stmt, ast.Call):
            for rule_id, detail in _call_findings(stmt, parents):
                emit(rule_id, detail, stmt)
        elif isinstance(stmt, (ast.Global, ast.Nonlocal)):
            kw = "global" if isinstance(stmt, ast.Global) else "nonlocal"
            emit("D106", f"{kw} {', '.join(stmt.names)}", stmt)
        elif isinstance(stmt, ast.Subscript) and isinstance(
            stmt.ctx, (ast.Store, ast.Del)
        ):
            base = root_name(stmt)
            if base in parent_set:
                emit("D107", f"writes into input table {base!r}", stmt)
        elif isinstance(stmt, ast.Attribute):
            if isinstance(stmt.ctx, (ast.Store, ast.Del)):
                base = root_name(stmt)
                if base in parent_set:
                    emit("D107", f"writes attribute of input table {base!r}", stmt)
            elif _env_read(stmt):
                emit("D104", "reads os.environ", stmt)
    return findings, suppressed
