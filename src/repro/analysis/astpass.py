"""AST plumbing for the static passes — source loading, noqa, column refs.

Everything here is *read-only over source code*: ``inspect.getsource`` on
decorated node functions, ``ast.parse`` on the dedented body, and a few
structural walks.  No node function is ever called — that is the whole
point of a preflight pass.

Suppression: a finding is silenced by a ``# repro: noqa`` comment on its
line (all rules) or ``# repro: noqa[D102]`` / ``# repro: noqa[D101,D105]``
(listed rules only).  A noqa on the ``def`` line or a decorator line
suppresses the whole function.
"""
from __future__ import annotations

import ast
import inspect
import re
import textwrap
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Set, Tuple

_NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa(?:\[(?P<rules>[A-Za-z0-9_,\s]+)\])?", re.IGNORECASE
)

#: Columnar methods whose first string argument names a column of self
_COLUMN_METHODS = {"sum", "mean", "min", "max", "column", "dtype_of"}


@dataclass
class FnSource:
    """A node function's source, parsed and line-mapped back to its file."""

    file: str
    #: absolute 1-based line of the first source line (decorators included)
    start_line: int
    lines: List[str]
    tree: ast.Module
    fn_def: ast.FunctionDef
    #: absolute line -> None (suppress all) or set of rule ids to suppress
    noqa: Dict[int, Optional[Set[str]]] = field(default_factory=dict)
    #: rules suppressed for the entire function (noqa on def/decorator line)
    fn_noqa: Optional[Set[str]] = None  # None = nothing; empty set = ALL
    _fn_noqa_all: bool = False

    def abs_line(self, node: ast.AST) -> int:
        return self.start_line + getattr(node, "lineno", 1) - 1

    def snippet(self, node: ast.AST) -> str:
        rel = getattr(node, "lineno", 1) - 1
        if 0 <= rel < len(self.lines):
            return self.lines[rel].rstrip()
        return ""

    def suppressed(self, rule: str, abs_line: int) -> bool:
        if self._fn_noqa_all:
            return True
        if self.fn_noqa is not None and rule in self.fn_noqa:
            return True
        if abs_line in self.noqa:
            rules = self.noqa[abs_line]
            return rules is None or rule in rules
        return False


def _parse_noqa(line: str) -> Optional[Optional[Set[str]]]:
    """``None`` if no noqa on the line; else the suppression spec
    (``None`` = all rules, or the explicit id set)."""
    m = _NOQA_RE.search(line)
    if not m:
        return None
    rules = m.group("rules")
    if rules is None:
        return (None,)  # wrapped so "bare noqa" is distinguishable
    return ({r.strip().upper() for r in rules.split(",") if r.strip()},)


def load_fn_source(fn: Callable) -> Optional[FnSource]:
    """Source + AST for a node function; ``None`` when source is
    unavailable (REPL/builtin) — AST rules are skipped, never guessed."""
    try:
        raw_lines, start = inspect.getsourcelines(fn)
        file = inspect.getsourcefile(fn) or fn.__code__.co_filename
    except (OSError, TypeError, AttributeError):
        return None
    source = textwrap.dedent("".join(raw_lines))
    try:
        tree = ast.parse(source)
    except SyntaxError:  # pragma: no cover - getsource gave a valid fn
        return None
    fn_def = next(
        (
            n
            for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        ),
        None,
    )
    if fn_def is None:  # pragma: no cover - lambdas etc.
        return None

    src = FnSource(
        file=file,
        start_line=start,
        lines=source.splitlines(),
        tree=tree,
        fn_def=fn_def,
    )
    for i, line in enumerate(src.lines):
        spec = _parse_noqa(line)
        if spec is not None:
            src.noqa[start + i] = spec[0]
    # function-level suppression: noqa on the def line or any decorator line
    head_lines = [fn_def.lineno] + [d.lineno for d in fn_def.decorator_list]
    for rel in head_lines:
        spec = src.noqa.get(start + rel - 1)
        if start + rel - 1 in src.noqa:
            if spec is None:
                src._fn_noqa_all = True
            else:
                src.fn_noqa = (src.fn_noqa or set()) | spec
    return src


def line_suppresses(
    file: Optional[str], line: Optional[int], rule: str
) -> bool:
    """Whether a ``# repro: noqa`` on one *source line* silences ``rule``.

    The suppression surface for findings that anchor on a registration
    line rather than a function body — SQL nodes (``p.sql("x", ...)``)
    have no AST to walk, so the typed-dataflow (T) rules honor a noqa on
    the registration call's first line, with the same bare/[RULE] scoping
    the D rules use inside function bodies.
    """
    if not file or not line:
        return False
    import linecache

    text = linecache.getline(file, line)
    if not text:
        return False
    spec = _parse_noqa(text)
    if spec is None:
        return False
    rules = spec[0]
    return rules is None or rule.upper() in rules


# --------------------------------------------------------------- name walks
def dotted_name(node: ast.AST) -> Optional[str]:
    """``np.random.default_rng`` -> that string; ``None`` for non-chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def root_name(node: ast.AST) -> Optional[str]:
    """The base ``Name`` a subscript/attribute chain hangs off, if any."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _subscript_key(node: ast.Subscript) -> Optional[str]:
    sl = node.slice
    # py3.8 wraps in ast.Index; 3.9+ is the expression itself
    if sl.__class__.__name__ == "Index":  # pragma: no cover - py38 only
        sl = sl.value  # type: ignore[attr-defined]
    return _const_str(sl)


def column_references(
    src: FnSource, parents: Tuple[str, ...]
) -> Iterator[Tuple[str, str, ast.AST]]:
    """Yield ``(parent, column, ast_node)`` for every statically-visible
    column access on a parent relation inside the function body:

    * ``trips["count"]`` and ``trips.columns["count"]`` subscripts;
    * ``trips.mean("count")`` / ``.sum`` / ``.min`` / ``.max`` /
      ``.column`` — the Columnar methods whose first argument names a
      column.

    Dynamic access (variables as keys, ``select`` lists, ``getattr``)
    is deliberately invisible — the pass under-reports rather than
    false-positives.
    """
    parent_set = set(parents)
    for node in ast.walk(src.fn_def):
        if isinstance(node, ast.Subscript):
            base = node.value
            if (
                isinstance(base, ast.Attribute)
                and base.attr == "columns"
                and isinstance(base.value, ast.Name)
            ):
                base = base.value
            if isinstance(base, ast.Name) and base.id in parent_set:
                key = _subscript_key(node)
                if key is not None:
                    yield base.id, key, node
        elif isinstance(node, ast.Call):
            fn = node.func
            if (
                isinstance(fn, ast.Attribute)
                and fn.attr in _COLUMN_METHODS
                and isinstance(fn.value, ast.Name)
                and fn.value.id in parent_set
                and node.args
            ):
                key = _const_str(node.args[0])
                if key is not None:
                    yield fn.value.id, key, node
