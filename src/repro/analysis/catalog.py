"""The rule catalog, generated from the registries the engine matches.

One source of truth: the D/T/C rules are :class:`Rule` tuples in
:mod:`repro.analysis.rules` / :mod:`repro.analysis.types`, the L/G rules
live in :data:`repro.analysis.lint.GRAPH_RULES`, and the R route checks
in :data:`repro.engine.route.ROUTE_CHECKS`.  The README's "Preflight
checks" section embeds :func:`rule_catalog_markdown` output between
markers, and a test asserts the embedded text equals the generated text
— documentation cannot drift from what the analyzer actually fires.
"""
from __future__ import annotations

from typing import List

from repro.analysis.lint import GRAPH_RULES
from repro.analysis.report import Severity
from repro.analysis.rules import CONCURRENCY_RULES, FUNCTION_RULES
from repro.analysis.types import TYPE_RULES
from repro.engine.route import ROUTE_CHECKS

#: markers the README embeds the generated catalog between
CATALOG_BEGIN = "<!-- rule-catalog:begin (generated; do not edit) -->"
CATALOG_END = "<!-- rule-catalog:end -->"

#: severities the lint orchestrator assigns to graph/lineage findings
#: (lint.py emits these inline; mirrored here for the catalog only)
_GRAPH_SEVERITY = {
    "L001": Severity.ERROR,
    "L002": Severity.ERROR,
    "L003": Severity.ERROR,
    "L004": Severity.ERROR,
    "G301": Severity.WARNING,
    "G302": Severity.ERROR,
    "G303": Severity.WARNING,
    "G304": Severity.WARNING,
}


def rule_catalog_markdown() -> str:
    """The full preflight rule catalog as a markdown fragment."""
    lines: List[str] = [
        "| id | severity | checks for |",
        "|----|----------|------------|",
    ]
    for rid in sorted(GRAPH_RULES):
        lines.append(
            f"| `{rid}` | {_GRAPH_SEVERITY[rid].value} | {GRAPH_RULES[rid]} |"
        )
    for rule in FUNCTION_RULES + TYPE_RULES + CONCURRENCY_RULES:
        summary = rule.summary.replace("\n", " ")
        lines.append(f"| `{rule.id}` | {rule.severity.value} | {summary} |")
    lines += [
        "",
        "Suppress a deliberate use with `# repro: noqa[RULE]` on the "
        "offending line (D rules: inside the function body; T/C rules: "
        "on the node registration line); bare `# repro: noqa` silences "
        "every rule on that line.",
        "",
        "**Route checks** — the eligibility checks `repro explain` "
        "reports per query (`R` ids in a route trace; these explain the "
        "kernel-vs-jnp verdict rather than gate a run):",
        "",
        "| id | check | verifies |",
        "|----|-------|----------|",
    ]
    for rid in sorted(ROUTE_CHECKS):
        slug, what, _hint = ROUTE_CHECKS[rid]
        lines.append(f"| `{rid}` | {slug} | {what} |")
    return "\n".join(lines)
