"""Typed lint results — findings, severities, and the report surface.

A ``LintReport`` is the static-analysis analog of a ``RunHandle``: one
typed object carrying everything the preflight pass found, consumable by
the SDK (``client.lint``), the CLI (``repro lint [--strict] [--json]``)
and the run gate (``Client.run(..., preflight=True)``).  Findings are
data, not log lines: each one names the rule that fired, the node it
fired on, and the ``file:line`` the user has to edit.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


class Severity(str, enum.Enum):
    ERROR = "error"      # the run WILL fail (or silently corrupt) — gate it
    WARNING = "warning"  # likely footgun (cache poison, redefinition, ...)
    INFO = "info"        # diagnostics; never gates anything

    def __str__(self) -> str:
        return self.value


#: sort key: errors first, info last
_RANK = {Severity.ERROR: 0, Severity.WARNING: 1, Severity.INFO: 2}


@dataclass(frozen=True)
class Finding:
    """One rule firing at one location."""

    rule: str            # catalog id, e.g. "L001", "D102"
    severity: Severity
    message: str
    node: Optional[str] = None        # pipeline node the finding is about
    file: Optional[str] = None        # source file (decoration/definition site)
    line: Optional[int] = None        # 1-based line within ``file``
    #: the offending fragment — a source line, or the SQL slice at the
    #: parser/lineage position — so reports read without opening the file
    snippet: Optional[str] = None
    #: a concrete fix for THIS firing ("cast zone to int32"), when the
    #: rule can name one — rendered after the message, carried in JSON
    hint: Optional[str] = None

    @property
    def location(self) -> str:
        if self.file is None:
            return "<unknown>"
        return f"{self.file}:{self.line}" if self.line else self.file

    def to_json_dict(self) -> Dict[str, Any]:
        return {
            "rule": self.rule,
            "severity": self.severity.value,
            "message": self.message,
            "node": self.node,
            "file": self.file,
            "line": self.line,
            "snippet": self.snippet,
            "hint": self.hint,
        }

    def describe(self) -> str:
        loc = f"{self.location}  " if self.file else ""
        node = f"[{self.node}] " if self.node else ""
        out = f"{self.severity.value.upper():<7} {self.rule}  {loc}{node}{self.message}"
        if self.snippet:
            out += f"\n        > {self.snippet.strip()}"
        if self.hint:
            out += f"\n        fix: {self.hint}"
        return out


@dataclass
class LintReport:
    """Everything the static preflight pass found — zero execution behind it."""

    pipeline: str
    findings: List[Finding] = field(default_factory=list)
    #: node -> downstream nodes whose transitive cache fingerprint changes
    #: when the node's code is edited (the cache-invalidation blast radius)
    blast_radius: Dict[str, List[str]] = field(default_factory=dict)
    #: findings silenced by ``# repro: noqa[RULE]`` comments
    suppressed: int = 0

    def __post_init__(self) -> None:
        self.findings.sort(
            key=lambda f: (_RANK[f.severity], f.file or "", f.line or 0, f.rule)
        )

    # -------------------------------------------------------------- status
    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity is Severity.ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity is Severity.WARNING]

    def ok(self, *, strict: bool = False) -> bool:
        """Clean enough to launch?  ``strict`` also counts warnings."""
        if self.errors:
            return False
        return not (strict and self.warnings)

    def by_rule(self, rule: str) -> List[Finding]:
        return [f for f in self.findings if f.rule == rule]

    # ------------------------------------------------------------ rendering
    def describe(self) -> str:
        lines = [
            f"lint report for {self.pipeline!r}: "
            f"{len(self.errors)} error(s), {len(self.warnings)} warning(s)"
            + (f", {self.suppressed} suppressed" if self.suppressed else "")
        ]
        for f in self.findings:
            lines.append("  " + f.describe().replace("\n", "\n  "))
        if self.blast_radius:
            lines.append("  cache blast radius (edit -> recompute):")
            for name, downstream in self.blast_radius.items():
                lines.append(
                    f"    {name}: {len(downstream)} downstream node(s)"
                    + (f" {downstream}" if downstream else "")
                )
        return "\n".join(lines)

    def to_json_dict(self) -> Dict[str, Any]:
        return {
            "pipeline": self.pipeline,
            "errors": len(self.errors),
            "warnings": len(self.warnings),
            "suppressed": self.suppressed,
            "findings": [f.to_json_dict() for f in self.findings],
            "blast_radius": {k: list(v) for k, v in self.blast_radius.items()},
        }


class LintFailed(RuntimeError):
    """Raised when ``Client.run(..., preflight=True)`` refuses to launch.

    Carries the full ``LintReport`` so callers can render the findings
    (the CLI prints them; tests assert on them) without re-linting.
    """

    def __init__(self, report: LintReport):
        blocking = report.errors
        super().__init__(
            f"preflight found {len(blocking)} error(s) in "
            f"{report.pipeline!r} — run refused: "
            + "; ".join(f"{f.rule} {f.message}" for f in blocking[:3])
            + (" ..." if len(blocking) > 3 else "")
        )
        self.report = report
