"""Static pipeline analysis — lineage, cache-poison rules, plan diagnostics.

Everything in this package runs with zero execution and zero store
writes: the inputs are a resolved :class:`~repro.core.pipeline.Pipeline`
and (optionally) catalog schemas plus already-loaded snapshot metadata;
the outputs are a typed :class:`LintReport` and — for the explain plane
— :class:`ExplainedQuery` / :class:`PipelineExplanation`.
"""
from repro.analysis.catalog import rule_catalog_markdown
from repro.analysis.explain import (
    ExplainedNode,
    ExplainedQuery,
    PipelineExplanation,
    explain_pipeline,
    explain_query,
)
from repro.analysis.lint import GRAPH_RULES, lint_pipeline
from repro.analysis.report import Finding, LintFailed, LintReport, Severity
from repro.analysis.rules import (
    CONCURRENCY_RULES,
    FUNCTION_RULES,
    RULES_BY_ID,
    Rule,
    run_concurrency_rules,
)
from repro.analysis.types import TYPE_RULES, query_type_findings

__all__ = [
    "CONCURRENCY_RULES",
    "ExplainedNode",
    "ExplainedQuery",
    "Finding",
    "FUNCTION_RULES",
    "GRAPH_RULES",
    "LintFailed",
    "LintReport",
    "PipelineExplanation",
    "Rule",
    "RULES_BY_ID",
    "Severity",
    "TYPE_RULES",
    "explain_pipeline",
    "explain_query",
    "lint_pipeline",
    "query_type_findings",
    "rule_catalog_markdown",
    "run_concurrency_rules",
]
