"""Static pipeline analysis — lineage, cache-poison rules, plan diagnostics.

Everything in this package runs with zero execution and zero store
writes: the inputs are a resolved :class:`~repro.core.pipeline.Pipeline`
and (optionally) catalog schemas; the output is a typed
:class:`LintReport`.
"""
from repro.analysis.lint import GRAPH_RULES, lint_pipeline
from repro.analysis.report import Finding, LintFailed, LintReport, Severity
from repro.analysis.rules import FUNCTION_RULES, RULES_BY_ID, Rule

__all__ = [
    "Finding",
    "FUNCTION_RULES",
    "GRAPH_RULES",
    "LintFailed",
    "LintReport",
    "Rule",
    "RULES_BY_ID",
    "Severity",
    "lint_pipeline",
]
