"""The preflight orchestrator — DAG diagnostics + the three static passes.

``lint_pipeline`` is the single entry point the SDK, CLI, and run gate
all call.  It walks the resolved pipeline exactly once:

1. graph diagnostics — cycles (``G302``), unreachable nodes (``G303``),
   unknown source tables (``L004``), orphan expectations (``G301``),
   silent node redefinitions surfaced by ``api/project.py`` (``G304``);
2. topological schema propagation + the lineage checks (``L001``-``L003``)
   from :mod:`repro.analysis.lineage`;
3. the cache-poison AST rules (``D101``-``D107``) from
   :mod:`repro.analysis.rules` over every decorated function body;
4. the typed-dataflow rules (``T401``-``T404``) from
   :mod:`repro.analysis.types` over every SQL node — join-key dtypes,
   2^24 f32-exactness (when shard stats are supplied), LEFT-JOIN
   zero-fill widening;
5. the concurrency-hazard rules (``C501``-``C503``) over the whole DAG —
   lake-table shadowing and shared-global traffic between co-schedulable
   nodes;
6. the cache-invalidation blast radius, computed by perturbing one
   node's fingerprint at a time through
   :func:`repro.core.physical.fingerprint_blast_radius`.

Nothing here executes a node or touches an object store — the only
inputs are the pipeline object and (optionally) catalog schemas plus
already-loaded snapshot metadata.
"""
from __future__ import annotations

from types import SimpleNamespace
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.analysis.lineage import (
    Unknown,
    check_python_node,
    check_sql_node,
    propagate_schema,
)
from repro.analysis.report import Finding, LintReport, Severity
from repro.analysis.rules import run_concurrency_rules, run_function_rules
from repro.analysis.types import check_node_types
from repro.analysis.astpass import load_fn_source
from repro.core.pipeline import Node, Pipeline
from repro.table.schema import Schema

#: graph-diagnostic rules (kept next to the D-rule catalog for the README)
GRAPH_RULES = {
    "L001": "referenced column missing from the input schema",
    "L002": "GROUP BY key dtype the engine cannot group on",
    "L003": "ORDER BY column absent from the node's outputs",
    "L004": "source table neither produced by the pipeline nor in the catalog",
    "G301": "expectation audits no pipeline-produced artifact",
    "G302": "dependency cycle",
    "G303": "node unreachable from any external source (cycle fallout)",
    "G304": "node name silently redefined at registration time",
}


def _node_loc(node: Node) -> Tuple[Optional[str], Optional[int]]:
    return getattr(node, "source_file", None), getattr(node, "source_line", None)


def _toposort(pipeline: Pipeline) -> Tuple[List[str], List[Finding]]:
    """Kahn's algorithm tolerant of cycles: returns the sortable prefix
    plus G302/G303 findings for whatever could not be ordered."""
    findings: List[Finding] = []
    names = set(pipeline.nodes)
    indeg = {
        n: sum(1 for p in node.parents if p in names)
        for n, node in pipeline.nodes.items()
    }
    ready = sorted(n for n, d in indeg.items() if d == 0)
    order: List[str] = []
    while ready:
        n = ready.pop(0)
        order.append(n)
        for child, cnode in pipeline.nodes.items():
            if n in cnode.parents:
                indeg[child] -= 1
                if indeg[child] == 0:
                    ready.append(child)
        ready.sort()
    stuck = sorted(names - set(order))
    if stuck:
        # walk one actual cycle for the message: follow in-pipeline parents
        # through stuck nodes until a repeat
        chain = [stuck[0]]
        seen = {stuck[0]}
        while True:
            nxt = next(
                (
                    p
                    for p in pipeline.nodes[chain[-1]].parents
                    if p in stuck
                ),
                None,
            )
            if nxt is None or nxt in seen:
                if nxt is not None:
                    chain.append(nxt)
                break
            chain.append(nxt)
            seen.add(nxt)
        cycle_members = set(chain)
        loc_bits = []
        for member in chain:
            f, ln = _node_loc(pipeline.nodes[member])
            loc_bits.append(f"{member} ({f}:{ln})" if f else member)
        file, line = _node_loc(pipeline.nodes[chain[0]])
        findings.append(
            Finding(
                rule="G302",
                severity=Severity.ERROR,
                message="dependency cycle: " + " -> ".join(reversed(loc_bits)),
                node=chain[0],
                file=file,
                line=line,
            )
        )
        for n in stuck:
            if n in cycle_members:
                continue
            file, line = _node_loc(pipeline.nodes[n])
            findings.append(
                Finding(
                    rule="G303",
                    severity=Severity.WARNING,
                    message=(
                        f"node {n!r} is unreachable — it sits behind the "
                        "cycle and can never be scheduled"
                    ),
                    node=n,
                    file=file,
                    line=line,
                )
            )
    return order, findings


def _blast_radius(
    pipeline: Pipeline, order: List[str]
) -> Dict[str, List[str]]:
    """node -> downstream nodes whose transitive fingerprint changes when
    the node's code is edited.  Pure fingerprint arithmetic — no I/O."""
    from repro.core.physical import fingerprint_blast_radius

    if not order or len(order) != len(pipeline.nodes):
        return {}  # cyclic graphs have no meaningful radius
    logical = SimpleNamespace(order=order, nodes=pipeline.nodes)
    externals = pipeline.external_sources()
    dummy_inputs = {t: f"lint:{t}" for t in externals}
    try:
        return fingerprint_blast_radius(logical, dummy_inputs, {})
    except Exception:  # diagnostics must never take the lint pass down
        return {}


def lint_pipeline(
    pipeline: Pipeline,
    *,
    external_schemas: Optional[Dict[str, Optional[Schema]]] = None,
    external_snapshots: Optional[Dict[str, Any]] = None,
    catalog_tables: Optional[Set[str]] = None,
) -> LintReport:
    """Run all static passes over ``pipeline``; executes nothing.

    ``external_schemas`` maps catalog table name -> :class:`Schema` for
    tables the pipeline reads from outside itself.  When the dict is
    provided (the SDK/CLI always provide it), a source table missing
    from both the pipeline and the dict is an ``L004`` error; when it is
    ``None`` (bare API use, no catalog at hand), table existence and all
    schema-dependent checks are skipped rather than guessed.

    ``external_snapshots`` (table -> Snapshot, already loaded — nothing
    is fetched here) feeds shard statistics to the stats-grounded typed
    checks (T403); ``catalog_tables`` (names at the lint branch head)
    powers the lake-table shadowing check (C501).  Both optional — bare
    callers lose those rules, not the pass.
    """
    findings: List[Finding] = []
    suppressed = 0

    order, graph_findings = _toposort(pipeline)
    findings.extend(graph_findings)

    # ---- table universe / L004 -----------------------------------------
    produced = set(pipeline.nodes)
    schemas: Dict[str, Optional[Schema]] = {}
    if external_schemas is not None:
        schemas.update(external_schemas)
    for node in pipeline.nodes.values():
        for parent in node.parents:
            if parent in produced or parent in schemas:
                continue
            if external_schemas is None:
                schemas[parent] = Unknown  # unknown, but not an error
                continue
            file, line = _node_loc(node)
            findings.append(
                Finding(
                    rule="L004",
                    severity=Severity.ERROR,
                    message=(
                        f"table {parent!r} is not produced by the pipeline "
                        "and does not exist in the catalog"
                    ),
                    node=node.name,
                    file=file,
                    line=line,
                )
            )
            schemas[parent] = Unknown  # report once per table

    # ---- orphan expectations / G301 ------------------------------------
    for name in pipeline.expectations:
        node = pipeline.nodes[name]
        if not any(p in produced for p in node.parents):
            file, line = _node_loc(node)
            findings.append(
                Finding(
                    rule="G301",
                    severity=Severity.WARNING,
                    message=(
                        f"expectation {name!r} audits no pipeline-produced "
                        f"artifact (parents: {list(node.parents)})"
                    ),
                    node=name,
                    file=file,
                    line=line,
                )
            )

    # ---- redefinitions / G304 ------------------------------------------
    for name, (old_loc, new_loc) in sorted(
        getattr(pipeline, "redefinitions", {}).items()
    ):
        node = pipeline.nodes.get(name)
        file, line = _node_loc(node) if node is not None else (None, None)
        findings.append(
            Finding(
                rule="G304",
                severity=Severity.WARNING,
                message=(
                    f"node {name!r} was registered twice with different "
                    f"code — {new_loc} silently replaced {old_loc}"
                ),
                node=name,
                file=file,
                line=line,
            )
        )

    # ---- lineage + typed-dataflow + cache-poison passes, topo order ----
    for name in order:
        node = pipeline.nodes[name]
        if node.kind == "sql" and node.query is not None:
            findings.extend(check_sql_node(node, schemas))
            stats: Dict[str, Tuple[int, int]] = {}
            total_rows: Optional[int] = None
            if external_snapshots:
                from repro.engine.route import column_stats_for_query

                stats, total_rows = column_stats_for_query(
                    node.query, external_snapshots
                )
            t_findings, t_sup = check_node_types(
                node, schemas, stats=stats, total_rows=total_rows
            )
            findings.extend(t_findings)
            suppressed += t_sup
        elif node.fn is not None:
            py_findings, py_sup = check_python_node(node, schemas)
            findings.extend(py_findings)
            suppressed += py_sup
            src = load_fn_source(node.fn)
            if src is not None:
                d_findings, d_sup = run_function_rules(
                    src, node.name, node.parents
                )
                findings.extend(d_findings)
                suppressed += d_sup
        schemas[name] = propagate_schema(node, schemas)

    # ---- concurrency hazards over the whole DAG ------------------------
    c_findings, c_sup = run_concurrency_rules(
        pipeline, catalog_tables=catalog_tables
    )
    findings.extend(c_findings)
    suppressed += c_sup

    return LintReport(
        pipeline=pipeline.name,
        findings=findings,
        blast_radius=_blast_radius(pipeline, order),
        suppressed=suppressed,
    )
