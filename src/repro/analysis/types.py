"""Typed dataflow verification over SQL plans (T-rules).

Static dtype inference over the full ``engine/expr.py`` algebra already
exists in :mod:`repro.analysis.lineage` (``expr_dtype`` / ``_agg_dtype``
/ schema propagation).  This module turns that inference into *verdicts*
— the dtype behaviors that today surface as runtime TypeErrors or silent
numeric surprises, flagged before anything executes:

* ``T401`` a JOIN key whose dtype the gather cannot probe —
  ``engine/exec._first_match_gather`` requires integer/bool keys on both
  sides, so a float key dies with a TypeError mid-run;
* ``T402`` JOIN keys of differing integer dtypes — legal, but both sides
  are implicitly widened to int32 in the probe, which is worth seeing;
* ``T403`` an aggregation whose *provable* value bounds cross the 2^24
  f32-exactness boundary (shard stats x row count) — auto routing will
  refuse the fused kernel, and a forced kernel may drift in the last
  ulp;
* ``T404`` a GROUP BY key or aggregated column sourced from a LEFT JOIN
  table — unmatched left rows zero-fill it, so the group domain grows a
  synthetic 0 and sums silently include zero contributions.

Suppression: SQL nodes have no function body, so a
``# repro: noqa[T401]`` on the registration line (the ``p.sql(...)``
call) silences the rule for that node — same bare/[RULE] scoping as the
D rules (:func:`repro.analysis.astpass.line_suppresses`).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.astpass import line_suppresses
from repro.analysis.lineage import Unknown, combined_input_schema
from repro.analysis.report import Finding, Severity
from repro.analysis.rules import Rule
from repro.core.pipeline import Node
from repro.engine.query import Query
from repro.engine.route import EXACT_BOUND
from repro.engine.sql import find_token
from repro.table.schema import Schema

TYPE_RULES: Tuple[Rule, ...] = (
    Rule(
        "T401", Severity.ERROR,
        "join-key type incompatibility — the first-match gather probes "
        "integer/bool keys only; a float key is a runtime TypeError",
        "... JOIN zones AS z ON t.score = z.zone_id  -- score is float32",
    ),
    Rule(
        "T402", Severity.INFO,
        "join-key dtype mismatch — both sides are implicitly widened to "
        "int32 in the join probe",
        "... ON t.zone_i8 = z.zone_id  -- int8 vs int32",
    ),
    Rule(
        "T403", Severity.WARNING,
        "aggregate crosses the 2^24 f32-exactness boundary — provable "
        "from shard stats x row count; auto routing refuses the kernel "
        "and a forced kernel may drift in the last ulp",
        "SELECT SUM(big_values) ... over 2^20 rows",
    ),
    Rule(
        "T404", Severity.WARNING,
        "LEFT JOIN zero-fill widening — a grouped/aggregated column from "
        "the left-joined table gains synthetic zeros for unmatched rows",
        "SELECT z.borough, SUM(z.weight) ... LEFT JOIN zones AS z ...",
    ),
)

TYPE_RULES_BY_ID = {r.id: r for r in TYPE_RULES}


def _ref_dtype(schema: Schema, ref: str) -> Optional[np.dtype]:
    return schema.dtype_of(ref) if schema.has(ref) else None


def _sql_loc(query: Query, token: str) -> Tuple[Optional[str], str]:
    """(position note, fragment) for ``token`` in the node's raw SQL."""
    raw = query.raw_sql
    pos = find_token(raw, token)
    if pos is None:
        return None, ""
    line = raw.count("\n", 0, pos) + 1
    frag = raw[max(0, pos - 20):pos + len(token) + 20].replace("\n", " ")
    return f"sql line {line}, pos {pos}", f"... {frag.strip()} ..."


def query_type_findings(
    query: Query,
    input_schemas: Dict[str, Optional[Schema]],
    *,
    stats: Optional[Dict[str, Tuple[int, int]]] = None,
    total_rows: Optional[int] = None,
    node: Optional[str] = None,
    file: Optional[str] = None,
    line: Optional[int] = None,
) -> Tuple[List[Finding], int]:
    """All T-rule findings for one query; ``(findings, suppressed)``.

    ``stats``/``total_rows`` are the same folded shard statistics the
    router sees (``column_stats_for_query``) — when absent (bare lint
    with schemas only, or node-sourced inputs), the stats-grounded T403
    simply cannot fire; the pass under-reports rather than guesses.
    """
    findings: List[Finding] = []
    suppressed = 0

    def emit(rule_id: str, message: str, token: str, hint: str) -> None:
        nonlocal suppressed
        if line_suppresses(file, line, rule_id):
            suppressed += 1
            return
        rule = TYPE_RULES_BY_ID[rule_id]
        pos, frag = _sql_loc(query, token)
        if pos:
            message = f"{message} ({pos})"
        findings.append(
            Finding(
                rule=rule.id,
                severity=rule.severity,
                message=message,
                node=node,
                file=file,
                line=line,
                snippet=frag or None,
                hint=hint,
            )
        )

    schema, _display = combined_input_schema(query, input_schemas)
    if schema is Unknown:
        return findings, suppressed

    # ------------------------------------------------ T401/T402: join keys
    for j in query.joins:
        ldt = _ref_dtype(schema, j.left_on)
        rdt = _ref_dtype(schema, j.right_on)
        if ldt is None or rdt is None:
            continue  # missing columns are L001 territory
        bad = [
            (ref, dt)
            for ref, dt in ((j.left_on, ldt), (j.right_on, rdt))
            if dt.kind not in ("i", "u", "b")
        ]
        if bad:
            ref, dt = bad[0]
            emit(
                "T401",
                f"join key {ref!r} has dtype {dt} — the first-match "
                "gather probes integer/bool keys only (runtime TypeError "
                "in ON "
                f"{j.left_on} = {j.right_on})",
                ref,
                hint=f"cast {ref!r} to int32 upstream (or join on an "
                "integer surrogate key)",
            )
        elif ldt != rdt:
            emit(
                "T402",
                f"join keys {j.left_on!r} ({ldt}) and {j.right_on!r} "
                f"({rdt}) differ — both sides are widened to int32 in "
                "the join probe",
                j.left_on,
                hint="store both keys as int32 to make the comparison "
                "explicit",
            )

    # ------------------------------- T403: 2^24 f32-exactness boundary
    if query.is_aggregation and stats:
        if total_rows is not None and total_rows >= EXACT_BOUND:
            emit(
                "T403",
                f"{total_rows} rows >= 2^24 — f32 counts are no longer "
                "exact integers; auto routing refuses the fused kernel",
                query.source,
                hint="shard the aggregation (pre-aggregate per partition) "
                "or stay on the jnp path",
            )
        for a in query.aggregates:
            if a.fn not in ("sum", "mean") or a.expr is None or a.expr.op != "col":
                continue
            vcol = a.expr.args[0]
            if vcol not in stats or total_rows is None:
                continue
            vmin, vmax = stats[vcol]
            bound = max(abs(vmin), abs(vmax)) * max(total_rows, 1)
            if bound >= EXACT_BOUND:
                emit(
                    "T403",
                    f"aggregate {a.name!r} over {vcol!r}: worst-case sum "
                    f"max(|{vmin}|, |{vmax}|) * {total_rows} rows = "
                    f"{bound} >= 2^24 — exact f32 accumulation is not "
                    "provable; auto routing refuses the fused kernel",
                    vcol,
                    hint=f"narrow {vcol!r}'s value range (or accept the "
                    "jnp path; engine='kernel' would drift in the last ulp)",
                )

    # --------------------------- T404: LEFT JOIN zero-fill widening
    left_joins = [j for j in query.joins if j.how == "left"]
    if left_joins and query.is_aggregation:
        # a plain name is attributed to a left-join table only when that
        # table uniquely owns it — mirroring the combined relation
        owners: Dict[str, List[str]] = {}
        for qual, table in query.qualifiers():
            s = input_schemas.get(table, Unknown)
            if s is Unknown:
                continue
            for n in s.names:
                owners.setdefault(n, []).append(qual)
        left_quals = {j.qualifier: j.table for j in left_joins}

        def from_left(ref: str) -> Optional[str]:
            if "." in ref:
                qual = ref.split(".", 1)[0]
                return left_quals.get(qual)
            own = owners.get(ref, [])
            if len(own) == 1 and own[0] in left_quals:
                return left_quals[own[0]]
            return None

        for k in query.group_keys:
            table = from_left(k)
            if table is not None:
                emit(
                    "T404",
                    f"GROUP BY key {k!r} comes from LEFT JOIN table "
                    f"{table!r} — unmatched rows zero-fill it, widening "
                    "the group domain with a synthetic 0 group",
                    k,
                    hint="use an INNER JOIN to drop unmatched rows, or "
                    "account for the 0 group downstream",
                )
        for a in query.aggregates:
            if a.expr is None or a.expr.op != "col":
                continue
            vcol = a.expr.args[0]
            table = from_left(vcol)
            if table is not None:
                emit(
                    "T404",
                    f"aggregate {a.name!r} reads {vcol!r} from LEFT JOIN "
                    f"table {table!r} — unmatched rows contribute "
                    "zero-filled values to the aggregate",
                    vcol,
                    hint="use an INNER JOIN, or COUNT matches explicitly "
                    "to separate real zeros from fill",
                )
    return findings, suppressed


def check_node_types(
    node: Node,
    input_schemas: Dict[str, Optional[Schema]],
    *,
    stats: Optional[Dict[str, Tuple[int, int]]] = None,
    total_rows: Optional[int] = None,
) -> Tuple[List[Finding], int]:
    """T-rules for one SQL pipeline node (lint entry point)."""
    if node.kind != "sql" or node.query is None:
        return [], 0
    return query_type_findings(
        node.query,
        input_schemas,
        stats=stats,
        total_rows=total_rows,
        node=node.name,
        file=node.source_file,
        line=node.source_line,
    )
