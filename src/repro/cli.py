"""The CLI — the paper's primary interaction surface (4.6).

Mirrors the two core commands plus the git-like helpers:

  python -m repro.cli --lake /path/to/lake query -q "SELECT ..." [-b branch]
  python -m repro.cli --lake ... run pipeline_module.py [-b branch]
                                      [--no-fusion] [--run-id N --replay]
  python -m repro.cli --lake ... branch [--create NAME] [--from BASE]
  python -m repro.cli --lake ... log [-b branch]
  python -m repro.cli --lake ... tables [-b branch]

plus the lakekeeper maintenance verbs (repro.maintenance):

  python -m repro.cli --lake ... gc [--dry-run] [--history N] [--grace S]
  python -m repro.cli --lake ... compact [TABLE] [-b branch]
                                      [--target-rows N] [--dry-run]
  python -m repro.cli --lake ... cache {prune,stats}
                                      [--max-bytes N] [--ttl S] [--dry-run]

A pipeline module is a plain Python file defining ``PIPELINE`` (a
``repro.core.Pipeline``) — the paper's "code in the IDE of choice".
"""
from __future__ import annotations

import argparse
import importlib.util
import sys
from pathlib import Path

import numpy as np

from repro.catalog import Catalog
from repro.core import ExpectationFailed, Pipeline, Runner
from repro.io import ObjectStore
from repro.runtime import ServerlessExecutor
from repro.table import TableFormat


def _load_pipeline(path: str) -> Pipeline:
    spec = importlib.util.spec_from_file_location("user_pipeline", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)  # type: ignore[union-attr]
    pipeline = getattr(mod, "PIPELINE", None)
    if not isinstance(pipeline, Pipeline):
        raise SystemExit(f"{path} must define PIPELINE = repro.core.Pipeline(...)")
    return pipeline


def _print_table(rows: dict, *, limit: int = 20) -> None:
    names = list(rows)
    if not names:
        print("(empty)")
        return
    n = len(rows[names[0]])
    widths = {c: max(len(c), 12) for c in names}
    print(" | ".join(c.ljust(widths[c]) for c in names))
    print("-+-".join("-" * widths[c] for c in names))
    for i in range(min(n, limit)):
        print(" | ".join(str(rows[c][i]).ljust(widths[c]) for c in names))
    if n > limit:
        print(f"... ({n - limit} more rows)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="repro.cli")
    ap.add_argument("--lake", required=True, help="lake root directory")
    sub = ap.add_subparsers(dest="cmd", required=True)

    q = sub.add_parser("query", help="synchronous SQL against an artifact")
    q.add_argument("-q", "--sql", required=True)
    q.add_argument("-b", "--branch", default=None)
    q.add_argument("--commit", default=None, help="time travel to a commit")

    r = sub.add_parser("run", help="execute a pipeline (transform-audit-write)")
    r.add_argument("pipeline", help="python file defining PIPELINE")
    r.add_argument("-b", "--branch", default="main")
    r.add_argument("--no-fusion", action="store_true")
    r.add_argument("--replay", action="store_true")
    r.add_argument("--run-id", type=int, default=None)
    r.add_argument(
        "--cache",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="plan around the node-granular differential cache: unchanged "
        "logical nodes restore from the object store or are elided "
        "entirely, whatever the fusion config (this is the default — the "
        "fast path is the default path; --no-cache forces a full "
        "recompute and persists nothing)",
    )

    b = sub.add_parser("branch", help="list/create branches")
    b.add_argument("--create", default=None)
    b.add_argument("--from", dest="from_branch", default=None)

    lg = sub.add_parser("log", help="commit log")
    lg.add_argument("-b", "--branch", default="main")

    t = sub.add_parser("tables", help="tables at a branch head")
    t.add_argument("-b", "--branch", default="main")

    g = sub.add_parser("gc", help="mark-and-sweep unreachable objects")
    g.add_argument("--dry-run", action="store_true",
                   help="report reclaimable garbage without deleting")
    g.add_argument("--history", type=int, default=None,
                   help="keep only the last N commits per branch "
                   "(snapshot expiry; default keeps all history)")
    g.add_argument("--grace", type=float, default=900.0, metavar="S",
                   help="never sweep objects younger than S seconds "
                   "(protects in-flight runs; default 900)")
    g.add_argument("--pin-ttl", type=float, default=86400.0, metavar="S",
                   help="ignore run pins older than S seconds "
                   "(leaked by crashed runs; default 1 day)")

    co = sub.add_parser("compact", help="merge small shards into larger ones")
    co.add_argument("table", nargs="?", default=None,
                    help="table to compact (default: every table)")
    co.add_argument("-b", "--branch", default="main")
    co.add_argument("--target-rows", type=int, default=None,
                    help="rows per output shard (default: format shard_rows)")
    co.add_argument("--min-fill", type=float, default=0.5,
                    help="shards below min_fill*target are merge candidates")
    co.add_argument("--dry-run", action="store_true")

    ca = sub.add_parser("cache", help="differential-cache maintenance")
    ca_sub = ca.add_subparsers(dest="cache_cmd", required=True)
    cp = ca_sub.add_parser("prune", help="evict entries by LRU/TTL policy")
    cp.add_argument("--max-bytes", type=int, default=None,
                    help="byte budget for summed entry output_bytes")
    cp.add_argument("--ttl", type=float, default=None, metavar="S",
                    help="evict entries not used for S seconds")
    cp.add_argument("--dry-run", action="store_true")
    ca_sub.add_parser("stats", help="registry size and entry listing")

    args = ap.parse_args(argv)
    store = ObjectStore(Path(args.lake))
    catalog = Catalog(store)
    fmt = TableFormat(store)

    if args.cmd == "branch":
        if args.create:
            catalog.create_branch(args.create, from_branch=args.from_branch)
            print(f"created branch {args.create!r}")
        for name in catalog.branches():
            print(name)
        return

    if args.cmd == "log":
        for c in catalog.log(args.branch):
            print(f"{c.commit_id[:12]}  {c.author:<8} {c.message}")
        return

    if args.cmd == "tables":
        for name, key in sorted(catalog.tables(branch=args.branch).items()):
            snap = fmt.load_snapshot(key)
            print(f"{name:<32} {snap.num_rows:>10} rows  {key[:12]}")
        return

    if args.cmd == "gc":
        from repro.maintenance import collect_garbage

        if args.history is not None and args.history < 1:
            raise SystemExit(
                f"--history must be >= 1 (got {args.history}): history=N "
                "keeps the last N commits per branch, 0 would keep nothing"
            )
        report = collect_garbage(
            store, catalog, fmt,
            history=args.history, grace_s=args.grace,
            pin_ttl_s=args.pin_ttl, dry_run=args.dry_run,
        )
        print(report.describe())
        return

    if args.cmd == "compact":
        from repro.maintenance import compact_branch, compact_table

        if args.table:
            reports = [compact_table(
                catalog, fmt, args.table, branch=args.branch,
                target_rows=args.target_rows, min_fill=args.min_fill,
                dry_run=args.dry_run,
            )]
        else:
            reports = compact_branch(
                catalog, fmt, branch=args.branch,
                target_rows=args.target_rows, min_fill=args.min_fill,
                dry_run=args.dry_run,
            )
        for report in reports:
            print(report.describe())
        print(f"shards merged (lifetime): {store.stats.compact_shards_merged}")
        return

    if args.cmd == "cache":
        from repro.core import NodeCacheRegistry
        from repro.maintenance import EvictionPolicy, prune_cache

        registry = NodeCacheRegistry(store)
        if args.cache_cmd == "prune":
            report = prune_cache(
                registry,
                EvictionPolicy(max_bytes=args.max_bytes, ttl_s=args.ttl),
                dry_run=args.dry_run,
            )
            print(report.describe())
        else:  # stats
            entries = registry.entries()
            print(f"{len(entries)} entries, {registry.total_bytes()} bytes")
            for fp, e in sorted(
                entries.items(), key=lambda kv: kv[1].last_used_at
            ):
                label = e.node or ",".join(sorted({*e.outputs, *e.checks}))
                print(
                    f"{fp[:16]}  {e.kind:<8} node={label:<24} "
                    f"run={e.run_id:<4} bytes={e.output_bytes:<10} "
                    f"outputs={sorted(e.outputs)}"
                )
        return

    with ServerlessExecutor() as ex:
        runner = Runner(catalog, fmt, ex)
        if args.cmd == "query":
            out = runner.query(args.sql, branch=args.branch, commit_id=args.commit)
            _print_table(out)
            return
        # run / replay
        pipeline = _load_pipeline(args.pipeline)
        if args.replay:
            if args.run_id is None:
                raise SystemExit("--replay needs --run-id")
            res = runner.replay(pipeline, args.run_id)
            print(f"replayed run {args.run_id} as {res.run_id}: "
                  f"artifacts={sorted(res.artifacts)}")
            return
        try:
            res = runner.run(
                pipeline, branch=args.branch, fusion=not args.no_fusion,
                pushdown=not args.no_fusion, cache=args.cache,
            )
        except ExpectationFailed as e:
            raise SystemExit(f"AUDIT FAILED: {e}")
        print(f"run {res.run_id} merged to {args.branch!r} "
              f"@ {res.merged_commit[:12]}")
        print(f"artifacts: {sorted(res.artifacts)}  checks: {res.checks}")
        print(f"wall: {res.stats['wall_s']:.2f}s  io: {res.stats['io']}")
        cache = res.stats.get("cache", {})
        if cache.get("enabled"):
            total = cache["hits"] + cache["nodes_executed"]
            print(
                f"cache: {cache['hits']}/{total} nodes hit "
                f"({cache['rehydrated']} rehydrated, {cache['elided']} "
                f"elided), {cache['nodes_executed']} executed, "
                f"{cache['bytes_saved']} bytes saved"
            )


if __name__ == "__main__":
    main()
