"""The CLI — the paper's primary interaction surface (4.6).

A thin argparse skin over ``repro.Client``: every verb constructs the
platform through the SDK facade (one construction path — the CLI has no
wiring of its own).  Mirrors the two core commands plus the git-like
helpers:

  python -m repro.cli --lake /path/to/lake query -q "SELECT ..." [-b branch]
  python -m repro.cli --lake ... run pipeline_module.py [-b branch]
                                      [--no-fusion] [--run-id N --replay]
                                      [--parallelism N] [--no-cache]
                                      [--schedule critical_path|stage_id]
                                      [--streaming | --no-streaming]
                                      [--preflight]
  python -m repro.cli --lake ... lint pipeline_module.py [-b branch]
                                      [--strict] [--json PATH]
  python -m repro.cli --lake ... explain (pipeline_module.py | -q SQL)
                                      [-b branch] [--engine auto|kernel|jnp]
                                      [--json PATH]
  python -m repro.cli --lake ... branch [--create NAME] [--from BASE]
  python -m repro.cli --lake ... log [-b branch]
  python -m repro.cli --lake ... tables [-b branch]

plus the lakekeeper maintenance verbs (repro.maintenance):

  python -m repro.cli --lake ... gc [--dry-run] [--history N] [--grace S]
                                      [--runlog-ttl S]
  python -m repro.cli --lake ... compact [TABLE] [-b branch]
                                      [--target-rows N] [--dry-run]
  python -m repro.cli --lake ... cache {prune,stats}
                                      [--max-bytes N] [--ttl S] [--dry-run]

and the observability verbs (repro.telemetry):

  python -m repro.cli --lake ... trace RUN_ID [--chrome out.json]
  python -m repro.cli --lake ... events [--follow] [--run-id N] [--limit N]

A pipeline module is a plain Python file — either the decorator SDK
(``@repro.model()`` / ``@repro.expectation()`` / ``repro.sql``) or the
legacy ``PIPELINE = repro.Pipeline(...)`` global ("code in the IDE of
choice").
"""
from __future__ import annotations

import argparse

from repro.api import Client, LintFailed, RunState, resolve_pipeline
from repro.runtime import ExecutorConfig


def _print_table(rows: dict, *, limit: int = 20) -> None:
    names = list(rows)
    if not names:
        print("(empty)")
        return
    n = len(rows[names[0]])
    widths = {c: max(len(c), 12) for c in names}
    print(" | ".join(c.ljust(widths[c]) for c in names))
    print("-+-".join("-" * widths[c] for c in names))
    for i in range(min(n, limit)):
        print(" | ".join(str(rows[c][i]).ljust(widths[c]) for c in names))
    if n > limit:
        print(f"... ({n - limit} more rows)")


def _format_event(event) -> str:
    """One spool event as one log line: time, kind, run, detail fields."""
    import time as _time

    d = event.to_json_dict()
    stamp = _time.strftime("%H:%M:%S", _time.localtime(d.pop("ts", 0.0)))
    kind = d.pop("kind", "Event")
    run = d.pop("run_id", None)
    d.pop("seq", None)
    detail = " ".join(
        f"{k}={v}" for k, v in sorted(d.items()) if v not in (None, [], "")
    )
    run_s = f"run={run} " if run is not None else ""
    return f"{stamp} {kind:<20} {run_s}{detail}"


def _run_summary_json(res) -> dict:
    """The ``repro run --json`` payload (machine-readable run summary)."""
    stats = res.stats or {}
    return {
        "run_id": res.run_id,
        "state": str(res.state),
        "branch": res.branch,
        "merged_commit": res.merged_commit,
        "artifacts": dict(res.artifacts),
        "checks": dict(res.checks),
        "failed_checks": res.failed_checks,
        "wall_s": stats.get("wall_s"),
        "parallelism": stats.get("parallelism"),
        # Scheduler v2 stats: ordering mode, streaming, per-stage cost
        # estimates / critical-path ranks / admission waits, and the
        # model's predicted critical path (stage ids)
        "scheduler": stats.get("scheduler", {}),
        "stage_timings": stats.get("stage_timings", {}),
        "cache": stats.get("cache", {}),
        "io": stats.get("io", {}),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="repro.cli")
    ap.add_argument("--lake", required=True, help="lake root directory")
    sub = ap.add_subparsers(dest="cmd", required=True)

    q = sub.add_parser("query", help="synchronous SQL against an artifact")
    q.add_argument("-q", "--sql", required=True)
    q.add_argument("-b", "--branch", default=None)
    q.add_argument("--commit", default=None, help="time travel to a commit")

    r = sub.add_parser("run", help="execute a pipeline (transform-audit-write)")
    r.add_argument("pipeline", help="python file: decorator SDK or PIPELINE global")
    r.add_argument("-b", "--branch", default="main")
    r.add_argument("--no-fusion", action="store_true")
    r.add_argument("--replay", action="store_true")
    r.add_argument("--run-id", type=int, default=None)
    r.add_argument(
        "--parallelism", type=int, default=None, metavar="N",
        help="max independent stages in flight at once (wave scheduler; "
        "default: executor max_concurrent_stages). Results are "
        "byte-identical at every level — this is a throughput knob, "
        "never a semantics knob",
    )
    r.add_argument(
        "--schedule", choices=("critical_path", "stage_id"),
        default="critical_path",
        help="ready-stage dispatch order: critical_path pops the stage "
        "heading the longest cost-weighted path to a sink (cost model: "
        "persisted latency medians, bytes-scanned fallback); stage_id is "
        "the legacy ascending order. Dispatch order only — artifacts are "
        "byte-identical either way",
    )
    r.add_argument(
        "--streaming",
        action=argparse.BooleanOptionalAction,
        default=None,
        help="unblock downstream stages as soon as upstream outputs exist "
        "in memory (before artifact writes land) and drive scans through "
        "the incremental shard iterator; default: on under critical_path, "
        "off under stage_id. Audits and commits keep the stage barrier",
    )
    r.add_argument(
        "--preflight", action="store_true",
        help="lint the pipeline first and refuse to launch on any "
        "error-severity finding (repro lint, wired into run)",
    )
    r.add_argument(
        "--json", action="store_true", dest="json_out",
        help="print a machine-readable run summary (state, per-stage "
        "queue/exec/commit timings, cache hit counts, io deltas) "
        "instead of the human lines",
    )
    r.add_argument(
        "--cache",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="plan around the node-granular differential cache: unchanged "
        "logical nodes restore from the object store or are elided "
        "entirely, whatever the fusion config (this is the default — the "
        "fast path is the default path; --no-cache forces a full "
        "recompute and persists nothing)",
    )

    li = sub.add_parser(
        "lint", help="static preflight: lineage, cache-poison, diagnostics"
    )
    li.add_argument(
        "pipeline", help="python file: decorator SDK or PIPELINE global"
    )
    li.add_argument("-b", "--branch", default="main",
                    help="branch whose table schemas ground the checks")
    li.add_argument("--strict", action="store_true",
                    help="warnings also fail the lint (exit 1)")
    li.add_argument("--json", default=None, metavar="PATH",
                    help="also write the full report as JSON to PATH")

    ex = sub.add_parser(
        "explain", help="static plan explainability: scans, pushdown, "
        "kernel-vs-jnp route trace, typed checks — executes nothing"
    )
    ex.add_argument("pipeline", nargs="?", default=None,
                    help="python file: decorator SDK or PIPELINE global")
    ex.add_argument("-q", "--sql", default=None,
                    help="explain one interactive SQL query instead")
    ex.add_argument("-b", "--branch", default="main")
    ex.add_argument("--engine", default="auto",
                    choices=("auto", "kernel", "jnp"),
                    help="engine to explain the route for (matches the "
                    "query/run engine flag)")
    ex.add_argument("--json", default=None, metavar="PATH",
                    help="also write the full explanation as JSON to PATH")

    b = sub.add_parser("branch", help="list/create branches")
    b.add_argument("--create", default=None)
    b.add_argument("--from", dest="from_branch", default=None)

    lg = sub.add_parser("log", help="commit log")
    lg.add_argument("-b", "--branch", default="main")

    t = sub.add_parser("tables", help="tables at a branch head")
    t.add_argument("-b", "--branch", default="main")

    g = sub.add_parser("gc", help="mark-and-sweep unreachable objects")
    g.add_argument("--dry-run", action="store_true",
                   help="report reclaimable garbage without deleting")
    g.add_argument("--history", type=int, default=None,
                   help="keep only the last N commits per branch "
                   "(snapshot expiry; default keeps all history)")
    g.add_argument("--grace", type=float, default=900.0, metavar="S",
                   help="never sweep objects younger than S seconds "
                   "(protects in-flight runs; default 900)")
    g.add_argument("--pin-ttl", type=float, default=86400.0, metavar="S",
                   help="ignore run pins older than S seconds "
                   "(leaked by crashed runs; default 1 day)")
    g.add_argument("--latency-ttl", type=float, default=30 * 86400.0,
                   metavar="S",
                   help="drop speculation latency baselines not refreshed "
                   "for S seconds (stale code fingerprints; default 30 days)")
    g.add_argument("--runlog-ttl", type=float, default=14 * 86400.0,
                   metavar="S",
                   help="retention window for persisted run traces: traces "
                   "older than S seconds are swept — ref and blob in one "
                   "pass (default 14 days)")

    co = sub.add_parser("compact", help="merge small shards into larger ones")
    co.add_argument("table", nargs="?", default=None,
                    help="table to compact (default: every table)")
    co.add_argument("-b", "--branch", default="main")
    co.add_argument("--target-rows", type=int, default=None,
                    help="rows per output shard (default: format shard_rows)")
    co.add_argument("--min-fill", type=float, default=0.5,
                    help="shards below min_fill*target are merge candidates")
    co.add_argument("--dry-run", action="store_true")

    ca = sub.add_parser("cache", help="differential-cache maintenance")
    ca_sub = ca.add_subparsers(dest="cache_cmd", required=True)
    cp = ca_sub.add_parser("prune", help="evict entries by LRU/TTL policy")
    cp.add_argument("--max-bytes", type=int, default=None,
                    help="byte budget for summed entry output_bytes")
    cp.add_argument("--ttl", type=float, default=None, metavar="S",
                    help="evict entries not used for S seconds")
    cp.add_argument("--dry-run", action="store_true")
    ca_sub.add_parser("stats", help="registry size and entry listing")

    tr = sub.add_parser(
        "trace", help="a recorded run's trace: critical-path table, "
        "queue/exec/commit breakdown, Chrome-trace export"
    )
    tr.add_argument("run_id", type=int)
    tr.add_argument("--chrome", default=None, metavar="PATH",
                    help="also export Chrome trace-event JSON to PATH "
                    "(open in chrome://tracing or ui.perfetto.dev)")

    ev = sub.add_parser(
        "events", help="the lake's telemetry event stream (spool file)"
    )
    ev.add_argument("--follow", action="store_true",
                    help="tail the spool live (works across processes — "
                    "a run in another shell shows up here); Ctrl-C stops")
    ev.add_argument("--run-id", type=int, default=None,
                    help="only events of this run")
    ev.add_argument("--limit", type=int, default=None,
                    help="only the last N events (non-follow mode)")

    args = ap.parse_args(argv)

    # --parallelism N widens the whole fleet: N stages in flight needs at
    # least N containers for their stage functions (plus headroom for
    # speculation backups and parallel shard reads)
    executor_config = None
    parallelism = getattr(args, "parallelism", None)
    if parallelism is not None:
        if parallelism < 1:
            raise SystemExit(f"--parallelism must be >= 1 (got {parallelism})")
        executor_config = ExecutorConfig(
            max_workers=max(4, parallelism),
            max_concurrent_stages=parallelism,
        )

    with Client(args.lake, executor_config=executor_config) as client:
        if args.cmd == "branch":
            if args.create:
                client.create_branch(args.create, from_branch=args.from_branch)
                print(f"created branch {args.create!r}")
            for name in client.branches():
                print(name)
            return

        if args.cmd == "log":
            for c in client.log(args.branch):
                print(f"{c.commit_id[:12]}  {c.author:<8} {c.message}")
            return

        if args.cmd == "tables":
            for name, key in sorted(client.tables(args.branch).items()):
                snap = client.fmt.load_snapshot(key)
                print(f"{name:<32} {snap.num_rows:>10} rows  {key[:12]}")
            return

        if args.cmd == "gc":
            if args.history is not None and args.history < 1:
                raise SystemExit(
                    f"--history must be >= 1 (got {args.history}): history=N "
                    "keeps the last N commits per branch, 0 would keep nothing"
                )
            report = client.gc(
                history=args.history, grace_s=args.grace,
                pin_ttl_s=args.pin_ttl, latency_ttl_s=args.latency_ttl,
                runlog_ttl_s=args.runlog_ttl,
                dry_run=args.dry_run,
            )
            print(report.describe())
            return

        if args.cmd == "trace":
            try:
                trace = client.trace(args.run_id)
            except KeyError as e:
                raise SystemExit(str(e))
            print(trace.describe())
            if args.chrome:
                trace.write_chrome_trace(args.chrome)
                print(f"chrome trace written to {args.chrome} "
                      f"(open in chrome://tracing or ui.perfetto.dev)")
            return

        if args.cmd == "events":
            from repro.api.client import SPOOL_RELPATH
            from repro.telemetry.bus import follow_spool

            spool = client.path / SPOOL_RELPATH
            if args.follow:
                try:
                    for event in follow_spool(spool, run_id=args.run_id):
                        print(_format_event(event))
                except KeyboardInterrupt:
                    pass
            else:
                events = client.events(run_id=args.run_id)
                if args.limit:
                    events = events[-args.limit:]
                for event in events:
                    print(_format_event(event))
            return

        if args.cmd == "compact":
            reports = client.compact(
                args.table, branch=args.branch,
                target_rows=args.target_rows, min_fill=args.min_fill,
                dry_run=args.dry_run,
            )
            for report in reports:
                print(report.describe())
            print(f"shards merged (lifetime): "
                  f"{client.store.stats.compact_shards_merged}")
            return

        if args.cmd == "cache":
            if args.cache_cmd == "prune":
                report = client.cache.prune(
                    max_bytes=args.max_bytes, ttl_s=args.ttl,
                    dry_run=args.dry_run,
                )
                print(report.describe())
            else:  # stats
                stats = client.cache.stats()
                print(f"{stats['entries']} entries, "
                      f"{stats['total_bytes']} bytes")
                for fp, e in sorted(
                    stats["items"].items(), key=lambda kv: kv[1].last_used_at
                ):
                    label = e.node or ",".join(sorted({*e.outputs, *e.checks}))
                    print(
                        f"{fp[:16]}  {e.kind:<8} node={label:<24} "
                        f"run={e.run_id:<4} bytes={e.output_bytes:<10} "
                        f"outputs={sorted(e.outputs)}"
                    )
            return

        if args.cmd == "lint":
            report = client.lint(args.pipeline, branch=args.branch)
            print(report.describe())
            if args.json:
                import json

                with open(args.json, "w") as fh:
                    json.dump(report.to_json_dict(), fh, indent=2)
                print(f"json report written to {args.json}")
            if not report.ok(strict=args.strict):
                raise SystemExit(1)
            print("preflight clean — pipeline is clear to run")
            return

        if args.cmd == "explain":
            if (args.sql is None) == (args.pipeline is None):
                raise SystemExit(
                    "explain takes exactly one target: a pipeline file, "
                    "or -q SQL"
                )
            target = args.sql if args.sql is not None else args.pipeline
            explanation = client.explain(
                target, branch=args.branch, engine=args.engine
            )
            print(explanation.describe())
            if args.json:
                import json

                with open(args.json, "w") as fh:
                    json.dump(explanation.to_json_dict(), fh, indent=2)
                print(f"json explanation written to {args.json}")
            # pipeline mode gates on lint errors like `repro lint`; SQL
            # mode always exits 0 — a predicted RouteError IS the product
            if hasattr(explanation, "report") and not explanation.report.ok():
                raise SystemExit(1)
            return

        if args.cmd == "query":
            out = client.query(
                args.sql, branch=args.branch, commit_id=args.commit
            )
            _print_table(out)
            return

        # run / replay
        pipeline = resolve_pipeline(args.pipeline)
        if args.replay:
            if args.run_id is None:
                raise SystemExit("--replay needs --run-id")
            res = client.replay(args.run_id, pipeline)
            print(f"replayed run {args.run_id} as {res.run_id}: "
                  f"artifacts={sorted(res.artifacts)}")
            return
        try:
            res = client.run(
                pipeline, branch=args.branch, fusion=not args.no_fusion,
                pushdown=not args.no_fusion, cache=args.cache,
                parallelism=parallelism, preflight=args.preflight,
                schedule=args.schedule, streaming=args.streaming,
            )
        except LintFailed as e:
            print(e.report.describe())
            raise SystemExit(f"PREFLIGHT FAILED: {e}")
        if args.json_out:
            import json

            print(json.dumps(_run_summary_json(res), indent=2, default=str))
            if res.state is RunState.AUDIT_FAILED:
                raise SystemExit(2)
            return
        if res.state is RunState.AUDIT_FAILED:
            raise SystemExit(
                f"AUDIT FAILED: expectations failed: {res.failed_checks} "
                f"— run {res.run_id} rolled back"
            )
        print(f"run {res.run_id} merged to {args.branch!r} "
              f"@ {res.merged_commit[:12]}")
        print(f"artifacts: {sorted(res.artifacts)}  checks: {res.checks}")
        sched = res.stats.get("scheduler", {})
        print(f"wall: {res.stats['wall_s']:.2f}s  "
              f"parallelism: {res.stats.get('parallelism', 1)}  "
              f"io: {res.stats['io']}")
        if sched:
            print(
                f"scheduler: {sched.get('schedule')} "
                f"(streaming={'on' if sched.get('streaming') else 'off'})  "
                f"critical path: {sched.get('critical_path')}  "
                f"admission waits: {sched.get('admission_waits', 0)}"
            )
        cache = res.cache
        if cache.get("enabled"):
            total = cache["hits"] + cache["nodes_executed"]
            print(
                f"cache: {cache['hits']}/{total} nodes hit "
                f"({cache['rehydrated']} rehydrated, {cache['elided']} "
                f"elided), {cache['nodes_executed']} executed, "
                f"{cache['bytes_saved']} bytes saved"
            )


if __name__ == "__main__":
    main()
