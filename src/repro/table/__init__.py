"""TensorTable — the Iceberg-like table format (paper 4.2).

Decouples a table's *logical* identity (``taxi_table``) from its physical
storage (content-addressed shards in the object store), and gives each table
a snapshot lineage so any historical version can be read ("time travel").
Column min/max statistics per shard power scan-level predicate pushdown —
the metadata the code-intelligence layer (core/physical.py) uses to avoid
reading data it can prove away.
"""
from repro.table.schema import Column, Schema
from repro.table.format import Snapshot, ShardMeta, TableFormat, TableData
from repro.table.scan import ScanPlan, Predicate, plan_scan, execute_scan

__all__ = [
    "Column",
    "Schema",
    "Snapshot",
    "ShardMeta",
    "TableFormat",
    "TableData",
    "ScanPlan",
    "Predicate",
    "plan_scan",
    "execute_scan",
]
