"""Scan planning: column pruning + predicate pushdown over shard stats.

This is the metadata half of the paper's 4.4.2 optimization: before any
bytes move, the planner uses per-shard min/max statistics to drop shards
that cannot contain matching rows, and reads only referenced columns.
``execute_scan`` then applies the residual predicate row-wise, so downstream
fused stages see an already-small in-memory table.
"""
from __future__ import annotations

import time
from concurrent.futures import Executor
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.table.format import ShardMeta, Snapshot, TableData, TableFormat

_OPS = {"<", "<=", ">", ">=", "==", "!="}

#: default ``chunk_rows`` for kernel-bound scans: 8 of the fused kernel's
#: (8×128)-row tiles per work item — large enough to amortize pool
#: round-trips, small enough that wide fan-outs still parallelize
KERNEL_CHUNK_ROWS = 8192


@dataclass(frozen=True)
class Predicate:
    """A conjunct: ``column <op> literal``."""

    column: str
    op: str
    value: float

    def __post_init__(self) -> None:
        if self.op not in _OPS:
            raise ValueError(f"unsupported predicate op {self.op!r}")

    def to_json_dict(self) -> Dict:
        return {"column": self.column, "op": self.op, "value": self.value}

    # --- shard-level: can this shard possibly contain a matching row? ------
    def may_match(self, stats: Dict[str, Dict[str, float]]) -> bool:
        st = stats.get(self.column)
        if st is None:
            return True
        lo, hi = st["min"], st["max"]
        v = self.value
        if self.op == "<":
            return lo < v
        if self.op == "<=":
            return lo <= v
        if self.op == ">":
            return hi > v
        if self.op == ">=":
            return hi >= v
        if self.op == "==":
            return lo <= v <= hi
        return not (lo == hi == v)  # "!=": only prunable if constant shard

    # --- row-level ----------------------------------------------------------
    def mask(self, col: np.ndarray) -> np.ndarray:
        v = col.dtype.type(self.value) if col.dtype.kind in "iuf" else self.value
        if self.op == "<":
            return col < v
        if self.op == "<=":
            return col <= v
        if self.op == ">":
            return col > v
        if self.op == ">=":
            return col >= v
        if self.op == "==":
            return col == v
        return col != v


@dataclass
class ScanPlan:
    """Output of planning: which shards survive, which columns to read."""

    snapshot: Snapshot
    #: columns to READ — the requested projection plus any predicate-only
    #: columns needed for residual filtering
    columns: List[str]
    predicates: Tuple[Predicate, ...]
    shards: List[ShardMeta]
    pruned_shards: int = 0
    pruned_columns: int = 0
    #: columns to RETURN (the caller's projection); predicate-only columns
    #: are read for filtering but dropped from the result.  ``None`` means
    #: everything read is projected (pre-projection plans deserialize so).
    projection: Optional[List[str]] = None

    @property
    def output_columns(self) -> List[str]:
        return self.columns if self.projection is None else self.projection

    @property
    def rows_to_read(self) -> int:
        return sum(s.num_rows for s in self.shards)


def plan_scan(
    snapshot: Snapshot,
    *,
    columns: Optional[Sequence[str]] = None,
    predicates: Sequence[Predicate] = (),
) -> ScanPlan:
    all_cols = snapshot.schema.names
    needed = list(columns) if columns is not None else list(all_cols)
    # predicate columns must be read even if not projected
    read_cols = list(dict.fromkeys(needed + [p.column for p in predicates]))
    snapshot.schema.select(read_cols)  # validates existence
    keep: List[ShardMeta] = []
    for shard in snapshot.shards:
        if all(p.may_match(shard.column_stats) for p in predicates):
            keep.append(shard)
    return ScanPlan(
        snapshot=snapshot,
        columns=read_cols,
        predicates=tuple(predicates),
        shards=keep,
        pruned_shards=len(snapshot.shards) - len(keep),
        pruned_columns=len(all_cols) - len(read_cols),
        projection=needed,
    )


def pruning_effectiveness(
    snapshot: Snapshot, predicates: Sequence[Predicate]
) -> float:
    """Fraction of *rows* a metadata-only plan proves away for these
    predicates (0.0 = stats prune nothing, 1.0 = everything).

    Compaction (repro.maintenance.compaction) reports this before/after
    for its ``guard_predicates`` and warns when merging shards coarsened
    pruning on the table's hot predicates — fewer, bigger shards
    inherently trade per-shard pruning granularity for scan overhead.
    """
    total = snapshot.num_rows
    if total == 0:
        return 0.0
    plan = plan_scan(snapshot, predicates=predicates)
    return 1.0 - plan.rows_to_read / total


def _chunk_work_items(
    indexed: List[Tuple[int, ShardMeta]], chunk_rows: Optional[int]
) -> List[List[Tuple[int, ShardMeta]]]:
    """Batch (index, shard) pairs into pool work items, order preserved.

    ``chunk_rows`` switches from the default fixed fan-out (≤16 items) to
    greedy row-count batching: consecutive shards pack into one item
    until it carries ~``chunk_rows`` rows.  Shared by the blocking and
    the streaming scan paths so both read the exact same chunks.
    """
    if chunk_rows is not None:
        chunks, cur, cur_rows = [], [], 0
        for item in indexed:
            cur.append(item)
            cur_rows += item[1].num_rows
            if cur_rows >= chunk_rows:
                chunks.append(cur)
                cur, cur_rows = [], 0
        if cur:
            chunks.append(cur)
        return chunks
    # batch shards into at most ~16 work items: many tiny shards would
    # otherwise pay one pool round-trip each and lose to the serial read
    # (ThreadPoolExecutor.map ignores chunksize, so the batching is done
    # by hand; order is preserved either way)
    step = -(-len(indexed) // 16)  # ceil division
    return [indexed[i : i + step] for i in range(0, len(indexed), step)]


#: streaming read-ahead window: chunk reads in flight ahead of the
#: consumer.  Bounds memory to ~window × chunk bytes while still hiding
#: per-shard store latency behind downstream work.
SCAN_PREFETCH_CHUNKS = 4


def execute_scan(
    fmt: TableFormat,
    plan: ScanPlan,
    *,
    pool: Optional[Executor] = None,
    bus=None,
    tags: Optional[Dict] = None,
    chunk_rows: Optional[int] = None,
    streaming: bool = False,
) -> TableData:
    """Read surviving shards, apply the residual row-level predicate.

    Returns only the plan's *projection* — predicate-only columns are read
    for filtering and then dropped.  ``pool`` (any
    ``concurrent.futures.Executor``) parallelizes the per-shard read +
    residual filter; shard order is preserved, so the concatenated result
    is byte-identical to the serial read.

    ``chunk_rows`` switches the work-item batching from the default
    fixed fan-out (≤16 items) to greedy row-count batching: consecutive
    shards pack into one item until it holds ~``chunk_rows`` rows.  The
    interactive query path uses :data:`KERNEL_CHUNK_ROWS` so each item
    feeds the fused kernel a whole number of its (8×128) tiles.

    ``bus`` (a :class:`repro.telemetry.bus.EventBus`) gets one
    ``ScanShardRead`` per shard; ``tags`` attributes the events to a run
    (``run_id``/``stage_id``/``table``/``source``) since the scan pool
    itself has no run context.

    ``streaming=True`` drives the same chunks through the incremental
    shard iterator (:func:`iter_scan`'s machinery): a bounded read-ahead
    window of chunk reads stays in flight while earlier chunks are
    already being consumed, instead of one barrier ``pool.map`` over all
    of them.  Chunking, shard order and the final concatenation are
    identical, so the result is byte-for-byte the same either way.
    """
    parts = [
        part
        for chunk_parts in _iter_chunk_parts(
            fmt, plan, pool=pool, bus=bus, tags=tags,
            chunk_rows=chunk_rows, streaming=streaming,
        )
        for part in chunk_parts
    ]
    out_cols = plan.output_columns
    if not parts:
        return {
            c: np.empty((0,), dtype=plan.snapshot.schema.dtype_of(c))
            for c in out_cols
        }
    return {c: np.concatenate([p[c] for p in parts]) for c in out_cols}


def iter_scan(
    fmt: TableFormat,
    plan: ScanPlan,
    *,
    pool: Optional[Executor] = None,
    bus=None,
    tags: Optional[Dict] = None,
    chunk_rows: Optional[int] = None,
    prefetch: int = SCAN_PREFETCH_CHUNKS,
) -> Iterator[TableData]:
    """Incremental shard-iterator mode: yield the scan chunk by chunk.

    Each yielded ``TableData`` covers one pool work item's shards (same
    chunking as :func:`execute_scan` — concatenating every yielded chunk
    reproduces the blocking scan's arrays byte-for-byte, in shard
    order).  With a ``pool``, up to ``prefetch`` chunk reads run ahead of
    the consumer, so a downstream filter/transform starts on completed
    shards while later shards are still in flight — the streaming half
    of Scheduler v2's scan→filter overlap.
    """
    out_cols = plan.output_columns
    for chunk_parts in _iter_chunk_parts(
        fmt, plan, pool=pool, bus=bus, tags=tags,
        chunk_rows=chunk_rows, streaming=True, prefetch=prefetch,
    ):
        if chunk_parts:
            yield {
                c: np.concatenate([p[c] for p in chunk_parts])
                if len(chunk_parts) > 1
                else chunk_parts[0][c]
                for c in out_cols
            }


def _iter_chunk_parts(
    fmt: TableFormat,
    plan: ScanPlan,
    *,
    pool: Optional[Executor] = None,
    bus=None,
    tags: Optional[Dict] = None,
    chunk_rows: Optional[int] = None,
    streaming: bool = False,
    prefetch: int = SCAN_PREFETCH_CHUNKS,
) -> Iterator[List[TableData]]:
    """Yield per-chunk lists of filtered shard parts, in shard order."""
    if not plan.shards:
        return
    tags = tags or {}

    def read_one(index: int, shard: ShardMeta) -> TableData:
        t0 = time.perf_counter()
        ts = time.time()
        part = fmt.read_shard(shard, plan.columns)
        if plan.predicates:
            mask = np.ones(shard.num_rows, dtype=bool)
            for p in plan.predicates:
                mask &= p.mask(part[p.column])
            if not mask.all():
                part = {c: v[mask] for c, v in part.items()}
        if bus is not None:
            from repro.telemetry.events import ScanShardRead

            rows_out = (
                len(next(iter(part.values()))) if part else shard.num_rows
            )
            bus.publish(ScanShardRead(
                run_id=tags.get("run_id"),
                ts=ts,
                table=tags.get("table", plan.snapshot.table),
                shard_index=index,
                rows_in=shard.num_rows,
                rows_out=rows_out,
                dur_s=time.perf_counter() - t0,
                source=tags.get("source", "stage"),
                stage_id=tags.get("stage_id"),
            ))
        return part

    indexed = list(enumerate(plan.shards))
    if pool is None or len(plan.shards) <= 1:
        for i, shard in indexed:
            yield [read_one(i, shard)]
        return
    chunks = _chunk_work_items(indexed, chunk_rows)

    def read_chunk(chunk: List[Tuple[int, ShardMeta]]) -> List[TableData]:
        return [read_one(i, s) for i, s in chunk]

    if not streaming:
        # barrier path: one pool.map over every chunk (results in order)
        yield from pool.map(read_chunk, chunks)
        return
    # streaming path: keep a bounded window of chunk reads in flight and
    # yield strictly in chunk order — same chunks, same order, the only
    # difference is that the consumer overlaps with later reads
    window = max(1, prefetch)
    futures = [pool.submit(read_chunk, c) for c in chunks[:window]]
    next_submit = window
    for consumed in range(len(chunks)):
        yield futures[consumed].result()
        if next_submit < len(chunks):
            futures.append(pool.submit(read_chunk, chunks[next_submit]))
            next_submit += 1
