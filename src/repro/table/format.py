"""TensorTable physical format: shards, manifests, snapshots.

Responsibility split (mirrors Parquet vs Iceberg):

* a **shard** is one immutable columnar blob per column (content-addressed),
  plus per-column min/max stats captured at write time;
* a **manifest** lists the shards of one table version;
* a **snapshot** is (schema, manifest, lineage) — the unit the catalog
  commits.  Appends create a new snapshot sharing parent shards
  (structural sharing = cheap time travel, paper 4.2/4.3).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.io.objectstore import ObjectStore
from repro.io.serialization import array_to_bytes, bytes_to_array, dumps_json, loads_json
from repro.table.schema import Schema
from repro.utils.hashing import stable_hash

#: default rows per shard — small enough that predicate pushdown has
#: something to prune, big enough to amortize per-shard overheads.
DEFAULT_SHARD_ROWS = 65536

#: ref namespace memoizing snapshot_id -> content fingerprint (tiny JSON
#: pointers; stale ones for expired snapshots are harmless)
_CONTENT_NS = "contenthash"


@dataclass(frozen=True)
class ShardMeta:
    """Metadata for one shard: blob keys + per-column stats."""

    num_rows: int
    column_blobs: Dict[str, str]  # column name -> object-store key
    column_stats: Dict[str, Dict[str, float]]  # column name -> {min, max}

    def to_json_dict(self) -> Dict:
        return {
            "num_rows": self.num_rows,
            "column_blobs": self.column_blobs,
            "column_stats": self.column_stats,
        }

    @staticmethod
    def from_json_dict(d: Dict) -> "ShardMeta":
        return ShardMeta(d["num_rows"], d["column_blobs"], d["column_stats"])


@dataclass(frozen=True)
class Snapshot:
    """One immutable table version."""

    table: str
    snapshot_id: str
    schema: Schema
    shards: Sequence[ShardMeta]
    parent_id: Optional[str]  # lineage for time travel

    @property
    def num_rows(self) -> int:
        return sum(s.num_rows for s in self.shards)

    def to_json_dict(self) -> Dict:
        return {
            "table": self.table,
            "snapshot_id": self.snapshot_id,
            "schema": self.schema.to_json_dict(),
            "shards": [s.to_json_dict() for s in self.shards],
            "parent_id": self.parent_id,
        }

    @staticmethod
    def from_json_dict(d: Dict) -> "Snapshot":
        return Snapshot(
            table=d["table"],
            snapshot_id=d["snapshot_id"],
            schema=Schema.from_json_dict(d["schema"]),
            shards=tuple(ShardMeta.from_json_dict(s) for s in d["shards"]),
            parent_id=d.get("parent_id"),
        )


#: A fully-materialized columnar table in memory: {column: 1-D array}.
TableData = Dict[str, np.ndarray]


@dataclass
class TableFormat:
    """Reader/writer for TensorTables over an ObjectStore."""

    store: ObjectStore
    shard_rows: int = DEFAULT_SHARD_ROWS

    # ----------------------------------------------------------------- write
    def write(
        self,
        table: str,
        schema: Schema,
        data: TableData,
        *,
        parent: Optional[Snapshot] = None,
        append: bool = False,
    ) -> Snapshot:
        """Write a new snapshot. ``append=True`` keeps the parent's shards."""
        nrows = schema.validate_batch(data)
        shards: List[ShardMeta] = []
        if append and parent is not None:
            if parent.schema != schema:
                raise TypeError(
                    f"append schema mismatch for {table}: "
                    f"{schema.names} vs {parent.schema.names}"
                )
            shards.extend(parent.shards)
        for start in range(0, max(nrows, 1), self.shard_rows):
            stop = min(start + self.shard_rows, nrows)
            if stop <= start:
                break
            shards.append(self._write_shard(
                schema, {c.name: data[c.name][start:stop] for c in schema.columns}
            ))
        return self._seal_snapshot(
            table, schema, shards, parent.snapshot_id if parent else None
        )

    def _write_shard(self, schema: Schema, data: TableData) -> ShardMeta:
        """Write one shard's column blobs, capturing min/max stats."""
        blobs: Dict[str, str] = {}
        stats: Dict[str, Dict[str, float]] = {}
        nrows = 0
        for col in schema.columns:
            chunk = np.ascontiguousarray(data[col.name])
            nrows = len(chunk)
            blobs[col.name] = self.store.put(array_to_bytes(chunk))
            if chunk.size and chunk.dtype.kind in "iuf":
                stats[col.name] = {
                    "min": float(np.min(chunk)),
                    "max": float(np.max(chunk)),
                }
            else:
                stats[col.name] = {"min": float("-inf"), "max": float("inf")}
        return ShardMeta(nrows, blobs, stats)

    def _seal_snapshot(
        self,
        table: str,
        schema: Schema,
        shards: Sequence[ShardMeta],
        parent_id: Optional[str],
    ) -> Snapshot:
        snapshot_id = stable_hash(
            {
                "table": table,
                "schema": schema.to_json_dict(),
                "shards": [s.to_json_dict() for s in shards],
                "parent": parent_id,
            }
        )
        snap = Snapshot(table, snapshot_id, schema, tuple(shards), parent_id)
        # persist the snapshot manifest itself so catalogs only hold keys
        self.store.put(dumps_json(snap.to_json_dict()))
        return snap

    # ----------------------------------------------------------- compaction
    def compact_snapshot(
        self,
        snapshot: Snapshot,
        *,
        target_rows: Optional[int] = None,
        min_fill: float = 0.5,
    ) -> tuple:
        """Rewrite runs of small shards into fewer near-``target_rows`` ones.

        The mechanics half of ``repro compact`` (policy + catalog commit
        live in repro.maintenance.compaction).  Only *adjacent* shards
        merge and the merged chunk preserves row order, so a full scan of
        the new snapshot is bit-identical to the old one.  Shards already
        at least ``min_fill * target_rows`` full pass through untouched —
        structural sharing keeps compaction incremental.  Per-column
        min/max stats are recomputed from the merged data, so
        ``Predicate.may_match`` pruning stays exact.

        Returns ``(new_snapshot, shards_merged)``; ``shards_merged == 0``
        means nothing to do and ``new_snapshot is snapshot``.
        """
        groups = plan_compaction_groups(
            snapshot.shards,
            target_rows=target_rows or self.shard_rows,
            min_fill=min_fill,
        )
        out: List[ShardMeta] = []
        merged = 0
        for group in groups:
            if len(group) == 1:
                out.append(group[0])
                continue
            parts = [self.read_shard(s) for s in group]
            data = {
                c: np.concatenate([p[c] for p in parts])
                for c in snapshot.schema.names
            }
            out.append(self._write_shard(snapshot.schema, data))
            merged += len(group)
        if merged == 0:
            return snapshot, 0
        return (
            self._seal_snapshot(
                snapshot.table, snapshot.schema, out, snapshot.snapshot_id
            ),
            merged,
        )

    # ------------------------------------------------------------------ read
    def read_shard(
        self, shard: ShardMeta, columns: Optional[Sequence[str]] = None
    ) -> TableData:
        cols = columns if columns is not None else list(shard.column_blobs)
        return {c: bytes_to_array(self.store.get(shard.column_blobs[c])) for c in cols}

    def read(
        self, snapshot: Snapshot, columns: Optional[Sequence[str]] = None
    ) -> TableData:
        """Materialize (selected columns of) a snapshot into memory."""
        cols = list(columns) if columns is not None else snapshot.schema.names
        if not snapshot.shards:
            return {
                c: np.empty((0,), dtype=snapshot.schema.dtype_of(c)) for c in cols
            }
        parts = [self.read_shard(s, cols) for s in snapshot.shards]
        return {c: np.concatenate([p[c] for p in parts]) for c in cols}

    def content_fingerprint(self, snapshot: Snapshot) -> str:
        """Sharding-invariant identity of a table version.

        Streams each column's raw row-order bytes (shard boundaries
        excluded) through sha256, then hashes the per-column digests with
        the schema.  Because ``compact_snapshot`` preserves row order, a
        compacted snapshot has the SAME content fingerprint as its parent
        even though its snapshot id (which hashes shard layout) differs —
        this is what keeps the differential cache warm across ``repro
        compact``.  The result is memoized per snapshot id in the ref
        space, so only the first caller per table version pays the scan.
        """
        memo = self.store.get_ref(_CONTENT_NS, snapshot.snapshot_id)
        if memo is not None:
            return memo["content_fingerprint"]
        hashers = {c: hashlib.sha256() for c in snapshot.schema.names}
        for shard in snapshot.shards:
            data = self.read_shard(shard)
            for c in snapshot.schema.names:
                hashers[c].update(np.ascontiguousarray(data[c]).tobytes())
        fp = stable_hash(
            {
                "table": snapshot.table,
                "schema": snapshot.schema.to_json_dict(),
                "columns": {c: h.hexdigest() for c, h in hashers.items()},
            }
        )
        self.store.set_ref(
            _CONTENT_NS, snapshot.snapshot_id, {"content_fingerprint": fp}
        )
        return fp

    def prune_content_fingerprints(
        self, live_snapshot_ids: set, *, dry_run: bool = False
    ) -> int:
        """Drop content-fingerprint memo refs whose snapshot is no longer
        live (``repro gc`` calls this after the mark) — without it every
        expired table version would leak one tiny ref forever.  Returns
        the number of refs pruned; a dropped memo is only a cache miss,
        the fingerprint recomputes on next use."""
        pruned = 0
        for snapshot_id in self.store.list_refs(_CONTENT_NS):
            if snapshot_id in live_snapshot_ids:
                continue
            pruned += 1
            if not dry_run:
                self.store.delete_ref(_CONTENT_NS, snapshot_id)
        return pruned

    def load_snapshot(self, manifest_key: str) -> Snapshot:
        return Snapshot.from_json_dict(loads_json(self.store.get(manifest_key)))

    def snapshot_object_keys(self, manifest_key: str) -> set:
        """The manifest blob itself plus every column blob it references —
        one table version's contribution to the GC live set.  A missing
        manifest yields the empty set (tolerates a crashed prior sweep)."""
        if not self.store.exists(manifest_key):
            return set()
        snap = self.load_snapshot(manifest_key)
        keys = {manifest_key}
        for shard in snap.shards:
            keys.update(shard.column_blobs.values())
        return keys

    def manifest_key(self, snapshot: Snapshot) -> str:
        """Content address of a snapshot manifest (what catalogs store)."""
        return self.store.put(dumps_json(snapshot.to_json_dict()))


def plan_compaction_groups(
    shards: Sequence[ShardMeta],
    *,
    target_rows: int,
    min_fill: float = 0.5,
) -> List[List[ShardMeta]]:
    """Greedy, order-preserving grouping: consecutive *small* shards
    (< ``min_fill * target_rows`` rows) pack together until adding the
    next would exceed ``target_rows``.  Each returned group becomes one
    output shard; singleton groups pass through without a rewrite.  Pure
    metadata — used both by the writer and by ``repro compact --dry-run``.
    """
    small_cutoff = max(1, int(min_fill * target_rows))
    groups: List[List[ShardMeta]] = []
    buffer: List[ShardMeta] = []
    buffered_rows = 0

    def flush() -> None:
        nonlocal buffered_rows
        if buffer:
            groups.append(list(buffer))
            buffer.clear()
        buffered_rows = 0

    for shard in shards:
        if shard.num_rows < small_cutoff:
            if buffer and buffered_rows + shard.num_rows > target_rows:
                flush()
            buffer.append(shard)
            buffered_rows += shard.num_rows
        else:
            flush()
            groups.append([shard])
    flush()
    return groups
