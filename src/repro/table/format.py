"""TensorTable physical format: shards, manifests, snapshots.

Responsibility split (mirrors Parquet vs Iceberg):

* a **shard** is one immutable columnar blob per column (content-addressed),
  plus per-column min/max stats captured at write time;
* a **manifest** lists the shards of one table version;
* a **snapshot** is (schema, manifest, lineage) — the unit the catalog
  commits.  Appends create a new snapshot sharing parent shards
  (structural sharing = cheap time travel, paper 4.2/4.3).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.io.objectstore import ObjectStore
from repro.io.serialization import array_to_bytes, bytes_to_array, dumps_json, loads_json
from repro.table.schema import Schema
from repro.utils.hashing import stable_hash

#: default rows per shard — small enough that predicate pushdown has
#: something to prune, big enough to amortize per-shard overheads.
DEFAULT_SHARD_ROWS = 65536


@dataclass(frozen=True)
class ShardMeta:
    """Metadata for one shard: blob keys + per-column stats."""

    num_rows: int
    column_blobs: Dict[str, str]  # column name -> object-store key
    column_stats: Dict[str, Dict[str, float]]  # column name -> {min, max}

    def to_json_dict(self) -> Dict:
        return {
            "num_rows": self.num_rows,
            "column_blobs": self.column_blobs,
            "column_stats": self.column_stats,
        }

    @staticmethod
    def from_json_dict(d: Dict) -> "ShardMeta":
        return ShardMeta(d["num_rows"], d["column_blobs"], d["column_stats"])


@dataclass(frozen=True)
class Snapshot:
    """One immutable table version."""

    table: str
    snapshot_id: str
    schema: Schema
    shards: Sequence[ShardMeta]
    parent_id: Optional[str]  # lineage for time travel

    @property
    def num_rows(self) -> int:
        return sum(s.num_rows for s in self.shards)

    def to_json_dict(self) -> Dict:
        return {
            "table": self.table,
            "snapshot_id": self.snapshot_id,
            "schema": self.schema.to_json_dict(),
            "shards": [s.to_json_dict() for s in self.shards],
            "parent_id": self.parent_id,
        }

    @staticmethod
    def from_json_dict(d: Dict) -> "Snapshot":
        return Snapshot(
            table=d["table"],
            snapshot_id=d["snapshot_id"],
            schema=Schema.from_json_dict(d["schema"]),
            shards=tuple(ShardMeta.from_json_dict(s) for s in d["shards"]),
            parent_id=d.get("parent_id"),
        )


#: A fully-materialized columnar table in memory: {column: 1-D array}.
TableData = Dict[str, np.ndarray]


@dataclass
class TableFormat:
    """Reader/writer for TensorTables over an ObjectStore."""

    store: ObjectStore
    shard_rows: int = DEFAULT_SHARD_ROWS

    # ----------------------------------------------------------------- write
    def write(
        self,
        table: str,
        schema: Schema,
        data: TableData,
        *,
        parent: Optional[Snapshot] = None,
        append: bool = False,
    ) -> Snapshot:
        """Write a new snapshot. ``append=True`` keeps the parent's shards."""
        nrows = schema.validate_batch(data)
        shards: List[ShardMeta] = []
        if append and parent is not None:
            if parent.schema != schema:
                raise TypeError(
                    f"append schema mismatch for {table}: "
                    f"{schema.names} vs {parent.schema.names}"
                )
            shards.extend(parent.shards)
        for start in range(0, max(nrows, 1), self.shard_rows):
            stop = min(start + self.shard_rows, nrows)
            if stop <= start:
                break
            blobs: Dict[str, str] = {}
            stats: Dict[str, Dict[str, float]] = {}
            for col in schema.columns:
                chunk = np.ascontiguousarray(data[col.name][start:stop])
                blobs[col.name] = self.store.put(array_to_bytes(chunk))
                if chunk.size and chunk.dtype.kind in "iuf":
                    stats[col.name] = {
                        "min": float(np.min(chunk)),
                        "max": float(np.max(chunk)),
                    }
                else:
                    stats[col.name] = {"min": float("-inf"), "max": float("inf")}
            shards.append(ShardMeta(stop - start, blobs, stats))
        snapshot_id = stable_hash(
            {
                "table": table,
                "schema": schema.to_json_dict(),
                "shards": [s.to_json_dict() for s in shards],
                "parent": parent.snapshot_id if parent else None,
            }
        )
        snap = Snapshot(table, snapshot_id, schema, tuple(shards),
                        parent.snapshot_id if parent else None)
        # persist the snapshot manifest itself so catalogs only hold keys
        self.store.put(dumps_json(snap.to_json_dict()))
        return snap

    # ------------------------------------------------------------------ read
    def read_shard(
        self, shard: ShardMeta, columns: Optional[Sequence[str]] = None
    ) -> TableData:
        cols = columns if columns is not None else list(shard.column_blobs)
        return {c: bytes_to_array(self.store.get(shard.column_blobs[c])) for c in cols}

    def read(
        self, snapshot: Snapshot, columns: Optional[Sequence[str]] = None
    ) -> TableData:
        """Materialize (selected columns of) a snapshot into memory."""
        cols = list(columns) if columns is not None else snapshot.schema.names
        if not snapshot.shards:
            return {
                c: np.empty((0,), dtype=snapshot.schema.dtype_of(c)) for c in cols
            }
        parts = [self.read_shard(s, cols) for s in snapshot.shards]
        return {c: np.concatenate([p[c] for p in parts]) for c in cols}

    def load_snapshot(self, manifest_key: str) -> Snapshot:
        return Snapshot.from_json_dict(loads_json(self.store.get(manifest_key)))

    def manifest_key(self, snapshot: Snapshot) -> str:
        """Content address of a snapshot manifest (what catalogs store)."""
        return self.store.put(dumps_json(snapshot.to_json_dict()))
