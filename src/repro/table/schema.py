"""Table schemas: named, typed columns over columnar numpy/JAX arrays."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

_ALLOWED_KINDS = {"i", "u", "f", "b"}  # int, uint, float, bool


@dataclass(frozen=True)
class Column:
    name: str
    dtype: str  # numpy dtype string, e.g. "int32", "float32"

    def __post_init__(self) -> None:
        kind = np.dtype(self.dtype).kind
        if kind not in _ALLOWED_KINDS:
            raise TypeError(
                f"column {self.name!r}: dtype {self.dtype} unsupported "
                f"(kind={kind}); the engine is numeric/boolean-columnar"
            )

    def to_json_dict(self) -> Dict[str, str]:
        return {"name": self.name, "dtype": self.dtype}


@dataclass(frozen=True)
class Schema:
    columns: Tuple[Column, ...]

    @staticmethod
    def of(**cols: str) -> "Schema":
        return Schema(tuple(Column(n, d) for n, d in cols.items()))

    @staticmethod
    def from_json_dict(d: Dict) -> "Schema":
        return Schema(tuple(Column(c["name"], c["dtype"]) for c in d["columns"]))

    def to_json_dict(self) -> Dict:
        return {"columns": [c.to_json_dict() for c in self.columns]}

    @property
    def names(self) -> List[str]:
        return [c.name for c in self.columns]

    def dtype_of(self, name: str) -> np.dtype:
        for c in self.columns:
            if c.name == name:
                return np.dtype(c.dtype)
        raise KeyError(f"no column {name!r} in schema {self.names}")

    def has(self, name: str) -> bool:
        return any(c.name == name for c in self.columns)

    def get(self, name: str) -> Optional[Column]:
        """The column named ``name``, or None — the non-raising lookup the
        static lineage pass uses."""
        for c in self.columns:
            if c.name == name:
                return c
        return None

    def select(self, names: List[str]) -> "Schema":
        by_name = {c.name: c for c in self.columns}
        missing = [n for n in names if n not in by_name]
        if missing:
            raise KeyError(f"columns {missing} not in schema {self.names}")
        return Schema(tuple(by_name[n] for n in names))

    def validate_batch(self, batch: Dict[str, np.ndarray]) -> int:
        """Check a columnar batch against the schema; return row count."""
        if set(batch.keys()) != set(self.names):
            raise ValueError(
                f"batch columns {sorted(batch)} != schema columns {sorted(self.names)}"
            )
        nrows = None
        for c in self.columns:
            arr = batch[c.name]
            if arr.ndim != 1:
                raise ValueError(f"column {c.name!r} must be 1-D, got shape {arr.shape}")
            if np.dtype(arr.dtype) != np.dtype(c.dtype):
                raise TypeError(
                    f"column {c.name!r}: dtype {arr.dtype} != schema {c.dtype}"
                )
            if nrows is None:
                nrows = len(arr)
            elif len(arr) != nrows:
                raise ValueError("ragged columnar batch")
        return int(nrows or 0)
