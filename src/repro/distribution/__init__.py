"""Distribution layer: mesh axes, sharding rules, gradient compression.

Parallelism map (DESIGN.md §5):
  DP    batch over ("pod", "data")
  FSDP  parameters + optimizer state sharded over "data" (ZeRO-ish)
  TP    head/FFN dims over "model" (Megatron column/row)
  EP    MoE experts over "model" (fallback: expert-internal TP)
  SP    long-context KV/state over "data" when batch=1
"""
from repro.distribution.sharding import (
    ShardingRules,
    DEFAULT_RULES,
    param_shardings,
    batch_shardings,
    state_shardings,
    constrain,
)
from repro.distribution.compression import (
    CompressionState,
    init_compression,
    compress_decompress,
)

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "param_shardings",
    "batch_shardings",
    "state_shardings",
    "constrain",
    "CompressionState",
    "init_compression",
    "compress_decompress",
]
