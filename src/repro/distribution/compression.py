"""Int8 gradient compression with error feedback.

On a real multi-slice deployment the DP gradient all-reduce crosses the
(slow) DCN between pods; quantizing to int8 cuts those bytes 4x.  The
error-feedback accumulator keeps the quantization *unbiased over time*
(residuals are re-added next step), which is what makes compressed SGD
converge like exact SGD.

Under single-program SPMD we express the transform at the value level
(quantize → dequantize around the reduction the compiler inserts); the
bytes saving is realized by the collective implementation on hardware.
Tests verify the error-feedback contraction property.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

CompressionState = Any  # pytree of f32 residuals, same structure as grads


def init_compression(grads_like: Any) -> CompressionState:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
    )


def _quantize_leaf(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array]:
    g32 = g.astype(jnp.float32) + err  # error feedback
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = g32 - deq  # residual carried to the next step
    return deq.astype(g.dtype), new_err


def compress_decompress(
    grads: Any, state: CompressionState
) -> Tuple[Any, CompressionState]:
    """Apply int8+EF quantization leaf-wise. Returns (grads', new_state)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        dg, de = _quantize_leaf(g, e)
        out_g.append(dg)
        out_e.append(de)
    return (
        jax.tree_util.tree_unflatten(treedef, out_g),
        jax.tree_util.tree_unflatten(treedef, out_e),
    )
