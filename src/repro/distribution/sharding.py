"""Sharding rules: parameter-path patterns → PartitionSpecs with fallbacks.

Rules are ordered ``(regex, candidates)`` where each candidate is a tuple
of mesh-axis names (or None) per trailing dimension.  The first candidate
whose every named axis divides the corresponding dim is chosen; otherwise
the dim is replicated.  This fallback chain is how e.g. qwen2-moe's 60
experts (not divisible by model=16) degrade gracefully from EP to
expert-internal TP without per-arch special cases.

Stacked-segment leaves (under ``seg*/``) carry a leading layer dim that is
never sharded — the matcher prepends None automatically.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.tree import tree_map_with_path_str

Axis = Optional[str]
Candidate = Tuple[Axis, ...]


#: ("data",) means FSDP over the data axis; ("model",) is tensor parallel.
#: Multi-axis entries like ("data", "model") shard one dim over both.
@dataclass(frozen=True)
class ShardingRules:
    rules: Tuple[Tuple[str, Tuple[Candidate, ...]], ...]
    #: batch axes for activations/inputs
    batch_axes: Tuple[str, ...] = ("pod", "data")
    #: axis to shard long sequences over when batch is unshardable
    seq_axis: str = "data"
    #: tensor-parallel axis for activation constraints (None = no TP)
    tp_axis: Optional[str] = "model"
    #: Megatron-style sequence sharding of residual activations
    seq_shard: bool = True
    name: str = "default"

    def spec_for(self, path: str, shape: Sequence[int], mesh: Mesh) -> P:
        trailing = list(shape)
        if re.search(r"(^|/)seg\d+/", path):  # stacked layer dim: unsharded
            trailing = trailing[1:]
        for pattern, candidates in self.rules:
            if re.search(pattern, path):
                chosen = _first_fitting(candidates, trailing, mesh)
                if chosen is None:
                    chosen = (None,) * len(trailing)
                if len(trailing) != len(shape):
                    chosen = (None,) + tuple(chosen)
                return P(*chosen)
        return P()  # replicate by default (norms, scalars)


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _first_fitting(
    candidates: Tuple[Candidate, ...], shape: Sequence[int], mesh: Mesh
) -> Optional[Candidate]:
    for cand in candidates:
        if len(cand) != len(shape):
            continue
        ok = True
        for dim, axis in zip(shape, cand):
            if axis is None:
                continue
            size = _axis_size(mesh, axis)
            if size == 0 or dim % size != 0:
                ok = False
                break
            # axis must exist in this mesh
            names = axis if isinstance(axis, tuple) else (axis,)
            if any(a not in mesh.shape for a in names):
                ok = False
                break
        if ok:
            return cand
    return None


DEFAULT_RULES = ShardingRules(
    rules=(
        # --- embeddings / output heads: vocab over model (Megatron-style),
        #     embed dim over data (FSDP); fall back to data-only.
        (r"embed(/cb\d+)?/table", ((("model"), ("data")), (None, ("data")), (None, None))),
        (r"(lm_head|heads/cb\d+)/w", ((("data"), ("model")), (None, ("model")), (None, None))),
        # --- MoE experts: EP first (experts over model), else expert TP
        (r"moe/experts/(gate|up)", (
            (("model"), ("data"), None),      # EP + FSDP on d_in
            (None, ("data"), ("model")),      # expert-internal TP on d_ff
            (None, None, ("model")),
            (None, None, None),
        )),
        (r"moe/experts/down", (
            (("model"), None, ("data")),
            (None, ("model"), ("data")),
            (None, ("model"), None),
            (None, None, None),
        )),
        (r"moe/router/w", ((("data"), None), (None, None))),
        (r"moe/shared/(gate|up)/w", ((("data"), ("model")), (None, ("model")), (None, None))),
        (r"moe/shared/down/w", ((("model"), ("data")), (("model"), None), (None, None))),
        # --- attention: column-parallel qkv, row-parallel out
        (r"attn/w(q|k|v)(_b)?/w", ((("data"), ("model")), (None, ("model")), (None, None))),
        (r"attn/wo/w", ((("model"), ("data")), (("model"), None), (None, None))),
        (r"attn/w(q|kv)_a/w", ((("data"), None), (None, None))),
        # --- dense MLPs: column then row
        (r"mlp/(gate|up)/w", ((("data"), ("model")), (None, ("model")), (None, None))),
        (r"mlp/down/w", ((("model"), ("data")), (("model"), None), (None, None))),
        # --- recurrent blocks: inner dim over model where divisible
        (r"mix/(up|wq|wk|wv|w_in|w_gate|up_gate)/w", ((("data"), ("model")), (None, ("model")), (None, None))),
        (r"mix/(down|w_out)/w", ((("model"), ("data")), (("model"), None), (None, None))),
        (r"mix/(wi|wf|wx|wr|w_a|w_x)/w", ((("data"), None), (None, None))),
        (r"mtp/proj/w", ((("data"), ("model")), (None, None))),
    ),
)


#: Pure-FSDP profile (§Perf iteration for collective-bound dense train):
#: batch shards over EVERY mesh axis, parameters fully shard over
#: (data, model) with no tensor parallelism — per-step collectives are
#: O(param bytes) all-gathers + grad reduce-scatters instead of
#: O(activations × layers) TP reductions.  MoE archs keep DEFAULT_RULES
#: (experts must stay distributed); this profile suits dense ≤ ~40B.
FSDP_RULES = ShardingRules(
    rules=(
        (
            r"",  # every parameter: fully shard, fall back gracefully
            (
                ("data", "model"),
                ("data", None),
                (None, "model"),
                (None, None),
                ("data", "model", None),
                (None, "data", "model"),
                (None, None, None),
                (None,),
            ),
        ),
    ),
    batch_axes=("pod", "data", "model"),
    seq_axis="model",
    tp_axis=None,
    seq_shard=False,
    name="fsdp",
)

RULE_PROFILES = {"default": DEFAULT_RULES, "fsdp": FSDP_RULES}


# ------------------------------------------------------------------ helpers
def param_shardings(
    rules: ShardingRules, mesh: Mesh, abstract_params: Any
) -> Any:
    """Map an abstract param tree to NamedShardings."""

    def assign(path: str, leaf: Any):
        spec = rules.spec_for(path, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return tree_map_with_path_str(assign, abstract_params)


def batch_shardings(rules: ShardingRules, mesh: Mesh, abstract_batch: Any) -> Any:
    """Inputs: batch dim over batch_axes (falls back to replication for
    unshardable batch=1 long-context cells)."""
    axes = tuple(a for a in rules.batch_axes if a in mesh.shape)

    def assign(path: str, leaf: Any):
        if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return NamedSharding(mesh, P())
        b = leaf.shape[0]
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if size > 1 and b % size == 0:
            return NamedSharding(mesh, P(axes, *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P())

    return tree_map_with_path_str(assign, abstract_batch)


def state_shardings(rules: ShardingRules, mesh: Mesh, abstract_state: Any) -> Any:
    """Decode caches: (layers, B, heads, S, D)-style leaves.

    Batch over batch_axes when divisible; otherwise shard the *sequence*
    axis (dim -2 for attention caches) over seq_axis — sequence-parallel
    serving for the batch=1 long-context cells.  The "model" axis shards
    the heads dim when it divides.
    """
    axes = tuple(a for a in rules.batch_axes if a in mesh.shape)
    batch_size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    model_size = mesh.shape.get("model", 1)
    seq_ok = rules.seq_axis in mesh.shape

    def assign(path: str, leaf: Any):
        shape = leaf.shape
        if len(shape) < 2:
            return NamedSharding(mesh, P())
        spec: List[Any] = [None] * len(shape)
        # leading dim is the stacked-layer dim for seg* state
        bdim = 1 if re.search(r"(^|/)seg\d+/", path) else 0
        if bdim < len(shape) and shape[bdim] % max(batch_size, 1) == 0 and batch_size > 1:
            spec[bdim] = axes
        elif len(shape) >= 4 and seq_ok and shape[-2] % mesh.shape[rules.seq_axis] == 0:
            spec[-2] = rules.seq_axis  # sequence-parallel cache (batch=1)
        # 5-D kv caches (L,B,H,S,D): heads over model when divisible,
        # otherwise shard the SEQUENCE dim over model — softmax/contraction
        # over a sharded cache axis partial-reduces cleanly under GSPMD
        # (§Perf iteration: unsharded caches blew past HBM on MHA archs)
        if len(shape) == 5 and model_size > 1:
            if shape[2] % model_size == 0:
                spec[2] = "model"
            elif spec[3] is None and shape[3] % model_size == 0:
                spec[3] = "model"
        # 4-D latent caches (L,B,S,dkv): sequence over model
        if (
            len(shape) == 4
            and bdim == 1
            and model_size > 1
            and spec[2] is None
            and shape[2] % model_size == 0
        ):
            spec[2] = "model"
        if len(shape) == 4 and bdim == 0 and model_size > 1 and shape[1] % model_size == 0:
            spec[1] = "model"
        return NamedSharding(mesh, P(*spec))

    return tree_map_with_path_str(assign, abstract_state)


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """Sharding constraint that no-ops outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# Activation-constraint context.
#
# FSDP shards parameters' non-TP dim over "data" while activations shard
# their *batch* dim over the same axis.  Without explicit anchors GSPMD may
# resolve the conflict the wrong way round (replicating activations and
# keeping weights sharded — catastrophic for activation memory).  The model
# calls ``constrain_batch``/``constrain_logits`` at block boundaries; when a
# mesh is registered here, those pin activations to batch-over-data and
# force the compiler to all-gather weights instead (the ZeRO dataflow).
# No mesh registered (single-device tests/examples) → exact no-op.
_ACT_MESH: Optional[Mesh] = None
_ACT_BATCH_AXES: Tuple[str, ...] = ()
_ACT_TP_AXIS: Optional[str] = None
_ACT_SEQ_SHARD: bool = False


def set_activation_mesh(
    mesh: Optional[Mesh],
    *,
    batch_axes: Tuple[str, ...] = ("pod", "data"),
    tp_axis: Optional[str] = "model",
    seq_shard: bool = True,
) -> None:
    """Register the mesh for activation constraints.

    ``seq_shard=True`` additionally shards the *sequence* dim of
    residual-stream activations over the TP axis (Megatron sequence
    parallelism): the per-layer scan checkpoints shrink by the TP degree,
    which is what makes remat-full fit HBM at 4k×256 batches.
    """
    global _ACT_MESH, _ACT_BATCH_AXES, _ACT_TP_AXIS, _ACT_SEQ_SHARD
    _ACT_MESH = mesh
    _ACT_SEQ_SHARD = seq_shard
    if mesh is not None:
        _ACT_BATCH_AXES = tuple(a for a in batch_axes if a in mesh.shape)
        _ACT_TP_AXIS = tp_axis if (tp_axis and tp_axis in mesh.shape) else None
    else:
        _ACT_BATCH_AXES = ()
        _ACT_TP_AXIS = None


def _batch_spec_for(x: jax.Array) -> Optional[P]:
    if _ACT_MESH is None or not _ACT_BATCH_AXES:
        return None
    size = int(np.prod([_ACT_MESH.shape[a] for a in _ACT_BATCH_AXES]))
    if x.ndim == 0 or x.shape[0] % size != 0 or size == 1:
        return None
    return P(_ACT_BATCH_AXES, *([None] * (x.ndim - 1)))


def constrain_batch(x: jax.Array) -> jax.Array:
    """Pin dim0 (batch) to the data axes; optionally dim1 (sequence) to
    the TP axis (sequence parallelism) for 3-D residual activations."""
    spec = _batch_spec_for(x)
    if spec is None:
        return x
    parts = list(spec)
    if (
        _ACT_SEQ_SHARD
        and _ACT_TP_AXIS is not None
        and x.ndim == 3
        and x.shape[1] % _ACT_MESH.shape[_ACT_TP_AXIS] == 0
    ):
        parts[1] = _ACT_TP_AXIS
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACT_MESH, P(*parts))
    )


def constrain_moe_buffer(x: jax.Array) -> jax.Array:
    """MoE expert tensors (B, E, C[, d]): batch over data, experts over
    the TP axis (expert parallelism) — the all-to-all boundary under
    pjit.  Works for both the int32 routing table (3-D) and the expert
    input/output buffers (4-D)."""
    if _ACT_MESH is None:
        return x
    spec = _batch_spec_for(x)
    parts = list(spec) if spec is not None else [None] * x.ndim
    if (
        _ACT_TP_AXIS is not None
        and x.ndim in (3, 4)
        and x.shape[1] % _ACT_MESH.shape[_ACT_TP_AXIS] == 0
    ):
        parts[1] = _ACT_TP_AXIS
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACT_MESH, P(*parts))
    )


def constrain_heads(x: jax.Array) -> jax.Array:
    """Attention tensors (B, H, S, D): batch over data, heads over the TP
    axis when the head count divides it (q always; kv only for MHA-kv)."""
    if _ACT_MESH is None or x.ndim != 4:
        return x
    spec = _batch_spec_for(x)
    parts = list(spec) if spec is not None else [None] * x.ndim
    if (
        _ACT_TP_AXIS is not None
        and x.shape[1] % _ACT_MESH.shape[_ACT_TP_AXIS] == 0
    ):
        parts[1] = _ACT_TP_AXIS
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACT_MESH, P(*parts))
    )


def constrain_logits(x: jax.Array) -> jax.Array:
    """Logits: batch over data axes, vocab (last dim) over the TP axis."""
    if _ACT_MESH is None:
        return x
    spec = _batch_spec_for(x)
    parts = list(spec) if spec is not None else [None] * x.ndim
    if (
        _ACT_TP_AXIS is not None
        and x.shape[-1] % _ACT_MESH.shape[_ACT_TP_AXIS] == 0
    ):
        parts[-1] = _ACT_TP_AXIS
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACT_MESH, P(*parts))
    )
