"""h2o-danube-3-4b [dense] — arXiv:2401.16818 family.

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000 — llama+mistral
mix with sliding-window attention (window 4096) → runs ``long_500k``.
"""
from repro.models.lm import LMConfig, ModelFamily

CONFIG = LMConfig(
    name="h2o-danube-3-4b",
    family=ModelFamily.DENSE,
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    segments=((("attn",), 24),),
    window=4096,
    tie_embeddings=False,
    remat="full",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="h2o-danube-smoke",
        family=ModelFamily.DENSE,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        segments=((("attn",), 2),),
        window=16,
        tie_embeddings=False,
        max_decode_len=64,
    )
