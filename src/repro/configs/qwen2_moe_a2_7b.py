"""qwen2-moe-a2.7b [moe] — hf:Qwen/Qwen1.5-MoE-A2.7B.

24L d_model=2048 16H (kv=16, MHA) d_ff=1408 (per expert), vocab=151936,
MoE 4 shared + 60 routed top-4.
"""
from repro.models.lm import LMConfig, ModelFamily

CONFIG = LMConfig(
    name="qwen2-moe-a2.7b",
    family=ModelFamily.MOE,
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    segments=((("moe_attn",), 24),),
    num_experts=60,
    top_k=4,
    num_shared_experts=4,
    moe_d_ff=1408,
    tie_embeddings=False,
    remat="full",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen2-moe-smoke",
        family=ModelFamily.MOE,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=32,
        vocab=256,
        segments=((("moe_attn",), 2),),
        num_experts=6,
        top_k=2,
        num_shared_experts=2,
        moe_d_ff=32,
        tie_embeddings=False,
        max_decode_len=64,
    )
