"""granite-34b [dense] — arXiv:2405.04324 (Granite Code).

88L d_model=6144 48H (GQA kv=1 → MQA) d_ff=24576 vocab=49152.
"""
from repro.models.lm import LMConfig, ModelFamily

CONFIG = LMConfig(
    name="granite-34b",
    family=ModelFamily.DENSE,
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    segments=((("attn",), 88),),
    tie_embeddings=True,
    remat="full",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="granite-smoke",
        family=ModelFamily.DENSE,
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab=256,
        segments=((("attn",), 3),),
        tie_embeddings=True,
        max_decode_len=64,
    )
