"""qwen3-32b [dense] — hf:Qwen/Qwen3 family.

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936 — qk-norm.
"""
from repro.models.lm import LMConfig, ModelFamily

CONFIG = LMConfig(
    name="qwen3-32b",
    family=ModelFamily.DENSE,
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab=151936,
    segments=((("attn",), 64),),
    qk_norm=True,
    tie_embeddings=False,
    remat="full",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen3-smoke",
        family=ModelFamily.DENSE,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        segments=((("attn",), 2),),
        qk_norm=True,
        tie_embeddings=False,
        max_decode_len=64,
    )
