"""xlstm-350m [ssm] — arXiv:2405.04517.

24 blocks d_model=1024 4H d_ff=0 (no separate FFN) vocab=50304 —
alternating mLSTM (matrix memory, chunked-parallel) and sLSTM blocks.
Recurrent → runs ``long_500k``.
"""
from repro.models.lm import LMConfig, ModelFamily

CONFIG = LMConfig(
    name="xlstm-350m",
    family=ModelFamily.SSM,
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    segments=((("mlstm", "slstm"), 12),),
    tie_embeddings=True,
    remat="full",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="xlstm-smoke",
        family=ModelFamily.SSM,
        n_layers=2,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        d_ff=0,
        vocab=256,
        segments=((("mlstm", "slstm"), 1),),
        tie_embeddings=True,
        max_decode_len=64,
    )
