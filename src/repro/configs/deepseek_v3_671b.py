"""deepseek-v3-671b [moe] — arXiv:2412.19437.

61L d_model=7168 128H (GQA kv=128 → MHA-shaped, realized as MLA)
d_ff=2048 (per routed expert), vocab=129280, MoE 1 shared + 256 routed
top-8, MTP head.  First 3 layers dense (inter 18432 per the paper).
"""
import jax.numpy as jnp

from repro.models.lm import LMConfig, ModelFamily

CONFIG = LMConfig(
    name="deepseek-v3-671b",
    family=ModelFamily.MOE,
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,           # routed expert width (assigned spec)
    dense_d_ff=18432,    # dense-layer FFN width (paper)
    vocab=129280,
    segments=((("mla_dense",), 3), (("mla_moe",), 58)),
    num_experts=256,
    top_k=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    mtp=True,
    tie_embeddings=False,
    remat="full",
    # 671B at 512 × 16GB chips: bf16 weights + factored optimizer is the
    # only layout that fits (f32 Adam would need 12.6 GB/chip for state
    # alone) — see EXPERIMENTS.md §Dry-run memory notes
    param_dtype=jnp.bfloat16,
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="deepseek-v3-smoke",
        family=ModelFamily.MOE,
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=32,
        dense_d_ff=128,
        vocab=256,
        segments=((("mla_dense",), 1), (("mla_moe",), 2)),
        num_experts=8,
        top_k=2,
        num_shared_experts=1,
        moe_d_ff=32,
        mtp=True,
        tie_embeddings=False,
        max_decode_len=64,
    )
