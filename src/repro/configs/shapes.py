"""Workload shapes and ShapeDtypeStruct input specs for every cell.

The four assigned shapes (per arch):

  train_4k      seq=4096    global_batch=256   → lowers train_step
  prefill_32k   seq=32768   global_batch=32    → lowers prefill
  decode_32k    seq=32768   global_batch=128   → lowers serve_step
                                                  (1 token, 32k KV cache)
  long_500k     seq=524288  global_batch=1     → serve_step, 500k state;
                                                  ONLY sub-quadratic archs

``input_specs`` returns allocation-free ShapeDtypeStructs (the dry-run
contract); ``make_batch`` materializes small real batches for smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LMConfig, ModelFamily

#: archs with sub-quadratic sequence mixing — the only ones that run
#: ``long_500k`` (see DESIGN.md §4: pure full-attention archs skip it)
SUB_QUADRATIC = {"h2o-danube-3-4b", "xlstm-350m", "recurrentgemma-9b"}


@dataclasses.dataclass(frozen=True)
class WorkloadShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, WorkloadShape] = {
    "train_4k": WorkloadShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": WorkloadShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": WorkloadShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": WorkloadShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: LMConfig, shape: WorkloadShape) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the skip reason."""
    if shape.name == "long_500k" and cfg.name not in SUB_QUADRATIC:
        return (
            "full-attention arch: 500k dense KV decode is the workload this "
            "shape excludes (DESIGN.md §4)"
        )
    return None


def cells(configs: Dict[str, LMConfig]) -> List:
    """All live (arch, shape) cells + skip records."""
    live, skipped = [], []
    for arch, cfg in configs.items():
        for shape in SHAPES.values():
            reason = shape_applicable(cfg, shape)
            if reason is None:
                live.append((arch, shape.name))
            else:
                skipped.append((arch, shape.name, reason))
    return live, skipped


# ------------------------------------------------------------- input specs
def _token_spec(cfg: LMConfig, batch: int, seq: int) -> jax.ShapeDtypeStruct:
    if cfg.n_codebooks > 1:
        return jax.ShapeDtypeStruct((batch, seq, cfg.n_codebooks), jnp.int32)
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(cfg: LMConfig, shape: WorkloadShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs: Dict[str, Any] = {"tokens": _token_spec(cfg, b, s)}
        if cfg.family == ModelFamily.VLM:
            # text shortened so patches + text == seq budget
            specs["tokens"] = _token_spec(cfg, b, s - cfg.num_patches)
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_patches, cfg.d_model), jnp.bfloat16
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": _token_spec(cfg, b, s)}
        if cfg.family == ModelFamily.VLM:
            specs["tokens"] = _token_spec(cfg, b, s - cfg.num_patches)
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_patches, cfg.d_model), jnp.bfloat16
            )
        return specs
    # decode: one new token against a seq-length cache
    return {
        "tokens": _token_spec(cfg, b, 1),
        "lengths": jax.ShapeDtypeStruct((b,), jnp.int32),
    }


# ------------------------------------------------------- smoke-test batches
def make_batch(
    cfg: LMConfig, *, batch: int, seq: int, rng: np.random.Generator
) -> Dict[str, jax.Array]:
    if cfg.n_codebooks > 1:
        tokens = rng.integers(0, cfg.vocab, (batch, seq, cfg.n_codebooks))
    else:
        tokens = rng.integers(0, cfg.vocab, (batch, seq))
    out = {"tokens": jnp.asarray(tokens.astype(np.int32))}
    if cfg.family == ModelFamily.VLM:
        out["patch_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.num_patches, cfg.d_model)).astype(
                np.float32
            ),
            dtype=jnp.bfloat16,
        )
    return out
