"""yi-6b [dense] — arXiv:2403.04652.

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.models.lm import LMConfig, ModelFamily

CONFIG = LMConfig(
    name="yi-6b",
    family=ModelFamily.DENSE,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    segments=((("attn",), 32),),
    tie_embeddings=False,
    remat="full",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="yi-smoke",
        family=ModelFamily.DENSE,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        segments=((("attn",), 2),),
        tie_embeddings=False,
        max_decode_len=64,
    )
