"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (Griffin).

38 blocks d_model=4096 16H (GQA kv=1 → MQA local attention) d_ff=12288
(GeGLU) vocab=256000 — RG-LRU + local attention in a 2:1 pattern
(rec, rec, attn)×12 + (rec, rec); local window 2048.  Linear recurrence
→ runs ``long_500k``.
"""
from repro.models.lm import LMConfig, ModelFamily

CONFIG = LMConfig(
    name="recurrentgemma-9b",
    family=ModelFamily.HYBRID,
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    segments=((("rec", "rec", "attn_geglu"), 12), (("rec", "rec"), 1)),
    window=2048,
    tie_embeddings=True,
    remat="full",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="recurrentgemma-smoke",
        family=ModelFamily.HYBRID,
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab=256,
        segments=((("rec", "rec", "attn_geglu"), 1), (("rec", "rec"), 1)),
        window=16,
        tie_embeddings=True,
        max_decode_len=64,
    )
