"""internvl2-2b [vlm] — arXiv:2404.16821.

LM backbone (InternLM2-1.8B): 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553.  The InternViT frontend is a STUB: ``input_specs`` provides
precomputed patch embeddings (256 patches) prepended to the text stream.
"""
from repro.models.lm import LMConfig, ModelFamily

CONFIG = LMConfig(
    name="internvl2-2b",
    family=ModelFamily.VLM,
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    segments=((("attn",), 24),),
    num_patches=256,
    tie_embeddings=False,
    remat="full",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="internvl2-smoke",
        family=ModelFamily.VLM,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        segments=((("attn",), 2),),
        num_patches=8,
        tie_embeddings=False,
        max_decode_len=64,
    )
