"""Architecture registry: ``--arch <id>`` resolves here.

One module per assigned architecture with the exact published config;
each exposes ``CONFIG`` (full-size) and ``smoke_config()`` (reduced, same
family) plus ``input_specs(shape)`` via the shared shapes module.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.lm import LMConfig

ARCH_IDS: List[str] = [
    "deepseek_v3_671b",
    "qwen2_moe_a2_7b",
    "h2o_danube_3_4b",
    "granite_34b",
    "yi_6b",
    "qwen3_32b",
    "internvl2_2b",
    "xlstm_350m",
    "musicgen_medium",
    "recurrentgemma_9b",
]

#: accepted spellings (CLI uses dashes)
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def resolve(arch: str) -> str:
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_IDS)}")
    return arch


def get_config(arch: str) -> LMConfig:
    mod = importlib.import_module(f"repro.configs.{resolve(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> LMConfig:
    mod = importlib.import_module(f"repro.configs.{resolve(arch)}")
    return mod.smoke_config()


def all_configs() -> Dict[str, LMConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
