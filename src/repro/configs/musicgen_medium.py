"""musicgen-medium [audio] — arXiv:2306.05284.

48L d_model=1536 24H (kv=24, MHA) d_ff=6144 vocab=2048 — decoder-only
over 4 parallel EnCodec codebooks (summed embeddings, 4 readout heads).
The EnCodec/text-conditioning frontend is a STUB — token streams arrive
precomputed via ``input_specs``.
"""
from repro.models.lm import LMConfig, ModelFamily

CONFIG = LMConfig(
    name="musicgen-medium",
    family=ModelFamily.AUDIO,
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    segments=((("attn",), 48),),
    n_codebooks=4,
    tie_embeddings=False,
    remat="full",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="musicgen-smoke",
        family=ModelFamily.AUDIO,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=64,
        segments=((("attn",), 2),),
        n_codebooks=4,
        tie_embeddings=False,
        max_decode_len=64,
    )
