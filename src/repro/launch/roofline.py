"""Roofline analysis from the dry-run's compiled artifacts.

Per (arch × shape × mesh) cell, three terms in SECONDS per step:

  compute    = HLO_FLOPs/device   / 197e12  (bf16 peak, TPU v5e)
  memory     = HLO_bytes/device   / 819e9   (HBM bandwidth)
  collective = wire_bytes/device  / 50e9    (ICI per-chip)

XLA's cost analysis counts while-loop (scan) bodies ONCE, so raw numbers
from the full compile undercount layer-stacked work.  We recover totals
by compiling tiny depth variants of each model (all segment counts = 1,
then one segment at 2) and extrapolating linearly:

  total = f(v0) + sum_i (count_i - 1) * (f(v_i) - f(v0))

The same extrapolation applies to bytes and to collective wire bytes
(parsed from the optimized HLO per variant).  The full-depth compile from
dryrun.py still provides memory_analysis (peak fit) and the existence
proof; this module adds the scaled roofline terms plus:

  MODEL_FLOPS       6·N_active·D (train) or 2·N_active·D_tokens (serve)
  useful ratio      MODEL_FLOPS / HLO_FLOPs  (remat/dispatch overheads)

Usage:
  PYTHONPATH=src python -m repro.launch.roofline            # all cached cells
  PYTHONPATH=src python -m repro.launch.roofline --mesh single --table
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link (effective per-chip)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results"
DRYRUN_PATH = RESULTS_DIR / "dryrun.json"
VARIANTS_PATH = RESULTS_DIR / "roofline_variants.json"
ROOFLINE_PATH = RESULTS_DIR / "roofline.json"


# ------------------------------------------------------- analytic FLOPs
def active_params(cfg) -> Tuple[int, int]:
    """(total_params, active_params) from an LMConfig, analytically."""
    import jax

    from repro.models.lm import LM

    model = LM(cfg)
    abstract = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    from repro.utils.tree import flatten_with_paths

    total = 0
    expert_total = 0
    for path, leaf in flatten_with_paths(abstract).items():
        n = int(np.prod(leaf.shape))
        total += n
        if "/experts/" in path:
            expert_total += n
    if cfg.num_experts:
        active = total - expert_total + expert_total * cfg.top_k // cfg.num_experts
    else:
        active = total
    return total, active


def model_flops(cfg, shape, kind: str) -> float:
    """6·N_active·D for train; 2·N_active per generated/processed token."""
    _, active = active_params(cfg)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * active * tokens


# ------------------------------------------------------ variant compiles
def _variant_config(cfg, reps: List[int]):
    """Variant with segment i expanded into ``reps[i]`` SEPARATE count-1
    segments.  Separate segments lower to separate scan ops, and XLA's
    cost analysis counts each loop body once — so doubling a segment
    this way (rather than bumping its trip count, which the cost model
    ignores) is what makes the per-unit delta measurable."""
    segments = []
    for (unit, _), r in zip(cfg.segments, reps):
        segments.extend([(unit, 1)] * r)
    segments = tuple(segments)
    n_layers = sum(len(u) * c for u, c in segments)
    return dataclasses.replace(cfg, segments=segments, n_layers=n_layers)


def measure_variants(arch: str, shape_name: str, multi_pod: bool) -> Dict[str, Any]:
    """Compile depth variants; return raw per-variant measurements."""
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    nseg = len(cfg.segments)
    base = [1] * nseg
    variants = {"v0": base}
    for i in range(nseg):
        reps = list(base)
        reps[i] = 2
        variants[f"v{i + 1}"] = reps
    out: Dict[str, Any] = {"counts": [c for _, c in cfg.segments]}
    for name, reps in variants.items():
        vcfg = _variant_config(cfg, reps)
        rec = lower_cell(arch, shape, mesh, cfg_override=vcfg)
        out[name] = {
            "flops": rec["flops_per_device"],
            "bytes": rec["bytes_per_device"],
            "wire": sum(c["wire_bytes"] for c in rec["collectives"].values()),
            "collectives": rec["collectives"],
        }
    return out


def extrapolate(var: Dict[str, Any], field: str) -> float:
    """total = v0 + sum_i (count_i - 1) * (v_i - v0)."""
    v0 = var["v0"][field]
    total = v0
    for i, count in enumerate(var["counts"]):
        vi = var[f"v{i + 1}"][field]
        total += (count - 1) * max(vi - v0, 0.0)
    return total


# -------------------------------------------------------------- reporting
def bottleneck_hint(dom: str, arch: str, kind: str) -> str:
    hints = {
        "compute": "raise arithmetic efficiency: cut remat recompute and "
                   "dispatch overhead so HLO FLOPs approach 6·N·D, or trade "
                   "memory for less remat",
        "memory": "cut bytes: larger fused blocks (chunked attention), bf16 "
                  "master/state, wider sequence sharding so activations "
                  "stream fewer HBM round-trips",
        "collective": "re-balance sharding: move collectives off the step "
                      "critical path (overlap with compute), hierarchical "
                      "reduce, or shift TP→DP to shrink per-step traffic",
    }
    return hints[dom]


def build_report(
    *,
    mesh_filter: Optional[str] = None,
    archs: Optional[List[str]] = None,
    refresh_variants: bool = False,
) -> Dict[str, Any]:
    from repro.configs import ARCH_IDS, get_config
    from repro.configs.shapes import SHAPES

    dryrun = json.loads(DRYRUN_PATH.read_text()) if DRYRUN_PATH.exists() else {}
    variants = (
        json.loads(VARIANTS_PATH.read_text()) if VARIANTS_PATH.exists() else {}
    )
    report: Dict[str, Any] = {}
    for key, rec in sorted(dryrun.items()):
        arch, shape_name, mesh_name = key.split("/")
        if mesh_filter and mesh_name != mesh_filter:
            continue
        if archs and arch not in archs:
            continue
        if rec.get("skipped"):
            report[key] = {"skipped": rec["skipped"]}
            continue
        if not rec.get("ok"):
            report[key] = {"error": rec.get("error", "?")}
            continue
        chips = int(np.prod(list(rec["mesh"].values())))
        vkey = key
        if vkey not in variants or refresh_variants:
            print(f"[variants] {vkey}")
            try:
                variants[vkey] = measure_variants(
                    arch, shape_name, mesh_name == "multi"
                )
                VARIANTS_PATH.parent.mkdir(parents=True, exist_ok=True)
                VARIANTS_PATH.write_text(json.dumps(variants, indent=1))
            except Exception as e:
                report[key] = {"error": f"variant compile failed: {e}"}
                continue
        var = variants[vkey]
        flops_dev = extrapolate(var, "flops")
        bytes_dev = extrapolate(var, "bytes")
        wire_dev = extrapolate(var, "wire")
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        mf = model_flops(cfg, shape, rec["kind"])
        compute_s = flops_dev / PEAK_FLOPS
        memory_s = bytes_dev / HBM_BW
        collective_s = wire_dev / ICI_BW
        terms = {
            "compute": compute_s,
            "memory": memory_s,
            "collective": collective_s,
        }
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        # roofline fraction: useful-FLOPs time over the bounding term
        useful_s = (mf / chips) / PEAK_FLOPS
        report[key] = {
            "chips": chips,
            "terms_s": terms,
            "dominant": dom,
            "model_flops": mf,
            "hlo_flops_global": flops_dev * chips,
            "useful_ratio": mf / max(flops_dev * chips, 1.0),
            "roofline_fraction": useful_s / max(bound, 1e-30),
            "memory_fit_gb": (
                (rec["memory"]["argument_bytes"] or 0)
                + (rec["memory"]["temp_bytes"] or 0)
            )
            / 2**30,
            "hint": bottleneck_hint(dom, arch, rec["kind"]),
        }
    ROOFLINE_PATH.parent.mkdir(parents=True, exist_ok=True)
    ROOFLINE_PATH.write_text(json.dumps(report, indent=1, sort_keys=True))
    return report


def markdown_table(report: Dict[str, Any]) -> str:
    lines = [
        "| cell | chips | compute s | memory s | collective s | dominant | "
        "useful ratio | roofline frac | fit GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key, r in sorted(report.items()):
        if "skipped" in r:
            lines.append(f"| {key} | — | — | — | — | skipped | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {key} | — | — | — | — | ERROR | — | — | — |")
            continue
        t = r["terms_s"]
        lines.append(
            f"| {key} | {r['chips']} | {t['compute']:.3e} | {t['memory']:.3e} "
            f"| {t['collective']:.3e} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['memory_fit_gb']:.1f} |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "all"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--table", action="store_true")
    ap.add_argument("--refresh", action="store_true")
    args = ap.parse_args()
    mesh_filter = None if args.mesh == "all" else args.mesh
    archs = [args.arch.replace("-", "_")] if args.arch else None
    report = build_report(
        mesh_filter=mesh_filter, archs=archs, refresh_variants=args.refresh
    )
    print(markdown_table(report))
    (RESULTS_DIR / "roofline.md").write_text(markdown_table(report))


if __name__ == "__main__":
    main()
