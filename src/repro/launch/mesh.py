"""Production meshes.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets the 512-placeholder-device
flag before any jax initialization, and tests import this module with a
single real device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods).

    Axis roles: "pod" × "data" carry data parallelism (gradients reduce
    hierarchically: reduce-scatter intra-pod over ICI, all-reduce across
    pods over DCN); "model" carries TP/EP/sequence sharding.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Tiny mesh over however many devices this host actually has —
    used by tests and the single-host examples."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
