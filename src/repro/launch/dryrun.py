"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The os.environ lines below MUST stay before any other import — jax locks
the device count at first initialization, and the production meshes need
512 placeholder devices.  Nothing here allocates real tensors:
parameters, optimizer state, caches and batches are all
ShapeDtypeStructs; ``.lower().compile()`` proves the sharding config is
coherent (no mismatched collectives, fits per-device memory) and yields
the cost/memory/HLO artifacts the roofline reads.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --force

Results accumulate in results/dryrun.json (cells are skipped when already
recorded — delete the file or pass --force to redo).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, WorkloadShape, input_specs, shape_applicable
from repro.distribution.sharding import (
    DEFAULT_RULES,
    batch_shardings,
    param_shardings,
    state_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models.lm import LM, LMConfig
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainStepConfig, make_train_state, make_train_step
from repro.utils.tree import tree_size_bytes

RESULTS_PATH = Path(__file__).resolve().parents[3] / "results" / "dryrun.json"

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(?:f32|f16|bf16|f64|s32|s8|u32|u8|pred|s64)\[([\d,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1,
}
_RESULT_RE = re.compile(
    r"=\s+(f64|s64|f32|s32|u32|bf16|f16|s8|u8|pred)\[([\d,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\("
)
_TUPLE_RESULT_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\("
)
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-type result bytes + wire bytes (per device).

    Result shapes in post-SPMD HLO are per-device.  Ring-algorithm wire
    bytes per device, from result bytes R and group size N:
      all-gather          R (N-1)/N
      all-reduce          2R (N-1)/N
      reduce-scatter      R (N-1)        (operand is R*N per device)
      all-to-all          R (N-1)/N
      collective-permute  R
    """
    out: Dict[str, Dict[str, float]] = {
        c: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0}
        for c in _COLLECTIVES
    }
    for line in hlo.splitlines():
        stripped = line.strip()
        m = _RESULT_RE.search(stripped)
        op: Optional[str] = None
        rbytes = 0.0
        if m:
            op = m.group(3)
            rbytes = _shape_bytes(m.group(1), m.group(2))
        else:
            mt = _TUPLE_RESULT_RE.search(stripped)
            if mt and any(f"{c}(" in stripped for c in _COLLECTIVES):
                op = mt.group(2)
                for dtm in re.finditer(
                    r"(f64|s64|f32|s32|u32|bf16|f16|s8|u8|pred)\[([\d,]*)\]",
                    mt.group(1),
                ):
                    rbytes += _shape_bytes(dtm.group(1), dtm.group(2))
        if op is None:
            continue
        n = 1
        g = _GROUP_RE.search(stripped)
        if g:
            n = int(g.group(2))
        else:
            ge = _GROUP_EXPL_RE.search(stripped)
            if ge:
                n = len(ge.group(1).split(","))
        if op == "collective-permute":
            wire = rbytes  # pairwise: always moves the result, no groups
        elif n <= 1:
            wire = 0.0
        elif op == "all-gather":
            wire = rbytes * (n - 1) / n
        elif op == "all-reduce":
            wire = 2 * rbytes * (n - 1) / n
        elif op == "reduce-scatter":
            wire = rbytes * (n - 1)
        else:  # all-to-all
            wire = rbytes * (n - 1) / n
        rec = out[op]
        rec["count"] += 1
        rec["result_bytes"] += rbytes
        rec["wire_bytes"] += wire
    return out


def _train_step_cfg(arch: str) -> TrainStepConfig:
    if arch == "deepseek_v3_671b":
        # factored second moment + bf16 first moment: the only optimizer
        # state that fits 671B on 512 x 16GB (see config docstring)
        return TrainStepConfig(optimizer="adafactor")
    return TrainStepConfig(optimizer="adamw")


def lower_cell(
    arch: str,
    shape: WorkloadShape,
    mesh,
    *,
    rules=DEFAULT_RULES,
    cfg_override: Optional[LMConfig] = None,
) -> Dict[str, Any]:
    """Lower+compile one cell; return the roofline-relevant artifacts."""
    cfg = cfg_override or get_config(arch)
    model = LM(cfg)
    t0 = time.perf_counter()
    # pin activations to the profile's layout for this trace (sharding.py)
    from repro.distribution.sharding import set_activation_mesh

    set_activation_mesh(
        mesh,
        batch_axes=rules.batch_axes,
        tp_axis=rules.tp_axis,
        seq_shard=rules.seq_shard,
    )
    key = jax.random.PRNGKey(0)
    abstract_params = jax.eval_shape(model.init, key)
    p_sh = param_shardings(rules, mesh, abstract_params)
    specs = input_specs(cfg, shape)
    record: Dict[str, Any] = {
        "arch": arch,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": dict(mesh.shape),
        "param_bytes_global": tree_size_bytes(abstract_params),
    }

    if shape.kind == "train":
        scfg = _train_step_cfg(arch)
        abstract_state = jax.eval_shape(
            lambda p: make_train_state(model, p, scfg), abstract_params
        )
        s_sh = state_shardings_like_params(rules, mesh, abstract_params, abstract_state)
        b_sh = batch_shardings(rules, mesh, specs)
        step_fn = make_train_step(model, scfg)
        lowered = jax.jit(
            step_fn,
            in_shardings=(p_sh, s_sh, b_sh),
            donate_argnums=(0, 1),
        ).lower(abstract_params, abstract_state, specs)
        record["optimizer"] = scfg.optimizer
        record["state_bytes_global"] = tree_size_bytes(abstract_state)
    elif shape.kind == "prefill":
        b_sh = batch_shardings(rules, mesh, specs)

        def prefill_fn(params, batch):
            logits = model.forward(
                params, batch["tokens"],
                patch_embeds=batch.get("patch_embeds"),
            )
            return logits[:, -1]  # serving prefill emits last-position only

        lowered = jax.jit(
            prefill_fn, in_shardings=(p_sh, b_sh)
        ).lower(abstract_params, specs)
    else:  # decode
        abstract_state = jax.eval_shape(
            lambda: model.init_decode_state(shape.global_batch, max_len=shape.seq_len)
        )
        st_sh = state_shardings(rules, mesh, abstract_state)
        b_sh = batch_shardings(rules, mesh, specs)

        def serve_step(params, state, batch):
            return model.decode_step(
                params, state, batch["tokens"], batch["lengths"]
            )

        lowered = jax.jit(
            serve_step,
            in_shardings=(p_sh, st_sh, b_sh),
            donate_argnums=(1,),
        ).lower(abstract_params, abstract_state, specs)
        record["decode_state_bytes_global"] = tree_size_bytes(abstract_state)

    t_lower = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter()

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    record.update(
        {
            "ok": True,
            "lower_s": t_lower - t0,
            "compile_s": t_compile - t_lower,
            # cost_analysis numbers are PER-DEVICE (post-SPMD partition)
            "flops_per_device": float(cost.get("flops", 0.0)),
            "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
            "collectives": coll,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None
                ),
            },
            "hlo_bytes": len(hlo),
        }
    )
    print(f"  memory_analysis: {mem}")
    print(
        f"  cost: flops/device={record['flops_per_device']:.3e} "
        f"bytes/device={record['bytes_per_device']:.3e}"
    )
    return record


def state_shardings_like_params(rules, mesh, abstract_params, abstract_state):
    """Optimizer state: moments shard exactly like their parameters
    (ZeRO via inheritance); factored/scalar leaves replicate."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.utils.tree import flatten_with_paths, tree_map_with_path_str

    params_flat = flatten_with_paths(abstract_params)
    p_specs = {
        path: rules.spec_for(path, leaf.shape, mesh)
        for path, leaf in params_flat.items()
    }

    def assign(path: str, leaf):
        m = re.match(r"(?:opt/)?(?:m|v|ef)/(.*)", path)
        if not m:
            return NamedSharding(mesh, P())  # step counters
        sub = m.group(1)
        fact = re.match(r"(.*)/(row|col|full)$", sub)
        base = fact.group(1) if fact else sub
        if base not in p_specs:
            return NamedSharding(mesh, P())
        pshape = params_flat[base].shape
        parts = list(p_specs[base])
        parts += [None] * (len(pshape) - len(parts))
        if fact is None or fact.group(2) == "full":
            if tuple(leaf.shape) == tuple(pshape):
                return NamedSharding(mesh, p_specs[base])
            return NamedSharding(mesh, P())
        # adafactor factored moments: inherit the parent spec on the
        # dims they keep (row drops the last dim, col the 2nd-to-last)
        spec = parts[:-1] if fact.group(2) == "row" else parts[:-2] + [parts[-1]]
        return NamedSharding(mesh, P(*spec))

    return tree_map_with_path_str(assign, abstract_state)


# --------------------------------------------------------------------- main
def load_results() -> Dict[str, Any]:
    if RESULTS_PATH.exists():
        return json.loads(RESULTS_PATH.read_text())
    return {}


def save_results(results: Dict[str, Any]) -> None:
    RESULTS_PATH.parent.mkdir(parents=True, exist_ok=True)
    RESULTS_PATH.write_text(json.dumps(results, indent=1, sort_keys=True))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES), help="one shape")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument(
        "--rules", default="default", choices=["default", "fsdp"],
        help="sharding profile (fsdp = no TP, batch over all axes)",
    )
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch.replace("-", "_")] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = load_results()
    mesh_cache = {}
    for multi in meshes:
        if multi not in mesh_cache:
            mesh_cache[multi] = make_production_mesh(multi_pod=multi)
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            shape = SHAPES[shape_name]
            skip = shape_applicable(cfg, shape)
            for multi in meshes:
                key = f"{arch}/{shape_name}/{'multi' if multi else 'single'}"
                if skip:
                    results[key] = {"skipped": skip}
                    print(f"[skip] {key}: {skip}")
                    continue
                if args.rules != "default":
                    key = f"{key}@{args.rules}"
                if key in results and results[key].get("ok") and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[lower+compile] {key} ...", flush=True)
                try:
                    from repro.distribution.sharding import RULE_PROFILES

                    rec = lower_cell(
                        arch, shape, mesh_cache[multi],
                        rules=RULE_PROFILES[args.rules],
                    )
                    results[key] = rec
                    print(
                        f"  OK lower {rec['lower_s']:.1f}s compile "
                        f"{rec['compile_s']:.1f}s"
                    )
                except Exception as e:  # record failure, keep going
                    tb = traceback.format_exc(limit=20)
                    results[key] = {"ok": False, "error": str(e)[:2000]}
                    failures.append((key, str(e)[:200]))
                    print(f"  FAIL {e}")
                    print(tb[-1500:])
                save_results(results)
    print("\n=== dry-run summary ===")
    done = sum(1 for v in results.values() if v.get("ok"))
    skipped = sum(1 for v in results.values() if "skipped" in v)
    failed = [(k, v) for k, v in results.items() if v.get("ok") is False]
    print(f"ok={done} skipped={skipped} failed={len(failed)}")
    for k, v in failed:
        print(f"  FAIL {k}: {v.get('error', '')[:160]}")


if __name__ == "__main__":
    main()
