"""Serving launcher: batched greedy generation for an assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --prompts 3 --new-tokens 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import LM
from repro.serve import Request, ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help=f"one of {[a.replace('_','-') for a in ARCH_IDS]}")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompts", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=2)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.n_codebooks > 1:
        raise SystemExit("codebook serving demo not wired; see tests")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    recurrent = {"mlstm", "slstm", "rec"} & {
        k for unit, _ in cfg.segments for k in unit
    }
    max_batch = 1 if recurrent else args.max_batch
    engine = ServeEngine(
        model, params, ServeConfig(max_batch=max_batch, max_len=64)
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            prompt=rng.integers(1, cfg.vocab, rng.integers(1, 5)).astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for _ in range(args.prompts)
    ]
    engine.generate(reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: {r.prompt.tolist()} -> {r.generated}")


if __name__ == "__main__":
    main()
