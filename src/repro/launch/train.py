"""Training launcher: ``--arch`` selects an assigned architecture.

Single-host entry point (the multi-pod path is exercised by dryrun.py —
on real hardware the same code runs under `jax.distributed.initialize`):

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 50 \
      --smoke            # reduced config, CPU-friendly
"""
from __future__ import annotations

import argparse
import tempfile

import numpy as np

from repro.catalog import Catalog
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.tokens import TokenDataset, write_token_table
from repro.io import ObjectStore
from repro.models import LM
from repro.table import TableFormat
from repro.train import TrainLoop, TrainLoopConfig, TrainStepConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help=f"one of {[a.replace('_','-') for a in ARCH_IDS]}")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (required on CPU)")
    ap.add_argument("--lake", default=None, help="lake root (default: tmp)")
    ap.add_argument("--branch", default="train")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.n_codebooks > 1 or cfg.num_patches:
        raise SystemExit(
            f"{cfg.name}: the token-table trainer drives LM-token archs; "
            "multimodal frontends are stubs (see examples/ for the "
            "end-to-end LM driver)"
        )
    model = LM(cfg)

    store = ObjectStore(args.lake or tempfile.mkdtemp())
    catalog = Catalog(store)
    fmt = TableFormat(store)
    rng = np.random.default_rng(0)
    corpus = rng.zipf(1.4, 500_000).clip(1, cfg.vocab - 1).astype(np.int32)
    key = write_token_table(fmt, catalog, "corpus", corpus)
    ds = TokenDataset(fmt, key, batch_size=args.batch, seq_len=args.seq, seed=0)

    loop = TrainLoop(
        model, ds, catalog, branch=args.branch,
        config=TrainLoopConfig(
            total_steps=args.steps,
            checkpoint_every=max(args.steps // 5, 5),
            log_every=max(args.steps // 10, 1),
            step=TrainStepConfig(
                peak_lr=3e-4, warmup_steps=max(args.steps // 10, 1),
                total_steps=args.steps,
            ),
        ),
    )
    out = loop.run()
    print(
        f"{cfg.name}: {out['steps_run']} steps, final loss "
        f"{out['final_loss']:.3f}, audit_ok={out['audit_ok']}, "
        f"{out['wall_s']:.1f}s"
    )


if __name__ == "__main__":
    main()
