from repro.serve.engine import ServeEngine, ServeConfig, Request

__all__ = ["ServeEngine", "ServeConfig", "Request"]
