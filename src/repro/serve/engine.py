"""Serving engine: batched prefill + decode over catalog checkpoints.

The Query+Wrangle interaction mode (paper Table 1) applied to models: a
synchronous request against an artifact checked out from a branch.  The
engine batches concurrent requests (static max_batch slots, ragged
lengths), prefills each prompt, then steps all live slots together —
a compact continuous-batching core:

* slots: fixed-capacity request table (ragged ``lengths`` mask);
* admission: new requests claim free slots between decode steps;
* the decode step is one jitted call for the whole slot table (the warm
  compiled-fn cache makes admission cheap — shapes never change).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM
from repro.utils.logging import get_logger

log = get_logger("serve.engine")


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4
    max_len: int = 256
    temperature: float = 0.0  # 0 = greedy


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 16
    # filled by the engine:
    slot: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: LM, params: Any, cfg: ServeConfig):
        if model.cfg.n_codebooks > 1:
            raise NotImplementedError(
                "the reference engine serves single-codebook LMs"
            )
        recurrent = {"mlstm", "slstm", "rec"}
        kinds = {k for unit, _ in model.cfg.segments for k in unit}
        if kinds & recurrent:
            # recurrent state updates are not lengths-gated: concurrent
            # slot batching would cross-contaminate; serve these archs
            # with max_batch==1 (decode_step itself is fine — it's what
            # the dry-run lowers)
            if cfg.max_batch != 1:
                raise NotImplementedError(
                    "recurrent-state archs: use max_batch=1 in the "
                    "reference engine"
                )
        self.model = model
        self.params = params
        self.cfg = cfg
        self.state = model.init_decode_state(cfg.max_batch, max_len=cfg.max_len)
        self.lengths = jnp.zeros((cfg.max_batch,), jnp.int32)
        self.free = list(range(cfg.max_batch))
        self._decode = jax.jit(model.decode_step)

    def _reset_slot(self, slot: int) -> None:
        """Zero a slot's cache/state and length before reuse."""
        self.state = jax.tree_util.tree_map(
            lambda s: s.at[:, slot].set(0) if s.ndim >= 2 else s, self.state
        )
        self.lengths = self.lengths.at[slot].set(0)

    # ------------------------------------------------------------ admission
    def admit(self, req: Request) -> bool:
        if not self.free:
            return False
        req.slot = self.free.pop(0)
        self._reset_slot(req.slot)
        # prefill: feed prompt tokens one step at a time through the same
        # decode path (keeps a single compiled executable; a blocked
        # prefill kernel is the §Perf upgrade path)
        for tok in req.prompt:
            logits, self.state = self._decode(
                self.params,
                self.state,
                self._slot_tokens(req.slot, int(tok)),
                self.lengths,
            )
            self.lengths = self.lengths.at[req.slot].add(1)
        req._next_logits = logits[req.slot, 0]
        return True

    def _slot_tokens(self, slot: int, token: int) -> jax.Array:
        toks = jnp.zeros((self.cfg.max_batch, 1), jnp.int32)
        return toks.at[slot, 0].set(token)

    # --------------------------------------------------------------- decode
    def _sample(self, logits: jax.Array, rng: np.random.Generator) -> int:
        if self.cfg.temperature <= 0.0:
            return int(jnp.argmax(logits))
        p = np.asarray(
            jax.nn.softmax(logits.astype(jnp.float32) / self.cfg.temperature)
        )
        return int(rng.choice(len(p), p=p / p.sum()))

    def step(self, live: List[Request], rng: np.random.Generator) -> None:
        """One synchronized decode step over all live requests."""
        if not live:
            return
        toks = jnp.zeros((self.cfg.max_batch, 1), jnp.int32)
        for req in live:
            nxt = self._sample(req._next_logits, rng)
            req.generated.append(nxt)
            toks = toks.at[req.slot, 0].set(nxt)
        logits, self.state = self._decode(
            self.params, self.state, toks, self.lengths
        )
        for req in live:
            req._next_logits = logits[req.slot, 0]
            self.lengths = self.lengths.at[req.slot].add(1)
            if (
                len(req.generated) >= req.max_new_tokens
                or int(self.lengths[req.slot]) >= self.cfg.max_len - 1
            ):
                req.done = True
                self.free.append(req.slot)

    # ------------------------------------------------------------------ run
    def generate(self, requests: List[Request], *, seed: int = 0) -> List[Request]:
        rng = np.random.default_rng(seed)
        queue = list(requests)
        live: List[Request] = []
        while queue or live:
            while queue and self.free:
                req = queue.pop(0)
                if self.admit(req):
                    live.append(req)
            self.step(live, rng)
            live = [r for r in live if not r.done]
        return requests
