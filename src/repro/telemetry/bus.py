"""In-process multi-consumer event bus (+ the on-disk live spool).

Modeled on Ray's aggregator ``MultiConsumerEventBuffer``: one publisher
lock, N subscribers each with a **bounded** buffer and per-subscriber
drop accounting — a slow consumer loses *its own* oldest events, never
anybody else's, and publishing never blocks on a consumer.  Publishers
are the wave scheduler's stage lane, the executor's container/timer
threads, the scan pool and the lakekeeper jobs, so ``publish`` is fully
thread-safe and cheap (one lock, one deque append per subscriber).

The bus also mirrors every event to a **spool file** (JSON lines) under
the lake root when given a path: that is what makes ``repro events
--follow`` work from a *different process* than the one executing
``run_async`` — the CLI tails the spool exactly like ``tail -f``, no
socket required.  The spool rotates at ``spool_max_bytes`` (current +
one ``.1`` predecessor) so a long-lived service does not grow it without
bound.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from pathlib import Path
from typing import Any, Callable, Deque, Dict, Iterator, List, Optional, Union

from repro.telemetry.events import Event, event_from_json_dict

__all__ = ["EventBus", "Subscription", "read_spool", "follow_spool"]

#: global sequence scope for events that carry no run_id
_GLOBAL_SCOPE = -1


class Subscription:
    """One consumer's bounded view of the bus.

    ``poll()`` drains what is buffered without blocking; ``follow()``
    yields events as they arrive (with an idle timeout).  ``dropped``
    counts events this subscriber lost to its bound — gaps are also
    detectable from the per-run ``seq`` numbers.
    """

    def __init__(self, bus: "EventBus", maxlen: int):
        self._bus = bus
        self.maxlen = maxlen
        self._buf: Deque[Event] = deque()
        self._dropped = 0
        self._closed = False

    # ---------------------------------------------------------- consuming
    @property
    def dropped(self) -> int:
        with self._bus._lock:
            return self._dropped

    def poll(self, max_items: Optional[int] = None) -> List[Event]:
        """Drain buffered events (up to ``max_items``), non-blocking."""
        with self._bus._lock:
            n = len(self._buf) if max_items is None else min(max_items, len(self._buf))
            return [self._buf.popleft() for _ in range(n)]

    def drain(self) -> List[Event]:
        """Everything buffered right now (alias for unbounded poll)."""
        return self.poll()

    def follow(
        self, *, idle_timeout_s: Optional[float] = None
    ) -> Iterator[Event]:
        """Yield events as they are published.  Stops when the
        subscription is closed, or after ``idle_timeout_s`` seconds with
        nothing new (None = wait forever)."""
        while True:
            with self._bus._cond:
                while not self._buf and not self._closed:
                    if not self._bus._cond.wait(timeout=idle_timeout_s):
                        return  # idle timeout
                if self._closed and not self._buf:
                    return
                batch = [self._buf.popleft() for _ in range(len(self._buf))]
            yield from batch

    def close(self) -> None:
        with self._bus._cond:
            self._closed = True
            self._bus._subs.discard(self)
            self._bus._cond.notify_all()

    def __enter__(self) -> "Subscription":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # called by the bus with the lock held
    def _offer(self, event: Event) -> None:
        if len(self._buf) >= self.maxlen:
            self._buf.popleft()  # drop-oldest; the tail stays fresh
            self._dropped += 1
        self._buf.append(event)


class EventBus:
    """Thread-safe publish, bounded multi-consumer delivery, spool mirror."""

    def __init__(
        self,
        *,
        spool_path: Union[str, Path, None] = None,
        spool_max_bytes: int = 8 * 1024 * 1024,
    ):
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._subs: set = set()
        #: per-scope monotonic sequence counters (scope = run_id or global)
        self._seqs: Dict[int, int] = {}
        self._published = 0
        self.spool_path = Path(spool_path) if spool_path is not None else None
        self._spool_max_bytes = spool_max_bytes
        self._spool_fh: Optional[Any] = None
        self._spool_bytes = 0

    # ----------------------------------------------------------- publish
    def publish(self, event: Event) -> Event:
        """Stamp ``ts``/``seq`` and deliver to every subscriber + spool."""
        if event.ts == 0.0:
            event.ts = time.time()
        line: Optional[str] = None
        with self._cond:
            scope = event.run_id if event.run_id is not None else _GLOBAL_SCOPE
            seq = self._seqs.get(scope, 0) + 1
            self._seqs[scope] = seq
            event.seq = seq
            self._published += 1
            for sub in self._subs:
                sub._offer(event)
            if self.spool_path is not None:
                line = json.dumps(event.to_json_dict(), sort_keys=True)
                self._spool_write(line)
            self._cond.notify_all()
        return event

    def _spool_write(self, line: str) -> None:
        # called with the lock held; spool failures must never sink a run
        try:
            if self._spool_fh is None:
                self.spool_path.parent.mkdir(parents=True, exist_ok=True)
                self._spool_fh = open(self.spool_path, "a", encoding="utf-8")
                self._spool_bytes = self._spool_fh.tell()
            self._spool_fh.write(line + "\n")
            self._spool_fh.flush()  # tail -f semantics for repro events
            self._spool_bytes += len(line) + 1
            if self._spool_bytes > self._spool_max_bytes:
                self._spool_fh.close()
                self._spool_fh = None
                os.replace(self.spool_path, str(self.spool_path) + ".1")
                self._spool_bytes = 0
        except OSError:
            self._spool_fh = None

    # --------------------------------------------------------- subscribe
    def subscribe(self, *, maxlen: int = 4096) -> Subscription:
        sub = Subscription(self, maxlen)
        with self._lock:
            self._subs.add(sub)
        return sub

    # ------------------------------------------------------------- stats
    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "published": self._published,
                "subscribers": len(self._subs),
                "dropped": sum(s._dropped for s in self._subs),
            }

    def close(self) -> None:
        with self._cond:
            for sub in list(self._subs):
                sub._closed = True
            self._subs.clear()
            if self._spool_fh is not None:
                try:
                    self._spool_fh.close()
                except OSError:
                    pass
                self._spool_fh = None
            self._cond.notify_all()


# ---------------------------------------------------------------- spool IO
def _iter_spool_lines(path: Path) -> Iterator[str]:
    # include the rotated predecessor so a tail spanning a rotation is whole
    for p in (Path(str(path) + ".1"), path):
        if p.exists():
            with open(p, "r", encoding="utf-8") as fh:
                yield from fh


def read_spool(
    path: Union[str, Path],
    *,
    run_id: Optional[int] = None,
    limit: Optional[int] = None,
) -> List[Event]:
    """Read the spool's current contents (``repro events`` without
    ``--follow``)."""
    path = Path(path)
    out: List[Event] = []
    for line in _iter_spool_lines(path):
        line = line.strip()
        if not line:
            continue
        try:
            ev = event_from_json_dict(json.loads(line))
        except (json.JSONDecodeError, TypeError):
            continue  # torn write at a rotation boundary
        if run_id is not None and ev.run_id != run_id:
            continue
        out.append(ev)
    if limit is not None:
        out = out[-limit:]
    return out


def follow_spool(
    path: Union[str, Path],
    *,
    run_id: Optional[int] = None,
    poll_s: float = 0.2,
    stop: Optional[Callable[[], bool]] = None,
) -> Iterator[Event]:
    """Tail the spool file across processes (``repro events --follow``).

    Yields existing events, then polls for appended lines until ``stop()``
    returns True (or forever).  Chunked-poll file tailing, the same shape
    as Ray's job-log ``file_tail_iterator``.
    """
    path = Path(path)
    # initial catch-up: rotated predecessor first, then the live file —
    # tracking exactly how many bytes of the live file were consumed so
    # a line appended mid-read is neither skipped nor double-yielded
    pos = 0
    initial: List[Event] = []
    rotated = Path(str(path) + ".1")
    if rotated.exists():
        initial.extend(read_spool(rotated, run_id=run_id))
    if path.exists():
        with open(path, "r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if line:
                    try:
                        ev = event_from_json_dict(json.loads(line))
                    except (json.JSONDecodeError, TypeError):
                        continue
                    if run_id is None or ev.run_id == run_id:
                        initial.append(ev)
            pos = fh.tell()
    yield from initial
    while stop is None or not stop():
        if not path.exists():
            time.sleep(poll_s)
            continue
        size = path.stat().st_size
        if size < pos:
            pos = 0  # rotated under us — restart from the fresh file
        if size == pos:
            time.sleep(poll_s)
            continue
        with open(path, "r", encoding="utf-8") as fh:
            fh.seek(pos)
            chunk = fh.read()
            pos = fh.tell()
        for line in chunk.splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                ev = event_from_json_dict(json.loads(line))
            except (json.JSONDecodeError, TypeError):
                continue
            if run_id is not None and ev.run_id != run_id:
                continue
            yield ev
