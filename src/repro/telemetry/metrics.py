"""The unified metrics plane: counters, gauges, histograms — one registry.

Before this module, the platform's numbers lived in ad-hoc places: the
object store bumped ``StoreStats`` fields, the executor kept a private
latency list per function fingerprint, the warm cache counted cold
starts on its own dataclass.  The registry absorbs them behind one
interface without breaking any of those call sites: ``StoreStats.bump``
forwards every delta here when a registry is attached
(``attach_metrics``), and the executor observes task durations into a
histogram next to its speculation baselines.

Instruments are cheap, thread-safe and allocation-light on the hot path
(one small lock per instrument); ``snapshot()`` is the single read
surface the CLI/benchmarks/tests consume.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Deque, Dict, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotonically increasing count (events, bytes, retries...)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """A point-in-time level (queue depth, in-flight stages...)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, delta: float) -> None:
        with self._lock:
            self._value += delta

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Streaming distribution: exact count/sum/min/max plus percentile
    estimates over a bounded reservoir of the most recent observations
    (the same shape as the executor's bounded latency history)."""

    __slots__ = ("name", "_count", "_sum", "_min", "_max", "_recent", "_lock")

    def __init__(self, name: str, *, reservoir: int = 512):
        self.name = name
        self._count = 0
        self._sum = 0.0
        self._min: Optional[float] = None
        self._max: Optional[float] = None
        self._recent: Deque[float] = deque(maxlen=reservoir)
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            self._min = v if self._min is None else min(self._min, v)
            self._max = v if self._max is None else max(self._max, v)
            self._recent.append(v)

    def summary(self) -> Dict[str, float]:
        with self._lock:
            if self._count == 0:
                return {"count": 0, "sum": 0.0}
            recent = sorted(self._recent)
            return {
                "count": self._count,
                "sum": self._sum,
                "mean": self._sum / self._count,
                "min": self._min,
                "max": self._max,
                "p50": recent[len(recent) // 2],
                "p95": recent[min(len(recent) - 1, int(len(recent) * 0.95))],
            }


class MetricsRegistry:
    """Name -> instrument, created on first touch (no registration step).

    Dotted names namespace by component: ``store.puts``,
    ``executor.task_duration_s``, ``query.shards_read`` — one flat
    snapshot, greppable like the rest of the system.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            inst = self._counters.get(name)
            if inst is None:
                inst = self._counters[name] = Counter(name)
            return inst

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            inst = self._gauges.get(name)
            if inst is None:
                inst = self._gauges[name] = Gauge(name)
            return inst

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            inst = self._histograms.get(name)
            if inst is None:
                inst = self._histograms[name] = Histogram(name)
            return inst

    def snapshot(self) -> Dict[str, Any]:
        """Every instrument's current value, one JSON-able dict."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {n: c.value for n, c in sorted(counters.items())},
            "gauges": {n: g.value for n, g in sorted(gauges.items())},
            "histograms": {
                n: h.summary() for n, h in sorted(histograms.items())
            },
        }
