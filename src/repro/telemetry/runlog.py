"""Run traces persisted to the lake — the ``runlog`` namespace.

A run's full event stream is written as one content-addressed blob plus
a small ref (``refs/runlog/run_<id>``) pointing at it, so traces are
first-class lake artifacts: branchable, content-addressed, and GC-able
like everything else.  Reachability (repro.maintenance.reachability)
treats runlog refs as roots **only within a retention TTL** — an expired
trace's ref is swept by ``repro gc --runlog-ttl`` and its blob is
reclaimed on the same pass, while live traces keep their bytes pinned.

``RunHandle.trace()`` / ``Client.trace(run_id)`` / ``repro trace`` all
read back through here.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.io.objectstore import ObjectStore
from repro.telemetry.events import Event, event_from_json_dict

__all__ = ["RUNLOG_NS", "RunLogStore"]

RUNLOG_NS = "runlog"


@dataclass
class RunLogStore:
    store: ObjectStore

    def _ref_name(self, run_id: int) -> str:
        return f"run_{run_id}"

    def put(
        self,
        run_id: int,
        events: Sequence[Event],
        *,
        pipeline: str = "",
        state: str = "",
    ) -> str:
        """Persist one run's events; returns the trace blob's key."""
        payload = json.dumps(
            {"run_id": run_id, "events": [e.to_json_dict() for e in events]},
            sort_keys=True,
        ).encode()
        blob = self.store.put(payload)
        self.store.set_ref(
            RUNLOG_NS,
            self._ref_name(run_id),
            {
                "run_id": run_id,
                "blob": blob,
                "events": len(events),
                "pipeline": pipeline,
                "state": state,
                "created_at": time.time(),
            },
        )
        return blob

    def get(self, run_id: int) -> List[Event]:
        """Load a run's events (KeyError if the trace is absent/expired)."""
        ref = self.store.get_ref(RUNLOG_NS, self._ref_name(run_id))
        if ref is None:
            raise KeyError(
                f"no runlog trace for run {run_id} (never recorded, "
                "telemetry disabled, or expired by gc --runlog-ttl)"
            )
        raw = json.loads(self.store.get(ref["blob"]))
        return [event_from_json_dict(d) for d in raw["events"]]

    def has(self, run_id: int) -> bool:
        return self.store.get_ref(RUNLOG_NS, self._ref_name(run_id)) is not None

    def refs(self) -> Dict[str, Dict]:
        """Every runlog ref (name -> {run_id, blob, created_at, ...})."""
        return self.store.list_refs(RUNLOG_NS)

    def live_blobs(self, *, ttl_s: Optional[float] = None) -> Dict[str, str]:
        """ref name -> blob key for refs still inside the retention TTL
        (None = every trace is live).  The reachability mark adds these
        blobs to the live object set."""
        now = time.time()
        out: Dict[str, str] = {}
        for name, ref in self.refs().items():
            if ttl_s is not None and now - ref.get("created_at", 0.0) > ttl_s:
                continue
            out[name] = ref["blob"]
        return out

    def sweep_expired(self, *, ttl_s: float, dry_run: bool = False) -> int:
        """Drop refs older than the TTL; their blobs become unreachable
        and fall to the same GC pass's object sweep.  Returns the count."""
        now = time.time()
        swept = 0
        for name, ref in self.refs().items():
            if now - ref.get("created_at", 0.0) > ttl_s:
                swept += 1
                if not dry_run:
                    self.store.delete_ref(RUNLOG_NS, name)
        return swept
