"""repro.telemetry — event bus, run tracing, and the unified metrics plane.

The observability layer the service plane publishes into (ROADMAP: run
queue + lakekeeper daemon + event bus):

* ``repro.telemetry.events``  — the typed event schema (Run/Stage/
  NodeCache/Speculation/Scan/Gc/Compaction kinds) with per-run monotonic
  sequence numbers;
* ``repro.telemetry.bus``     — in-process multi-consumer bus with
  bounded per-subscriber buffers, drop accounting, and an on-disk spool
  for cross-process tailing (``repro events --follow``);
* ``repro.telemetry.tracing`` — span assembly (run→stage→node→scan),
  critical-path analysis, Chrome trace export (``repro trace``);
* ``repro.telemetry.metrics`` — counters/gauges/histograms behind one
  registry (absorbs ``StoreStats`` bumps + executor latencies);
* ``repro.telemetry.runlog``  — traces persisted to the lake as
  GC-able artifacts under the ``runlog`` namespace.
"""
from repro.telemetry.bus import EventBus, Subscription, follow_spool, read_spool
from repro.telemetry.events import (
    EVENT_TYPES,
    CompactionApplied,
    Event,
    GcSweep,
    NodeCacheHit,
    NodeCacheMiss,
    NodeCacheRehydrated,
    QueryExecuted,
    RunFinished,
    RunStarted,
    ScanShardRead,
    SpeculationArmed,
    SpeculationFired,
    SpeculationWon,
    StageCommitted,
    StageFinished,
    StageQueued,
    StageStarted,
    event_from_json_dict,
)
from repro.telemetry.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.telemetry.runlog import RUNLOG_NS, RunLogStore
from repro.telemetry.tracing import RunTrace, Span

__all__ = [
    "EventBus",
    "Subscription",
    "read_spool",
    "follow_spool",
    "Event",
    "EVENT_TYPES",
    "event_from_json_dict",
    "RunStarted",
    "RunFinished",
    "StageQueued",
    "StageStarted",
    "StageFinished",
    "StageCommitted",
    "NodeCacheHit",
    "NodeCacheMiss",
    "NodeCacheRehydrated",
    "SpeculationArmed",
    "SpeculationFired",
    "SpeculationWon",
    "ScanShardRead",
    "QueryExecuted",
    "GcSweep",
    "CompactionApplied",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "RunLogStore",
    "RUNLOG_NS",
    "RunTrace",
    "Span",
]
