"""Span-based run tracing: events in, a run→stage→node→scan tree out.

The trace assembler consumes one run's event stream (live from the bus,
or loaded back from the ``runlog`` namespace) and rebuilds where the
wall-clock went:

* the **run span** (RunStarted → RunFinished) is the root;
* a **plan phase** covers planning + cache rehydration (with one
  ``rehydrate`` child span per restored node — a warm run is *all*
  rehydrate spans, which is exactly what the differential cache promised);
* each stage owns a lane with **queue** (scheduler handoff → driver
  start), **exec** (scan → execute → write) and **commit** spans; scan
  shard reads and the stage's logical nodes nest inside exec.  Nodes of
  a fused stage share the executor window — the platform deliberately
  does not time individual nodes inside one jitted stage function, so
  their spans carry ``fused_with`` instead of fabricated durations;
* an **audit+write phase** covers the expectation gate + atomic merge.

``critical_path()`` walks the stage dependency edges (carried on
``StageQueued.parents``) to the longest queue+exec chain — the stages a
speedup must target.  It delegates to the SAME longest-path
implementation the Scheduler-v2 cost model uses for dispatch ordering
(``repro.core.physical.longest_path_weights`` / ``critical_path_ids``),
fed observed latencies instead of estimates — one implementation, two
cost sources.  ``StageScheduled`` events are joined onto the stage
lanes, so ``describe()`` reports predicted-vs-actual per stage.
``to_chrome_trace()`` exports the tree as Chrome trace-event JSON (load
in ``chrome://tracing`` / Perfetto).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.telemetry.events import (
    Event,
    NodeCacheRehydrated,
    RunFinished,
    RunStarted,
    ScanShardRead,
    StageCommitted,
    StageFinished,
    StageQueued,
    StageScheduled,
    StageStarted,
)

__all__ = ["Span", "RunTrace"]


@dataclass
class Span:
    name: str
    #: run | phase | queue | exec | commit | node | scan | rehydrate
    kind: str
    start: float
    end: float
    #: display lane ("run", "stage 3", ...) — the Chrome tid
    lane: str = "run"
    attrs: Dict[str, Any] = field(default_factory=dict)
    children: List["Span"] = field(default_factory=list)

    @property
    def dur(self) -> float:
        return max(0.0, self.end - self.start)

    def walk(self) -> List["Span"]:
        out = [self]
        for c in self.children:
            out.extend(c.walk())
        return out


def _union_seconds(intervals: Sequence[Tuple[float, float]]) -> float:
    """Total wall seconds covered by the union of [start, end) intervals."""
    covered = 0.0
    hi = None
    for s, e in sorted(intervals):
        if e <= s:
            continue
        if hi is None or s > hi:
            covered += e - s
            hi = e
        elif e > hi:
            covered += e - hi
            hi = e
    return covered


@dataclass
class RunTrace:
    run_id: int
    root: Span
    #: stage_id -> {"queue": Span, "exec": Span, "commit": Span?}
    stage_spans: Dict[int, Dict[str, Span]]
    #: stage_id -> parent stage ids (the scheduler's dependency edges)
    stage_parents: Dict[int, List[int]]
    state: str = "SUCCESS"
    events: List[Event] = field(default_factory=list)
    #: stage_id -> the scheduler's admission decision (cost estimate,
    #: critical-path rank, admission wait) — predicted-vs-actual source
    stage_scheduled: Dict[int, StageScheduled] = field(default_factory=dict)

    # ------------------------------------------------------------ assembly
    @classmethod
    def from_events(
        cls, events: Sequence[Event], *, run_id: Optional[int] = None
    ) -> "RunTrace":
        events = sorted(events, key=lambda e: (e.ts, e.seq))
        started = next((e for e in events if isinstance(e, RunStarted)), None)
        finished = next((e for e in events if isinstance(e, RunFinished)), None)
        if not events:
            raise ValueError("cannot build a trace from zero events")
        if run_id is None:
            run_id = next(
                (e.run_id for e in events if e.run_id is not None), -1
            )
        t0 = started.ts if started is not None else events[0].ts
        t1 = finished.ts if finished is not None else events[-1].ts
        state = finished.state if finished is not None else "UNKNOWN"

        root = Span(
            name=f"run {run_id}",
            kind="run",
            start=t0,
            end=max(t1, t0),
            lane="run",
            attrs={
                "state": state,
                "pipeline": started.pipeline if started else "",
                "branch": started.branch if started else "",
            },
        )

        # ---- per-stage event index
        queued: Dict[int, StageQueued] = {}
        scheduled: Dict[int, StageScheduled] = {}
        started_ev: Dict[int, StageStarted] = {}
        finished_ev: Dict[int, StageFinished] = {}
        committed: Dict[int, StageCommitted] = {}
        scans: Dict[Optional[int], List[ScanShardRead]] = {}
        rehydrated: List[NodeCacheRehydrated] = []
        for e in events:
            if isinstance(e, StageQueued):
                queued[e.stage_id] = e
            elif isinstance(e, StageScheduled):
                scheduled[e.stage_id] = e
            elif isinstance(e, StageStarted):
                started_ev[e.stage_id] = e
            elif isinstance(e, StageFinished):
                finished_ev[e.stage_id] = e
            elif isinstance(e, StageCommitted):
                committed[e.stage_id] = e
            elif isinstance(e, ScanShardRead):
                scans.setdefault(e.stage_id, []).append(e)
            elif isinstance(e, NodeCacheRehydrated):
                rehydrated.append(e)

        # ---- phases
        first_queued = min((e.ts for e in queued.values()), default=None)
        plan_end = first_queued
        if plan_end is None:
            plan_end = max((e.ts for e in rehydrated), default=root.end)
        plan = Span(
            name="plan+rehydrate",
            kind="phase",
            start=root.start,
            end=min(max(plan_end, root.start), root.end),
            lane="run",
        )
        for e in rehydrated:
            plan.children.append(
                Span(
                    name=f"rehydrate {e.node}",
                    kind="rehydrate",
                    start=max(root.start, e.ts - e.dur_s),
                    end=e.ts,
                    lane="run",
                    attrs={"node": e.node, "bytes": e.bytes},
                )
            )
        root.children.append(plan)

        # ---- stage lanes
        stage_spans: Dict[int, Dict[str, Span]] = {}
        stage_parents: Dict[int, List[int]] = {}
        last_stage_ts = plan.end
        for sid in sorted(queued):
            q = queued[sid]
            lane = f"stage {sid}"
            stage_parents[sid] = list(q.parents)
            s_ev, f_ev, c_ev = (
                started_ev.get(sid), finished_ev.get(sid), committed.get(sid)
            )
            spans: Dict[str, Span] = {}
            exec_start = s_ev.ts if s_ev is not None else q.ts
            q_attrs: Dict[str, Any] = {"nodes": list(q.nodes)}
            sched = scheduled.get(sid)
            if sched is not None:
                q_attrs.update(
                    est_cost_s=sched.est_cost_s,
                    cost_source=sched.cost_source,
                    cp_weight_s=sched.cp_weight_s,
                    cp_rank=sched.cp_rank,
                    est_memory_gb=sched.est_memory_gb,
                    admission=sched.admission,
                    admission_wait_s=sched.admission_wait_s,
                    warm=sched.warm,
                )
            queue_span = Span(
                name=f"queue stage {sid}",
                kind="queue",
                start=q.ts,
                end=exec_start,
                lane=lane,
                attrs=q_attrs,
            )
            spans["queue"] = queue_span
            root.children.append(queue_span)
            if s_ev is not None:
                exec_end = f_ev.ts if f_ev is not None else root.end
                exec_span = Span(
                    name=f"exec stage {sid}",
                    kind="exec",
                    start=exec_start,
                    end=exec_end,
                    lane=lane,
                    attrs={
                        "nodes": list(q.nodes),
                        "outputs": list(f_ev.outputs) if f_ev else [],
                        "checks": list(f_ev.checks) if f_ev else [],
                        "incomplete": f_ev is None,
                    },
                )
                for scan in scans.get(sid, ()):
                    exec_span.children.append(
                        Span(
                            name=f"scan {scan.table}[{scan.shard_index}]",
                            kind="scan",
                            start=scan.ts,
                            end=scan.ts + scan.dur_s,
                            lane=lane,
                            attrs={
                                "table": scan.table,
                                "rows_in": scan.rows_in,
                                "rows_out": scan.rows_out,
                            },
                        )
                    )
                for node in q.nodes:
                    # fused nodes share the executor window (see module doc)
                    exec_span.children.append(
                        Span(
                            name=f"node {node}",
                            kind="node",
                            start=exec_span.start,
                            end=exec_span.end,
                            lane=lane,
                            attrs={
                                "fused_with": [n for n in q.nodes if n != node]
                            },
                        )
                    )
                spans["exec"] = exec_span
                root.children.append(exec_span)
                last_stage_ts = max(last_stage_ts, exec_span.end)
            if c_ev is not None:
                commit_span = Span(
                    name=f"commit stage {sid}",
                    kind="commit",
                    start=max(root.start, c_ev.ts - c_ev.commit_s),
                    end=c_ev.ts,
                    lane=lane,
                    attrs={"tables": list(c_ev.tables)},
                )
                spans["commit"] = commit_span
                root.children.append(commit_span)
                last_stage_ts = max(last_stage_ts, commit_span.end)
            stage_spans[sid] = spans

        # interactive/query scans carry no stage — attach them to the root
        for scan in scans.get(None, ()):
            root.children.append(
                Span(
                    name=f"scan {scan.table}[{scan.shard_index}]",
                    kind="scan",
                    start=scan.ts,
                    end=scan.ts + scan.dur_s,
                    lane="run",
                    attrs={"table": scan.table, "rows_out": scan.rows_out},
                )
            )

        write = Span(
            name="audit+write",
            kind="phase",
            start=min(max(last_stage_ts, root.start), root.end),
            end=root.end,
            lane="run",
        )
        root.children.append(write)

        return cls(
            run_id=run_id,
            root=root,
            stage_spans=stage_spans,
            stage_parents=stage_parents,
            state=state,
            events=list(events),
            stage_scheduled=scheduled,
        )

    # ------------------------------------------------------------ analysis
    def coverage(self) -> float:
        """Fraction of the run's wall-clock accounted for by child spans
        (the ≥95% acceptance bar: if this drops, some phase of the run
        has gone dark and the trace is lying by omission)."""
        if self.root.dur <= 0.0:
            return 1.0
        intervals = [
            (s.start, s.end) for s in self.root.children
        ]
        return min(1.0, _union_seconds(intervals) / self.root.dur)

    def stage_latency(self, sid: int) -> float:
        """Queue + exec seconds for one stage (commit excluded: commits
        are serialized in stage-id order and overlap later stages)."""
        spans = self.stage_spans.get(sid, {})
        q = spans.get("queue")
        ex = spans.get("exec")
        return (q.dur if q else 0.0) + (ex.dur if ex else 0.0)

    def critical_path(self) -> List[int]:
        """Stage ids on the longest dependency chain by queue+exec time.

        Delegates to the scheduler's own longest-path implementation
        (``repro.core.physical``) fed *observed* stage latencies — the
        table `repro trace` prints and the order Scheduler v2 dispatched
        by come from one algorithm, so they are directly comparable.
        """
        # lazy import: telemetry stays importable without the planner
        from repro.core.physical import critical_path_ids

        costs = {
            sid: self.stage_latency(sid) for sid in self.stage_spans
        }
        if not costs:
            return []
        parents = {
            sid: tuple(
                p for p in self.stage_parents.get(sid, []) if p in costs
            )
            for sid in costs
        }
        return critical_path_ids(costs, parents)

    # ------------------------------------------------------------- reports
    def describe(self) -> str:
        """The ``repro trace`` critical-path table."""
        lines = [
            f"run {self.run_id}: state={self.state} "
            f"wall={self.root.dur * 1e3:.1f}ms coverage={self.coverage():.1%}"
        ]
        crit = set(self.critical_path())
        if self.stage_spans:
            show_sched = bool(self.stage_scheduled)
            header = (
                f"{'stage':>5}  {'queue_ms':>9} {'exec_ms':>9} "
                f"{'commit_ms':>9}  {'crit':>4}"
            )
            if show_sched:
                header += f"  {'est_ms':>8} {'src':>7} {'rank':>4} {'adm':>9}"
            lines.append(header + "  nodes")
            for sid in sorted(self.stage_spans):
                spans = self.stage_spans[sid]
                q = spans.get("queue")
                ex = spans.get("exec")
                co = spans.get("commit")
                nodes = (q.attrs.get("nodes") if q else None) or []
                row = (
                    f"{sid:>5}  "
                    f"{(q.dur if q else 0) * 1e3:>9.1f} "
                    f"{(ex.dur if ex else 0) * 1e3:>9.1f} "
                    f"{(co.dur if co else 0) * 1e3:>9.1f}  "
                    f"{'*' if sid in crit else '':>4}"
                )
                if show_sched:
                    sched = self.stage_scheduled.get(sid)
                    if sched is not None:
                        row += (
                            f"  {sched.est_cost_s * 1e3:>8.1f} "
                            f"{sched.cost_source:>7} {sched.cp_rank:>4} "
                            f"{sched.admission:>9}"
                        )
                    else:
                        row += f"  {'-':>8} {'-':>7} {'-':>4} {'-':>9}"
                lines.append(row + f"  {','.join(nodes)}")
            crit_s = sum(self.stage_latency(s) for s in crit)
            lines.append(
                f"critical path: stages {sorted(crit)} "
                f"({crit_s * 1e3:.1f}ms, {crit_s / max(self.root.dur, 1e-9):.0%} "
                f"of wall)"
            )
            if self.stage_scheduled:
                pred = sum(
                    e.est_cost_s for e in self.stage_scheduled.values()
                )
                actual = sum(
                    (self.stage_spans[s].get("exec").dur
                     if self.stage_spans[s].get("exec") else 0.0)
                    for s in self.stage_scheduled
                    if s in self.stage_spans
                )
                waited = sum(
                    1 for e in self.stage_scheduled.values()
                    if e.admission == "waited"
                )
                sample = next(iter(self.stage_scheduled.values()))
                lines.append(
                    f"scheduler: {sample.schedule} "
                    f"(streaming={'on' if sample.streaming else 'off'}) "
                    f"predicted {pred * 1e3:.1f}ms vs actual "
                    f"{actual * 1e3:.1f}ms exec; "
                    f"{waited} admission wait(s)"
                )
        rehydrate = [
            s for s in self.root.walk() if s.kind == "rehydrate"
        ]
        if rehydrate:
            lines.append(
                f"rehydrated {len(rehydrate)} node(s) from the differential "
                f"cache ({sum(s.attrs.get('bytes', 0) for s in rehydrate)} "
                f"bytes not recomputed)"
            )
        return "\n".join(lines)

    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (the ``--chrome out.json`` payload).

        Complete ("X") events on one pid (the run id), one tid per lane —
        loadable in chrome://tracing or https://ui.perfetto.dev.
        """
        pid = max(self.run_id, 0)
        lanes: Dict[str, int] = {"run": 0}
        trace_events: List[Dict[str, Any]] = []
        for span in self.root.walk():
            tid = lanes.setdefault(span.lane, len(lanes))
            trace_events.append(
                {
                    "name": span.name,
                    "cat": span.kind,
                    "ph": "X",
                    "ts": span.start * 1e6,  # microseconds
                    "dur": span.dur * 1e6,
                    "pid": pid,
                    "tid": tid,
                    "args": span.attrs,
                }
            )
        meta = [
            {
                "name": "process_name",
                "ph": "M",
                "pid": pid,
                "tid": 0,
                "args": {"name": f"repro run {self.run_id} [{self.state}]"},
            }
        ] + [
            {
                "name": "thread_name",
                "ph": "M",
                "pid": pid,
                "tid": tid,
                "args": {"name": lane},
            }
            for lane, tid in lanes.items()
        ]
        return {
            "traceEvents": meta + trace_events,
            "displayTimeUnit": "ms",
            "otherData": {
                "run_id": self.run_id,
                "state": self.state,
                "coverage": self.coverage(),
            },
        }

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.to_chrome_trace(), fh, indent=1)
