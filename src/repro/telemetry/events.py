"""The typed telemetry event schema — one vocabulary for the whole platform.

Every component that does observable work (the wave scheduler, the
serverless executor, the scan pool, the lakekeeper) publishes one of the
event types below onto the :class:`repro.telemetry.bus.EventBus`.  Events
are plain dataclasses with a ``kind`` discriminator so they round-trip
through JSON — the run log persisted to the lake (``runlog`` namespace),
the live spool file tailed by ``repro events --follow``, and the Chrome
trace export all speak this one schema.

Two fields are stamped by the bus at publish time, never by the caller:

* ``ts``  — wall-clock seconds (``time.time()``); span durations carried
  on the events themselves (``dur_s``/``exec_s``/...) are measured with
  ``perf_counter`` at the site, so the trace assembler prefers those;
* ``seq`` — monotonic sequence number **per run** (events without a
  ``run_id`` share one global scope), so a consumer can detect gaps after
  a bounded buffer dropped on it, and the run log has a total order that
  does not depend on thread interleaving of equal timestamps.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Any, ClassVar, Dict, List, Optional, Type

__all__ = [
    "Event",
    "RunStarted",
    "RunFinished",
    "StageScheduled",
    "StageQueued",
    "StageStarted",
    "StageFinished",
    "StageCommitted",
    "NodeCacheHit",
    "NodeCacheMiss",
    "NodeCacheRehydrated",
    "SpeculationArmed",
    "SpeculationFired",
    "SpeculationWon",
    "ScanShardRead",
    "QueryExecuted",
    "GcSweep",
    "CompactionApplied",
    "EVENT_TYPES",
    "event_from_json_dict",
]


@dataclass
class Event:
    """Base event: the envelope every concrete kind shares.

    Subclass fields must stay JSON-serializable (str/int/float/bool and
    flat lists thereof) — events are persisted verbatim to the run log.
    """

    kind: ClassVar[str] = "Event"

    #: the run this event belongs to (None for maintenance/global events)
    run_id: Optional[int] = None
    #: wall-clock seconds; stamped by the bus unless the publisher set it
    #: (publishers that measured a span set ts to the span *start*)
    ts: float = 0.0
    #: per-run monotonic sequence number, stamped by the bus
    seq: int = 0

    def to_json_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        d["kind"] = self.kind
        return d


# ------------------------------------------------------------------ run
@dataclass
class RunStarted(Event):
    kind: ClassVar[str] = "RunStarted"
    pipeline: str = ""
    branch: str = ""
    #: set when this run re-executes a recorded one (Runner.replay)
    replay_of: Optional[int] = None


@dataclass
class RunFinished(Event):
    """Always emitted, whatever the outcome — a mid-DAG stage crash or a
    failed audit still closes the run span (state carries the verdict)."""

    kind: ClassVar[str] = "RunFinished"
    #: SUCCESS | AUDIT_FAILED | ERROR
    state: str = "SUCCESS"
    wall_s: float = 0.0
    failed_checks: List[str] = field(default_factory=list)


# ---------------------------------------------------------------- stages
@dataclass
class StageScheduled(Event):
    """The Scheduler-v2 admission decision for one stage: the cost-model
    estimate that ordered it, its critical-path rank, and how long the
    memory-capped admission gate held it after it became ready.  `repro
    trace` joins this against StageStarted/StageFinished for the
    predicted-vs-actual table."""

    kind: ClassVar[str] = "StageScheduled"
    stage_id: int = 0
    #: estimated runtime seconds ("latency" = latencyhist median,
    #: "bytes" = scan-bytes heuristic)
    est_cost_s: float = 0.0
    cost_source: str = "bytes"
    #: longest-path-to-sink weight and rank (0 = most critical)
    cp_weight_s: float = 0.0
    cp_rank: int = 0
    #: estimated peak memory tier charged against the admission budget
    est_memory_gb: int = 1
    #: seconds between becoming ready (parents satisfied) and admission
    admission_wait_s: float = 0.0
    #: "immediate" | "waited" — whether the admission gate held the stage
    admission: str = "immediate"
    #: ordering mode ("critical_path" | "stage_id") and streaming handoff
    schedule: str = "critical_path"
    streaming: bool = False
    #: compiled executable already cached for this stage's fingerprint
    warm: bool = False


@dataclass
class StageQueued(Event):
    """The wave scheduler handed the stage to the executor's stage lane;
    queue time is StageStarted.ts - StageQueued.ts."""

    kind: ClassVar[str] = "StageQueued"
    stage_id: int = 0
    nodes: List[str] = field(default_factory=list)
    #: dependency edges — lets the trace assembler compute the critical
    #: path without re-planning the pipeline
    parents: List[int] = field(default_factory=list)


@dataclass
class StageStarted(Event):
    kind: ClassVar[str] = "StageStarted"
    stage_id: int = 0


@dataclass
class StageFinished(Event):
    """The stage driver finished scan → execute → write (commit pending)."""

    kind: ClassVar[str] = "StageFinished"
    stage_id: int = 0
    exec_s: float = 0.0
    outputs: List[str] = field(default_factory=list)
    checks: List[str] = field(default_factory=list)


@dataclass
class StageCommitted(Event):
    """The stage's table updates landed on the ephemeral branch (commits
    are applied in stage-id order, possibly by a later stage's thread)."""

    kind: ClassVar[str] = "StageCommitted"
    stage_id: int = 0
    tables: List[str] = field(default_factory=list)
    commit_s: float = 0.0


# ----------------------------------------------------------------- cache
@dataclass
class NodeCacheHit(Event):
    """A logical node the differential cache satisfied at plan time."""

    kind: ClassVar[str] = "NodeCacheHit"
    node: str = ""
    fingerprint: str = ""
    #: True when the node's artifact is restored (committed) this run;
    #: False for elided nodes and audited-check hits
    rehydrated: bool = False
    bytes: int = 0


@dataclass
class NodeCacheMiss(Event):
    """A logical node the plan must execute (cache consulted, no entry)."""

    kind: ClassVar[str] = "NodeCacheMiss"
    node: str = ""
    fingerprint: str = ""
    stage_id: int = 0


@dataclass
class NodeCacheRehydrated(Event):
    """A cached artifact's manifest was committed to the run's ephemeral
    branch instead of being recomputed (the rehydrate span)."""

    kind: ClassVar[str] = "NodeCacheRehydrated"
    node: str = ""
    bytes: int = 0
    dur_s: float = 0.0


# ----------------------------------------------------------- speculation
@dataclass
class SpeculationArmed(Event):
    """A straggler deadline was armed against the task's latency history."""

    kind: ClassVar[str] = "SpeculationArmed"
    task: str = ""
    stage_id: Optional[int] = None
    baseline_s: float = 0.0
    deadline_s: float = 0.0


@dataclass
class SpeculationFired(Event):
    """The deadline passed — a duplicate container launched."""

    kind: ClassVar[str] = "SpeculationFired"
    task: str = ""
    stage_id: Optional[int] = None


@dataclass
class SpeculationWon(Event):
    """The backup finished (successfully) before the straggler."""

    kind: ClassVar[str] = "SpeculationWon"
    task: str = ""
    stage_id: Optional[int] = None


# ------------------------------------------------------------------ scans
@dataclass
class ScanShardRead(Event):
    """One shard read (+ residual filter) by the scan pool.  ``ts`` is the
    read's start; ``dur_s`` its wall duration — together they place the
    scan span inside its stage lane."""

    kind: ClassVar[str] = "ScanShardRead"
    table: str = ""
    shard_index: int = 0
    rows_in: int = 0
    rows_out: int = 0
    dur_s: float = 0.0
    #: "stage" for pipeline scans, "query" for interactive client.query()
    source: str = "stage"
    stage_id: Optional[int] = None


@dataclass
class QueryExecuted(Event):
    """One interactive query completed (point-wise path, paper 4.6).

    ``engine_path`` records which engine ran the filter+group+agg
    pipeline ("kernel" = fused Pallas kernel, "jnp" = reference path) and
    the ``*_s`` attrs break the wall clock into per-operator phases —
    parse, plan (catalog + routing + scan planning), scan (pooled shard
    reads), exec (compiled query)."""

    kind: ClassVar[str] = "QueryExecuted"
    table: str = ""
    rows_out: int = 0
    shards_read: int = 0
    wall_s: float = 0.0
    engine_path: str = "jnp"
    parse_s: float = 0.0
    plan_s: float = 0.0
    scan_s: float = 0.0
    exec_s: float = 0.0


# ------------------------------------------------------------ maintenance
@dataclass
class GcSweep(Event):
    kind: ClassVar[str] = "GcSweep"
    swept_objects: int = 0
    swept_commits: int = 0
    swept_runlog_refs: int = 0
    bytes_reclaimed: int = 0
    dry_run: bool = False


@dataclass
class CompactionApplied(Event):
    kind: ClassVar[str] = "CompactionApplied"
    table: str = ""
    branch: str = ""
    shards_before: int = 0
    shards_after: int = 0
    shards_merged: int = 0
    dry_run: bool = False


#: kind discriminator -> event class (the run-log reader's vocabulary)
EVENT_TYPES: Dict[str, Type[Event]] = {
    cls.kind: cls
    for cls in (
        RunStarted,
        RunFinished,
        StageScheduled,
        StageQueued,
        StageStarted,
        StageFinished,
        StageCommitted,
        NodeCacheHit,
        NodeCacheMiss,
        NodeCacheRehydrated,
        SpeculationArmed,
        SpeculationFired,
        SpeculationWon,
        ScanShardRead,
        QueryExecuted,
        GcSweep,
        CompactionApplied,
    )
}


def event_from_json_dict(d: Dict[str, Any]) -> Event:
    """Rebuild a typed event from its JSON form.  Unknown kinds (a newer
    writer) degrade to the base ``Event`` rather than failing the reader;
    unknown fields on a known kind are dropped for the same reason."""
    d = dict(d)
    kind = d.pop("kind", "Event")
    cls = EVENT_TYPES.get(kind, Event)
    known = {f for f in cls.__dataclass_fields__}  # type: ignore[attr-defined]
    return cls(**{k: v for k, v in d.items() if k in known})
