"""Declarative pipeline definition — "functions are all you need" (4.1).

Users declare artifacts one by one; the DAG is *implicit*:

* a SQL node's parent is whatever its ``FROM`` references;
* a Python node's parents are its argument names (after ``ctx``);
* a function named ``<something>_expectation`` is an audit, not an artifact.

No imperative DAG wiring anywhere — exactly the paper's dbt-style
one-query-one-artifact pattern, with the Appendix code reproducible
almost verbatim (see examples/taxi_pipeline.py).
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.engine.query import Query
from repro.engine.sql import parse_sql
from repro.utils.hashing import fingerprint_fn, stable_hash


class PipelineError(ValueError):
    pass


@dataclass(frozen=True)
class Node:
    """One artifact (or one audit) in the DAG."""

    name: str
    kind: str  # "sql" | "python" | "expectation"
    parents: Tuple[str, ...]
    query: Optional[Query] = None
    fn: Optional[Callable] = None
    requirements: Dict[str, str] = field(default_factory=dict)
    #: force materialization of this artifact even if fused past
    materialize: bool = False
    #: where the node was declared (decoration/registration site) — lint
    #: diagnostics only, deliberately excluded from the fingerprint
    source_file: Optional[str] = field(default=None, compare=False)
    source_line: Optional[int] = field(default=None, compare=False)

    @property
    def is_expectation(self) -> bool:
        return self.kind == "expectation"

    @property
    def fingerprint(self) -> str:
        payload: Dict[str, Any] = {
            "name": self.name,
            "kind": self.kind,
            "parents": list(self.parents),
            "requirements": self.requirements,
            "materialize": self.materialize,
        }
        if self.query is not None:
            payload["query"] = self.query.to_json_dict()
        if self.fn is not None:
            payload["fn"] = fingerprint_fn(self.fn)
        return stable_hash(payload)


def requirements(reqs: Dict[str, str]) -> Callable:
    """The paper's ``@requirements({'pandas': '2.0.0'})`` decorator.

    In a single-process JAX runtime the packages are fixed, so the pinned
    requirements become part of the node fingerprint (reproducibility key)
    rather than a pip install — see DESIGN.md 2.
    """

    def deco(fn: Callable) -> Callable:
        fn.__repro_requirements__ = dict(reqs)
        return fn

    return deco


class Pipeline:
    """A named collection of nodes. Purely declarative — running is the
    Runner's job (sync or async, Table 1)."""

    def __init__(self, name: str):
        self.name = name
        self.nodes: Dict[str, Node] = {}

    # ----------------------------------------------------------- builders
    def _add(self, node: Node) -> None:
        if node.name in self.nodes:
            raise PipelineError(f"duplicate artifact {node.name!r}")
        for p in node.parents:
            if p == node.name:
                raise PipelineError(f"node {node.name!r} references itself")
        self.nodes[node.name] = node

    def add_node(self, node: Node) -> Node:
        """Add a fully-formed node (the SDK's ``Project`` assembles nodes
        from decorator registrations and installs them through here)."""
        self._add(node)
        return node

    def sql(self, name: str, sql_text: str, *, materialize: bool = False) -> Node:
        """Declare a SQL artifact; its parent is the FROM table."""
        query = parse_sql(sql_text)
        caller = inspect.currentframe().f_back
        node = Node(
            name=name,
            kind="sql",
            parents=tuple(query.source_tables()),
            query=query,
            materialize=materialize,
            source_file=caller.f_code.co_filename if caller else None,
            source_line=caller.f_lineno if caller else None,
        )
        self._add(node)
        return node

    def python(
        self, fn: Optional[Callable] = None, *, materialize: bool = False
    ) -> Callable:
        """Declare a Python artifact or expectation from a function.

        Usage::

            @p.python
            def pickups(ctx, trips): ...          # artifact "pickups"

            @p.python
            def trips_expectation(ctx, trips): ... # audit on "trips"
        """

        def deco(f: Callable) -> Callable:
            params = list(inspect.signature(f).parameters)
            if not params or params[0] != "ctx":
                raise PipelineError(
                    f"python node {f.__name__!r} must take ctx as first arg"
                )
            parents = tuple(params[1:])
            if not parents:
                raise PipelineError(
                    f"python node {f.__name__!r} references no parent tables"
                )
            kind = "expectation" if f.__name__.endswith("_expectation") else "python"
            node = Node(
                name=f.__name__,
                kind=kind,
                parents=parents,
                fn=f,
                requirements=getattr(f, "__repro_requirements__", {}),
                materialize=materialize and kind != "expectation",
                source_file=getattr(f.__code__, "co_filename", None),
                source_line=getattr(f.__code__, "co_firstlineno", None),
            )
            self._add(node)
            return f

        return deco(fn) if fn is not None else deco

    # ----------------------------------------------------------- analysis
    @property
    def artifacts(self) -> List[str]:
        return [n.name for n in self.nodes.values() if not n.is_expectation]

    @property
    def expectations(self) -> List[str]:
        return [n.name for n in self.nodes.values() if n.is_expectation]

    def consumers(self, name: str) -> List[str]:
        return [n.name for n in self.nodes.values() if name in n.parents]

    def external_sources(self) -> List[str]:
        """Referenced tables that no node in the pipeline produces."""
        produced = set(self.artifacts)
        out: List[str] = []
        for n in self.nodes.values():
            for p in n.parents:
                if p not in produced and p not in out:
                    out.append(p)
        return out

    @property
    def fingerprint(self) -> str:
        """The run-reproducibility key for the whole project (4.4.1)."""
        return stable_hash(
            {
                "name": self.name,
                "nodes": {k: v.fingerprint for k, v in self.nodes.items()},
            }
        )
