"""Physical planner: fusion + scan pushdown (the paper's 4.4.2 optimization).

The first Bauplan version mapped the logical plan isomorphically — one
(serverless, stateless) function per node, every intermediate spilled to
object storage.  The optimized planner instead:

1. **pushes filters down** into the scan (shard pruning via min/max stats
   + residual row filter), so the in-memory table starts small;
2. **fuses** chains of nodes into a single stage executed as ONE jitted
   XLA program — SQL logic and Python expectations run in place on
   device-resident data, nothing round-trips through the store.

Both behaviours are switchable (``PlannerConfig``) because the naive plan
is the baseline the paper's 5x claim is measured against
(benchmarks/bench_fusion.py).

The planner is also **cache-aware** (the FaaS-and-Furious differential
cache, re-keyed at node granularity): every logical node gets a
*transitive fingerprint* — node code + upstream node fingerprints +
input table content hashes + run params — that is independent of how
nodes are fused into stages.  Given a ``CacheView``, the planner cuts
fused chains at cache boundaries: nodes the cache satisfies become
rehydrations (or are elided outright when nothing downstream needs
them), and stages are built only over the uncached remainder.  A fusion
config flip therefore re-plans *around* the warm cache instead of
invalidating it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.logical import LogicalPlan
from repro.core.pipeline import Node
from repro.core.snapshot import CacheView, NodeCacheEntry
from repro.engine.columnar import Columnar
from repro.engine.exec import execute_query
from repro.engine.expr import Expr
from repro.engine.query import Query
from repro.engine.route import RouteDecision, column_stats_for_query, plan_route
from repro.runtime.function import FunctionSpec
from repro.runtime.resources import CostModel, ResourceRequest
from repro.table.format import Snapshot
from repro.table.scan import Predicate, ScanPlan, plan_scan
from repro.utils.hashing import stable_hash


@dataclass(frozen=True)
class PlannerConfig:
    fusion: bool = True
    pushdown: bool = True
    #: cap on fused nodes per stage (very long chains recompile slowly)
    max_stage_nodes: int = 32
    #: SQL execution engine: "auto" routes eligible filter+group+agg
    #: pipelines through kernels/fused_filter_agg when byte-identity with
    #: the jnp path is provable from shard statistics (engine/route.py),
    #: "kernel" forces it, "jnp" pins the reference path.  NOT part of
    #: node fingerprints — both paths produce identical artifacts, so
    #: flipping the engine must keep the differential cache warm.
    sql_engine: str = "auto"


@dataclass(frozen=True)
class ScanSpec:
    """One external-table read feeding a stage."""

    table: str
    plan: ScanPlan
    #: bytes that will actually be read after shard/column pruning
    estimated_bytes: int

    @property
    def predicates(self) -> Tuple[Predicate, ...]:
        return self.plan.predicates


@dataclass
class Stage:
    stage_id: int
    node_names: Tuple[str, ...]
    scans: Dict[str, ScanSpec]
    internal_inputs: Tuple[str, ...]
    outputs: Tuple[str, ...]
    checks: Tuple[str, ...]
    fn: Callable[..., Tuple[Dict[str, Columnar], Dict[str, Any]]]
    resources: ResourceRequest
    fingerprint: str
    #: stage-level transitive identity (node code + upstream stage
    #: fingerprints + input table snapshot ids + run params).  This is the
    #: *legacy* (PR 1) differential-cache key — new entries are keyed by
    #: per-node fingerprints (``PhysicalPlan.node_fingerprints``) — kept so
    #: stage-keyed entries written by old lakes can still be matched and
    #: upgraded (``CacheView.adopt_legacy``).
    transitive_fingerprint: str = ""
    #: stage ids whose outputs feed this stage — the dependency edges the
    #: wave scheduler walks (always lower than this stage's id; restored
    #: cache inputs are not edges, they are committed before any stage runs)
    parent_stages: Tuple[int, ...] = ()
    #: per-SQL-node engine decisions (engine/route.py) — observability
    #: only, deliberately excluded from every fingerprint
    sql_routes: Dict[str, RouteDecision] = field(default_factory=dict)

    @property
    def input_order(self) -> Tuple[str, ...]:
        """Stage fn positional args: scans first (sorted), then internals."""
        return tuple(sorted(self.scans)) + self.internal_inputs


@dataclass
class PhysicalPlan:
    logical: LogicalPlan
    config: PlannerConfig
    stages: List[Stage]
    #: logical node name -> transitive node fingerprint (the cache key,
    #: independent of fusion grouping)
    node_fingerprints: Dict[str, str] = field(default_factory=dict)
    #: nodes the cache satisfied at plan time: name -> entry
    cached_nodes: Dict[str, NodeCacheEntry] = field(default_factory=dict)
    #: cache-satisfied artifacts the runner must restore (commit their
    #: cached manifest keys): contract outputs, inputs of executing
    #: stages, and same-config materialization points
    rehydrate: Tuple[str, ...] = ()
    #: cache-satisfied expectations — verdict True recorded at audit time,
    #: reported without re-evaluation
    cached_checks: Tuple[str, ...] = ()
    #: nodes neither executed nor rehydrated: nothing downstream of them
    #: needs their value this run (the fusion-flip win).  Contract outputs
    #: are never elided; an interior materialization the current config
    #: would have produced cold can be (see build_physical_plan)
    elided: Tuple[str, ...] = ()

    @property
    def num_materializations(self) -> int:
        return sum(len(s.outputs) for s in self.stages)

    @property
    def nodes_executed(self) -> int:
        """Logical nodes this plan actually computes (cache hits excluded)."""
        return sum(len(s.node_names) for s in self.stages)

    def describe(self) -> str:
        lines = [f"physical plan ({'fused' if self.config.fusion else 'isomorphic'}):"]
        for s in self.stages:
            scans = {
                t: f"{spec.plan.rows_to_read} rows"
                f" (-{spec.plan.pruned_shards} shards)"
                for t, spec in s.scans.items()
            }
            lines.append(
                f"  stage {s.stage_id}: nodes={list(s.node_names)} scans={scans} "
                f"inputs={list(s.internal_inputs)} outputs={list(s.outputs)} "
                f"checks={list(s.checks)} mem={s.resources.memory_gb}GB"
            )
        if self.cached_nodes:
            lines.append(
                f"  cache: rehydrate={list(self.rehydrate)} "
                f"checks={list(self.cached_checks)} elided={list(self.elided)}"
            )
        return "\n".join(lines)


def _ensure_columnar(value: Any, node_name: str) -> Columnar:
    if isinstance(value, Columnar):
        return value
    if isinstance(value, dict):
        return Columnar.from_arrays(value)
    raise TypeError(
        f"python node {node_name!r} must return a Columnar or a dict of "
        f"columns, got {type(value)}"
    )


def _make_stage_fn(
    ordered_nodes: Sequence[Node],
    rewrites: Dict[str, Query],
    input_order: Sequence[str],
    outputs: Sequence[str],
    ctx: Any,
    routes: Optional[Dict[str, RouteDecision]] = None,
) -> Callable:
    """Compose stage nodes into one pure function (jit-able end to end)."""
    routes = routes or {}

    def stage_fn(*inputs: Columnar):
        env: Dict[str, Columnar] = dict(zip(input_order, inputs))
        checks: Dict[str, Any] = {}
        for node in ordered_nodes:
            if node.kind == "sql":
                query = rewrites.get(node.name, node.query)
                joined = {j.table: env[j.table] for j in query.joins}
                env[node.name] = execute_query(
                    query,
                    env[query.source],
                    joined=joined or None,
                    route=routes.get(node.name),
                )
            elif node.kind == "python":
                out = node.fn(ctx, *[env[p] for p in node.parents])
                env[node.name] = _ensure_columnar(out, node.name)
            else:  # expectation — returns a (traced) boolean
                checks[node.name] = node.fn(ctx, *[env[p] for p in node.parents])
        return {name: env[name] for name in outputs}, checks

    return stage_fn


def _split_primary_pushdown(
    query: Query, snapshots: Dict[str, Snapshot]
) -> Tuple[List[Predicate], Optional[Expr]]:
    """Filter conjuncts pushable into the FROM table's scan, plus residual.

    Only predicates provably over the *primary* table are pushed: pushing
    into a joined table could change which duplicate-key row wins the
    first-match gather, and an unqualified column is attributed to the
    primary only when no (known) join table also owns the name.  Pushed
    predicates are re-keyed to the plain column name the shard stats use.
    """
    conjuncts = query.filter_expr._flatten_and()
    primary_qual = query.source_alias or query.source
    psnap = snapshots.get(query.source)
    primary_cols = set(psnap.schema.names) if psnap else set()
    join_cols: set = set()
    unknown_join = False
    for j in query.joins:
        s = snapshots.get(j.table)
        if s is None:
            unknown_join = True  # node-sourced join: columns unknowable here
        else:
            join_cols.update(s.schema.names)

    pushed: List[Predicate] = []
    residual: List[Expr] = []
    for c in conjuncts:
        p = c._as_simple_predicate()
        tail: Optional[str] = None
        if p is not None:
            if "." in p.column:
                qual, t = p.column.split(".", 1)
                if qual == primary_qual and t in primary_cols:
                    tail = t
            elif p.column in primary_cols and (
                not query.joins or (not unknown_join and p.column not in join_cols)
            ):
                tail = p.column
        if tail is not None:
            pushed.append(Predicate(tail, p.op, p.value))
        else:
            residual.append(c)
    res: Optional[Expr] = None
    for r in residual:
        res = r if res is None else Expr("and", (res, r))
    return pushed, res


def _columns_for_table(
    query: Query, table: str, snapshot: Snapshot
) -> Optional[List[str]]:
    """The (plain-named) columns of ``table`` the query touches.

    None means "read everything" — the SELECT * case.  Ambiguous plain
    references load the name from every owning table; the executor's
    combined relation then reports the ambiguity on use."""
    if not (query.projections or query.is_aggregation):
        return None
    names = set(snapshot.schema.names)
    quals = {q for q, t in query.qualifiers() if t == table}
    out: List[str] = []
    for r in query.referenced_columns():
        if "." in r:
            qual, tail = r.split(".", 1)
            if qual in quals and tail in names:
                out.append(tail)
        elif r in names:
            out.append(r)
    # pure COUNT(*): still need one column to carry the row count
    return list(dict.fromkeys(out)) or [snapshot.schema.names[0]]


@dataclass(frozen=True)
class InteractiveQueryPlan:
    """Everything the interactive query path decides before touching data.

    One shared planning artifact behind both ``Runner.query`` (which
    executes it) and ``repro explain`` (which only describes it) — the
    static route verdict agrees with the runtime decision *by
    construction*, because both read this object.
    """

    query: Query
    #: filter conjuncts pushed into the FROM table's scan
    pushed: Tuple[Predicate, ...]
    #: filter remainder evaluated by the engine (None = fully pushed)
    residual: Optional[Expr]
    #: folded shard statistics that grounded the route decision
    stats: Dict[str, Tuple[int, int]]
    total_rows: Optional[int]
    route: "RouteDecision"
    #: per-table scan plans (column projection + shard pruning applied)
    scans: Dict[str, ScanPlan]


def resolve_query_snapshots(
    catalog: Any,
    fmt: Any,
    query: Query,
    *,
    branch: Optional[str] = None,
    commit_id: Optional[str] = None,
    text: Optional[str] = None,
) -> Dict[str, Snapshot]:
    """Zero-registration name resolution: every FROM/JOIN table against
    the catalog, unknown names surfacing as positioned SqlErrors."""
    from repro.catalog.nessie import CatalogError
    from repro.engine.sql import SqlError, find_token

    text = text if text is not None else (query.raw_sql or "")
    snapshots: Dict[str, Snapshot] = {}
    for table in query.source_tables():
        try:
            key = catalog.table_key(table, branch=branch, commit_id=commit_id)
            snapshots[table] = fmt.load_snapshot(key)
        except CatalogError as e:
            raise SqlError(
                f"unknown table {table!r} ({e})", text,
                find_token(text, table) or 0,
            ) from e
    return snapshots


def plan_interactive_query(
    query: Query,
    snapshots: Dict[str, Snapshot],
    *,
    engine: str = "auto",
) -> InteractiveQueryPlan:
    """Plan one interactive query: pushdown split, stats fold, engine
    route, and per-table scan plans.  Pure function of the query and the
    resolved snapshots — no data is read, nothing is written, so the
    explain plane can call it as-is.  Raises :class:`RouteError` when
    ``engine='kernel'`` is forced on an ineligible query, exactly as the
    execution path would."""
    pushed, residual = (
        _split_primary_pushdown(query, snapshots)
        if query.filter_expr is not None
        else ([], None)
    )
    stats, total_rows = column_stats_for_query(query, snapshots)
    route = plan_route(
        query, engine=engine, stats=stats, total_rows=total_rows
    )
    scans = {
        table: plan_scan(
            snapshots[table],
            columns=_columns_for_table(query, table, snapshots[table]),
            predicates=tuple(pushed) if table == query.source else (),
        )
        for table in query.source_tables()
    }
    return InteractiveQueryPlan(
        query=query,
        pushed=tuple(pushed),
        residual=residual,
        stats=stats,
        total_rows=total_rows,
        route=route,
        scans=scans,
    )


def _scan_bytes(plan: ScanPlan) -> int:
    row_bytes = sum(
        np.dtype(plan.snapshot.schema.dtype_of(c)).itemsize for c in plan.columns
    )
    return plan.rows_to_read * row_bytes


def compute_node_fingerprints(
    logical: LogicalPlan,
    input_fingerprints: Dict[str, str],
    run_params: Dict[str, Any],
    *,
    edited_node: Optional[str] = None,
) -> Dict[str, str]:
    """Per-node transitive identity, independent of fusion grouping.

    ``node code + upstream node fingerprints + input table identities +
    run params`` — two nodes with equal transitive fingerprints produce
    bit-identical outputs, so a cached result can substitute for
    execution regardless of how either plan grouped nodes into stages.
    ``input_fingerprints`` should be sharding-invariant content hashes
    (``TableFormat.content_fingerprint``) so compaction doesn't bust the
    cache; snapshot ids are an acceptable conservative fallback.

    ``edited_node`` salts exactly that node's payload, simulating a code
    edit; the baseline hashing path is byte-identical when it is unset
    (the payload only gains a key for the salted node).  The lint pass
    uses this to compute cache-invalidation blast radii.
    """
    fps: Dict[str, str] = {}
    for name in logical.order:
        node = logical.nodes[name]
        parents: Dict[str, str] = {}
        scans: Dict[str, str] = {}
        for p in node.parents:
            if p in logical.nodes:
                parents[p] = fps[p]
            else:
                scans[p] = input_fingerprints[p]
        payload = {
            "node": node.fingerprint,
            "parents": parents,
            "scans": scans,
            "params": run_params,
        }
        if name == edited_node:
            payload["edited"] = True
        fps[name] = stable_hash(payload)
    return fps


def fingerprint_blast_radius(
    logical: LogicalPlan,
    input_fingerprints: Optional[Dict[str, str]] = None,
    run_params: Optional[Dict[str, Any]] = None,
) -> Dict[str, List[str]]:
    """For every node: the downstream nodes whose transitive fingerprint
    changes when that node's code is edited — i.e. the differential
    cache's invalidation set.  Pure hash arithmetic, no I/O: the actual
    input fingerprints don't matter for *which* hashes move, only that
    they are fixed across the comparison, so dummy values are fine.
    """
    inputs = dict(input_fingerprints or {})
    for name in logical.order:
        for p in logical.nodes[name].parents:
            if p not in logical.nodes:
                inputs.setdefault(p, f"radius:{p}")
    params = run_params or {}
    baseline = compute_node_fingerprints(logical, inputs, params)
    radius: Dict[str, List[str]] = {}
    for name in logical.order:
        perturbed = compute_node_fingerprints(
            logical, inputs, params, edited_node=name
        )
        radius[name] = [
            n for n in logical.order
            if n != name and perturbed[n] != baseline[n]
        ]
    return radius


def _greedy_stages(
    logical: LogicalPlan,
    config: PlannerConfig,
    names: Sequence[str],
) -> Tuple[List[List[str]], Dict[str, int], Dict[str, int]]:
    """Greedy fusion grouping over ``names`` (topological subsequence of
    ``logical.order``): a node joins the stage that produced ALL its
    in-subset parents (expectations likewise); otherwise it opens a new
    stage.  Parents outside the subset — external tables, cache-restored
    artifacts — are boundaries, which is exactly how a fused chain gets
    cut at a cache hit: the cached prefix is absent from ``names`` and the
    uncached suffix starts a fresh (shorter) stage."""
    node_stage: Dict[str, int] = {}
    stage_nodes: List[List[str]] = []
    produced_in: Dict[str, int] = {}
    for name in names:
        node = logical.nodes[name]
        internal_parents = [p for p in node.parents if p in produced_in]
        target: Optional[int] = None
        if config.fusion and internal_parents:
            parent_stages = {produced_in[p] for p in internal_parents}
            if len(parent_stages) == 1:
                cand = parent_stages.pop()
                if len(stage_nodes[cand]) < config.max_stage_nodes:
                    target = cand
        # (fusion disabled → target stays None → every node its own stage,
        #  expectations included: the paper's "three separate executions")
        if target is None:
            stage_nodes.append([])
            target = len(stage_nodes) - 1
        stage_nodes[target].append(name)
        node_stage[name] = target
        if not node.is_expectation:
            produced_in[name] = target
    return stage_nodes, node_stage, produced_in


def _stage_outputs(
    logical: LogicalPlan,
    stage_nodes: List[List[str]],
    node_stage: Dict[str, int],
    produced_in: Dict[str, int],
) -> List[Tuple[str, ...]]:
    """Materialization points of a grouping: artifacts that are contract
    outputs or cross a stage boundary."""
    needed_later: Dict[str, List[int]] = {}
    for names in stage_nodes:
        for name in names:
            for p in logical.nodes[name].parents:
                if p in produced_in and produced_in[p] != node_stage[name]:
                    needed_later.setdefault(p, []).append(node_stage[name])
    outs: List[Tuple[str, ...]] = []
    for names in stage_nodes:
        outs.append(
            tuple(
                n
                for n in names
                if not logical.nodes[n].is_expectation
                and (n in logical.outputs or n in needed_later)
            )
        )
    return outs


def _legacy_stage_fingerprints(
    logical: LogicalPlan,
    snapshots: Dict[str, Snapshot],
    run_params: Dict[str, Any],
    stage_nodes: List[List[str]],
    produced_in: Dict[str, int],
    outputs_per_stage: List[Tuple[str, ...]],
) -> List[str]:
    """The PR 1 stage-keyed cache fingerprints, byte-for-byte: node code +
    upstream stage fingerprints + input snapshot ids + params.  Only used
    to match (and then upgrade) entries written by pre-node lakes."""
    fps: List[str] = []
    for sid, names in enumerate(stage_nodes):
        scan_tables = sorted(
            {
                p
                for n in names
                for p in logical.nodes[n].parents
                if p not in logical.nodes
            }
        )
        internal_inputs = {
            p
            for n in names
            for p in logical.nodes[n].parents
            if p in produced_in and produced_in[p] != sid
        }
        parent_stages = sorted({produced_in[p] for p in internal_inputs})
        fps.append(
            stable_hash(
                {
                    "nodes": [logical.nodes[n].fingerprint for n in names],
                    "outputs": sorted(outputs_per_stage[sid]),
                    "parents": [fps[p] for p in parent_stages],
                    "scans": {t: snapshots[t].snapshot_id for t in scan_tables},
                    "params": run_params,
                }
            )
        )
    return fps


def _consult_cache(
    cache: CacheView,
    logical: LogicalPlan,
    snapshots: Dict[str, Snapshot],
    run_params: Dict[str, Any],
    node_fp: Dict[str, str],
    natural: List[List[str]],
    nat_produced_in: Dict[str, int],
    nat_outputs: List[Tuple[str, ...]],
) -> Dict[str, NodeCacheEntry]:
    """Which nodes can the cache satisfy?  Node-keyed lookups first; any
    still-unsatisfied natural stage is then matched against legacy
    stage-keyed entries and, on a hit, staged for the one-way upgrade
    into node entries (so the *next* planner change still finds them).
    ``natural``/``nat_produced_in``/``nat_outputs`` describe the
    cache-unaware grouping of the CURRENT config (computed once by
    ``build_physical_plan``) — old lakes warm up as long as the config
    matches what wrote the legacy entry, and the adopted node entries
    are config-proof from then on."""
    satisfied: Dict[str, NodeCacheEntry] = {}
    for name in logical.order:
        node = logical.nodes[name]
        entry = cache.node(node_fp[name])
        if entry is None:
            continue
        if node.is_expectation:
            if entry.checks.get(name, False):
                satisfied[name] = entry
        elif name in entry.outputs:
            satisfied[name] = entry

    produced_in = nat_produced_in
    legacy_fps = _legacy_stage_fingerprints(
        logical, snapshots, run_params, natural, produced_in, nat_outputs
    )
    for sid, names in enumerate(natural):
        checks = [n for n in names if logical.nodes[n].is_expectation]
        missing = [
            n for n in (*nat_outputs[sid], *checks) if n not in satisfied
        ]
        if not missing:
            continue
        legacy = cache.legacy_stage(legacy_fps[sid])
        if legacy is None:
            continue
        if not set(nat_outputs[sid]) <= set(legacy.outputs):
            continue
        if not all(legacy.checks.get(c, False) for c in checks):
            continue
        per_node_bytes = legacy.output_bytes // max(len(nat_outputs[sid]), 1)
        # adopted entries are being used RIGHT NOW — fresh LRU clock, or a
        # TTL prune straight after the upgrade run would evict them (the
        # legacy timestamp can be arbitrarily old); created_at keeps the
        # provenance.  Names a live node entry already satisfies are NOT
        # re-adopted: overwriting would regress their clock and replace
        # accurate output_bytes with the legacy bytes//n estimate.
        now = time.time()
        adopted: List[NodeCacheEntry] = []
        for out in nat_outputs[sid]:
            if out in satisfied:
                continue
            entry = NodeCacheEntry(
                fingerprint=node_fp[out],
                outputs={out: legacy.outputs[out]},
                checks={},
                output_bytes=per_node_bytes,
                run_id=legacy.run_id,
                created_at=legacy.created_at,
                last_used_at=now,
                node=out,
            )
            adopted.append(entry)
            satisfied[out] = entry
        for c in checks:
            if c in satisfied:
                continue
            entry = NodeCacheEntry(
                fingerprint=node_fp[c],
                outputs={},
                checks={c: True},
                output_bytes=0,
                run_id=legacy.run_id,
                created_at=legacy.created_at,
                last_used_at=now,
                node=c,
            )
            adopted.append(entry)
            satisfied[c] = entry
        cache.adopt_legacy(legacy, adopted)
    return satisfied


def build_physical_plan(
    logical: LogicalPlan,
    snapshots: Dict[str, Snapshot],
    *,
    config: PlannerConfig = PlannerConfig(),
    ctx: Any = None,
    cost_model: Optional[CostModel] = None,
    cache: Optional[CacheView] = None,
    input_fingerprints: Optional[Dict[str, str]] = None,
) -> PhysicalPlan:
    """Plan ``logical`` into fused stages, planning *around* the cache.

    ``cache`` (when given) is consulted at node granularity: satisfied
    nodes are never assigned to a stage — terminal ones become
    rehydrations, interior ones cut fused chains so only the uncached
    suffix executes, and nodes no executing consumer needs are elided.
    ``input_fingerprints`` carries the sharding-invariant content identity
    of each external table (defaults to snapshot ids, which are exact but
    conservatively miss after a compaction rewrite).
    """
    cost_model = cost_model or CostModel()
    # run params feed python nodes through ctx, so they are part of every
    # node's cache identity (a param change must invalidate everything)
    run_params = dict(getattr(ctx, "params", None) or {})
    input_ids = input_fingerprints or {
        t: snap.snapshot_id for t, snap in snapshots.items()
    }
    node_fp = compute_node_fingerprints(logical, input_ids, run_params)

    # the natural (cache-unaware) grouping of this config — shared by the
    # legacy-entry match and the materialization-parity restore set below
    nat_stages, nat_node_stage, nat_produced = _greedy_stages(
        logical, config, list(logical.order)
    )
    nat_outputs_per_stage = _stage_outputs(
        logical, nat_stages, nat_node_stage, nat_produced
    )

    # ------------------------------------------------- cache consultation
    satisfied = (
        _consult_cache(
            cache, logical, snapshots, run_params, node_fp,
            nat_stages, nat_produced, nat_outputs_per_stage,
        )
        if cache is not None
        else {}
    )

    # ------------------------------------------ needed-set (reverse walk)
    # An unsatisfied audit or contract output must run; running a node
    # needs its parents' values; a satisfied parent is restored instead of
    # recomputed, so *its* parents are not needed on its account.
    value_needed: Set[str] = set()
    exec_set: Set[str] = set()
    for name in reversed(list(logical.order)):
        if name in satisfied:
            continue
        node = logical.nodes[name]
        if not (
            node.is_expectation
            or name in logical.outputs
            or name in value_needed
        ):
            continue  # every consumer is satisfied or elided
        exec_set.add(name)
        for p in node.parents:
            if p in logical.nodes:
                value_needed.add(p)

    # what the natural (cache-unaware) grouping would materialize — cheap
    # manifest-key commits that keep a warm re-run's artifacts identical
    # to the cold run's under the same config with an intact cache.
    # Parity is deliberately best-effort beyond that: an UNSATISFIED node
    # whose consumers are all cached is elided rather than recomputed —
    # whether it lost its entry to a config flip (it was never
    # materialized under the old grouping) or to `repro cache prune`.
    # Contract outputs (logical.outputs) are always produced; an interior
    # table the current config would have materialized cold may be absent
    # from the warm branch, and `--no-cache` forces a full materializing
    # recompute.  This is the acceptance trade-off: recomputing such
    # nodes would turn every planner flip into real work.
    natural_outputs = {n for outs in nat_outputs_per_stage for n in outs}
    restored = tuple(
        name
        for name in logical.order
        if name in satisfied
        and not logical.nodes[name].is_expectation
        and (
            name in logical.outputs
            or name in value_needed
            or name in natural_outputs
        )
    )
    restored_set = set(restored)
    cached_checks = tuple(
        name
        for name in logical.order
        if name in satisfied and logical.nodes[name].is_expectation
    )

    # ---------------------------------------------------- stage assignment
    exec_names = [n for n in logical.order if n in exec_set]
    stage_nodes, node_stage, produced_in = _greedy_stages(
        logical, config, exec_names
    )

    # --------------------------------------------- boundary identification
    needed_later: Dict[str, List[int]] = {}
    for name in exec_names:
        node = logical.nodes[name]
        for p in node.parents:
            if p in produced_in and produced_in[p] != node_stage[name]:
                needed_later.setdefault(p, []).append(node_stage[name])

    stages: List[Stage] = []
    transitive: Dict[int, str] = {}
    for sid, names in enumerate(stage_nodes):
        nodes = [logical.nodes[n] for n in names]
        artifact_names = {n.name for n in nodes if not n.is_expectation}

        # external scans for this stage
        scan_tables: List[str] = []
        for node in nodes:
            for p in node.parents:
                if p not in logical.nodes and p not in scan_tables:
                    scan_tables.append(p)

        # pushdown: only when a table feeds exactly one SQL node in-stage,
        # and (with joins) only predicates attributable to the FROM table
        rewrites: Dict[str, Query] = {}
        scans: Dict[str, ScanSpec] = {}
        for table in scan_tables:
            snapshot = snapshots[table]
            consumers_here = [
                n for n in nodes if table in n.parents
            ]
            predicates: List[Predicate] = []
            columns: Optional[List[str]] = None
            if (
                config.pushdown
                and len(consumers_here) == 1
                and consumers_here[0].kind == "sql"
                and consumers_here[0].query is not None
            ):
                consumer = consumers_here[0]
                query = consumer.query
                if query.filter_expr is not None and table == query.source:
                    pushed, residual = _split_primary_pushdown(query, snapshots)
                    if pushed:
                        predicates = pushed
                        rewrites[consumer.name] = replace(
                            query, filter_expr=residual
                        )
                columns = _columns_for_table(query, table, snapshot)
            plan = plan_scan(snapshot, columns=columns, predicates=predicates)
            scans[table] = ScanSpec(table, plan, _scan_bytes(plan))

        # inputs produced by other stages OR restored from the cache (the
        # rehydrate-then-shorter-stage cut)
        internal_inputs = tuple(
            sorted(
                {
                    p
                    for n in nodes
                    for p in n.parents
                    if (p in produced_in and produced_in[p] != sid)
                    or p in restored_set
                }
            )
        )
        outputs = tuple(
            n
            for n in names
            if n in artifact_names
            and (n in logical.outputs or n in needed_later)
        )
        checks = tuple(n.name for n in nodes if n.is_expectation)
        # kernel routing per SQL node: decided from shard statistics at
        # plan time, never fingerprinted (both engines produce identical
        # artifacts, so the cache stays warm across engine flips)
        routes: Dict[str, RouteDecision] = {}
        for node in nodes:
            if node.kind == "sql" and node.query is not None:
                stats, total_rows = column_stats_for_query(node.query, snapshots)
                routes[node.name] = plan_route(
                    node.query,
                    engine=config.sql_engine,
                    stats=stats,
                    total_rows=total_rows,
                )
        input_order = tuple(sorted(scans)) + internal_inputs
        fn = _make_stage_fn(nodes, rewrites, input_order, outputs, ctx, routes)
        total_bytes = sum(s.estimated_bytes for s in scans.values())
        # legacy stage fingerprint: parents are topologically earlier
        # stages, so their fingerprints are already in ``transitive``; a
        # restored parent contributes its node fingerprint instead (the
        # "restored" key is only present for cache-cut stages, keeping
        # cold-plan fingerprints byte-identical to PR 1 entries)
        parent_stages = sorted(
            {produced_in[p] for p in internal_inputs if p in produced_in}
        )
        payload: Dict[str, Any] = {
            "nodes": [logical.nodes[n].fingerprint for n in names],
            "outputs": sorted(outputs),
            "parents": [transitive[p] for p in parent_stages],
            "scans": {t: snapshots[t].snapshot_id for t in scans},
            "params": run_params,
        }
        restored_parents = {
            p: node_fp[p] for p in internal_inputs if p in restored_set
        }
        if restored_parents:
            payload["restored"] = restored_parents
        transitive[sid] = stable_hash(payload)
        stages.append(
            Stage(
                stage_id=sid,
                node_names=tuple(names),
                scans=scans,
                internal_inputs=internal_inputs,
                outputs=outputs,
                checks=checks,
                fn=fn,
                resources=cost_model.request_for_scan(total_bytes),
                fingerprint="-".join(logical.nodes[n].fingerprint for n in names),
                transitive_fingerprint=transitive[sid],
                parent_stages=tuple(parent_stages),
                sql_routes=routes,
            )
        )
    executed = {n for names in stage_nodes for n in names}
    elided = tuple(
        n
        for n in logical.order
        if n not in executed
        and n not in restored_set
        and n not in cached_checks
    )
    return PhysicalPlan(
        logical=logical,
        config=config,
        stages=stages,
        node_fingerprints=node_fp,
        cached_nodes=satisfied,
        rehydrate=restored,
        cached_checks=cached_checks,
        elided=elided,
    )


# ===================================================================== cost
# Scheduler v2: the per-stage cost model + the critical-path weights the
# wave scheduler orders its ready heap by.  The same longest-path
# arithmetic backs `repro trace`'s critical-path table (telemetry/tracing
# feeds it *observed* stage latencies instead of estimates) — one shared
# implementation, so the scheduler's priorities and the trace's critical
# path can never disagree about the graph math.

#: bytes-scanned fallback throughput: with no latency history for a
#: stage's function fingerprint, its runtime is estimated as
#: ``overhead + scanned_bytes / SCAN_BYTES_PER_S`` (a conservative
#: single-host read+filter rate; the estimate self-corrects as soon as
#: the stage has run once, via the persisted ``latencyhist`` medians)
SCAN_BYTES_PER_S = 200e6
#: fixed per-stage overhead (dispatch + trace/compile amortized) the
#: bytes heuristic starts from, so zero-scan stages still carry weight
STAGE_OVERHEAD_S = 0.01


def stage_function_spec(pipeline_name: str, stage: Stage) -> FunctionSpec:
    """The ``FunctionSpec`` the runner dispatches ``stage`` under.

    One construction site for the spec means the scheduler's cost lookup
    and the executor's latency-history key are the same fingerprint by
    definition — the cost model reads exactly the history the stage's
    past executions wrote.
    """
    return FunctionSpec(
        name=f"{pipeline_name}/stage{stage.stage_id}",
        fn=stage.fn,
        static_config={"fingerprint": stage.fingerprint},
        resources=stage.resources,
    )


@dataclass(frozen=True)
class StageCost:
    """One stage's scheduling estimate (see ``estimate_stage_costs``)."""

    stage_id: int
    #: estimated runtime seconds
    est_s: float
    #: "latency" = per-fingerprint history median, "bytes" = scan heuristic
    source: str
    #: estimated peak memory (the admission cap's unit), from the stage's
    #: ResourceRequest tier
    est_memory_gb: int
    #: longest-path-to-sink weight (this stage + its heaviest downstream
    #: chain) — the ready heap's priority
    cp_weight_s: float = 0.0
    #: rank by descending weight (0 = most critical, ties by stage id)
    cp_rank: int = 0


def longest_path_weights(
    costs: Dict[int, float], parents: Dict[int, Sequence[int]]
) -> Dict[int, float]:
    """Longest-path-to-sink weight per stage: ``w(s) = cost(s) +
    max(w(child))`` over the dependency DAG described by ``parents``
    (child -> parent ids; parent ids are always lower, as the physical
    planner guarantees).  A sink's weight is its own cost."""
    children: Dict[int, List[int]] = {}
    for sid, ps in parents.items():
        for p in ps:
            children.setdefault(p, []).append(sid)
    weights: Dict[int, float] = {}
    for sid in sorted(costs, reverse=True):  # reverse topological order
        down = [weights[c] for c in children.get(sid, ()) if c in weights]
        weights[sid] = costs.get(sid, 0.0) + (max(down) if down else 0.0)
    return weights


def critical_path_ids(
    costs: Dict[int, float], parents: Dict[int, Sequence[int]]
) -> List[int]:
    """The stage ids of one heaviest root-to-sink chain, in execution
    order.  Ties break toward the lowest stage id, deterministically."""
    if not costs:
        return []
    weights = longest_path_weights(costs, parents)
    children: Dict[int, List[int]] = {}
    roots = []
    for sid in sorted(costs):
        live = [p for p in parents.get(sid, ()) if p in costs]
        if not live:
            roots.append(sid)
        for p in live:
            children.setdefault(p, []).append(sid)
    if not roots:
        roots = sorted(costs)[:1]
    head = min(roots, key=lambda s: (-weights[s], s))
    path = [head]
    while True:
        nxt = [c for c in sorted(children.get(path[-1], ())) if c in weights]
        if not nxt:
            return path
        path.append(min(nxt, key=lambda c: (-weights[c], c)))


def estimate_stage_costs(
    stages: Sequence[Stage],
    pipeline_name: str,
    latency_medians: Dict[str, float],
    *,
    scan_bytes_per_s: float = SCAN_BYTES_PER_S,
    stage_overhead_s: float = STAGE_OVERHEAD_S,
) -> Dict[int, StageCost]:
    """Estimate every stage's runtime and critical-path weight.

    Primary source: the median of the persisted ``latencyhist`` durations
    for the stage's function fingerprint (``stage_function_spec`` — the
    executor records one duration per completed dispatch under the same
    key, and the SDK Client persists/seeds them across processes).
    Fallback: a bytes-scanned heuristic from the stage's pruned scan
    plans.  Weights are longest-path-to-sink over ``parent_stages``.
    """
    est: Dict[int, Tuple[float, str]] = {}
    for stage in stages:
        median = latency_medians.get(
            stage_function_spec(pipeline_name, stage).fingerprint
        )
        if median is not None and median > 0.0:
            est[stage.stage_id] = (float(median), "latency")
        else:
            scanned = sum(s.estimated_bytes for s in stage.scans.values())
            est[stage.stage_id] = (
                stage_overhead_s + scanned / scan_bytes_per_s,
                "bytes",
            )
    parents = {s.stage_id: s.parent_stages for s in stages}
    weights = longest_path_weights(
        {sid: e[0] for sid, e in est.items()}, parents
    )
    by_weight = sorted(weights, key=lambda s: (-weights[s], s))
    ranks = {sid: rank for rank, sid in enumerate(by_weight)}
    return {
        stage.stage_id: StageCost(
            stage_id=stage.stage_id,
            est_s=est[stage.stage_id][0],
            source=est[stage.stage_id][1],
            est_memory_gb=stage.resources.memory_gb,
            cp_weight_s=weights[stage.stage_id],
            cp_rank=ranks[stage.stage_id],
        )
        for stage in stages
    }
