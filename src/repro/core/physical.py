"""Physical planner: fusion + scan pushdown (the paper's 4.4.2 optimization).

The first Bauplan version mapped the logical plan isomorphically — one
(serverless, stateless) function per node, every intermediate spilled to
object storage.  The optimized planner instead:

1. **pushes filters down** into the scan (shard pruning via min/max stats
   + residual row filter), so the in-memory table starts small;
2. **fuses** chains of nodes into a single stage executed as ONE jitted
   XLA program — SQL logic and Python expectations run in place on
   device-resident data, nothing round-trips through the store.

Both behaviours are switchable (``PlannerConfig``) because the naive plan
is the baseline the paper's 5x claim is measured against
(benchmarks/bench_fusion.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.logical import LogicalPlan
from repro.core.pipeline import Node
from repro.engine.columnar import Columnar
from repro.engine.exec import execute_query
from repro.engine.query import Query
from repro.runtime.resources import CostModel, ResourceRequest
from repro.table.format import Snapshot
from repro.table.scan import Predicate, ScanPlan, plan_scan
from repro.utils.hashing import stable_hash


@dataclass(frozen=True)
class PlannerConfig:
    fusion: bool = True
    pushdown: bool = True
    #: cap on fused nodes per stage (very long chains recompile slowly)
    max_stage_nodes: int = 32


@dataclass(frozen=True)
class ScanSpec:
    """One external-table read feeding a stage."""

    table: str
    plan: ScanPlan
    #: bytes that will actually be read after shard/column pruning
    estimated_bytes: int

    @property
    def predicates(self) -> Tuple[Predicate, ...]:
        return self.plan.predicates


@dataclass
class Stage:
    stage_id: int
    node_names: Tuple[str, ...]
    scans: Dict[str, ScanSpec]
    internal_inputs: Tuple[str, ...]
    outputs: Tuple[str, ...]
    checks: Tuple[str, ...]
    fn: Callable[..., Tuple[Dict[str, Columnar], Dict[str, Any]]]
    resources: ResourceRequest
    fingerprint: str
    #: transitive identity: node code + upstream stage fingerprints + input
    #: table snapshot ids + run params — the differential-cache key.  Two
    #: stages with equal transitive fingerprints produce bit-identical
    #: outputs, so a cached result can be substituted for execution.
    transitive_fingerprint: str = ""

    @property
    def input_order(self) -> Tuple[str, ...]:
        """Stage fn positional args: scans first (sorted), then internals."""
        return tuple(sorted(self.scans)) + self.internal_inputs


@dataclass
class PhysicalPlan:
    logical: LogicalPlan
    config: PlannerConfig
    stages: List[Stage]

    @property
    def num_materializations(self) -> int:
        return sum(len(s.outputs) for s in self.stages)

    def describe(self) -> str:
        lines = [f"physical plan ({'fused' if self.config.fusion else 'isomorphic'}):"]
        for s in self.stages:
            scans = {
                t: f"{spec.plan.rows_to_read} rows"
                f" (-{spec.plan.pruned_shards} shards)"
                for t, spec in s.scans.items()
            }
            lines.append(
                f"  stage {s.stage_id}: nodes={list(s.node_names)} scans={scans} "
                f"inputs={list(s.internal_inputs)} outputs={list(s.outputs)} "
                f"checks={list(s.checks)} mem={s.resources.memory_gb}GB"
            )
        return "\n".join(lines)


def _ensure_columnar(value: Any, node_name: str) -> Columnar:
    if isinstance(value, Columnar):
        return value
    if isinstance(value, dict):
        return Columnar.from_arrays(value)
    raise TypeError(
        f"python node {node_name!r} must return a Columnar or a dict of "
        f"columns, got {type(value)}"
    )


def _make_stage_fn(
    ordered_nodes: Sequence[Node],
    rewrites: Dict[str, Query],
    input_order: Sequence[str],
    outputs: Sequence[str],
    ctx: Any,
) -> Callable:
    """Compose stage nodes into one pure function (jit-able end to end)."""

    def stage_fn(*inputs: Columnar):
        env: Dict[str, Columnar] = dict(zip(input_order, inputs))
        checks: Dict[str, Any] = {}
        for node in ordered_nodes:
            if node.kind == "sql":
                query = rewrites.get(node.name, node.query)
                env[node.name] = execute_query(query, env[query.source])
            elif node.kind == "python":
                out = node.fn(ctx, *[env[p] for p in node.parents])
                env[node.name] = _ensure_columnar(out, node.name)
            else:  # expectation — returns a (traced) boolean
                checks[node.name] = node.fn(ctx, *[env[p] for p in node.parents])
        return {name: env[name] for name in outputs}, checks

    return stage_fn


def _scan_bytes(plan: ScanPlan) -> int:
    row_bytes = sum(
        np.dtype(plan.snapshot.schema.dtype_of(c)).itemsize for c in plan.columns
    )
    return plan.rows_to_read * row_bytes


def build_physical_plan(
    logical: LogicalPlan,
    snapshots: Dict[str, Snapshot],
    *,
    config: PlannerConfig = PlannerConfig(),
    ctx: Any = None,
    cost_model: Optional[CostModel] = None,
) -> PhysicalPlan:
    cost_model = cost_model or CostModel()

    # ---------------------------------------------------- stage assignment
    # greedy: a node joins the stage that produced ALL its internal parents
    # (expectations likewise); otherwise it opens a new stage.
    node_stage: Dict[str, int] = {}
    stage_nodes: List[List[str]] = []
    produced_in: Dict[str, int] = {}
    for name in logical.order:
        node = logical.nodes[name]
        internal_parents = [p for p in node.parents if p in logical.nodes]
        target: Optional[int] = None
        if config.fusion and internal_parents:
            parent_stages = {produced_in[p] for p in internal_parents}
            if len(parent_stages) == 1:
                cand = parent_stages.pop()
                if len(stage_nodes[cand]) < config.max_stage_nodes:
                    target = cand
        # (fusion disabled → target stays None → every node its own stage,
        #  expectations included: the paper's "three separate executions")
        if target is None:
            stage_nodes.append([])
            target = len(stage_nodes) - 1
        stage_nodes[target].append(name)
        node_stage[name] = target
        if not node.is_expectation:
            produced_in[name] = target

    # --------------------------------------------- boundary identification
    needed_later: Dict[str, List[int]] = {}
    for name in logical.order:
        node = logical.nodes[name]
        for p in node.parents:
            if p in produced_in and produced_in[p] != node_stage[name]:
                needed_later.setdefault(p, []).append(node_stage[name])

    stages: List[Stage] = []
    # run params feed python nodes through ctx, so they are part of every
    # stage's cache identity (a param change must invalidate everything)
    run_params = dict(getattr(ctx, "params", None) or {})
    transitive: Dict[int, str] = {}
    for sid, names in enumerate(stage_nodes):
        nodes = [logical.nodes[n] for n in names]
        artifact_names = {n.name for n in nodes if not n.is_expectation}

        # external scans for this stage
        scan_tables: List[str] = []
        for node in nodes:
            for p in node.parents:
                if p not in logical.nodes and p not in scan_tables:
                    scan_tables.append(p)

        # pushdown: only when a table feeds exactly one SQL node in-stage
        rewrites: Dict[str, Query] = {}
        scans: Dict[str, ScanSpec] = {}
        for table in scan_tables:
            snapshot = snapshots[table]
            consumers_here = [
                n for n in nodes if table in n.parents
            ]
            predicates: List[Predicate] = []
            columns: Optional[List[str]] = None
            if (
                config.pushdown
                and len(consumers_here) == 1
                and consumers_here[0].kind == "sql"
                and consumers_here[0].query is not None
            ):
                consumer = consumers_here[0]
                query = consumer.query
                if query.filter_expr is not None:
                    pushed, residual = query.filter_expr.as_pushdown_conjuncts()
                    if pushed:
                        predicates = pushed
                        rewrites[consumer.name] = replace(
                            query, filter_expr=residual
                        )
                referenced = query.referenced_columns()
                if query.projections or query.is_aggregation:
                    # pure COUNT(*): still need one column for row counts
                    columns = referenced or [snapshot.schema.names[0]]
            plan = plan_scan(snapshot, columns=columns, predicates=predicates)
            scans[table] = ScanSpec(table, plan, _scan_bytes(plan))

        internal_inputs = tuple(
            sorted(
                {
                    p
                    for n in nodes
                    for p in n.parents
                    if p in produced_in and produced_in[p] != sid
                }
            )
        )
        outputs = tuple(
            n
            for n in names
            if n in artifact_names
            and (n in logical.outputs or n in needed_later)
        )
        checks = tuple(n.name for n in nodes if n.is_expectation)
        input_order = tuple(sorted(scans)) + internal_inputs
        fn = _make_stage_fn(nodes, rewrites, input_order, outputs, ctx)
        total_bytes = sum(s.estimated_bytes for s in scans.values())
        # transitive fingerprint: parents are topologically earlier stages,
        # so their fingerprints are already in ``transitive``
        parent_stages = sorted({produced_in[p] for p in internal_inputs})
        transitive[sid] = stable_hash(
            {
                "nodes": [logical.nodes[n].fingerprint for n in names],
                "outputs": sorted(outputs),
                "parents": [transitive[p] for p in parent_stages],
                "scans": {t: snapshots[t].snapshot_id for t in scans},
                "params": run_params,
            }
        )
        stages.append(
            Stage(
                stage_id=sid,
                node_names=tuple(names),
                scans=scans,
                internal_inputs=internal_inputs,
                outputs=outputs,
                checks=checks,
                fn=fn,
                resources=cost_model.request_for_scan(total_bytes),
                fingerprint="-".join(logical.nodes[n].fingerprint for n in names),
                transitive_fingerprint=transitive[sid],
            )
        )
    return PhysicalPlan(logical=logical, config=config, stages=stages)
