"""Logical plan: the typed, validated DAG over catalog artifacts (4.4.1).

Parsing a Pipeline yields a LogicalPlan: nodes in topological order,
external sources resolved against a catalog commit (so the plan is pinned
to a data version), and per-node column requirements for pruning.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

from repro.core.pipeline import Node, Pipeline, PipelineError
from repro.table.schema import Schema


@dataclass(frozen=True)
class LogicalPlan:
    pipeline_name: str
    pipeline_fingerprint: str
    #: topological order, expectations after the artifact they audit
    order: Sequence[str]
    nodes: Dict[str, Node]
    #: tables read from the catalog: name -> schema
    external_schemas: Dict[str, Schema]
    #: artifacts that must be written back (terminal or explicitly marked)
    outputs: Sequence[str]

    def consumers(self, name: str) -> List[str]:
        return [n.name for n in self.nodes.values() if name in n.parents]

    def artifact_consumers(self, name: str) -> List[str]:
        """Consumers that are artifacts (expectations don't force
        materialization — they fuse with their parent)."""
        return [
            n.name
            for n in self.nodes.values()
            if name in n.parents and not n.is_expectation
        ]


def _toposort(pipeline: Pipeline, produced: Set[str]) -> List[str]:
    state: Dict[str, int] = {}  # 0=unseen 1=visiting 2=done
    order: List[str] = []

    def visit(name: str, chain: List[str]) -> None:
        if state.get(name) == 2:
            return
        if state.get(name) == 1:
            cycle = " -> ".join(chain + [name])
            raise PipelineError(f"cycle in pipeline DAG: {cycle}")
        state[name] = 1
        for parent in pipeline.nodes[name].parents:
            if parent in produced:
                visit(parent, chain + [name])
        state[name] = 2
        order.append(name)

    for name in pipeline.nodes:
        visit(name, [])
    return order


def build_logical_plan(
    pipeline: Pipeline,
    *,
    external_schemas: Dict[str, Schema],
) -> LogicalPlan:
    """Validate references + types, return the pinned logical plan.

    ``external_schemas`` is what the catalog resolves at the base commit —
    passing it in (rather than a live catalog handle) keeps the planner a
    pure function, which is what makes run replay exact.
    """
    produced = set(pipeline.artifacts)
    # -- reference validation --------------------------------------------
    for node in pipeline.nodes.values():
        for parent in node.parents:
            if parent not in produced and parent not in external_schemas:
                raise PipelineError(
                    f"node {node.name!r} references unknown table {parent!r} "
                    f"(not produced by the pipeline, not in the catalog)"
                )
        if node.is_expectation and node.name in produced:
            raise PipelineError(
                f"{node.name!r} is an expectation but also an artifact"
            )
    order = _toposort(pipeline, produced | set(pipeline.expectations))

    # -- column-level validation for SQL nodes over external tables ------
    # Multi-source aware: qualified references are checked against the
    # schema their qualifier resolves to; plain references against the
    # union of all source schemas — but only when every source is a
    # catalog table (a node-produced source has no static schema here,
    # so plain names cannot be attributed and are left to the executor).
    for node in pipeline.nodes.values():
        if node.query is None:
            continue
        q = node.query
        qual_tables = dict(q.qualifiers())
        qual_schemas = {
            qual: external_schemas[table]
            for qual, table in qual_tables.items()
            if table in external_schemas
        }
        if not qual_schemas:
            continue
        all_known = len(qual_schemas) == len(qual_tables)
        union = {n for s in qual_schemas.values() for n in s.names}
        for c in q.referenced_columns():
            if "." in c:
                qual, tail = c.split(".", 1)
                if qual in qual_schemas and not qual_schemas[qual].has(tail):
                    raise PipelineError(
                        f"node {node.name!r} references column {c!r} "
                        f"missing from table {qual_tables[qual]!r} "
                        f"({sorted(qual_schemas[qual].names)})"
                    )
                if all_known and qual not in qual_schemas:
                    raise PipelineError(
                        f"node {node.name!r} references {c!r} but "
                        f"{qual!r} is not a table or alias of this query "
                        f"({sorted(qual_tables)})"
                    )
            elif all_known and c not in union:
                raise PipelineError(
                    f"node {node.name!r} references column {c!r} "
                    f"missing from table {q.source!r} ({sorted(union)})"
                )

    # -- outputs: terminal artifacts + explicitly materialized ------------
    outputs = [
        n.name
        for n in pipeline.nodes.values()
        if not n.is_expectation
        and (
            n.materialize
            or not [c for c in pipeline.consumers(n.name)]
        )
    ]
    # artifacts consumed ONLY by expectations are still terminal outputs
    for n in pipeline.nodes.values():
        if n.is_expectation:
            continue
        consumers = pipeline.consumers(n.name)
        if consumers and all(
            pipeline.nodes[c].is_expectation for c in consumers
        ) and n.name not in outputs:
            outputs.append(n.name)

    return LogicalPlan(
        pipeline_name=pipeline.name,
        pipeline_fingerprint=pipeline.fingerprint,
        order=tuple(order),
        nodes=dict(pipeline.nodes),
        external_schemas=dict(external_schemas),
        outputs=tuple(outputs),
    )
