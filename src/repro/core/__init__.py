"""The paper's primary contribution: declarative pipelines + code intelligence.

``Pipeline``      — one artifact per node, implicit DAG (paper 4.1, A)
``LogicalPlan``   — typed DAG over catalog artifacts (paper 4.4.1)
``PhysicalPlan``  — fused stages with scan pushdown (paper 4.4.2)
``Runner``        — transform-audit-write over ephemeral branches (4.3)
``RunRegistry``   — snapshotting, fingerprints, replay (4.4.1, 4.6)
``NodeCacheRegistry`` — cross-run differential artifact cache (FaaS &
                    Furious-style, keyed per logical node: clean nodes
                    restore or elide, dirty cones rerun, planner-config
                    changes stay warm)
"""
from repro.core.pipeline import Pipeline, Node, PipelineError, requirements
from repro.core.logical import LogicalPlan, build_logical_plan
from repro.core.physical import (
    PhysicalPlan,
    Stage,
    ScanSpec,
    PlannerConfig,
    build_physical_plan,
)
from repro.core.runner import Runner, RunResult, ExpectationFailed
from repro.core.snapshot import (
    CacheView,
    NodeCacheEntry,
    NodeCacheRegistry,
    RunRecord,
    RunRegistry,
    StageCacheEntry,
    StageCacheRegistry,
)

__all__ = [
    "CacheView",
    "NodeCacheEntry",
    "NodeCacheRegistry",
    "StageCacheEntry",
    "StageCacheRegistry",
    "Pipeline",
    "Node",
    "PipelineError",
    "requirements",
    "LogicalPlan",
    "build_logical_plan",
    "PhysicalPlan",
    "Stage",
    "ScanSpec",
    "PlannerConfig",
    "build_physical_plan",
    "Runner",
    "RunResult",
    "ExpectationFailed",
    "RunRecord",
    "RunRegistry",
]
