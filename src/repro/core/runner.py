"""The run orchestrator: transform → audit → write (paper 4.3, Fig. 4).

``bauplan run`` semantics:

1. resolve (or create) the working branch — "Bauplan detects the Git
   context and creates a Nessie branch with the same name";
2. pin the base commit (or the one a replayed run recorded);
3. execute the physical plan **into an ephemeral branch** ``run_<id>``;
4. audit: every expectation must pass;
5. write: merge the ephemeral branch atomically into the working branch
   and delete it — or, on any failure, delete it without merging so dirty
   artifacts are never visible (the database-transaction analogy).

Stage execution goes through the serverless executor (retries, warm
starts, speculation); artifacts flow between stages in memory within a
run (data locality, 4.5) and hit the object store only at stage
boundaries/outputs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.catalog.nessie import Catalog
from repro.core.logical import LogicalPlan, build_logical_plan
from repro.core.physical import (
    PhysicalPlan,
    PlannerConfig,
    build_physical_plan,
)
from repro.core.pipeline import Pipeline
from repro.core.snapshot import RunRecord, RunRegistry
from repro.engine.columnar import Columnar
from repro.runtime.executor import ServerlessExecutor
from repro.runtime.function import FunctionSpec
from repro.table.format import Snapshot, TableFormat
from repro.table.scan import execute_scan
from repro.table.schema import Column, Schema
from repro.utils.logging import get_logger

log = get_logger("core.runner")


class ExpectationFailed(RuntimeError):
    def __init__(self, failed: List[str]):
        super().__init__(f"expectations failed: {failed} — run rolled back")
        self.failed = failed


class RunContext:
    """Per-run context handed to python nodes (``ctx`` argument).

    __repr__ deliberately covers only ``params`` — run_id and branch do
    not change any node's computation, so stage fingerprints (and the
    warm compiled-function cache) stay stable across runs.  This is the
    compiled-executable analog of reusing a frozen container (4.5).
    """

    def __init__(self, branch: str, run_id: int, params: Dict[str, Any]):
        self.branch = branch
        self.run_id = run_id
        self.params = params

    def __repr__(self) -> str:
        return f"RunContext(params={sorted(self.params.items())})"


@dataclass
class RunResult:
    run_id: int
    branch: str
    merged_commit: Optional[str]
    artifacts: Dict[str, str]
    checks: Dict[str, bool]
    stats: Dict[str, Any]
    plan: PhysicalPlan

    @property
    def ok(self) -> bool:
        return self.merged_commit is not None


@dataclass
class Runner:
    catalog: Catalog
    fmt: TableFormat
    executor: ServerlessExecutor
    registry: RunRegistry = None  # type: ignore[assignment]

    def __post_init__(self) -> None:
        if self.registry is None:
            self.registry = RunRegistry(self.catalog.store)

    # ------------------------------------------------------------ queries
    def query(
        self,
        sql: str,
        *,
        branch: Optional[str] = None,
        commit_id: Optional[str] = None,
    ) -> Dict[str, np.ndarray]:
        """``bauplan query -q "SELECT ..." [-b branch]`` — synchronous QW.

        Point-wise interactive path: scan (with pushdown) + one compiled
        query, straight to the caller. Time travel via branch/commit.
        """
        from repro.engine.exec import compile_query
        from repro.engine.sql import parse_sql

        query = parse_sql(sql)
        key = self.catalog.table_key(
            query.source, branch=branch, commit_id=commit_id
        )
        snapshot = self.fmt.load_snapshot(key)
        pushed, residual = (
            query.filter_expr.as_pushdown_conjuncts()
            if query.filter_expr is not None
            else ([], None)
        )
        from dataclasses import replace as _replace

        from repro.table.scan import plan_scan

        columns = (
            query.referenced_columns()
            if (query.projections or query.is_aggregation)
            else None
        )
        if columns == []:  # pure COUNT(*): any one column carries the rows
            columns = [snapshot.schema.names[0]]
        scan = plan_scan(snapshot, columns=columns, predicates=pushed)
        rel = Columnar.from_numpy(execute_scan(self.fmt, scan))
        residual_query = _replace(query, filter_expr=residual)
        out = compile_query(residual_query)(rel)
        return out.to_numpy()

    # ---------------------------------------------------------------- run
    def run(
        self,
        pipeline: Pipeline,
        *,
        branch: str = "main",
        params: Optional[Dict[str, Any]] = None,
        fusion: bool = True,
        pushdown: bool = True,
        base_commit: Optional[str] = None,
        author: str = "user",
    ) -> RunResult:
        t_start = time.perf_counter()
        params = dict(params or {})

        # 1. branch handling (auto-create like the paper's git detection)
        if not self.catalog.has_branch(branch):
            self.catalog.create_branch(branch)
            log.info("created catalog branch %r from main", branch)
        base = (
            self.catalog.get_commit(base_commit)
            if base_commit
            else self.catalog.head(branch)
        )

        run_id = self.registry.next_run_id()
        ephemeral = f"run_{run_id}"
        self.catalog.create_branch(ephemeral, at_commit=base.commit_id)

        try:
            result = self._execute(
                pipeline, branch, ephemeral, base.commit_id, params,
                PlannerConfig(fusion=fusion, pushdown=pushdown), run_id,
            )
        except Exception:
            # any failure: discard the ephemeral branch — prod stays clean
            self.catalog.delete_branch(ephemeral)
            raise

        # 4. audit
        failed = [k for k, v in result["checks"].items() if not v]
        if failed:
            self.catalog.delete_branch(ephemeral)
            rec = self._record(
                run_id, pipeline, branch, base.commit_id, params,
                result, merged=None, t_start=t_start,
            )
            raise ExpectationFailed(failed)

        # 5. write: atomic merge + ephemeral cleanup
        merged = self.catalog.merge(
            ephemeral, branch,
            message=f"run {run_id}: {pipeline.name}",
            author=author, delete_source=True,
        )
        rec = self._record(
            run_id, pipeline, branch, base.commit_id, params,
            result, merged=merged.commit_id, t_start=t_start,
        )
        return RunResult(
            run_id=run_id,
            branch=branch,
            merged_commit=merged.commit_id,
            artifacts=result["artifacts"],
            checks=result["checks"],
            stats=rec.stats,
            plan=result["plan"],
        )

    # ------------------------------------------------------------- replay
    def replay(
        self,
        pipeline: Pipeline,
        run_id: int,
        *,
        strict_code: bool = True,
    ) -> RunResult:
        """Re-execute run ``run_id``: same code, same data version (4.6).

        Executes into a fresh ephemeral branch that is dropped afterwards —
        replay is for debugging/inspection, it never moves branches.
        """
        rec = self.registry.get(run_id)
        if strict_code and rec.pipeline_fingerprint != pipeline.fingerprint:
            raise ValueError(
                "pipeline code differs from the recorded run "
                f"({rec.pipeline_fingerprint} != {pipeline.fingerprint}); "
                "pass strict_code=False to replay anyway"
            )
        replay_id = self.registry.next_run_id()
        ephemeral = f"run_{replay_id}"
        self.catalog.create_branch(ephemeral, at_commit=rec.base_commit)
        try:
            result = self._execute(
                pipeline, rec.branch, ephemeral, rec.base_commit,
                dict(rec.params), PlannerConfig(fusion=rec.fused), replay_id,
            )
        finally:
            self.catalog.delete_branch(ephemeral)
        return RunResult(
            run_id=replay_id,
            branch=rec.branch,
            merged_commit=None,
            artifacts=result["artifacts"],
            checks=result["checks"],
            stats={"replay_of": run_id},
            plan=result["plan"],
        )

    # ------------------------------------------------------------ internal
    def _execute(
        self,
        pipeline: Pipeline,
        branch: str,
        ephemeral: str,
        base_commit: str,
        params: Dict[str, Any],
        config: PlannerConfig,
        run_id: int,
    ) -> Dict[str, Any]:
        # 2. code intelligence: logical plan pinned to the base commit
        tables_at_base = self.catalog.get_commit(base_commit).tables
        schemas = {}
        snapshots: Dict[str, Snapshot] = {}
        for name in pipeline.external_sources():
            if name not in tables_at_base:
                raise KeyError(
                    f"pipeline references table {name!r} missing at commit "
                    f"{base_commit[:12]} on branch {branch!r}"
                )
            snap = self.fmt.load_snapshot(tables_at_base[name])
            snapshots[name] = snap
            schemas[name] = snap.schema
        logical = build_logical_plan(pipeline, external_schemas=schemas)
        ctx = RunContext(branch, run_id, params)
        plan = build_physical_plan(logical, snapshots, config=config, ctx=ctx)
        log.info("\n%s", plan.describe())

        # 3. transform: execute stages through the serverless executor
        env: Dict[str, Columnar] = {}  # in-memory artifact cache (locality)
        artifacts: Dict[str, str] = {}
        checks: Dict[str, bool] = {}
        bytes_before = self.fmt.store.stats.snapshot()
        for stage in plan.stages:
            inputs: List[Columnar] = []
            for table in sorted(stage.scans):
                data = execute_scan(self.fmt, stage.scans[table].plan)
                inputs.append(Columnar.from_numpy(data))
            for name in stage.internal_inputs:
                if name in env:  # data locality: reuse in-memory artifact
                    inputs.append(env[name])
                else:  # fallback: read back from the ephemeral branch
                    key = self.catalog.table_key(name, branch=ephemeral)
                    inputs.append(
                        Columnar.from_numpy(self.fmt.read(self.fmt.load_snapshot(key)))
                    )
            spec = FunctionSpec(
                name=f"{pipeline.name}/stage{stage.stage_id}",
                fn=stage.fn,
                static_config={"fingerprint": stage.fingerprint},
                resources=stage.resources,
            )
            outputs, stage_checks = self.executor.run(spec, *inputs)
            for cname, val in stage_checks.items():
                checks[cname] = bool(np.asarray(val))
            updates: Dict[str, Optional[str]] = {}
            for name, rel in outputs.items():
                env[name] = rel
                compact = rel.to_numpy(compact=True)
                schema = Schema(
                    tuple(
                        Column(c, str(compact[c].dtype)) for c in sorted(compact)
                    )
                )
                snap = self.fmt.write(name, schema, compact)
                key = self.fmt.manifest_key(snap)
                artifacts[name] = key
                updates[name] = key
            if updates:
                self.catalog.commit(
                    ephemeral, updates,
                    message=f"run {run_id} stage {stage.stage_id}",
                    author="runner",
                )
        bytes_after = self.fmt.store.stats.snapshot()
        io_delta = {
            k: bytes_after[k] - bytes_before[k] for k in bytes_after
        }
        return {
            "plan": plan,
            "artifacts": artifacts,
            "checks": checks,
            "io": io_delta,
        }

    def _record(
        self,
        run_id: int,
        pipeline: Pipeline,
        branch: str,
        base_commit: str,
        params: Dict[str, Any],
        result: Dict[str, Any],
        *,
        merged: Optional[str],
        t_start: float,
    ) -> RunRecord:
        rec = RunRecord(
            run_id=run_id,
            pipeline_name=pipeline.name,
            pipeline_fingerprint=pipeline.fingerprint,
            branch=branch,
            base_commit=base_commit,
            params=params,
            artifacts=result["artifacts"],
            checks=result["checks"],
            merged_commit=merged,
            fused=result["plan"].config.fusion,
            stats={
                "wall_s": time.perf_counter() - t_start,
                "stages": len(result["plan"].stages),
                "io": result["io"],
                "executor": self.executor.stats(),
            },
            created_at=time.time(),
        )
        self.registry.record(rec)
        return rec
