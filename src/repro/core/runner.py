"""The run orchestrator: transform → audit → write (paper 4.3, Fig. 4).

``bauplan run`` semantics:

1. resolve (or create) the working branch — "Bauplan detects the Git
   context and creates a Nessie branch with the same name";
2. pin the base commit (or the one a replayed run recorded);
3. execute the physical plan **into an ephemeral branch** ``run_<id>``;
4. audit: every expectation must pass;
5. write: merge the ephemeral branch atomically into the working branch
   and delete it — or, on any failure, delete it without merging so dirty
   artifacts are never visible (the database-transaction analogy).

Stage execution goes through the serverless executor (retries, warm
starts, speculation); artifacts flow between stages in memory within a
run (data locality, 4.5) and hit the object store only at stage
boundaries/outputs.

Since PR 5 stages are *wave-scheduled*: every stage whose parents have
completed is submitted to the executor's stage lane immediately, so
independent fan-out stages run concurrently — the serverless promise of
the paper, on the single-host build.

Scheduler v2 (this module + core/physical.py's cost model) makes the
wave scheduler cost-aware and streaming:

* ``schedule="critical_path"`` (default) pops the ready set by
  longest-path-to-sink weight — stage runtimes estimated from persisted
  ``latencyhist`` medians with a bytes-scanned fallback — and admission
  is capped by estimated peak memory (``ExecutorConfig
  .memory_budget_gb``) instead of a flat stage count;
  ``schedule="stage_id"`` reproduces the PR 5 policy exactly.
* ``streaming=True`` (default under critical_path) hands a stage's
  outputs to its dependents the moment the stage function produces them
  — downstream scan→filter stages start consuming completed upstream
  shards while the upstream stage is still writing its artifacts and
  before it commits.  The stage barrier is retained where it matters:
  audits and catalog commits.

Neither knob changes semantics: artifact manifests, check verdicts and
cache entries are byte-identical at every parallelism level, ordering
mode and streaming setting, and per-stage catalog commits are applied in
stage-id order so branch history stays linear and deterministic.
"""
from __future__ import annotations

import heapq
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.catalog.nessie import Catalog, CatalogError
from repro.core.logical import LogicalPlan, build_logical_plan
from repro.core.physical import (
    PhysicalPlan,
    PlannerConfig,
    build_physical_plan,
    critical_path_ids,
    estimate_stage_costs,
    stage_function_spec,
)
from repro.core.pipeline import Pipeline
from repro.core.snapshot import (
    CacheView,
    NodeCacheEntry,
    NodeCacheRegistry,
    RunRecord,
    RunRegistry,
)
from repro.engine.columnar import Columnar
from repro.runtime.executor import ServerlessExecutor
from repro.table.format import Snapshot, TableFormat
from repro.table.scan import execute_scan
from repro.table.schema import Column, Schema
from repro.telemetry.bus import EventBus
from repro.telemetry.events import (
    Event,
    NodeCacheHit,
    NodeCacheMiss,
    NodeCacheRehydrated,
    QueryExecuted,
    RunFinished,
    RunStarted,
    StageCommitted,
    StageFinished,
    StageQueued,
    StageScheduled,
    StageStarted,
)
from repro.telemetry.runlog import RunLogStore
from repro.utils.logging import get_logger

log = get_logger("core.runner")

#: per-run event collector bound: large enough that no realistic run
#: drops its own trace (a 1000-stage, 50-shard-per-stage run is ~55k
#: events); the bound still protects a pathological publisher
_RUNLOG_BUFFER = 131072


class ExpectationFailed(RuntimeError):
    def __init__(
        self,
        failed: List[str],
        record: Optional[RunRecord] = None,
        plan: Optional[PhysicalPlan] = None,
    ):
        super().__init__(f"expectations failed: {failed} — run rolled back")
        self.failed = failed
        #: the rolled-back run's record (run_id, stats, artifact keys) — the
        #: SDK's ``Client.run`` turns this into an AUDIT_FAILED ``RunHandle``
        #: instead of letting the exception escape
        self.record = record
        self.plan = plan


class RunContext:
    """Per-run context handed to python nodes (``ctx`` argument).

    __repr__ deliberately covers only ``params`` — run_id and branch do
    not change any node's computation, so stage fingerprints (and the
    warm compiled-function cache) stay stable across runs.  This is the
    compiled-executable analog of reusing a frozen container (4.5).
    """

    def __init__(self, branch: str, run_id: int, params: Dict[str, Any]):
        self.branch = branch
        self.run_id = run_id
        self.params = params

    def __repr__(self) -> str:
        return f"RunContext(params={sorted(self.params.items())})"


def _check_query_columns(query, snapshots, text: str) -> None:
    """Zero-registration column validation for the interactive path.

    Every referenced column must exist in the table(s) it can refer to:
    ``qual.col`` against its owner's schema, plain names against the
    union of all resolved tables.  Failures surface as
    :class:`repro.engine.sql.SqlError` carrying the offending position,
    mirroring what logical-plan validation does for pipelines.
    """
    import re as _re

    from repro.engine.sql import SqlError

    def pos_of(name: str) -> int:
        m = _re.search(rf"\b{_re.escape(name)}\b", text)
        return m.start() if m else 0

    qual_tables = dict(query.qualifiers())
    union = set()
    for snap in snapshots.values():
        union |= set(snap.schema.names)
    for ref in query.referenced_columns():
        if "." in ref:
            qual, _, col = ref.partition(".")
            table = qual_tables.get(qual)
            if table is None or table not in snapshots:
                raise SqlError(
                    f"unknown table qualifier {qual!r}", text, pos_of(ref)
                )
            if not snapshots[table].schema.has(col):
                raise SqlError(
                    f"table {table!r} has no column {col!r}", text, pos_of(ref)
                )
        elif ref not in union:
            raise SqlError(f"unknown column {ref!r}", text, pos_of(ref))


@dataclass
class RunResult:
    run_id: int
    branch: str
    merged_commit: Optional[str]
    artifacts: Dict[str, str]
    checks: Dict[str, bool]
    stats: Dict[str, Any]
    plan: PhysicalPlan

    @property
    def ok(self) -> bool:
        return self.merged_commit is not None


@dataclass
class Runner:
    catalog: Catalog
    fmt: TableFormat
    executor: ServerlessExecutor
    registry: RunRegistry = None  # type: ignore[assignment]
    cache_registry: NodeCacheRegistry = None  # type: ignore[assignment]
    #: telemetry event bus (None = telemetry off: no events, no run log).
    #: The runner publishes run/stage/cache events; the executor and scan
    #: pool publish speculation/shard events tagged with the run id.
    bus: Optional[EventBus] = None
    runlog: RunLogStore = None  # type: ignore[assignment]

    def __post_init__(self) -> None:
        if self.registry is None:
            self.registry = RunRegistry(self.catalog.store)
        if self.cache_registry is None:
            self.cache_registry = NodeCacheRegistry(self.catalog.store)
        if self.runlog is None:
            self.runlog = RunLogStore(self.catalog.store)

    def _publish(self, event: Event) -> None:
        if self.bus is not None:
            self.bus.publish(event)

    def _collect_run_events(self, collector, run_id: int) -> List[Event]:
        """Drain the per-run collector down to this run's events.  The
        collector subscribes before RunStarted and drains after
        RunFinished, so with per-run filtering a concurrent run's events
        never leak into this run's trace."""
        events = [e for e in collector.drain() if e.run_id == run_id]
        collector.close()
        return events

    # ------------------------------------------------------------ queries
    def query(
        self,
        sql: str,
        *,
        branch: Optional[str] = None,
        commit_id: Optional[str] = None,
        engine: str = "auto",
    ) -> Dict[str, np.ndarray]:
        """``bauplan query -q "SELECT ..." [-b branch]`` — synchronous QW.

        Point-wise interactive path, zero registration: every table name
        in the statement (FROM + JOINs) resolves against the catalog at
        query time — lake tables and materialized pipeline outputs alike
        — and unknown tables/columns come back as :class:`SqlError` with
        the offending position.  Each table scans through the pooled
        parallel reader in kernel-sized chunks; ``engine`` picks the
        filter+agg execution path ("auto" | "kernel" | "jnp", see
        engine/route.py).  Time travel via branch/commit.
        """
        from dataclasses import replace as _replace

        from repro.core.physical import (
            plan_interactive_query,
            resolve_query_snapshots,
        )
        from repro.engine.exec import compile_query
        from repro.engine.sql import parse_sql
        from repro.table.scan import KERNEL_CHUNK_ROWS

        t0 = time.perf_counter()
        query = parse_sql(sql)
        text = query.raw_sql or sql
        parse_s = time.perf_counter() - t0

        # -- zero-registration name resolution + planning ----------------
        # (shared with `repro explain` — the static route verdict agrees
        # with this decision because it IS this decision)
        t1 = time.perf_counter()
        snapshots = resolve_query_snapshots(
            self.catalog, self.fmt, query,
            branch=branch, commit_id=commit_id, text=text,
        )
        _check_query_columns(query, snapshots, text)
        iq = plan_interactive_query(query, snapshots, engine=engine)
        route, residual, scans = iq.route, iq.residual, iq.scans
        plan_s = time.perf_counter() - t1

        # -- pooled parallel scans, kernel-sized chunks -------------------
        # tables scan one after another; each scan parallelizes its own
        # shards on the io pool (nesting table-level fan-out on the same
        # pool could deadlock it)
        t2 = time.perf_counter()
        rels = {
            table: Columnar.from_numpy(
                execute_scan(
                    self.fmt, scan, pool=self.executor.io_pool,
                    bus=self.bus, tags={"source": "query", "table": table},
                    chunk_rows=KERNEL_CHUNK_ROWS,
                )
            )
            for table, scan in scans.items()
        }
        scan_s = time.perf_counter() - t2

        # -- one compiled program (jnp or fused-kernel path) --------------
        t3 = time.perf_counter()
        residual_query = _replace(query, filter_expr=residual)
        joined = {j.table: rels[j.table] for j in query.joins}
        out = compile_query(residual_query, route=route)(
            rels[query.source], joined or None
        )
        result = out.to_numpy()
        exec_s = time.perf_counter() - t3

        rows_out = len(next(iter(result.values()))) if result else 0
        self._publish(QueryExecuted(
            table=query.source,
            rows_out=rows_out,
            shards_read=sum(len(s.shards) for s in scans.values()),
            wall_s=time.perf_counter() - t0,
            engine_path=route.engine_path,
            parse_s=parse_s,
            plan_s=plan_s,
            scan_s=scan_s,
            exec_s=exec_s,
        ))
        return result

    # ---------------------------------------------------------------- run
    def run(
        self,
        pipeline: Pipeline,
        *,
        branch: str = "main",
        params: Optional[Dict[str, Any]] = None,
        fusion: bool = True,
        pushdown: bool = True,
        base_commit: Optional[str] = None,
        author: str = "user",
        cache: bool = True,
        planner_config: Optional[PlannerConfig] = None,
        parallelism: Optional[int] = None,
        schedule: str = "critical_path",
        streaming: Optional[bool] = None,
    ) -> RunResult:
        """Execute ``pipeline`` with transform-audit-write semantics.

        The cross-run differential cache is ON by default (the fast path
        is the default path): logical nodes whose transitive fingerprint
        matches a previous audited run are planned around — restored from
        the object store or elided outright — and after this run's audit
        passes its own node outputs are registered for future runs.
        ``cache=False`` bypasses the cache in both directions (full
        recompute, nothing persisted).

        ``planner_config`` overrides the ``fusion``/``pushdown`` shorthands
        when the caller needs full control (e.g. ``max_stage_nodes``) —
        thanks to node-granular cache keys, replanning under a different
        config still reuses every cached node.

        ``schedule`` picks the ready-set ordering policy of the wave
        scheduler: ``"critical_path"`` (default, Scheduler v2) pops the
        stage with the heaviest longest-path-to-sink cost estimate first
        and admits stages under the executor's estimated-peak-memory
        budget; ``"stage_id"`` reproduces the PR 5 policy exactly —
        ascending stage ids, in-flight bounded by a flat count.
        ``streaming`` hands stage outputs to dependents as soon as the
        stage function produces them, overlapping upstream artifact
        writes/commits with downstream work (default: on under
        ``critical_path``, off under ``stage_id``).  ``parallelism``
        pins how many stages stay in flight at once, superseding
        memory-capped admission's count backstop.  All three are
        throughput knobs, never semantics knobs: every combination
        produces byte-identical artifact manifests, check verdicts and
        cache entries.
        """
        if schedule not in ("critical_path", "stage_id"):
            raise ValueError(
                f"schedule must be 'critical_path' or 'stage_id', "
                f"got {schedule!r}"
            )
        t_start = time.perf_counter()
        params = dict(params or {})

        # 1. branch handling (auto-create like the paper's git detection);
        # tolerate a concurrent run creating the same branch first
        if not self.catalog.has_branch(branch):
            try:
                self.catalog.create_branch(branch)
                log.info("created catalog branch %r from main", branch)
            except CatalogError:
                if not self.catalog.has_branch(branch):
                    raise
        base = (
            self.catalog.get_commit(base_commit)
            if base_commit
            else self.catalog.head(branch)
        )

        run_id = self.registry.next_run_id()
        ephemeral = f"run_{run_id}"
        # telemetry: subscribe BEFORE the first event so the run's trace
        # is complete; RunFinished is published on every exit path (a
        # mid-DAG crash or failed audit still closes the run span)
        collector = (
            self.bus.subscribe(maxlen=_RUNLOG_BUFFER)
            if self.bus is not None
            else None
        )
        self._publish(
            RunStarted(run_id=run_id, pipeline=pipeline.name, branch=branch)
        )
        state = "ERROR"
        failed_checks: List[str] = []
        self.catalog.create_branch(ephemeral, at_commit=base.commit_id)
        # pin the base commit: a concurrent `repro gc` must not expire the
        # data version this run is reading (grace-period pinning)
        self.registry.pin_run(run_id, base.commit_id)

        try:
            try:
                result = self._execute(
                    pipeline, branch, ephemeral, base.commit_id, params,
                    planner_config
                    or PlannerConfig(fusion=fusion, pushdown=pushdown),
                    run_id,
                    use_cache=cache,
                    parallelism=parallelism,
                    schedule=schedule,
                    streaming=streaming,
                )
            except Exception:
                # any failure: discard the ephemeral branch — prod stays clean
                self.catalog.delete_branch(ephemeral)
                raise

            # 4. audit — a failed expectation also rolls back this run's
            # candidate cache entries (they are only persisted below, after
            # the audit), so the cache can never serve unaudited artifacts
            failed = [k for k, v in result["checks"].items() if not v]
            if failed:
                self.catalog.delete_branch(ephemeral)
                rec = self._record(
                    run_id, pipeline, branch, base.commit_id, params,
                    result, merged=None, t_start=t_start,
                )
                state, failed_checks = "AUDIT_FAILED", failed
                raise ExpectationFailed(failed, record=rec, plan=result["plan"])

            # 5. write: atomic merge + ephemeral cleanup
            merged = self.catalog.merge(
                ephemeral, branch,
                message=f"run {run_id}: {pipeline.name}",
                author=author, delete_source=True,
            )
            # 6. publish this run's node outputs to the differential cache,
            # and only now apply any staged legacy->node upgrades — a
            # failed audit must leave the registry untouched, adoptions
            # included (write-after-audit covers re-keying)
            if cache:
                view = result["cache"]["view"]
                if view is not None:
                    view.apply_adoptions()
                for entry in result["cache"]["entries"].values():
                    self.cache_registry.put(entry)
            rec = self._record(
                run_id, pipeline, branch, base.commit_id, params,
                result, merged=merged.commit_id, t_start=t_start,
            )
            state = "SUCCESS"
        except BaseException as e:
            # stamp the run id on the escaping exception so an ERROR
            # handle can still locate this run's persisted trace
            try:
                e.repro_run_id = run_id  # type: ignore[attr-defined]
            except Exception:
                pass
            raise
        finally:
            self.registry.unpin_run(run_id)
            self._publish(
                RunFinished(
                    run_id=run_id,
                    state=state,
                    wall_s=time.perf_counter() - t_start,
                    failed_checks=failed_checks,
                )
            )
            if collector is not None:
                events = self._collect_run_events(collector, run_id)
                try:
                    self.runlog.put(
                        run_id, events, pipeline=pipeline.name, state=state
                    )
                except Exception:  # a failed trace write must not sink a run
                    log.warning(
                        "failed to persist runlog for run %d", run_id,
                        exc_info=True,
                    )
        return RunResult(
            run_id=run_id,
            branch=branch,
            merged_commit=merged.commit_id,
            artifacts=result["artifacts"],
            checks=result["checks"],
            stats=rec.stats,
            plan=result["plan"],
        )

    # ------------------------------------------------------------- replay
    def replay(
        self,
        pipeline: Pipeline,
        run_id: int,
        *,
        strict_code: bool = True,
        parallelism: Optional[int] = None,
        schedule: str = "critical_path",
        streaming: Optional[bool] = None,
    ) -> RunResult:
        """Re-execute run ``run_id``: same code, same data version (4.6).

        Executes into a fresh ephemeral branch that is dropped afterwards —
        replay is for debugging/inspection, it never moves branches.
        """
        rec = self.registry.get(run_id)
        if strict_code and rec.pipeline_fingerprint != pipeline.fingerprint:
            raise ValueError(
                "pipeline code differs from the recorded run "
                f"({rec.pipeline_fingerprint} != {pipeline.fingerprint}); "
                "pass strict_code=False to replay anyway"
            )
        replay_id = self.registry.next_run_id()
        ephemeral = f"run_{replay_id}"
        collector = (
            self.bus.subscribe(maxlen=_RUNLOG_BUFFER)
            if self.bus is not None
            else None
        )
        t_start = time.perf_counter()
        self._publish(
            RunStarted(
                run_id=replay_id, pipeline=pipeline.name,
                branch=rec.branch, replay_of=run_id,
            )
        )
        state = "ERROR"
        self.catalog.create_branch(ephemeral, at_commit=rec.base_commit)
        self.registry.pin_run(replay_id, rec.base_commit)
        try:
            # replay must genuinely re-execute — the differential cache is
            # bypassed so the reproducibility claim is tested, not assumed
            result = self._execute(
                pipeline, rec.branch, ephemeral, rec.base_commit,
                dict(rec.params), PlannerConfig(fusion=rec.fused), replay_id,
                use_cache=False,
                parallelism=parallelism,
                schedule=schedule,
                streaming=streaming,
            )
            state = "SUCCESS"
        finally:
            self.catalog.delete_branch(ephemeral)
            self.registry.unpin_run(replay_id)
            self._publish(
                RunFinished(
                    run_id=replay_id,
                    state=state,
                    wall_s=time.perf_counter() - t_start,
                )
            )
            if collector is not None:
                events = self._collect_run_events(collector, replay_id)
                try:
                    self.runlog.put(
                        replay_id, events, pipeline=pipeline.name, state=state
                    )
                except Exception:
                    log.warning(
                        "failed to persist runlog for replay %d", replay_id,
                        exc_info=True,
                    )
        return RunResult(
            run_id=replay_id,
            branch=rec.branch,
            merged_commit=None,
            artifacts=result["artifacts"],
            checks=result["checks"],
            stats={"replay_of": run_id},
            plan=result["plan"],
        )

    # ------------------------------------------------------------ internal
    def _execute(
        self,
        pipeline: Pipeline,
        branch: str,
        ephemeral: str,
        base_commit: str,
        params: Dict[str, Any],
        config: PlannerConfig,
        run_id: int,
        *,
        use_cache: bool = False,
        parallelism: Optional[int] = None,
        schedule: str = "critical_path",
        streaming: Optional[bool] = None,
    ) -> Dict[str, Any]:
        # 2. code intelligence: logical plan pinned to the base commit
        tables_at_base = self.catalog.get_commit(base_commit).tables
        schemas = {}
        snapshots: Dict[str, Snapshot] = {}
        for name in pipeline.external_sources():
            if name not in tables_at_base:
                raise KeyError(
                    f"pipeline references table {name!r} missing at commit "
                    f"{base_commit[:12]} on branch {branch!r}"
                )
            snap = self.fmt.load_snapshot(tables_at_base[name])
            snapshots[name] = snap
            schemas[name] = snap.schema
        logical = build_logical_plan(pipeline, external_schemas=schemas)
        ctx = RunContext(branch, run_id, params)
        # sharding-invariant input identity: a compaction rewrite changes
        # snapshot ids but not content, so fingerprints key on the content
        # hash (memoized per snapshot — only the first run pays the scan)
        input_fps = (
            {
                name: self.fmt.content_fingerprint(snap)
                for name, snap in snapshots.items()
            }
            if use_cache
            else None
        )
        cache_view = CacheView(self.cache_registry) if use_cache else None
        plan = build_physical_plan(
            logical, snapshots, config=config, ctx=ctx,
            cache=cache_view, input_fingerprints=input_fps,
        )
        log.info("\n%s", plan.describe())

        # 3. transform: execute stages through the serverless executor —
        # the planner already cut every cache-satisfied node out of them
        env: Dict[str, Columnar] = {}  # in-memory artifact cache (locality)
        artifacts: Dict[str, str] = {}
        checks: Dict[str, bool] = {}
        bytes_saved = 0
        new_entries: Dict[str, NodeCacheEntry] = {}
        bytes_before = self.fmt.store.stats.snapshot()

        # 3a. rehydrate cache-satisfied nodes: commit their cached manifest
        # keys to the ephemeral branch (contract outputs stay queryable and
        # executing stages read restored inputs back on demand) and report
        # their audited verdicts.  Expectations were audited when the entry
        # was created — same code, same data, same verdict (4.4.1).
        rehydrate_updates: Dict[str, str] = {}
        t_rehydrate = time.perf_counter()
        ts_rehydrate = time.time()
        for name in plan.rehydrate:
            entry = plan.cached_nodes[name]
            key = entry.outputs[name]
            artifacts[name] = key
            rehydrate_updates[name] = key
            bytes_saved += entry.output_bytes
            self.fmt.store.record_cache_hit(entry.output_bytes)
            # bump the entry's LRU clock so eviction favours cold ones.
            # Deliberately re-fetch instead of passing the in-hand entry:
            # entries staged by a legacy adoption are not persisted until
            # the audit passes, and touch() must not write them early.
            self.cache_registry.touch(entry.fingerprint)
        for cname in plan.cached_checks:
            checks[cname] = True
            self.cache_registry.touch(plan.cached_nodes[cname].fingerprint)
        if rehydrate_updates:
            self.catalog.commit(
                ephemeral, rehydrate_updates,
                message=f"run {run_id}: rehydrated "
                        f"{sorted(rehydrate_updates)} from node cache",
                author="runner",
            )
            log.info(
                "cache: rehydrated %d artifact(s), skipped %d audited "
                "check(s), elided %d node(s)",
                len(rehydrate_updates), len(plan.cached_checks),
                len(plan.elided),
            )
        if self.bus is not None:
            # plan-time cache verdicts, one event per logical node.  Hit
            # events for every cache-satisfied node (rehydrated, elided or
            # audited-check); rehydrated artifacts additionally get a
            # timed rehydrate span covering the manifest re-commit.
            rehydrate_s = time.perf_counter() - t_rehydrate
            for name in sorted(plan.cached_nodes):
                entry = plan.cached_nodes[name]
                self._publish(NodeCacheHit(
                    run_id=run_id, node=name, fingerprint=entry.fingerprint,
                    rehydrated=name in rehydrate_updates,
                    bytes=entry.output_bytes,
                ))
            for name in sorted(rehydrate_updates):
                self._publish(NodeCacheRehydrated(
                    run_id=run_id, ts=ts_rehydrate, node=name,
                    bytes=plan.cached_nodes[name].output_bytes,
                    dur_s=rehydrate_s,
                ))
            if use_cache:
                for stage in plan.stages:
                    for name in stage.node_names:
                        self._publish(NodeCacheMiss(
                            run_id=run_id, node=name,
                            fingerprint=plan.node_fingerprints.get(name, ""),
                            stage_id=stage.stage_id,
                        ))

        # 3b. wave/eager scheduling (Scheduler v2): every stage whose
        # parent stages are satisfied is submitted to the executor's stage
        # lane; completions (or, under streaming, outputs-ready) unblock
        # dependents immediately — no barrier between waves.  Shared run
        # state (env, artifacts, checks, cache candidates, counters) is
        # guarded by ``state_lock``; catalog commits are funneled through
        # ``pending_commits`` and applied in stage-id order, so the
        # ephemeral branch's history is linear and identical to a
        # sequential run's, whatever order stages actually finish in.
        use_streaming = (
            (schedule == "critical_path") if streaming is None else bool(streaming)
        )
        # per-stage runtime estimates + longest-path-to-sink weights: the
        # latencyhist medians the Client seeded into the executor win;
        # never-seen stages fall back to the bytes-scanned heuristic
        costs = estimate_stage_costs(
            plan.stages, pipeline.name, self.executor.latency_medians()
        )
        cfg = self.executor.config
        if parallelism is not None:
            # an explicit per-run parallelism pins the in-flight count in
            # either mode (the parity matrix isolates ordering/streaming
            # at a fixed level this way)
            workers = max(1, parallelism)
        elif schedule == "critical_path" and cfg.memory_budget_gb is not None:
            # memory-capped admission supersedes the flat stage count —
            # the count backstop is only the stage lane's thread capacity
            workers = max(cfg.max_concurrent_stages, 32)
        else:
            workers = max(1, cfg.max_concurrent_stages)
        mem_budget = (
            cfg.memory_budget_gb if schedule == "critical_path" else None
        )
        state_lock = threading.Lock()
        counters = {"stages_executed": 0}
        pending_commits: Dict[int, Dict[str, Optional[str]]] = {}
        next_commit = [0]
        # perf_counter at submit time, keyed by stage id — queue latency is
        # StageStarted - StageQueued, reported per stage in run stats
        queued_at: Dict[int, float] = {}
        stage_timings: Dict[int, Dict[str, float]] = {}

        def flush_commits_locked() -> None:
            # called with state_lock held: drain the contiguous prefix of
            # completed stages (the commit queue's epoch advance)
            while next_commit[0] in pending_commits:
                sid = next_commit[0]
                updates = pending_commits.pop(sid)
                t0 = time.perf_counter()
                if updates:
                    self.catalog.commit(
                        ephemeral, updates,
                        message=f"run {run_id} stage {sid}",
                        author="runner",
                    )
                commit_s = time.perf_counter() - t0
                stage_timings.setdefault(sid, {})["commit_s"] = commit_s
                self._publish(StageCommitted(
                    run_id=run_id, stage_id=sid,
                    tables=sorted(updates), commit_s=commit_s,
                ))
                next_commit[0] += 1

        def run_stage(stage) -> None:
            t_exec = time.perf_counter()
            queue_s = t_exec - queued_at.get(stage.stage_id, t_exec)
            self._publish(StageStarted(run_id=run_id, stage_id=stage.stage_id))
            scan_tags = {"run_id": run_id, "stage_id": stage.stage_id}
            inputs: List[Columnar] = []
            for table in sorted(stage.scans):
                # streaming mode drives the scan through the incremental
                # shard iterator (bounded read-ahead window) — chunking and
                # shard order are shared with the barrier path, so the
                # concatenated input is byte-identical either way
                data = execute_scan(
                    self.fmt, stage.scans[table].plan,
                    pool=self.executor.io_pool,
                    bus=self.bus, tags=dict(scan_tags, table=table),
                    streaming=use_streaming,
                )
                inputs.append(Columnar.from_numpy(data))
            for name in stage.internal_inputs:
                with state_lock:  # data locality: reuse in-memory artifact
                    rel = env.get(name)
                if rel is None:  # fallback: read from the ephemeral branch
                    key = self.catalog.table_key(name, branch=ephemeral)
                    rel = Columnar.from_numpy(
                        self.fmt.read(self.fmt.load_snapshot(key))
                    )
                inputs.append(rel)
            # one construction site (physical.stage_function_spec) for the
            # dispatch spec — the scheduler's cost lookup and the executor's
            # latency history key the same fingerprint by definition
            spec = stage_function_spec(pipeline.name, stage)
            outputs, stage_checks = self.executor.run(
                spec, *inputs, tags=scan_tags
            )
            if use_streaming:
                # streaming handoff: publish in-memory outputs and unblock
                # dependent stages NOW, before artifact writes land —
                # downstream stages consume completed upstream results
                # while this stage's store I/O is still in flight.  The
                # stage barrier is retained where it matters: audits and
                # catalog commits still drain in stage-id order below.
                with state_lock:
                    for name, rel in outputs.items():
                        env[name] = rel
                outputs_ready(stage.stage_id)
            # store I/O (artifact writes) runs outside the state lock so
            # concurrent stages overlap their writes; only the publication
            # of results + the ordered commit drain is serialized
            updates: Dict[str, Optional[str]] = {}
            node_bytes: Dict[str, int] = {}
            written: Dict[str, Any] = {}
            for name, rel in outputs.items():
                compact = rel.to_numpy(compact=True)
                node_bytes[name] = sum(arr.nbytes for arr in compact.values())
                schema = Schema(
                    tuple(
                        Column(c, str(compact[c].dtype)) for c in sorted(compact)
                    )
                )
                snap = self.fmt.write(name, schema, compact)
                key = self.fmt.manifest_key(snap)
                updates[name] = key
                written[name] = (rel, key)
            now = time.time()
            exec_s = time.perf_counter() - t_exec
            # predicted-vs-actual: the scheduling estimate against the full
            # driver span (scan → execute → write) — persisted to the
            # latencyhist namespace alongside the self-correcting medians
            self.executor.record_forecast(
                spec.fingerprint, costs[stage.stage_id].est_s, exec_s
            )
            self._publish(StageFinished(
                run_id=run_id, stage_id=stage.stage_id, exec_s=exec_s,
                outputs=sorted(outputs), checks=sorted(stage_checks),
            ))
            with state_lock:
                counters["stages_executed"] += 1
                stage_timings.setdefault(stage.stage_id, {}).update(
                    queue_s=queue_s, exec_s=exec_s
                )
                for name, (rel, key) in written.items():
                    env[name] = rel
                    artifacts[name] = key
                this_stage_checks: Dict[str, bool] = {}
                for cname, val in stage_checks.items():
                    verdict = bool(np.asarray(val))
                    checks[cname] = verdict
                    this_stage_checks[cname] = verdict
                if use_cache:
                    # candidate node entries — persisted by run() only if
                    # the audit passes (failed audits must not poison
                    # future runs).  One entry per materialized artifact
                    # and one per evaluated expectation, keyed by the
                    # fusion-independent node fingerprint, so any future
                    # plan shape can reuse them.
                    for name in stage.outputs:
                        fp = plan.node_fingerprints[name]
                        new_entries[fp] = NodeCacheEntry(
                            fingerprint=fp,
                            outputs={name: artifacts[name]},
                            checks={},
                            output_bytes=node_bytes.get(name, 0),
                            run_id=run_id,
                            created_at=now,
                            node=name,
                        )
                    for cname, verdict in this_stage_checks.items():
                        fp = plan.node_fingerprints[cname]
                        new_entries[fp] = NodeCacheEntry(
                            fingerprint=fp,
                            outputs={},
                            checks={cname: verdict},
                            output_bytes=0,
                            run_id=run_id,
                            created_at=now,
                            node=cname,
                        )
                pending_commits[stage.stage_id] = updates
                flush_commits_locked()

        stage_by_id = {s.stage_id: s for s in plan.stages}
        deps = {s.stage_id: set(s.parent_stages) for s in plan.stages}
        dependents: Dict[int, List[int]] = {}
        for s in plan.stages:
            for p in s.parent_stages:
                dependents.setdefault(p, []).append(s.stage_id)

        # The ready set is a min-heap whose key is the ordering mode:
        #   critical_path — (-cp_weight_s, stage_id): the stage heading the
        #       longest remaining cost-weighted path to a sink dispatches
        #       first; stage id is the deterministic tie-break.
        #   stage_id — ascending stage id, the PR 5 baseline: at
        #       parallelism 1 this degenerates to exactly the old
        #       sequential stage loop (the determinism-parity anchor).
        # Either way the knob changes dispatch ORDER only — artifacts,
        # checks and cache entries are byte-identical across modes.
        if schedule == "critical_path":
            def ready_key(sid: int) -> Tuple[float, int]:
                return (-costs[sid].cp_weight_s, sid)
        else:
            def ready_key(sid: int) -> Tuple[float, int]:
                return (0.0, sid)

        # Scheduler state below is guarded by ``cond``.  An RLock backs it
        # because a done-callback can fire inline on the submitting thread
        # (future already finished) while admit_locked still holds the
        # lock — a plain Lock would deadlock there.
        cond = threading.Condition(threading.RLock())
        ready: List[Tuple[Tuple[float, int], int]] = []
        ready_at: Dict[int, float] = {}
        unblocked: Set[int] = set()
        in_flight: Dict[int, Future] = {}
        inflight_mem = [0.0]
        failures: Dict[int, BaseException] = {}
        sched_stats: Dict[int, Dict[str, Any]] = {}

        def unblock_locked(sid: int) -> None:
            # idempotent: streaming fires this at outputs-ready AND the
            # done-callback fires it again when the driver future resolves
            if sid in unblocked:
                return
            unblocked.add(sid)
            for child in dependents.get(sid, ()):
                deps[child].discard(sid)
                if not deps[child]:
                    ready_at[child] = time.perf_counter()
                    heapq.heappush(ready, (ready_key(child), child))

        def outputs_ready(sid: int) -> None:
            # streaming handoff entry point (called from stage drivers)
            with cond:
                unblock_locked(sid)
                cond.notify_all()

        def on_stage_done(sid: int, fut: Future) -> None:
            with cond:
                err = fut.exception()
                if err is not None:
                    # stop scheduling, drain in-flight stages, then raise
                    failures[sid] = err
                else:
                    unblock_locked(sid)
                in_flight.pop(sid, None)
                inflight_mem[0] -= costs[sid].est_memory_gb
                cond.notify_all()

        def admit_locked() -> None:
            while ready and len(in_flight) < workers and not failures:
                _, sid = ready[0]
                cost = costs[sid]
                if (
                    mem_budget is not None
                    and in_flight
                    and inflight_mem[0] + cost.est_memory_gb > mem_budget
                ):
                    # memory-capped admission with head-of-line blocking:
                    # the most critical ready stage never loses its slot to
                    # a smaller one behind it (bypass could co-schedule two
                    # huge stages the moment the big head admits).  An
                    # empty in_flight always admits — no deadlock when one
                    # stage alone exceeds the budget.
                    sched_stats.setdefault(sid, {})["admission"] = "waited"
                    break
                heapq.heappop(ready)
                t_admit = time.perf_counter()
                wait_s = t_admit - ready_at.get(sid, t_admit)
                inflight_mem[0] += cost.est_memory_gb
                queued_at[sid] = t_admit
                stage = stage_by_id[sid]
                spec = stage_function_spec(pipeline.name, stage)
                warm = self.executor.warm_ready(spec)
                admission = (
                    "waited"
                    if sched_stats.get(sid, {}).get("admission") == "waited"
                    else "immediate"
                )
                sched_stats[sid] = {
                    "est_s": cost.est_s,
                    "source": cost.source,
                    "cp_weight_s": cost.cp_weight_s,
                    "cp_rank": cost.cp_rank,
                    "est_memory_gb": cost.est_memory_gb,
                    "admission_wait_s": wait_s,
                    "admission": admission,
                    "warm": warm,
                }
                self._publish(StageScheduled(
                    run_id=run_id, stage_id=sid,
                    est_cost_s=cost.est_s, cost_source=cost.source,
                    cp_weight_s=cost.cp_weight_s, cp_rank=cost.cp_rank,
                    est_memory_gb=cost.est_memory_gb,
                    admission_wait_s=wait_s, admission=admission,
                    schedule=schedule, streaming=use_streaming, warm=warm,
                ))
                self._publish(StageQueued(
                    run_id=run_id, stage_id=sid,
                    nodes=list(stage.node_names),
                    parents=sorted(stage.parent_stages),
                ))
                fut = self.executor.submit_stage(run_stage, stage)
                in_flight[sid] = fut
                fut.add_done_callback(
                    lambda f, sid=sid: on_stage_done(sid, f)
                )

        with cond:
            for s in plan.stages:
                if not deps[s.stage_id]:
                    ready_at[s.stage_id] = time.perf_counter()
                    heapq.heappush(ready, (ready_key(s.stage_id), s.stage_id))
            admit_locked()
            while in_flight or (ready and not failures):
                # timeout is a liveness backstop only — done-callbacks and
                # outputs_ready notify the loop on every state change
                cond.wait(timeout=0.1)
                admit_locked()
        if failures:
            # deterministic surfacing: raise the lowest failed stage id —
            # what the sequential loop would have hit first
            raise failures[min(failures)]
        stages_executed = counters["stages_executed"]
        bytes_after = self.fmt.store.stats.snapshot()
        # cache_* counters are run-level telemetry (reported under "cache")
        # and gc_*/compact_* belong to the lakekeeper, not bytes moved by
        # this run — keep the io dict strictly I/O
        io_delta = {
            k: bytes_after[k] - bytes_before[k]
            for k in bytes_after
            if not k.startswith(("cache_", "gc_", "compact_"))
        }
        return {
            "plan": plan,
            "artifacts": artifacts,
            "checks": checks,
            "io": io_delta,
            "parallelism": workers,
            "scheduler": {
                "schedule": schedule,
                "streaming": use_streaming,
                "memory_budget_gb": mem_budget,
                "workers": workers,
                "admission_waits": sum(
                    1 for s in sched_stats.values()
                    if s.get("admission") == "waited"
                ),
                # str keys: JSON-roundtrips through the run record
                "stages": {
                    str(sid): dict(s) for sid, s in sorted(sched_stats.items())
                },
                # the model's predicted critical path (stage ids, source →
                # sink) — same longest-path implementation `repro trace`
                # uses on observed latencies
                "critical_path": critical_path_ids(
                    {s.stage_id: costs[s.stage_id].est_s for s in plan.stages},
                    {s.stage_id: s.parent_stages for s in plan.stages},
                ),
            },
            # per-stage queue/exec/commit seconds (str keys: JSON-roundtrips
            # through the run record for `repro run --json`)
            "stage_timings": {
                str(sid): {
                    "queue_s": t.get("queue_s", 0.0),
                    "exec_s": t.get("exec_s", 0.0),
                    "commit_s": t.get("commit_s", 0.0),
                }
                for sid, t in sorted(stage_timings.items())
            },
            "cache": {
                "enabled": use_cache,
                # node-granular hit accounting: every cache-satisfied
                # logical node counts, whether rehydrated or elided
                "hits": len(plan.cached_nodes),
                "nodes_executed": plan.nodes_executed,
                "stages_executed": stages_executed,
                "rehydrated": len(plan.rehydrate),
                "elided": len(plan.elided),
                "bytes_saved": bytes_saved,
                "entries": new_entries,
                "view": cache_view,
            },
        }

    def _record(
        self,
        run_id: int,
        pipeline: Pipeline,
        branch: str,
        base_commit: str,
        params: Dict[str, Any],
        result: Dict[str, Any],
        *,
        merged: Optional[str],
        t_start: float,
    ) -> RunRecord:
        cache = result["cache"]
        rec = RunRecord(
            run_id=run_id,
            pipeline_name=pipeline.name,
            pipeline_fingerprint=pipeline.fingerprint,
            branch=branch,
            base_commit=base_commit,
            params=params,
            artifacts=result["artifacts"],
            checks=result["checks"],
            merged_commit=merged,
            fused=result["plan"].config.fusion,
            stats={
                "wall_s": time.perf_counter() - t_start,
                "stages": len(result["plan"].stages),
                "stages_executed": cache["stages_executed"],
                "parallelism": result.get("parallelism", 1),
                "scheduler": result.get("scheduler", {}),
                "stage_timings": result.get("stage_timings", {}),
                "io": result["io"],
                "executor": self.executor.stats(),
                "cache": {
                    "enabled": cache["enabled"],
                    "hits": cache["hits"],
                    "nodes_executed": cache["nodes_executed"],
                    "stages_executed": cache["stages_executed"],
                    "rehydrated": cache["rehydrated"],
                    "elided": cache["elided"],
                    "bytes_saved": cache["bytes_saved"],
                },
            },
            created_at=time.time(),
            # only audited (merged) runs publish entries; record what we did
            stage_cache={
                fp: dict(e.outputs) for fp, e in cache["entries"].items()
            } if merged is not None else {},
        )
        self.registry.record(rec)
        return rec
