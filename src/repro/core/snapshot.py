"""Run snapshotting + replay (paper 4.4.1, 4.6) and the differential cache.

Every run is assigned an id and an immutable record: pipeline fingerprint,
base data commit, parameters, produced artifact keys, and execution stats.
"The same code on the same data version will produce identical results" —
``Runner.replay`` re-executes a recorded run against its pinned commit and
the tests assert snapshot-id equality (bit-for-bit reproducibility).

That same determinism, read forward, is a performance win (the follow-up
paper's differential caching): if a *logical node's* transitive
fingerprint — node code + upstream node fingerprints + input table
content hashes + params — matches a previous successful run, its output
can be restored from the object store instead of recomputed.  The cache
is keyed at **node** granularity, independent of how the physical
planner happened to fuse nodes into stages, so a planner-config change
(fusion toggled, ``max_stage_nodes`` tweaked) never invalidates the
cache.  ``NodeCacheRegistry`` is the fingerprint → entry index; entries
are written only after a run's audit passes, so a failed expectation can
never leave poisoned cache entries behind.  Entries written by the old
stage-keyed scheme (PR 1) are kept readable in their own namespace and
upgraded one-way to node entries the first time a plan matches them
(``CacheView.adopt_legacy``), so pre-migration lakes don't cold-start.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

from repro.io.objectstore import ObjectStore

_RUN_NS = "runs"
_COUNTER = "run_counter"
#: legacy (PR 1) stage-keyed entries — read-only except for the one-way
#: upgrade; new entries always land in the node namespace
_LEGACY_CACHE_NS = "stagecache"
_CACHE_NS = "nodecache"
#: in-flight run pins — GC roots protecting a running run's base commit
#: (see repro.maintenance.reachability)
_PIN_NS = "pins"


@dataclass(frozen=True)
class RunRecord:
    run_id: int
    pipeline_name: str
    pipeline_fingerprint: str
    branch: str
    base_commit: str
    params: Dict[str, Any]
    #: artifact name -> snapshot manifest key
    artifacts: Dict[str, str]
    checks: Dict[str, bool]
    merged_commit: Optional[str]
    fused: bool
    stats: Dict[str, Any]
    created_at: float
    #: transitive *node* fingerprint -> artifact manifest keys persisted to
    #: the differential cache by this run (empty for cache-off / failed
    #: runs; check entries appear with an empty mapping).  Named
    #: ``stage_cache`` for on-disk compatibility with pre-node records.
    stage_cache: Dict[str, Dict[str, str]] = field(default_factory=dict)

    def to_json_dict(self) -> Dict:
        return {
            "run_id": self.run_id,
            "pipeline_name": self.pipeline_name,
            "pipeline_fingerprint": self.pipeline_fingerprint,
            "branch": self.branch,
            "base_commit": self.base_commit,
            "params": self.params,
            "artifacts": self.artifacts,
            "checks": self.checks,
            "merged_commit": self.merged_commit,
            "fused": self.fused,
            "stats": self.stats,
            "created_at": self.created_at,
            "stage_cache": self.stage_cache,
        }

    @staticmethod
    def from_json_dict(d: Dict) -> "RunRecord":
        return RunRecord(**d)


@dataclass
class RunRegistry:
    """The Postgres-of-spare-parts: run records as refs in the store."""

    store: ObjectStore

    def next_run_id(self) -> int:
        for _ in range(1000):
            cur = self.store.get_ref(_RUN_NS, _COUNTER)  # None on first run
            val = (cur or {"value": 0})["value"] + 1
            if self.store.compare_and_set_ref(_RUN_NS, _COUNTER, cur, {"value": val}):
                return val
        raise RuntimeError("run-id contention")

    def record(self, rec: RunRecord) -> None:
        self.store.set_ref(_RUN_NS, f"run_{rec.run_id}", rec.to_json_dict())

    def get(self, run_id: int) -> RunRecord:
        raw = self.store.get_ref(_RUN_NS, f"run_{run_id}")
        if raw is None:
            raise KeyError(f"no run record for id {run_id}")
        return RunRecord.from_json_dict(raw)

    def all_runs(self) -> List[RunRecord]:
        out = []
        for name, raw in self.store.list_refs(_RUN_NS).items():
            if name.startswith("run_"):
                out.append(RunRecord.from_json_dict(raw))
        return sorted(out, key=lambda r: r.run_id)

    # -------------------------------------------------------------- pinning
    # An executing run holds a pin on its base commit so a concurrent
    # ``repro gc`` cannot expire the data version it is reading.  Pins are
    # dropped in the runner's ``finally``; a pin leaked by a crashed
    # process ages out via the GC's ``pin_ttl_s``.

    def pin_run(self, run_id: int, base_commit: str) -> None:
        self.store.set_ref(
            _PIN_NS, f"run_{run_id}",
            {"base_commit": base_commit, "created_at": time.time()},
        )

    def unpin_run(self, run_id: int) -> None:
        self.store.delete_ref(_PIN_NS, f"run_{run_id}")

    def pinned_commits(self, *, max_age_s: Optional[float] = None) -> Dict[int, str]:
        """Live pins: run_id -> base commit.  Pins older than
        ``max_age_s`` are treated as leaked and ignored."""
        now = time.time()
        out: Dict[int, str] = {}
        for name, raw in self.store.list_refs(_PIN_NS).items():
            if not name.startswith("run_"):
                continue
            if max_age_s is not None and now - raw.get("created_at", 0.0) > max_age_s:
                continue
            out[int(name[len("run_"):])] = raw["base_commit"]
        return out


@dataclass(frozen=True)
class NodeCacheEntry:
    """Everything needed to substitute one cached logical node for execution.

    An **artifact** node's entry maps its name -> snapshot manifest key in
    ``outputs`` (a single-key dict); an **expectation** node's entry records
    its audited verdict in ``checks`` instead.  The blobs behind a manifest
    key are content-addressed, so the key stays dereferenceable until the
    lakekeeper (repro.maintenance) evicts the entry and a GC sweep reclaims
    any blobs no longer reachable from another root.  Since entries are
    only persisted after a fully-audited run, every recorded verdict is
    True — audit can be skipped for cache-restored nodes.  ``output_bytes``
    (size) and ``last_used_at`` (recency) are the metadata the eviction
    policy (LRU within a byte budget, optional TTL) ranks entries by.

    Legacy stage-keyed entries (PR 1) deserialize into the same shape
    (multi-name ``outputs``/``checks``, empty ``node``) and are upgraded
    one-way to node entries by ``CacheView.adopt_legacy``.
    """

    fingerprint: str
    outputs: Dict[str, str]
    checks: Dict[str, bool]
    #: decompressed bytes the cached outputs represent (what a recompute
    #: would have re-written) — feeds StoreStats.cache_bytes_saved and
    #: counts against the eviction policy's byte budget
    output_bytes: int
    run_id: int
    created_at: float
    #: bumped on every cache hit (LRU clock); equals created_at until the
    #: entry is first restored
    last_used_at: float = 0.0
    #: logical node name this entry caches ("" for legacy stage entries)
    node: str = ""

    def __post_init__(self) -> None:
        if self.last_used_at == 0.0:
            object.__setattr__(self, "last_used_at", self.created_at)

    @property
    def kind(self) -> str:
        if not self.node:
            return "stage"  # legacy, pre-node-granularity
        return "check" if self.checks else "artifact"

    def to_json_dict(self) -> Dict:
        return {
            "fingerprint": self.fingerprint,
            "outputs": self.outputs,
            "checks": self.checks,
            "output_bytes": self.output_bytes,
            "run_id": self.run_id,
            "created_at": self.created_at,
            "last_used_at": self.last_used_at,
            "node": self.node,
        }

    @staticmethod
    def from_json_dict(d: Dict) -> "NodeCacheEntry":
        return NodeCacheEntry(**d)


#: historical name — external callers and old records still use it
StageCacheEntry = NodeCacheEntry


@dataclass
class NodeCacheRegistry:
    """Differential-cache index: transitive node fingerprint -> entry.

    Entries live in the same ref namespace machinery as branches and run
    records, so the cache shares the store's durability and atomic-swap
    semantics without any new storage layer.  Two namespaces back the
    registry: ``nodecache`` (current, node-keyed) and ``stagecache``
    (legacy PR 1 stage-keyed entries, kept readable so old lakes warm up
    instead of cold-starting).  Reads/evictions see the union; writes go
    to the node namespace only.
    """

    store: ObjectStore

    def get(self, fingerprint: str) -> Optional[NodeCacheEntry]:
        raw = self.store.get_ref(_CACHE_NS, fingerprint)
        return None if raw is None else NodeCacheEntry.from_json_dict(raw)

    def get_legacy(self, stage_fingerprint: str) -> Optional[NodeCacheEntry]:
        """Look up a PR 1 stage-keyed entry (the upgrade-path read)."""
        raw = self.store.get_ref(_LEGACY_CACHE_NS, stage_fingerprint)
        return None if raw is None else NodeCacheEntry.from_json_dict(raw)

    def put(self, entry: NodeCacheEntry) -> None:
        self.store.set_ref(_CACHE_NS, entry.fingerprint, entry.to_json_dict())

    def put_legacy(self, entry: NodeCacheEntry) -> None:
        """Write into the legacy stage-keyed namespace.  Only migration
        tests and pre-node tooling should ever need this."""
        self.store.set_ref(
            _LEGACY_CACHE_NS, entry.fingerprint, entry.to_json_dict()
        )

    def invalidate(self, fingerprint: str) -> bool:
        """Drop an entry from whichever namespace holds it; idempotent,
        returns whether it existed."""
        dropped = self.store.delete_ref(_CACHE_NS, fingerprint)
        return self.store.delete_ref(_LEGACY_CACHE_NS, fingerprint) or dropped

    def touch(
        self,
        fingerprint: str,
        *,
        entry: Optional[NodeCacheEntry] = None,
        now: Optional[float] = None,
    ) -> None:
        """Bump an entry's LRU clock (called by the runner on a hit).
        Pass ``entry`` when already in hand to skip the re-fetch."""
        entry = entry if entry is not None else self.get(fingerprint)
        if entry is None:
            return
        self.put(replace(entry, last_used_at=now if now is not None else time.time()))

    def entries(self) -> Dict[str, NodeCacheEntry]:
        """Union of node-keyed and surviving legacy entries — what the
        eviction policy budgets and the GC mark walks."""
        out = {
            fp: NodeCacheEntry.from_json_dict(raw)
            for fp, raw in self.store.list_refs(_LEGACY_CACHE_NS).items()
        }
        out.update(
            (fp, NodeCacheEntry.from_json_dict(raw))
            for fp, raw in self.store.list_refs(_CACHE_NS).items()
        )
        return out

    def total_bytes(self) -> int:
        """Sum of output_bytes across live entries (the budgeted figure)."""
        return sum(e.output_bytes for e in self.entries().values())

    def clear(self) -> None:
        for fp in list(self.entries()):
            self.invalidate(fp)


#: historical name — maintenance, CLI and tests predating node granularity
StageCacheRegistry = NodeCacheRegistry


class CacheView:
    """The planner's window onto the differential cache.

    ``build_physical_plan`` consults it to decide which logical nodes can
    be satisfied without execution; the runner constructs one per cached
    run.  The view is strictly read-only at plan time: ``adopt_legacy``
    only *stages* the one-way upgrade of a matched PR 1 stage entry into
    per-node entries, and the runner applies it (``apply_adoptions``)
    after the run's audit passes — a failed run must not mutate the
    registry, re-keying included.
    """

    def __init__(self, registry: NodeCacheRegistry):
        self.registry = registry
        #: (legacy entry, replacement node entries) staged by the planner
        self.pending_adoptions: List[
            Tuple[NodeCacheEntry, List[NodeCacheEntry]]
        ] = []

    def node(self, fingerprint: str) -> Optional[NodeCacheEntry]:
        return self.registry.get(fingerprint)

    def legacy_stage(self, stage_fingerprint: str) -> Optional[NodeCacheEntry]:
        return self.registry.get_legacy(stage_fingerprint)

    def adopt_legacy(
        self,
        legacy: NodeCacheEntry,
        node_entries: List[NodeCacheEntry],
    ) -> None:
        """Stage the split of ``legacy`` into node-keyed ``node_entries``.

        The legacy entry's outputs were written by a fully-audited run, so
        the adopted entries inherit its provenance (run_id/created_at);
        this run can plan against them immediately.  Nothing is persisted
        here — ``apply_adoptions`` runs post-audit.
        """
        self.pending_adoptions.append((legacy, list(node_entries)))

    def apply_adoptions(self) -> None:
        """Persist staged upgrades: write the node entries, retire the
        stage-keyed originals (the node entries now root the same
        manifests for the GC).  Idempotent; called by the runner after a
        successful audit."""
        for legacy, entries in self.pending_adoptions:
            for entry in entries:
                self.registry.put(entry)
            self.registry.store.delete_ref(
                _LEGACY_CACHE_NS, legacy.fingerprint
            )
        self.pending_adoptions.clear()
