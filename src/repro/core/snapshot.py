"""Run snapshotting + replay (paper 4.4.1, 4.6) and the differential cache.

Every run is assigned an id and an immutable record: pipeline fingerprint,
base data commit, parameters, produced artifact keys, and execution stats.
"The same code on the same data version will produce identical results" —
``Runner.replay`` re-executes a recorded run against its pinned commit and
the tests assert snapshot-id equality (bit-for-bit reproducibility).

That same determinism, read forward, is a performance win (the follow-up
paper's differential caching): if a stage's *transitive* fingerprint —
node code + upstream fingerprints + input snapshot ids + params — matches
a previous successful run, its outputs can be restored from the object
store instead of recomputed.  ``StageCacheRegistry`` is the fingerprint →
outputs index; entries are written only after a run's audit passes, so a
failed expectation can never leave poisoned cache entries behind.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional

from repro.io.objectstore import ObjectStore

_RUN_NS = "runs"
_COUNTER = "run_counter"
_CACHE_NS = "stagecache"
#: in-flight run pins — GC roots protecting a running run's base commit
#: (see repro.maintenance.reachability)
_PIN_NS = "pins"


@dataclass(frozen=True)
class RunRecord:
    run_id: int
    pipeline_name: str
    pipeline_fingerprint: str
    branch: str
    base_commit: str
    params: Dict[str, Any]
    #: artifact name -> snapshot manifest key
    artifacts: Dict[str, str]
    checks: Dict[str, bool]
    merged_commit: Optional[str]
    fused: bool
    stats: Dict[str, Any]
    created_at: float
    #: transitive stage fingerprint -> artifact manifest keys persisted to
    #: the differential cache by this run (empty for cache-off / failed runs)
    stage_cache: Dict[str, Dict[str, str]] = field(default_factory=dict)

    def to_json_dict(self) -> Dict:
        return {
            "run_id": self.run_id,
            "pipeline_name": self.pipeline_name,
            "pipeline_fingerprint": self.pipeline_fingerprint,
            "branch": self.branch,
            "base_commit": self.base_commit,
            "params": self.params,
            "artifacts": self.artifacts,
            "checks": self.checks,
            "merged_commit": self.merged_commit,
            "fused": self.fused,
            "stats": self.stats,
            "created_at": self.created_at,
            "stage_cache": self.stage_cache,
        }

    @staticmethod
    def from_json_dict(d: Dict) -> "RunRecord":
        return RunRecord(**d)


@dataclass
class RunRegistry:
    """The Postgres-of-spare-parts: run records as refs in the store."""

    store: ObjectStore

    def next_run_id(self) -> int:
        for _ in range(1000):
            cur = self.store.get_ref(_RUN_NS, _COUNTER)  # None on first run
            val = (cur or {"value": 0})["value"] + 1
            if self.store.compare_and_set_ref(_RUN_NS, _COUNTER, cur, {"value": val}):
                return val
        raise RuntimeError("run-id contention")

    def record(self, rec: RunRecord) -> None:
        self.store.set_ref(_RUN_NS, f"run_{rec.run_id}", rec.to_json_dict())

    def get(self, run_id: int) -> RunRecord:
        raw = self.store.get_ref(_RUN_NS, f"run_{run_id}")
        if raw is None:
            raise KeyError(f"no run record for id {run_id}")
        return RunRecord.from_json_dict(raw)

    def all_runs(self) -> List[RunRecord]:
        out = []
        for name, raw in self.store.list_refs(_RUN_NS).items():
            if name.startswith("run_"):
                out.append(RunRecord.from_json_dict(raw))
        return sorted(out, key=lambda r: r.run_id)

    # -------------------------------------------------------------- pinning
    # An executing run holds a pin on its base commit so a concurrent
    # ``repro gc`` cannot expire the data version it is reading.  Pins are
    # dropped in the runner's ``finally``; a pin leaked by a crashed
    # process ages out via the GC's ``pin_ttl_s``.

    def pin_run(self, run_id: int, base_commit: str) -> None:
        self.store.set_ref(
            _PIN_NS, f"run_{run_id}",
            {"base_commit": base_commit, "created_at": time.time()},
        )

    def unpin_run(self, run_id: int) -> None:
        self.store.delete_ref(_PIN_NS, f"run_{run_id}")

    def pinned_commits(self, *, max_age_s: Optional[float] = None) -> Dict[int, str]:
        """Live pins: run_id -> base commit.  Pins older than
        ``max_age_s`` are treated as leaked and ignored."""
        now = time.time()
        out: Dict[int, str] = {}
        for name, raw in self.store.list_refs(_PIN_NS).items():
            if not name.startswith("run_"):
                continue
            if max_age_s is not None and now - raw.get("created_at", 0.0) > max_age_s:
                continue
            out[int(name[len("run_"):])] = raw["base_commit"]
        return out


@dataclass(frozen=True)
class StageCacheEntry:
    """Everything needed to substitute a cached stage for execution.

    ``outputs`` maps artifact name -> snapshot manifest key; the blobs
    are content-addressed, so the keys stay dereferenceable until the
    lakekeeper (repro.maintenance) evicts the entry and a GC sweep
    reclaims any blobs no longer reachable from another root.
    ``checks`` records the stage's expectation verdicts at creation
    time; since entries are only persisted after a fully-audited run,
    every recorded verdict is True — downstream audit can therefore be
    skipped for cache-restored stages.  ``output_bytes`` (size) and
    ``last_used_at`` (recency) are the metadata the eviction policy
    (LRU within a byte budget, optional TTL) ranks entries by.
    """

    fingerprint: str
    outputs: Dict[str, str]
    checks: Dict[str, bool]
    #: decompressed bytes the cached outputs represent (what a recompute
    #: would have re-written) — feeds StoreStats.cache_bytes_saved and
    #: counts against the eviction policy's byte budget
    output_bytes: int
    run_id: int
    created_at: float
    #: bumped on every cache hit (LRU clock); equals created_at until the
    #: entry is first restored
    last_used_at: float = 0.0

    def __post_init__(self) -> None:
        if self.last_used_at == 0.0:
            object.__setattr__(self, "last_used_at", self.created_at)

    def to_json_dict(self) -> Dict:
        return {
            "fingerprint": self.fingerprint,
            "outputs": self.outputs,
            "checks": self.checks,
            "output_bytes": self.output_bytes,
            "run_id": self.run_id,
            "created_at": self.created_at,
            "last_used_at": self.last_used_at,
        }

    @staticmethod
    def from_json_dict(d: Dict) -> "StageCacheEntry":
        return StageCacheEntry(**d)


@dataclass
class StageCacheRegistry:
    """Differential-cache index: transitive stage fingerprint -> entry.

    Entries live in the same ref namespace machinery as branches and run
    records, so the cache shares the store's durability and atomic-swap
    semantics without any new storage layer.
    """

    store: ObjectStore

    def get(self, fingerprint: str) -> Optional[StageCacheEntry]:
        raw = self.store.get_ref(_CACHE_NS, fingerprint)
        return None if raw is None else StageCacheEntry.from_json_dict(raw)

    def put(self, entry: StageCacheEntry) -> None:
        self.store.set_ref(_CACHE_NS, entry.fingerprint, entry.to_json_dict())

    def invalidate(self, fingerprint: str) -> bool:
        """Drop an entry; idempotent, returns whether it existed."""
        return self.store.delete_ref(_CACHE_NS, fingerprint)

    def touch(
        self,
        fingerprint: str,
        *,
        entry: Optional[StageCacheEntry] = None,
        now: Optional[float] = None,
    ) -> None:
        """Bump an entry's LRU clock (called by the runner on a hit).
        Pass ``entry`` when already in hand to skip the re-fetch."""
        entry = entry if entry is not None else self.get(fingerprint)
        if entry is None:
            return
        self.put(replace(entry, last_used_at=now if now is not None else time.time()))

    def entries(self) -> Dict[str, StageCacheEntry]:
        return {
            fp: StageCacheEntry.from_json_dict(raw)
            for fp, raw in self.store.list_refs(_CACHE_NS).items()
        }

    def total_bytes(self) -> int:
        """Sum of output_bytes across live entries (the budgeted figure)."""
        return sum(e.output_bytes for e in self.entries().values())

    def clear(self) -> None:
        for fp in list(self.entries()):
            self.invalidate(fp)
