"""Run snapshotting + replay (paper 4.4.1, 4.6).

Every run is assigned an id and an immutable record: pipeline fingerprint,
base data commit, parameters, produced artifact keys, and execution stats.
"The same code on the same data version will produce identical results" —
``Runner.replay`` re-executes a recorded run against its pinned commit and
the tests assert snapshot-id equality (bit-for-bit reproducibility).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.io.objectstore import ObjectStore

_RUN_NS = "runs"
_COUNTER = "run_counter"


@dataclass(frozen=True)
class RunRecord:
    run_id: int
    pipeline_name: str
    pipeline_fingerprint: str
    branch: str
    base_commit: str
    params: Dict[str, Any]
    #: artifact name -> snapshot manifest key
    artifacts: Dict[str, str]
    checks: Dict[str, bool]
    merged_commit: Optional[str]
    fused: bool
    stats: Dict[str, Any]
    created_at: float

    def to_json_dict(self) -> Dict:
        return {
            "run_id": self.run_id,
            "pipeline_name": self.pipeline_name,
            "pipeline_fingerprint": self.pipeline_fingerprint,
            "branch": self.branch,
            "base_commit": self.base_commit,
            "params": self.params,
            "artifacts": self.artifacts,
            "checks": self.checks,
            "merged_commit": self.merged_commit,
            "fused": self.fused,
            "stats": self.stats,
            "created_at": self.created_at,
        }

    @staticmethod
    def from_json_dict(d: Dict) -> "RunRecord":
        return RunRecord(**d)


@dataclass
class RunRegistry:
    """The Postgres-of-spare-parts: run records as refs in the store."""

    store: ObjectStore

    def next_run_id(self) -> int:
        for _ in range(1000):
            cur = self.store.get_ref(_RUN_NS, _COUNTER)  # None on first run
            val = (cur or {"value": 0})["value"] + 1
            if self.store.compare_and_set_ref(_RUN_NS, _COUNTER, cur, {"value": val}):
                return val
        raise RuntimeError("run-id contention")

    def record(self, rec: RunRecord) -> None:
        self.store.set_ref(_RUN_NS, f"run_{rec.run_id}", rec.to_json_dict())

    def get(self, run_id: int) -> RunRecord:
        raw = self.store.get_ref(_RUN_NS, f"run_{run_id}")
        if raw is None:
            raise KeyError(f"no run record for id {run_id}")
        return RunRecord.from_json_dict(raw)

    def all_runs(self) -> List[RunRecord]:
        out = []
        for name, raw in self.store.list_refs(_RUN_NS).items():
            if name.startswith("run_"):
                out.append(RunRecord.from_json_dict(raw))
        return sorted(out, key=lambda r: r.run_id)
