"""Serverless runtime (paper 4.5) — functions, warm starts, elasticity, faults.

The paper's differentiating investment: an orchestration + memory-management
layer where *vertical elasticity* and data locality matter more than
horizontal scale-out.  TPU adaptation:

* container freeze/thaw (their 300 ms trick)  →  warm compiled-executable
  cache keyed by function fingerprint × abstract input shapes;
* per-function memory sizing                  →  cost-model-driven memory
  tiers and submesh allocation;
* function isolation + shared artifacts       →  stateless pure functions
  passing device arrays inside a run (object store only at run boundaries);
* reliability (async mode)                    →  retries, heartbeat timeouts,
  straggler speculation, failure injection for tests.
"""
from repro.runtime.function import FunctionSpec
from repro.runtime.warm import WarmFunctionCache, StartupStats
from repro.runtime.resources import ResourceRequest, CostModel, MEMORY_TIERS_GB
from repro.runtime.executor import (
    ServerlessExecutor,
    ExecutorConfig,
    TaskFailure,
    FaultInjector,
)

__all__ = [
    "FunctionSpec",
    "WarmFunctionCache",
    "StartupStats",
    "ResourceRequest",
    "CostModel",
    "MEMORY_TIERS_GB",
    "ServerlessExecutor",
    "ExecutorConfig",
    "TaskFailure",
    "FaultInjector",
]
