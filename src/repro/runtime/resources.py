"""Vertical elasticity: size the runtime to the artifact (paper 4.5).

"The same transformation logic should run with 10GB or 20GB of memory
depending on the underlying artifacts."  The cost model estimates a
stage's working set from its scan plan (bytes to read after pruning ×
an operator expansion factor) and rounds up to a memory tier; model jobs
additionally request a device submesh sized by parameter + activation
footprint.  The Reasonable-Scale insight (3.1) is encoded in the tier
distribution: most stages land in the smallest tiers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

#: power-of-two "container sizes" in GB — vertical elasticity ladder
MEMORY_TIERS_GB = (1, 2, 4, 8, 16, 32, 64, 128)


@dataclass(frozen=True)
class ResourceRequest:
    memory_gb: int = 1
    devices: int = 1
    #: estimated working set that produced this request (for telemetry)
    estimated_bytes: int = 0

    def fits_tier(self) -> bool:
        return self.memory_gb in MEMORY_TIERS_GB


@dataclass
class CostModel:
    """Bytes/FLOPs → ResourceRequest.

    * ``expansion``: transient multiplier for sort/group buffers (sort-based
      group-by keeps key copies + permutations ≈ 4x input columns).
    * ``headroom``: safety margin before rounding to a tier.
    """

    expansion: float = 4.0
    headroom: float = 1.3

    def request_for_scan(
        self, bytes_after_pruning: int, *, devices: int = 1
    ) -> ResourceRequest:
        working = int(bytes_after_pruning * self.expansion * self.headroom)
        return ResourceRequest(
            memory_gb=self._tier(working), devices=devices, estimated_bytes=working
        )

    def request_for_params(
        self, param_bytes: int, activation_bytes: int, *, devices: int = 1
    ) -> ResourceRequest:
        # params + grads + 2x optimizer state + activations
        working = int((param_bytes * 4 + activation_bytes) * self.headroom)
        return ResourceRequest(
            memory_gb=self._tier(math.ceil(working / max(devices, 1))),
            devices=devices,
            estimated_bytes=working,
        )

    @staticmethod
    def _tier(nbytes: int) -> int:
        gb = max(nbytes / (1 << 30), 1e-9)
        for tier in MEMORY_TIERS_GB:
            if gb <= tier:
                return tier
        return MEMORY_TIERS_GB[-1]


def tier_histogram(requests) -> Dict[int, int]:
    """Distribution of memory tiers across stages (Reasonable-Scale check)."""
    hist: Dict[int, int] = {}
    for r in requests:
        hist[r.memory_gb] = hist.get(r.memory_gb, 0) + 1
    return dict(sorted(hist.items()))
