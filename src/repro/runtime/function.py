"""FunctionSpec — the unit of serverless execution.

One spec == one node of a physical plan (or one training/serving step).
The fingerprint plays the role of the paper's pinned environment
(`@requirements`): since the OS/container/interpreter layers are fixed in
a single JAX process, the degrees of freedom left are exactly (code,
static config, dtype policy, mesh axes) — so they are what we hash.
Same fingerprint + same abstract inputs → the warm cache may reuse a
compiled executable; anything else is a cold start.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.runtime.resources import ResourceRequest
from repro.utils.hashing import fingerprint_fn, stable_hash


@dataclass(frozen=True)
class FunctionSpec:
    name: str
    fn: Callable[..., Any]
    static_config: Dict[str, Any] = field(default_factory=dict)
    resources: Optional[ResourceRequest] = None
    #: non-traceable functions opt out of jit (executed eagerly, still
    #: retried/speculated like any other task)
    jit: bool = True

    @property
    def fingerprint(self) -> str:
        return stable_hash(
            {
                "name": self.name,
                "code": fingerprint_fn(self.fn),
                "config": self.static_config,
                "jit": self.jit,
            }
        )

    def __hash__(self) -> int:
        return hash(self.fingerprint)
