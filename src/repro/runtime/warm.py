"""Warm-start cache — the compiled-executable analog of frozen containers.

The paper freezes initialized containers so a "cold" Spark-session start
(seconds-minutes) becomes a ~300 ms thaw.  The JAX analog: tracing+XLA
compilation is the cold start; re-invoking a cached executable for the
same (fingerprint, abstract shapes) is the warm start.  We make the split
explicit with ``.lower().compile()`` so both phases are measurable —
benchmarks/bench_serverless.py reports the cold:warm ratio next to the
paper's claim.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Tuple

import jax

from repro.runtime.function import FunctionSpec
from repro.utils.hashing import stable_hash
from repro.utils.logging import get_logger

log = get_logger("runtime.warm")


@dataclass
class StartupStats:
    cold_starts: int = 0
    warm_hits: int = 0
    cold_seconds: float = 0.0

    @property
    def warm_ratio(self) -> float:
        total = self.cold_starts + self.warm_hits
        return self.warm_hits / total if total else 0.0


def _abstract_key(tree: Any) -> str:
    leaves = [
        (str(getattr(l, "shape", None)), str(getattr(l, "dtype", None)))
        for l in jax.tree_util.tree_leaves(tree)
    ]
    treedef = str(jax.tree_util.tree_structure(tree))
    return stable_hash({"leaves": leaves, "treedef": treedef})


@dataclass
class WarmFunctionCache:
    """fingerprint × abstract-input-key → compiled executable."""

    stats: StartupStats = field(default_factory=StartupStats)
    _cache: Dict[Tuple[str, str], Callable] = field(default_factory=dict)

    def __post_init__(self) -> None:
        self._lock = threading.Lock()

    def get_or_compile(self, spec: FunctionSpec, *example_inputs: Any) -> Callable:
        """Return an executable for ``spec`` at these input shapes."""
        if not spec.jit:
            return spec.fn
        key = (spec.fingerprint, _abstract_key(example_inputs))
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                self.stats.warm_hits += 1
                return hit
        t0 = time.perf_counter()
        abstract = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype)
            if hasattr(l, "shape")
            else l,
            example_inputs,
        )
        compiled = jax.jit(spec.fn).lower(*abstract).compile()
        dt = time.perf_counter() - t0
        with self._lock:
            self._cache[key] = compiled
            self.stats.cold_starts += 1
            self.stats.cold_seconds += dt
        log.debug("cold start %s: %.1f ms", spec.name, dt * 1e3)
        return compiled

    def has_fingerprint(self, fingerprint: str) -> bool:
        """True when ANY compiled executable exists for this function
        fingerprint (some shape already paid the cold start).  The wave
        scheduler stamps this onto ``StageScheduled`` as the warm/cold
        admission hint — shapes are only known once the stage's scans
        complete, so the fingerprint is the honest pre-dispatch signal."""
        with self._lock:
            return any(k[0] == fingerprint for k in self._cache)

    def invalidate(self) -> None:
        with self._lock:
            self._cache.clear()
