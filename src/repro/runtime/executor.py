"""The serverless executor: isolation, retries, stragglers, fault injection.

Each submitted task is conceptually one ephemeral container.  On this
single-host build, containers are worker threads; the *semantics* carried
to a real deployment are what matter and are what the tests pin down:

* **at-least-once with idempotence** — tasks are pure functions of their
  inputs, so retries and speculative duplicates are safe by construction
  (this is why the paper insists on functional pipelines);
* **bounded retries** on worker failure, with exponential backoff;
* **straggler speculation** — if a task exceeds ``speculation_factor`` ×
  the median duration of its completed siblings, a duplicate launches and
  the first finisher wins (standard backup-request trick, scaled down).
  Single tasks (the ``submit()``/``run()`` path — one fused stage, one
  container) have no siblings, so their baseline is the **per-fingerprint
  latency history** of prior runs of the same function: a pipeline stage
  that usually takes 50 ms but is stuck at 500 ms gets a backup request
  too, not just fan-out batches;
* **failure injection** — tests wrap task functions with a FaultInjector
  that kills the first N attempts to prove the retry path.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.runtime.function import FunctionSpec
from repro.runtime.warm import WarmFunctionCache
from repro.utils.logging import get_logger

log = get_logger("runtime.executor")


class TaskFailure(RuntimeError):
    """A task exhausted its retries."""


@dataclass
class ExecutorConfig:
    max_workers: int = 4
    max_retries: int = 3
    retry_backoff_s: float = 0.01
    #: speculate a duplicate when runtime > factor × median sibling time
    speculation_factor: float = 3.0
    #: minimum completed siblings before speculation kicks in
    speculation_min_samples: int = 3
    #: hard per-attempt timeout (None = no timeout); a timed-out attempt
    #: counts as a failure and is retried
    attempt_timeout_s: Optional[float] = None
    #: completed durations remembered per function fingerprint — the
    #: baseline single-task speculation falls back to when a task has no
    #: completed siblings to take a median over
    latency_history_size: int = 64
    #: upper bound on pipeline stages the wave scheduler keeps in flight at
    #: once (the CLI's ``--parallelism``).  Stage *functions* still execute
    #: on the container pool, so effective compute parallelism is
    #: ``min(max_concurrent_stages, max_workers)``.  Under
    #: ``schedule="critical_path"`` this flat count is superseded by
    #: memory-capped admission (``memory_budget_gb``) unless the caller
    #: pins an explicit per-run ``parallelism``.
    max_concurrent_stages: int = 4
    #: estimated-peak-memory budget for co-scheduled stages (Scheduler
    #: v2's adaptive admission): the wave scheduler admits a ready stage
    #: only while the sum of in-flight ``ResourceRequest.memory_gb``
    #: tiers plus the candidate's stays within this budget — two 80 GB
    #: stages never run together on a 128 GB budget.  ``None`` disables
    #: the memory cap (count-capped admission only).
    memory_budget_gb: Optional[float] = 32.0


@dataclass
class TaskRecord:
    name: str
    attempts: int = 0
    speculated: bool = False
    duration_s: float = 0.0
    worker: str = ""


@dataclass
class FaultInjector:
    """Deterministically fail the first ``failures`` attempts of a task.

    ``seen`` counts attempts by task *name*, so a speculated duplicate and
    its original share one attempt ledger — exactly the cross-container
    accounting the retry tests pin down.  ``crash_delay_s`` simulates a
    container that hangs before crashing (slow failure), which is what
    triggers straggler speculation on a doomed task.
    """

    failures: Dict[str, int] = field(default_factory=dict)
    seen: Dict[str, int] = field(default_factory=dict)
    crash_delay_s: Dict[str, float] = field(default_factory=dict)

    def __post_init__(self) -> None:
        self._lock = threading.Lock()

    def maybe_fail(self, task_name: str) -> None:
        with self._lock:
            remaining = self.failures.get(task_name, 0)
            count = self.seen.get(task_name, 0)
            self.seen[task_name] = count + 1
        if count < remaining:
            delay = self.crash_delay_s.get(task_name, 0.0)
            if delay:
                time.sleep(delay)
            raise RuntimeError(
                f"[fault-injection] simulated container crash for {task_name!r} "
                f"(attempt {count + 1}/{remaining})"
            )


class ServerlessExecutor:
    """Thread-pool "container fleet" with the semantics described above."""

    def __init__(
        self,
        config: Optional[ExecutorConfig] = None,
        *,
        warm_cache: Optional[WarmFunctionCache] = None,
        fault_injector: Optional[FaultInjector] = None,
        bus: Any = None,
        metrics: Any = None,
    ) -> None:
        self.config = config or ExecutorConfig()
        self.warm_cache = warm_cache or WarmFunctionCache()
        self.fault_injector = fault_injector
        #: telemetry (both optional, duck-typed to avoid an import cycle):
        #: ``bus`` is a repro.telemetry.bus.EventBus for speculation events,
        #: ``metrics`` a repro.telemetry.metrics.MetricsRegistry absorbing
        #: task durations/retries next to the speculation baselines
        self.bus = bus
        self.metrics = metrics
        self.records: List[TaskRecord] = []
        self._pool = ThreadPoolExecutor(
            max_workers=self.config.max_workers, thread_name_prefix="container"
        )
        #: drivers of whole pipeline stages (scan → execute → write) run in
        #: their own lane: they *block* on container-pool futures, so giving
        #: them container workers could deadlock a full fleet.  Sized above
        #: ``max_concurrent_stages`` because the lane only provides threads —
        #: the wave scheduler enforces the actual in-flight bound.
        self._stage_pool: Optional[ThreadPoolExecutor] = None
        self._durations: List[float] = []
        self._speculations = 0  # duplicates launched, lifetime of the pool
        #: function fingerprint -> recent completed durations (the prior-run
        #: baseline for single-task speculation AND the scheduler's cost
        #: model medians)
        self._latency_history: Dict[str, List[float]] = {}
        #: function fingerprint -> latest predicted-vs-actual stage cost
        #: (Scheduler v2); persisted next to the durations in the
        #: ``latencyhist`` namespace so the model's accuracy is auditable
        #: across processes
        self._forecasts: Dict[str, Dict[str, float]] = {}
        self._lock = threading.Lock()

    # ----------------------------------------------------------- lifecycle
    def shutdown(self) -> None:
        with self._lock:
            stage_pool, self._stage_pool = self._stage_pool, None
        if stage_pool is not None:
            stage_pool.shutdown(wait=True)
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "ServerlessExecutor":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.shutdown()

    # ------------------------------------------------------------- running
    def _attempt(self, spec: FunctionSpec, args: Tuple[Any, ...]) -> Any:
        if self.fault_injector is not None:
            self.fault_injector.maybe_fail(spec.name)
        fn = self.warm_cache.get_or_compile(spec, *args)
        return fn(*args)

    def _run_with_retries(
        self, spec: FunctionSpec, args: Tuple[Any, ...], speculated: bool = False
    ) -> Any:
        record = TaskRecord(
            name=spec.name,
            speculated=speculated,
            worker=threading.current_thread().name,
        )
        last_err: Optional[BaseException] = None
        for attempt in range(self.config.max_retries + 1):
            record.attempts = attempt + 1
            t0 = time.perf_counter()
            try:
                result = self._attempt(spec, args)
                record.duration_s = time.perf_counter() - t0
                with self._lock:
                    self.records.append(record)
                    self._durations.append(record.duration_s)
                    history = self._latency_history.setdefault(
                        spec.fingerprint, []
                    )
                    history.append(record.duration_s)
                    del history[: -self.config.latency_history_size]
                if self.metrics is not None:
                    self.metrics.counter("executor.tasks").inc()
                    self.metrics.counter("executor.retries").inc(attempt)
                    self.metrics.histogram(
                        "executor.task_duration_s"
                    ).observe(record.duration_s)
                return result
            except Exception as e:  # container crash → retry
                last_err = e
                log.warning(
                    "task %s attempt %d failed: %s", spec.name, attempt + 1, e
                )
                time.sleep(self.config.retry_backoff_s * (2**attempt))
        with self._lock:
            self.records.append(record)
        if self.metrics is not None:
            self.metrics.counter("executor.task_failures").inc()
            self.metrics.counter("executor.retries").inc(
                self.config.max_retries
            )
        raise TaskFailure(
            f"task {spec.name!r} failed after {self.config.max_retries + 1} attempts"
        ) from last_err

    def submit(self, spec: FunctionSpec, *args: Any) -> "Future[Any]":
        return self._pool.submit(self._run_with_retries, spec, args)

    def submit_stage(self, fn: Callable[..., Any], *args: Any) -> "Future[Any]":
        """Submit one stage *driver* (scan → execute → write) to the stage
        lane.  Drivers block on container-pool futures (``run`` /
        ``submit_speculative``) and on parallel shard reads, so they get
        their own threads — a fleet of busy containers can never deadlock
        the wave scheduler."""
        with self._lock:
            if self._stage_pool is None:
                self._stage_pool = ThreadPoolExecutor(
                    max_workers=max(self.config.max_concurrent_stages, 32),
                    thread_name_prefix="stage",
                )
            pool = self._stage_pool
        return pool.submit(fn, *args)

    @property
    def io_pool(self) -> ThreadPoolExecutor:
        """Leaf-task lane for parallel shard reads (``execute_scan``'s
        ``pool``).  Shares the container pool — shard reads never block on
        other futures, so they are always safe to queue there."""
        return self._pool

    # ------------------------------------------------- latency baselines
    def seed_latency_history(
        self, history: Dict[str, Sequence[float]]
    ) -> None:
        """Install persisted per-fingerprint latency baselines.

        Called by the SDK Client when it opens a lake, with the histories
        a previous process recorded — a fresh process speculates against
        inherited medians instead of re-learning them.  Locally-observed
        durations win: fingerprints this executor has already timed are
        left untouched.
        """
        size = self.config.latency_history_size
        with self._lock:
            for fp, durations in history.items():
                if fp not in self._latency_history:
                    self._latency_history[fp] = [
                        float(d) for d in list(durations)[-size:]
                    ]

    def latency_history(self) -> Dict[str, List[float]]:
        """Snapshot of the per-fingerprint completed-duration histories
        (what the SDK Client persists into the lake after each run)."""
        with self._lock:
            return {fp: list(ds) for fp, ds in self._latency_history.items()}

    def latency_medians(self) -> Dict[str, float]:
        """Median completed duration per function fingerprint — the
        scheduler cost model's primary source.  One completed run is
        enough to beat the bytes heuristic (unlike speculation, which
        needs ``speculation_min_samples`` before arming a backup)."""
        with self._lock:
            return {
                fp: sorted(ds)[len(ds) // 2]
                for fp, ds in self._latency_history.items()
                if ds
            }

    def record_forecast(
        self, fingerprint: str, predicted_s: float, actual_s: float
    ) -> None:
        """Record one stage's predicted-vs-actual cost (Scheduler v2).
        The SDK Client persists these next to the latency durations so
        the cost model's calibration survives the process."""
        with self._lock:
            self._forecasts[fingerprint] = {
                "predicted_s": float(predicted_s),
                "actual_s": float(actual_s),
            }

    def forecasts(self) -> Dict[str, Dict[str, float]]:
        """Snapshot of the latest predicted-vs-actual cost per fingerprint."""
        with self._lock:
            return {fp: dict(f) for fp, f in self._forecasts.items()}

    def warm_ready(self, spec: FunctionSpec) -> bool:
        """True when the warm cache already holds a compiled executable
        for this spec's fingerprint (any shape) — the scheduler's
        warm/cold dispatch hint on ``StageScheduled``."""
        return self.warm_cache.has_fingerprint(spec.fingerprint)

    def _historical_baseline(self, spec: FunctionSpec) -> Optional[float]:
        """Median completed duration of prior runs of this function, or
        None below ``speculation_min_samples`` (no evidence, no backup)."""
        with self._lock:
            history = list(self._latency_history.get(spec.fingerprint, ()))
        if len(history) < self.config.speculation_min_samples:
            return None
        return sorted(history)[len(history) // 2]

    def _publish(self, event_cls_name: str, spec: FunctionSpec,
                 tags: Optional[Dict[str, Any]], **fields: Any) -> None:
        """Publish one speculation event if a bus is attached.  The event
        class is resolved lazily by name — the executor predates telemetry
        and must stay importable without it (no import cycle)."""
        if self.bus is None:
            return
        from repro.telemetry import events as ev

        tags = tags or {}
        self.bus.publish(getattr(ev, event_cls_name)(
            run_id=tags.get("run_id"),
            task=spec.name,
            stage_id=tags.get("stage_id"),
            **fields,
        ))

    def submit_speculative(
        self, spec: FunctionSpec, *args: Any,
        tags: Optional[Dict[str, Any]] = None,
    ) -> "Future[Any]":
        """Future-returning ``run()``: primary submitted now, straggler
        backup armed against the per-fingerprint latency history.

        A single task has no completed siblings to take a median over, so
        the straggler baseline is the latency history of prior runs: once
        the primary exceeds ``speculation_factor`` × that median, ONE
        duplicate launches and the first successful finisher wins.  With
        no history the primary just runs to completion.  Because the
        deadline is a timer (not a blocking wait), any number of
        concurrently submitted stages each keep their own speculation —
        this is what lets straggler backup requests compose with the wave
        scheduler's concurrent stage submissions.
        """
        result: "Future[Any]" = Future()
        state_lock = threading.Lock()
        with self._lock:
            # records before this invocation (baseline-building successes
            # included) must not count toward this task's attempt ledger
            start_idx = len(self.records)
        racers: List[Future] = []
        timer: List[Optional[threading.Timer]] = [None]

        def on_racer_done(fut: "Future[Any]") -> None:
            with state_lock:
                if result.done():
                    return
                if fut.exception() is None:
                    if timer[0] is not None:
                        timer[0].cancel()
                    if len(racers) > 1 and fut is racers[1]:
                        # the duplicate beat the straggling primary
                        self._publish("SpeculationWon", spec, tags)
                        if self.metrics is not None:
                            self.metrics.counter(
                                "executor.speculation_wins"
                            ).inc()
                    result.set_result(fut.result())
                    return
                if not all(r.done() for r in racers):
                    return  # a twin is still running — it may yet win
                if timer[0] is not None:
                    timer[0].cancel()
                if len(racers) == 1:
                    # every retry failed before the deadline — no twin to
                    # wait on; surface the primary's TaskFailure as-is
                    result.set_exception(fut.exception())
                    return
                # every racer failed — one TaskFailure, attempts accounted
                # across the original and its duplicate (this invocation)
                with self._lock:
                    attempts = sum(
                        r.attempts
                        for r in self.records[start_idx:]
                        if r.name == spec.name
                    )
                failure = TaskFailure(
                    f"task {spec.name!r} failed on all {len(racers)} "
                    f"container(s) after {attempts} total attempts"
                )
                failure.__cause__ = racers[-1].exception()
                result.set_exception(failure)

        def arm_backup() -> None:
            with state_lock:
                if result.done() or racers[0].done():
                    return
                log.info("speculating single straggler task %s", spec.name)
                with self._lock:
                    self._speculations += 1
                self._publish("SpeculationFired", spec, tags)
                if self.metrics is not None:
                    self.metrics.counter("executor.speculations").inc()
                backup = self._pool.submit(
                    self._run_with_retries, spec, args, True
                )
                racers.append(backup)
            backup.add_done_callback(on_racer_done)

        primary = self._pool.submit(self._run_with_retries, spec, args)
        racers.append(primary)
        baseline = self._historical_baseline(spec)
        if baseline is not None:
            deadline = self.config.speculation_factor * max(baseline, 1e-4)
            self._publish(
                "SpeculationArmed", spec, tags,
                baseline_s=baseline, deadline_s=deadline,
            )
            t = threading.Timer(deadline, arm_backup)
            t.daemon = True
            timer[0] = t
            t.start()
        primary.add_done_callback(on_racer_done)
        return result

    def run(
        self, spec: FunctionSpec, *args: Any,
        tags: Optional[Dict[str, Any]] = None,
    ) -> Any:
        """Run one task synchronously, speculating against its own history
        (blocking face of ``submit_speculative``)."""
        return self.submit_speculative(spec, *args, tags=tags).result()

    # -------------------------------------------------- bulk + speculation
    def map_with_speculation(
        self, specs_and_args: Sequence[Tuple[FunctionSpec, Tuple[Any, ...]]]
    ) -> List[Any]:
        """Run a batch of sibling tasks; duplicate stragglers.

        Used for fan-out stages (per-shard transforms, eval shards).  The
        duplicate races the original; the first *successful* finisher wins —
        pure functions make the race benign.  A racer that exhausts its
        retries does not sink the task while its twin is still running: the
        task fails (one ``TaskFailure``) only once every racer has failed,
        with attempts accounted across the duplicates.
        """
        cfg = self.config
        futures: List[Future] = [
            self._pool.submit(self._run_with_retries, spec, args)
            for spec, args in specs_and_args
        ]
        start = [time.perf_counter()] * len(futures)
        results: List[Any] = [None] * len(futures)
        done = [False] * len(futures)
        # duration at *completion* (not now-start: measuring completed
        # siblings against the wall clock would grow the median in lockstep
        # with the straggler's elapsed time and speculation could never fire)
        finish: List[Optional[float]] = [None] * len(futures)
        speculated: Dict[int, Future] = {}
        while not all(done):
            completed_times = [
                finish[i] - start[i]
                for i, d in enumerate(done)
                if d and finish[i] is not None
            ]
            median = (
                sorted(completed_times)[len(completed_times) // 2]
                if len(completed_times) >= cfg.speculation_min_samples
                else None
            )
            for i, fut in enumerate(futures):
                if done[i]:
                    continue
                spec, args = specs_and_args[i]
                racers: List[Future] = [fut]
                if i in speculated:
                    racers.append(speculated[i])
                finished = [f for f in racers if f.done()]
                success = next(
                    (f for f in finished if f.exception() is None), None
                )
                if success is not None:
                    results[i] = success.result()
                    done[i] = True
                    finish[i] = time.perf_counter()
                    continue
                if finished and len(finished) == len(racers):
                    # every racer failed — surface exactly one TaskFailure
                    # carrying the attempt count across all duplicates
                    done[i] = True
                    attempts = self._attempts_for(spec.name)
                    raise TaskFailure(
                        f"task {spec.name!r} failed on all {len(racers)} "
                        f"container(s) after {attempts} total attempts"
                    ) from finished[-1].exception()
                # at least one racer in flight: maybe launch a duplicate
                elapsed = time.perf_counter() - start[i]
                if (
                    median is not None
                    and i not in speculated
                    and not finished  # don't duplicate an already-failed task
                    and elapsed > cfg.speculation_factor * max(median, 1e-4)
                ):
                    log.info("speculating straggler task %s", spec.name)
                    with self._lock:
                        self._speculations += 1
                    self._publish("SpeculationFired", spec, None)
                    if self.metrics is not None:
                        self.metrics.counter("executor.speculations").inc()
                    speculated[i] = self._pool.submit(
                        self._run_with_retries, spec, args, True
                    )
            time.sleep(0.002)
        return results

    def _attempts_for(self, name: str) -> int:
        """Attempts recorded for ``name`` across the original and any
        speculated duplicates (the cross-container retry ledger)."""
        with self._lock:
            return sum(r.attempts for r in self.records if r.name == name)

    # ------------------------------------------------------------- metrics
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "tasks": len(self.records),
                "retries": sum(r.attempts - 1 for r in self.records),
                "speculated": self._speculations,
                "cold_starts": self.warm_cache.stats.cold_starts,
                "warm_hits": self.warm_cache.stats.warm_hits,
            }
