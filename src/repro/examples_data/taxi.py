"""The paper's NYC-taxi working example (4.1, Appendix A).

Schema, synthetic data generator and the Appendix pipeline (SQL verbatim
from the paper) — the shared fixture behind examples/, benchmarks/ and
tests/.
"""
from __future__ import annotations

import datetime as dt

import numpy as np

from repro.core import Pipeline, requirements
from repro.table import Schema

TAXI_SCHEMA = Schema.of(
    pickup_at="int32",  # days since epoch (see engine/sql.py literals)
    pickup_location_id="int32",
    passenger_count="int32",
    dropoff_location_id="int32",
)

APRIL_1 = (dt.date(2019, 4, 1) - dt.date(1970, 1, 1)).days


def make_taxi_data(n: int, rng: np.random.Generator, *, mean_count: float = 30.0):
    """Synthetic taxi trips; sorted by date so pushdown can prune shards."""
    days = np.sort(rng.integers(APRIL_1 - 60, APRIL_1 + 30, n)).astype(np.int32)
    return {
        "pickup_at": days,
        "pickup_location_id": rng.integers(0, 64, n).astype(np.int32),
        "passenger_count": rng.poisson(mean_count, n).astype(np.int32),
        "dropoff_location_id": rng.integers(0, 64, n).astype(np.int32),
    }


def build_taxi_pipeline(threshold: float = 10.0) -> Pipeline:
    """The Appendix pipeline, SQL verbatim from the paper."""
    p = Pipeline("taxi_demo")

    # Step 1 (trips)
    p.sql(
        "trips",
        """
        SELECT
         pickup_location_id,
         passenger_count as count,
         dropoff_location_id
        FROM
         taxi_table
        WHERE
         pickup_at >= '2019-04-01'
        """,
    )

    # Step 2 (trips_expectation)
    @p.python
    @requirements({"pandas": "2.0.0"})
    def trips_expectation(ctx, trips):
        m = trips.mean("count")
        return m > threshold

    # Step 3 (pickups)
    p.sql(
        "pickups",
        """
        SELECT
         pickup_location_id,
         dropoff_location_id,
         COUNT(*) AS counts
        FROM
         trips
        GROUP BY
         pickup_location_id,
         dropoff_location_id
        ORDER BY
         counts DESC
        """,
    )
    return p
