"""Shared example fixtures that ship with the package.

Examples, benchmarks and tests all exercise the paper's NYC-taxi working
example (4.1, Appendix A); keeping the schema/data-generator/pipeline
builder here means ``examples/`` runs without the test tree on
``sys.path`` (tests/helpers_taxi.py is now a re-export of this module).
"""
from repro.examples_data.taxi import (
    APRIL_1,
    TAXI_SCHEMA,
    build_taxi_pipeline,
    make_taxi_data,
)

__all__ = ["APRIL_1", "TAXI_SCHEMA", "build_taxi_pipeline", "make_taxi_data"]
