"""repro — a serverless data lakehouse from spare parts (paper reproduction).

The public SDK is deliberately tiny (the paper's "functions are all you
need", 4.1): one client, three decorators, typed handles::

    import repro

    client = repro.Client("/path/to/lake")      # or Client.ephemeral()

    repro.sql("trips", "SELECT ... FROM taxi_table WHERE ...")

    @repro.model()
    def pickups(ctx, trips): ...

    @repro.expectation()
    def trips_are_plausible(ctx, trips): ...

    with client.branch("feat_1") as branch:     # merge-on-success
        handle = branch.run("my_module")        # import a module, get a DAG
        assert handle.state == repro.RunState.SUCCESS

Imports are lazy (PEP 562) so ``import repro`` stays cheap; subsystem
packages remain importable directly (``repro.core.Runner`` is the
internal engine — ``repro.Runner`` is a deprecated alias of it).
"""
from typing import Any

__version__ = "0.3.0"

#: public name -> (module, attribute) — resolved on first access
_EXPORTS = {
    "Client": ("repro.api", "Client"),
    "BranchHandle": ("repro.api", "BranchHandle"),
    "AsyncRunHandle": ("repro.api", "AsyncRunHandle"),
    "RunHandle": ("repro.api", "RunHandle"),
    "RunState": ("repro.api", "RunState"),
    "RunFailed": ("repro.api", "RunFailed"),
    "Project": ("repro.api", "Project"),
    "project": ("repro.api", "project"),
    "model": ("repro.api", "model"),
    "expectation": ("repro.api", "expectation"),
    "sql": ("repro.api", "sql"),
    "requirements": ("repro.api", "requirements"),
    "discover": ("repro.api", "discover"),
    "Pipeline": ("repro.core", "Pipeline"),
    "Schema": ("repro.table", "Schema"),
    "LintReport": ("repro.analysis", "LintReport"),
    "Finding": ("repro.analysis", "Finding"),
    "Severity": ("repro.analysis", "Severity"),
    "LintFailed": ("repro.analysis", "LintFailed"),
    "lint_pipeline": ("repro.analysis", "lint_pipeline"),
}

__all__ = ["__version__", *sorted(_EXPORTS)]


def __getattr__(name: str) -> Any:
    if name in _EXPORTS:
        import importlib

        module, attr = _EXPORTS[name]
        value = getattr(importlib.import_module(module), attr)
        globals()[name] = value  # cache: resolve once
        return value
    if name == "Runner":
        # thin deprecation shim: the engine stays importable, the facade
        # is the supported construction path
        import warnings

        warnings.warn(
            "repro.Runner is deprecated — construct the platform through "
            "repro.Client (the engine remains at repro.core.Runner)",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.core import Runner

        return Runner
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__() -> list:
    return sorted(set(globals()) | set(_EXPORTS) | {"Runner"})
