"""Deterministic hashing for content addressing and run fingerprinting.

The paper (4.4.1) snapshots the full project into object storage and
fingerprints it in a database so that "the same code on the same data version
will produce identical results".  Everything in the lakehouse that needs an
identity — blobs, table snapshots, commits, run ids, compiled-function cache
keys — goes through the helpers here so identities are stable across
processes and platforms.
"""
from __future__ import annotations

import hashlib
import inspect
import json
import re
from typing import Any

import numpy as np

_ADDR_RE = re.compile(r"0x[0-9a-fA-F]+")


def _canonical(obj: Any) -> Any:
    """Convert ``obj`` into a deterministically-serializable structure."""
    if isinstance(obj, (str, int, bool)) or obj is None:
        return obj
    if isinstance(obj, float):
        # repr is stable for finite floats; normalize NaN/inf.
        if obj != obj:
            return "__nan__"
        return repr(obj)
    if isinstance(obj, bytes):
        return {"__bytes__": hashlib.sha256(obj).hexdigest()}
    if isinstance(obj, (list, tuple)):
        return [_canonical(x) for x in obj]
    if isinstance(obj, dict):
        return {str(k): _canonical(v) for k, v in sorted(obj.items(), key=lambda kv: str(kv[0]))}
    if isinstance(obj, set):
        return sorted(str(x) for x in obj)
    if isinstance(obj, np.dtype):
        return str(obj)
    if isinstance(obj, np.ndarray):
        return {
            "__ndarray__": hashlib.sha256(np.ascontiguousarray(obj).tobytes()).hexdigest(),
            "dtype": str(obj.dtype),
            "shape": list(obj.shape),
        }
    if hasattr(obj, "to_json_dict"):
        return _canonical(obj.to_json_dict())
    if callable(obj):
        return {"__callable__": fingerprint_fn(obj)}
    return {"__repr__": repr(obj)}


def stable_hash(obj: Any, *, length: int = 16) -> str:
    """Deterministic hex digest of an arbitrary (JSON-able-ish) structure."""
    payload = json.dumps(_canonical(obj), sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(payload.encode("utf-8")).hexdigest()[:length]


def content_hash(data: bytes, *, length: int = 32) -> str:
    """Content address for a blob (the object-store key)."""
    return hashlib.sha256(data).hexdigest()[:length]


def fingerprint_fn(fn: Any, *, length: int = 16) -> str:
    """Fingerprint a Python function by source + captured values (4.4.1).

    Closure cell contents and defaults are part of the identity: two
    pipelines built from the same source with different captured
    parameters are different code ("code is data" taken literally).
    Falls back to qualified name for builtins whose source is unavailable.
    """
    try:
        src = inspect.getsource(fn)
    except (OSError, TypeError):
        src = getattr(fn, "__qualname__", repr(fn))
    captured = []
    for cell in getattr(fn, "__closure__", None) or ():
        try:
            captured.append(repr(cell.cell_contents))
        except ValueError:  # empty cell
            captured.append("<empty>")
    defaults = repr(getattr(fn, "__defaults__", None))
    payload = src + "||" + "|".join(captured) + "||" + defaults
    # reprs of captured functions/objects embed memory addresses, which
    # would make semantically-identical closures fingerprint differently
    # (and bust the warm compiled-fn cache) — strip them
    payload = _ADDR_RE.sub("0xADDR", payload)
    return hashlib.sha256(payload.encode("utf-8")).hexdigest()[:length]
