"""Shared utilities: hashing, pytree helpers, logging, timing."""
from repro.utils.hashing import stable_hash, content_hash, fingerprint_fn
from repro.utils.timing import Timer, timed
from repro.utils.logging import get_logger

__all__ = [
    "stable_hash",
    "content_hash",
    "fingerprint_fn",
    "Timer",
    "timed",
    "get_logger",
]
