"""Pytree helpers shared by train/serve/checkpoint layers."""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import numpy as np


def tree_size_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStruct too)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape, dtype=np.int64)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_param_count(tree: Any) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape, dtype=np.int64))
    return total


def flatten_with_paths(tree: Any) -> Dict[str, Any]:
    """Flatten a pytree into {'a/b/0': leaf} path-keyed dict."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_elem(p) for p in path)
        flat[key] = leaf
    return flat


def _path_elem(p: Any) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def tree_map_with_path_str(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map ``fn(path_str, leaf)`` over a pytree preserving structure."""

    def _wrap(path: Tuple, leaf: Any) -> Any:
        return fn("/".join(_path_elem(p) for p in path), leaf)

    return jax.tree_util.tree_map_with_path(_wrap, tree)
