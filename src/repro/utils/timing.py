"""Wall-clock timing helpers used by the runtime and the benchmark harness."""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Iterator, List


@dataclass
class Timer:
    """Accumulating named timer (microsecond resolution)."""

    samples: Dict[str, List[float]] = field(default_factory=dict)

    @contextmanager
    def section(self, name: str) -> Iterator[None]:
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.samples.setdefault(name, []).append(time.perf_counter() - t0)

    def total(self, name: str) -> float:
        return sum(self.samples.get(name, []))

    def mean(self, name: str) -> float:
        xs = self.samples.get(name, [])
        return sum(xs) / len(xs) if xs else 0.0

    def report(self) -> Dict[str, float]:
        return {k: sum(v) for k, v in self.samples.items()}


@contextmanager
def timed() -> Iterator[List[float]]:
    """``with timed() as t: ...`` — ``t[0]`` holds elapsed seconds after."""
    box = [0.0]
    t0 = time.perf_counter()
    try:
        yield box
    finally:
        box[0] = time.perf_counter() - t0
