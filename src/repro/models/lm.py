"""The unified decoder LM assembling all ten assigned architectures.

A model is a sequence of *segments*; each segment is ``count`` repetitions
of a *unit* (a short tuple of block kinds).  Homogeneous repetition is
expressed as ``lax.scan`` over stacked parameters, so compile time and HLO
size are ~independent of depth (critical for the 61/88-layer dry-runs).

Block kinds:
  attn        GQA/MQA/MHA attention (+ optional window/qk-norm) + SwiGLU
  attn_geglu  same but GeGLU MLP (recurrentgemma's local attention layer)
  moe_attn    attention + MoE FFN (qwen2-moe)
  mla_dense   DeepSeek MLA attention + dense SwiGLU (first-k layers)
  mla_moe     DeepSeek MLA attention + MoE FFN
  mlstm       xLSTM matrix-memory block (no FFN)
  slstm       xLSTM scalar-memory block (no FFN)
  rec         RG-LRU recurrent block + GeGLU MLP

Examples:
  granite-34b        ((("attn",), 88),)
  xlstm-350m         ((("mlstm", "slstm"), 12),)
  recurrentgemma-9b  ((("rec", "rec", "attn_geglu"), 12), (("rec", "rec"), 1))
  deepseek-v3        ((("mla_dense",), 3), (("mla_moe",), 58))
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.sharding import constrain_batch, constrain_logits
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (
    Params,
    cross_entropy,
    embed,
    geglu,
    init_embedding,
    init_geglu,
    init_linear,
    init_rmsnorm,
    init_swiglu,
    linear,
    logits_head,
    rmsnorm,
    swiglu,
)


class ModelFamily(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    VLM = "vlm"
    AUDIO = "audio"


Segments = Tuple[Tuple[Tuple[str, ...], int], ...]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: ModelFamily
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    segments: Segments
    d_head: Optional[int] = None  # default d_model // n_heads
    # attention options
    qk_norm: bool = False
    window: Optional[int] = None
    rope_theta: float = 10000.0
    attn_logit_soft_cap: Optional[float] = None
    # MoE options
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    dense_d_ff: int = 0  # FFN width of dense layers in hybrid-MoE stacks
    # extras
    mtp: bool = False  # DeepSeek multi-token prediction head
    mtp_loss_weight: float = 0.1
    n_codebooks: int = 1  # musicgen: parallel EnCodec codebooks
    num_patches: int = 0  # vlm: prepended image patch embeddings
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # execution
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    use_flash_kernel: bool = False
    remat: str = "none"  # "none" | "full" | "dots"
    # serving
    max_decode_len: int = 4096

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def attention_config(self, *, window_override=-1) -> attn_mod.AttentionConfig:
        return attn_mod.AttentionConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            d_head=self.head_dim,
            rope_theta=self.rope_theta,
            qk_norm=self.qk_norm,
            window=self.window if window_override == -1 else window_override,
            use_flash_kernel=self.use_flash_kernel,
            compute_dtype=self.compute_dtype,
        )

    def mla_config(self) -> mla_mod.MLAConfig:
        return mla_mod.MLAConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            compute_dtype=self.compute_dtype,
        )

    def moe_config(self) -> moe_mod.MoEConfig:
        return moe_mod.MoEConfig(
            d_model=self.d_model,
            d_ff=self.moe_d_ff or self.d_ff,
            num_experts=self.num_experts,
            top_k=self.top_k,
            num_shared=self.num_shared_experts,
            compute_dtype=self.compute_dtype,
        )

    def xlstm_config(self) -> xlstm_mod.XLSTMConfig:
        return xlstm_mod.XLSTMConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            compute_dtype=self.compute_dtype,
        )

    def rglru_config(self) -> rglru_mod.RGLRUConfig:
        return rglru_mod.RGLRUConfig(
            d_model=self.d_model,
            d_rnn=self.d_model,
            compute_dtype=self.compute_dtype,
        )


# ====================================================================== LM
class LM:
    """(init, loss, forward, prefill, decode_step) over an LMConfig."""

    def __init__(self, cfg: LMConfig):
        total = sum(len(unit) * count for unit, count in cfg.segments)
        if total != cfg.n_layers:
            raise ValueError(
                f"{cfg.name}: segments sum to {total} layers, expected {cfg.n_layers}"
            )
        self.cfg = cfg

    # -------------------------------------------------------------- blocks
    def _init_block(self, kind: str, key) -> Params:
        cfg = self.cfg
        dt = cfg.param_dtype
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p: Params = {"norm1": init_rmsnorm(cfg.d_model, dtype=dt)}
        if kind in ("attn", "attn_geglu", "moe_attn"):
            p["attn"] = attn_mod.init_attention(k1, cfg.attention_config(), dtype=dt)
            p["norm2"] = init_rmsnorm(cfg.d_model, dtype=dt)
            if kind == "attn":
                p["mlp"] = init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype=dt)
            elif kind == "attn_geglu":
                p["mlp"] = init_geglu(k2, cfg.d_model, cfg.d_ff, dtype=dt)
            else:
                p["moe"] = moe_mod.init_moe(k2, cfg.moe_config(), dtype=dt)
        elif kind in ("mla_dense", "mla_moe"):
            p["attn"] = mla_mod.init_mla(k1, cfg.mla_config(), dtype=dt)
            p["norm2"] = init_rmsnorm(cfg.d_model, dtype=dt)
            if kind == "mla_dense":
                p["mlp"] = init_swiglu(
                    k2, cfg.d_model, cfg.dense_d_ff or cfg.d_ff, dtype=dt
                )
            else:
                p["moe"] = moe_mod.init_moe(k2, cfg.moe_config(), dtype=dt)
        elif kind == "mlstm":
            p["mix"] = xlstm_mod.init_mlstm(k1, cfg.xlstm_config(), dtype=dt)
        elif kind == "slstm":
            p["mix"] = xlstm_mod.init_slstm(k1, cfg.xlstm_config(), dtype=dt)
        elif kind == "rec":
            p["mix"] = rglru_mod.init_rglru(k1, cfg.rglru_config(), dtype=dt)
            p["norm2"] = init_rmsnorm(cfg.d_model, dtype=dt)
            p["mlp"] = init_geglu(k2, cfg.d_model, cfg.d_ff, dtype=dt)
        else:
            raise ValueError(f"unknown block kind {kind!r}")
        return p

    def _apply_block(
        self, kind: str, p: Params, h: jax.Array, positions: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence path. Returns (h, aux_loss)."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        x = rmsnorm(p["norm1"], h, eps=cfg.norm_eps)
        if kind in ("attn", "attn_geglu", "moe_attn"):
            h = h + attn_mod.attend_train(p["attn"], cfg.attention_config(), x, positions)
            y = rmsnorm(p["norm2"], h, eps=cfg.norm_eps)
            if kind == "attn":
                h = h + swiglu(p["mlp"], y, compute_dtype=cfg.compute_dtype)
            elif kind == "attn_geglu":
                h = h + geglu(p["mlp"], y, compute_dtype=cfg.compute_dtype)
            else:
                out, moe_aux = moe_mod.moe_apply(p["moe"], cfg.moe_config(), y)
                h = h + out
                aux = aux + moe_aux["balance_loss"] + moe_aux["z_loss"]
        elif kind in ("mla_dense", "mla_moe"):
            h = h + mla_mod.mla_train(p["attn"], cfg.mla_config(), x, positions)
            y = rmsnorm(p["norm2"], h, eps=cfg.norm_eps)
            if kind == "mla_dense":
                h = h + swiglu(p["mlp"], y, compute_dtype=cfg.compute_dtype)
            else:
                out, moe_aux = moe_mod.moe_apply(p["moe"], cfg.moe_config(), y)
                h = h + out
                aux = aux + moe_aux["balance_loss"] + moe_aux["z_loss"]
        elif kind == "mlstm":
            h = h + xlstm_mod.mlstm_block(p["mix"], cfg.xlstm_config(), x)
        elif kind == "slstm":
            h = h + xlstm_mod.slstm_block(p["mix"], cfg.xlstm_config(), x)
        elif kind == "rec":
            h = h + rglru_mod.rglru_block(p["mix"], cfg.rglru_config(), x)
            y = rmsnorm(p["norm2"], h, eps=cfg.norm_eps)
            h = h + geglu(p["mlp"], y, compute_dtype=cfg.compute_dtype)
        return h, aux

    # --------------------------------------------------------------- init
    def init(self, key) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        params: Params = {}
        if cfg.n_codebooks > 1:
            params["embed"] = {
                f"cb{i}": init_embedding(
                    jax.random.fold_in(keys[0], i), cfg.vocab, cfg.d_model,
                    dtype=cfg.param_dtype,
                )
                for i in range(cfg.n_codebooks)
            }
        else:
            params["embed"] = init_embedding(
                keys[0], cfg.vocab, cfg.d_model, dtype=cfg.param_dtype
            )
        for si, (unit, count) in enumerate(cfg.segments):
            seg_key = jax.random.fold_in(keys[1], si)

            def init_unit(k, _unit=unit):
                uks = jax.random.split(k, len(_unit))
                return {
                    f"b{i}": self._init_block(kind, uks[i])
                    for i, kind in enumerate(_unit)
                }

            params[f"seg{si}"] = jax.vmap(init_unit)(
                jax.random.split(seg_key, count)
            )
        params["final_norm"] = init_rmsnorm(cfg.d_model, dtype=cfg.param_dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = init_linear(
                keys[2], cfg.d_model, cfg.vocab, dtype=cfg.param_dtype
            )
        if cfg.n_codebooks > 1:
            params["heads"] = {
                f"cb{i}": init_linear(
                    jax.random.fold_in(keys[3], i), cfg.d_model, cfg.vocab,
                    dtype=cfg.param_dtype,
                )
                for i in range(cfg.n_codebooks)
            }
        if cfg.mtp:
            params["mtp"] = {
                "proj": init_linear(
                    keys[4], 2 * cfg.d_model, cfg.d_model, dtype=cfg.param_dtype
                ),
                "block": self._init_block(
                    "mla_dense" if cfg.segments[0][0][0].startswith("mla") else "attn",
                    keys[5],
                ),
                "norm": init_rmsnorm(cfg.d_model, dtype=cfg.param_dtype),
            }
        return params

    # ------------------------------------------------------------ embedding
    def _embed_tokens(self, params: Params, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.n_codebooks > 1:  # (B, S, K) summed codebook embeddings
            return sum(
                embed(params["embed"][f"cb{i}"], tokens[..., i],
                      compute_dtype=cfg.compute_dtype)
                for i in range(cfg.n_codebooks)
            )
        return embed(params["embed"], tokens, compute_dtype=cfg.compute_dtype)

    def _read_out(self, params: Params, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.n_codebooks > 1:
            outs = [
                linear(params["heads"][f"cb{i}"], h, compute_dtype=cfg.compute_dtype)
                for i in range(cfg.n_codebooks)
            ]
            return jnp.stack(outs, axis=-2)  # (B, S, K, V)
        if cfg.tie_embeddings:
            return logits_head(params["embed"], h, compute_dtype=cfg.compute_dtype)
        return linear(params["lm_head"], h, compute_dtype=cfg.compute_dtype)

    # -------------------------------------------------------------- forward
    def _stack(self, params: Params, h: jax.Array, positions: jax.Array):
        """Run all segments. Returns (h, aux_loss_sum)."""
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        for si, (unit, count) in enumerate(cfg.segments):

            def unit_fn(carry, layer_params, _unit=unit):
                h, aux = carry
                for i, kind in enumerate(_unit):
                    h = constrain_batch(h)  # pin batch-over-data (FSDP flow)
                    h, a = self._apply_block(kind, layer_params[f"b{i}"], h, positions)
                    aux = aux + a
                return (constrain_batch(h), aux), None

            if cfg.remat == "full":
                unit_fn = jax.checkpoint(unit_fn)
            elif cfg.remat == "dots":
                unit_fn = jax.checkpoint(
                    unit_fn,
                    policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
                )
            (h, aux_total), _ = jax.lax.scan(
                unit_fn, (h, aux_total), params[f"seg{si}"]
            )
        return h, aux_total

    def forward(
        self,
        params: Params,
        tokens: jax.Array,
        *,
        patch_embeds: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Full-sequence logits (B, S[, K], V)."""
        cfg = self.cfg
        h = constrain_batch(self._embed_tokens(params, tokens))
        n_prefix = 0
        if cfg.num_patches and patch_embeds is not None:
            h = jnp.concatenate([patch_embeds.astype(h.dtype), h], axis=1)
            n_prefix = patch_embeds.shape[1]
        positions = jnp.arange(h.shape[1])
        h, _ = self._stack(params, h, positions)
        h = rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
        if n_prefix:
            h = h[:, n_prefix:]
        return constrain_logits(self._read_out(params, h))

    # ----------------------------------------------------------------- loss
    def loss(
        self,
        params: Params,
        batch: Dict[str, jax.Array],
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """batch: tokens (B,S[,K]) int32, optional loss_mask (B,S),
        optional patch_embeds (B,P,d).  Next-token LM objective."""
        cfg = self.cfg
        tokens = batch["tokens"]
        h = constrain_batch(self._embed_tokens(params, tokens))
        n_prefix = 0
        if cfg.num_patches and "patch_embeds" in batch:
            h = jnp.concatenate(
                [batch["patch_embeds"].astype(h.dtype), h], axis=1
            )
            n_prefix = batch["patch_embeds"].shape[1]
        positions = jnp.arange(h.shape[1])
        h, aux = self._stack(params, h, positions)
        h = rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
        if n_prefix:
            h = h[:, n_prefix:]

        inputs_h = constrain_batch(h[:, :-1])
        labels = tokens[:, 1:]
        logits = constrain_logits(self._read_out(params, inputs_h))
        mask = batch.get("loss_mask")
        mask = mask[:, 1:] if mask is not None else None
        if cfg.n_codebooks > 1:
            k_mask = None if mask is None else mask[..., None] * jnp.ones(
                (1, 1, cfg.n_codebooks)
            )
            ce = cross_entropy(logits, labels, mask=k_mask)
        else:
            ce = cross_entropy(logits, labels, mask=mask)
        metrics = {"ce": ce, "aux": aux}
        total = ce + aux

        if cfg.mtp:  # predict t+2 from (h_t, emb_{t+1})
            emb_next = self._embed_tokens(params, tokens[:, 1:-1])
            h_mtp = jnp.concatenate([h[:, :-2], emb_next], axis=-1)
            h_mtp = constrain_batch(
                linear(params["mtp"]["proj"], h_mtp, compute_dtype=cfg.compute_dtype)
            )
            kind = "mla_dense" if cfg.segments[0][0][0].startswith("mla") else "attn"
            h_mtp, _ = self._apply_block(
                kind, params["mtp"]["block"], h_mtp, positions[: h_mtp.shape[1]]
            )
            h_mtp = rmsnorm(params["mtp"]["norm"], h_mtp, eps=cfg.norm_eps)
            mtp_logits = self._read_out(params, h_mtp)
            mtp_ce = cross_entropy(
                mtp_logits, tokens[:, 2:], mask=None if mask is None else mask[:, 1:]
            )
            metrics["mtp_ce"] = mtp_ce
            total = total + cfg.mtp_loss_weight * mtp_ce

        metrics["loss"] = total
        return total, metrics

    # -------------------------------------------------------------- serving
    def _block_state(self, kind: str, batch: int, max_len: int):
        cfg = self.cfg
        if kind in ("attn", "attn_geglu", "moe_attn"):
            # NOTE: windowed attention could use a ring buffer of size
            # `window` — kept as a §Perf lever; full-length cache + masking
            # is the correctness baseline.
            return attn_mod.init_cache(
                cfg.attention_config(), batch, max_len, dtype=cfg.compute_dtype
            )
        if kind in ("mla_dense", "mla_moe"):
            return mla_mod.init_mla_cache(
                self.cfg.mla_config(), batch, max_len, dtype=cfg.compute_dtype
            )
        if kind == "mlstm":
            return xlstm_mod.init_mlstm_state(cfg.xlstm_config(), batch)
        if kind == "slstm":
            return xlstm_mod.init_slstm_state(cfg.xlstm_config(), batch)
        if kind == "rec":
            return rglru_mod.init_rglru_state(cfg.rglru_config(), batch)
        raise ValueError(kind)

    def init_decode_state(self, batch: int, max_len: Optional[int] = None) -> Params:
        cfg = self.cfg
        max_len = max_len or cfg.max_decode_len
        state: Params = {}
        for si, (unit, count) in enumerate(cfg.segments):
            def one(_, _unit=unit):
                return {
                    f"b{i}": self._block_state(kind, batch, max_len)
                    for i, kind in enumerate(_unit)
                }
            state[f"seg{si}"] = jax.vmap(one)(jnp.arange(count))
        return state

    def _apply_block_decode(
        self, kind: str, p: Params, h: jax.Array, cache, lengths: jax.Array
    ):
        cfg = self.cfg
        x = rmsnorm(p["norm1"], h, eps=cfg.norm_eps)
        if kind in ("attn", "attn_geglu", "moe_attn"):
            acfg = cfg.attention_config()
            out, cache = attn_mod.decode_step(p["attn"], acfg, x, cache, lengths)
            h = h + out
            y = rmsnorm(p["norm2"], h, eps=cfg.norm_eps)
            if kind == "attn":
                h = h + swiglu(p["mlp"], y, compute_dtype=cfg.compute_dtype)
            elif kind == "attn_geglu":
                h = h + geglu(p["mlp"], y, compute_dtype=cfg.compute_dtype)
            else:
                out, _ = moe_mod.moe_apply(p["moe"], cfg.moe_config(), y)
                h = h + out
        elif kind in ("mla_dense", "mla_moe"):
            out, cache = mla_mod.mla_decode_step(
                p["attn"], cfg.mla_config(), x, cache, lengths
            )
            h = h + out
            y = rmsnorm(p["norm2"], h, eps=cfg.norm_eps)
            if kind == "mla_dense":
                h = h + swiglu(p["mlp"], y, compute_dtype=cfg.compute_dtype)
            else:
                out, _ = moe_mod.moe_apply(p["moe"], cfg.moe_config(), y)
                h = h + out
        elif kind == "mlstm":
            out, cache = xlstm_mod.mlstm_decode_step(
                p["mix"], cfg.xlstm_config(), x, cache
            )
            h = h + out
        elif kind == "slstm":
            out, cache = xlstm_mod.slstm_decode_step(
                p["mix"], cfg.xlstm_config(), x, cache
            )
            h = h + out
        elif kind == "rec":
            out, cache = rglru_mod.rglru_decode_step(
                p["mix"], cfg.rglru_config(), x, cache
            )
            h = h + out
            y = rmsnorm(p["norm2"], h, eps=cfg.norm_eps)
            h = h + geglu(p["mlp"], y, compute_dtype=cfg.compute_dtype)
        return h, cache

    def decode_step(
        self,
        params: Params,
        state: Params,
        tokens: jax.Array,   # (B, 1[, K])
        lengths: jax.Array,  # (B,)
    ):
        """One decoding step. Returns (logits (B, 1[, K], V), new_state)."""
        cfg = self.cfg
        h = constrain_batch(self._embed_tokens(params, tokens))
        new_state: Params = {}
        for si, (unit, count) in enumerate(cfg.segments):

            def unit_fn(h, xs, _unit=unit):
                layer_params, layer_cache = xs
                new_cache = {}
                for i, kind in enumerate(_unit):
                    h = constrain_batch(h)
                    h, c = self._apply_block_decode(
                        kind, layer_params[f"b{i}"], h, layer_cache[f"b{i}"], lengths
                    )
                    new_cache[f"b{i}"] = c
                return h, new_cache

            h, new_state[f"seg{si}"] = jax.lax.scan(
                unit_fn, h, (params[f"seg{si}"], state[f"seg{si}"])
            )
        h = rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
        return self._read_out(params, h), new_state
