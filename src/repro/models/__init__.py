"""Model zoo substrate: composable pure-JAX decoder blocks.

Everything is (init, apply) pairs over plain dict pytrees — no framework
dependency.  ``lm.py`` assembles the ten assigned architectures from:

* ``attention.py``  — GQA/MQA/MHA with RoPE, qk-norm, sliding window
* ``mla.py``        — DeepSeek Multi-head Latent Attention
* ``moe.py``        — shared + routed top-k experts, sort-based dispatch
* ``xlstm.py``      — mLSTM (chunked-parallel) and sLSTM blocks
* ``rglru.py``      — RecurrentGemma RG-LRU + conv block
* ``common.py``     — norms, MLPs, embeddings, RoPE, losses
"""
from repro.models.lm import LM, LMConfig, ModelFamily

__all__ = ["LM", "LMConfig", "ModelFamily"]
