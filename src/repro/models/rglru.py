"""RG-LRU recurrent block (Griffin / RecurrentGemma).

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t)                      (recurrence gate)
    i_t = sigmoid(W_x x_t)                      (input gate)
    log a_t = c * r_t * log(sigmoid(Lambda))    (elementwise decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

A *linear* recurrence, so training/prefill use ``lax.associative_scan``
(log-depth over sequence — this is why the arch runs ``long_500k``), and
decode is a single O(1) elementwise update.  The block wraps the LRU with
the Griffin structure: linear in → causal depthwise conv (width 4) → LRU,
times a GeLU gate branch, then linear out.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, init_linear, linear

_C = 8.0


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int  # recurrence width (== d_model for recurrentgemma)
    conv_width: int = 4
    compute_dtype: Any = jnp.bfloat16


def init_rglru(key, cfg: RGLRUConfig, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 7)
    d, dr = cfg.d_model, cfg.d_rnn
    # Lambda init so that a = sigmoid(Lambda)^c is spread in (0.9, 0.999)
    u = jax.random.uniform(ks[4], (dr,), minval=0.9, maxval=0.999)
    lam = jnp.log(u ** (1.0 / _C) / (1 - u ** (1.0 / _C)))
    return {
        "w_in": init_linear(ks[0], d, dr, dtype=dtype),
        "w_gate": init_linear(ks[1], d, dr, dtype=dtype),
        "conv": (jax.random.normal(ks[5], (cfg.conv_width, dr)) * 0.1).astype(dtype),
        "w_a": init_linear(ks[2], dr, dr, dtype=dtype),
        "w_x": init_linear(ks[3], dr, dr, dtype=dtype),
        "lam": lam.astype(dtype),
        "w_out": init_linear(ks[6], dr, d, dtype=dtype, scale=dr**-0.5),
    }


def _causal_conv(p: Params, cfg: RGLRUConfig, x: jax.Array) -> jax.Array:
    """Depthwise causal conv along time. x: (B, S, dr)."""
    w = p["conv"].astype(jnp.float32)  # (W, dr)
    pad = cfg.conv_width - 1
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (pad, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i] for i in range(cfg.conv_width)
    )
    return out.astype(x.dtype)


def _lru_gates(p: Params, x: jax.Array):
    """x: (..., dr) f32 → (log_a, scaled input) f32."""
    r = jax.nn.sigmoid(linear(p["w_a"], x, compute_dtype=jnp.float32))
    i = jax.nn.sigmoid(linear(p["w_x"], x, compute_dtype=jnp.float32))
    log_a = _C * r * jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-8)) * (i * x)
    return a, gated


def rglru_block(p: Params, cfg: RGLRUConfig, x: jax.Array) -> jax.Array:
    """Full-sequence path (training/prefill). x: (B, S, d_model)."""
    cd = cfg.compute_dtype
    inner = linear(p["w_in"], x, compute_dtype=cd)
    gate = jax.nn.gelu(linear(p["w_gate"], x, compute_dtype=cd))
    conv = _causal_conv(p, cfg, inner).astype(jnp.float32)
    a, gated = _lru_gates(p, conv)

    # associative linear recurrence h_t = a_t h_{t-1} + b_t
    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    out = (h.astype(cd) * gate)
    return linear(p["w_out"], out, compute_dtype=cd)


def init_rglru_state(cfg: RGLRUConfig, batch: int) -> Dict[str, jax.Array]:
    return {
        "h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn), jnp.float32),
    }


def rglru_decode_step(
    p: Params, cfg: RGLRUConfig, x: jax.Array, state: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token path. x: (B, 1, d_model)."""
    cd = cfg.compute_dtype
    inner = linear(p["w_in"], x, compute_dtype=cd)  # (B,1,dr)
    gate = jax.nn.gelu(linear(p["w_gate"], x, compute_dtype=cd))
    w = p["conv"].astype(jnp.float32)
    hist = jnp.concatenate(
        [state["conv"], inner[:, 0:1].astype(jnp.float32)], axis=1
    )  # (B, W, dr)
    conv = jnp.einsum("bwd,wd->bd", hist, w)
    a, gated = _lru_gates(p, conv)
    h = a * state["h"] + gated
    out = (h[:, None].astype(cd) * gate)
    new_state = {"h": h, "conv": hist[:, 1:]}
    return linear(p["w_out"], out, compute_dtype=cd), new_state
