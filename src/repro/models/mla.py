"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and KV are low-rank-compressed; only the compressed latent
``c_kv`` (rank 512) plus the small decoupled-RoPE key ``k_rope`` (64)
need caching at decode time — an ~14x KV-cache reduction vs MHA at 128
heads, which is exactly why the 500k-class serving shapes want it.

Shapes follow the V3 paper: d_model 7168, q rank 1536, kv rank 512,
per-head nope 128 + rope 64 query/key dims, v head 128.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    Params,
    apply_rope,
    init_linear,
    init_rmsnorm,
    linear,
    rmsnorm,
)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0
    #: kv-chunked online softmax for the train path (see attention.py)
    chunk: Optional[int] = 1024
    compute_dtype: Any = jnp.bfloat16

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim


def init_mla(key, cfg: MLAConfig, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 7)
    h, dq, dkv = cfg.n_heads, cfg.q_lora_rank, cfg.kv_lora_rank
    return {
        "wq_a": init_linear(ks[0], cfg.d_model, dq, dtype=dtype),
        "q_norm": init_rmsnorm(dq, dtype=dtype),
        "wq_b": init_linear(ks[1], dq, h * cfg.qk_dim, dtype=dtype),
        "wkv_a": init_linear(
            ks[2], cfg.d_model, dkv + cfg.qk_rope_dim, dtype=dtype
        ),
        "kv_norm": init_rmsnorm(dkv, dtype=dtype),
        "wkv_b": init_linear(
            ks[3], dkv, h * (cfg.qk_nope_dim + cfg.v_head_dim), dtype=dtype
        ),
        "wo": init_linear(
            ks[4], h * cfg.v_head_dim, cfg.d_model, dtype=dtype,
            scale=(h * cfg.v_head_dim) ** -0.5,
        ),
    }


def _compress(p: Params, cfg: MLAConfig, x: jax.Array, positions: jax.Array):
    """Shared Q/KV compression for train + serve paths."""
    b, s, _ = x.shape
    cd = cfg.compute_dtype
    h = cfg.n_heads
    # --- queries: down, norm, up, split nope/rope
    cq = rmsnorm(p["q_norm"], linear(p["wq_a"], x, compute_dtype=cd))
    q = linear(p["wq_b"], cq, compute_dtype=cd).reshape(b, s, h, cfg.qk_dim)
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = apply_rope(
        q[..., cfg.qk_nope_dim :].swapaxes(1, 2), positions, theta=cfg.rope_theta
    )  # (B,H,S,rope)
    # --- kv latent + decoupled shared rope key
    kv_a = linear(p["wkv_a"], x, compute_dtype=cd)
    c_kv = rmsnorm(p["kv_norm"], kv_a[..., : cfg.kv_lora_rank])  # (B,S,dkv)
    k_rope = apply_rope(
        kv_a[..., cfg.kv_lora_rank :][:, None], positions, theta=cfg.rope_theta
    )  # (B,1,S,rope) shared across heads
    return q_nope.swapaxes(1, 2), q_rope, c_kv, k_rope


def _expand_kv(p: Params, cfg: MLAConfig, c_kv: jax.Array):
    b, t, _ = c_kv.shape
    h = cfg.n_heads
    kv = linear(p["wkv_b"], c_kv, compute_dtype=cfg.compute_dtype)
    kv = kv.reshape(b, t, h, cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope = kv[..., : cfg.qk_nope_dim].swapaxes(1, 2)  # (B,H,T,nope)
    v = kv[..., cfg.qk_nope_dim :].swapaxes(1, 2)  # (B,H,T,v)
    return k_nope, v


def _attend(cfg, q_nope, q_rope, k_nope, k_rope, v, *, causal_rows, visible_cols):
    scale = cfg.qk_dim**-0.5
    scores = (
        jnp.einsum(
            "bhqd,bhtd->bhqt", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32)
        )
        + jnp.einsum(
            "bhqd,bxtd->bhqt", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32)
        )
    ) * scale
    mask = visible_cols[None, :] <= causal_rows[:, None]
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqt,bhtd->bhqd", w, v.astype(jnp.float32))


def _attend_chunked(cfg, q_nope, q_rope, k_nope, k_rope, v, *, chunk):
    """Online-softmax over kv chunks (memory: S×chunk, not S×S).

    Heads are pinned to the TP axis (constrain_heads) so per-device
    score blocks are (B_loc, H/TP, S, chunk); score einsums run in the
    compute dtype with f32 accumulation.
    """
    from repro.distribution.sharding import constrain_heads

    cd = cfg.compute_dtype
    q_nope = constrain_heads(q_nope)
    q_rope = constrain_heads(q_rope)
    k_nope = constrain_heads(k_nope)
    v = constrain_heads(v)
    b, h, s, dn = q_nope.shape
    t = k_nope.shape[2]
    pad = -t % chunk
    if pad:  # padded keys are > all causal rows — masked for free
        k_nope = jnp.pad(k_nope, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        t += pad
    scale = cfg.qk_dim**-0.5
    qn = (q_nope.astype(jnp.float32) * scale).astype(cd)
    qr = (q_rope.astype(jnp.float32) * scale).astype(cd)
    rows = jnp.arange(s)
    neg = -1e30

    def body(carry, kc):
        acc, m, l = carry
        kn = jax.lax.dynamic_slice_in_dim(k_nope, kc * chunk, chunk, 2).astype(cd)
        kr = jax.lax.dynamic_slice_in_dim(k_rope, kc * chunk, chunk, 2).astype(cd)
        vs = jax.lax.dynamic_slice_in_dim(v, kc * chunk, chunk, 2).astype(cd)
        scores = jnp.einsum(
            "bhqd,bhtd->bhqt", qn, kn, preferred_element_type=jnp.float32
        ) + jnp.einsum(
            "bhqd,bxtd->bhqt", qr, kr, preferred_element_type=jnp.float32
        )
        cols = kc * chunk + jnp.arange(chunk)
        mask = cols[None, :] <= rows[:, None]
        scores = jnp.where(mask[None, None], scores, neg)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        pw = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(pw, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqt,bhtd->bhqd", pw.astype(cd), vs,
            preferred_element_type=jnp.float32,
        )
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, h, s, cfg.v_head_dim), jnp.float32)
    m0 = jnp.full((b, h, s), neg, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(t // chunk))
    return acc / jnp.maximum(l, 1e-30)[..., None]


def mla_train(
    p: Params, cfg: MLAConfig, x: jax.Array, positions: jax.Array
) -> jax.Array:
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _compress(p, cfg, x, positions)
    k_nope, v = _expand_kv(p, cfg, c_kv)
    if cfg.chunk is not None and s > cfg.chunk:
        out = _attend_chunked(
            cfg, q_nope, q_rope, k_nope, k_rope, v, chunk=cfg.chunk
        )
    else:
        out = _attend(
            cfg, q_nope, q_rope, k_nope, k_rope, v,
            causal_rows=jnp.arange(s), visible_cols=jnp.arange(s),
        )
    merged = out.swapaxes(1, 2).reshape(b, s, cfg.n_heads * cfg.v_head_dim)
    return linear(p["wo"], merged.astype(cfg.compute_dtype), compute_dtype=cfg.compute_dtype)


# ------------------------------------------------------------------ serving
def init_mla_cache(
    cfg: MLAConfig, batch: int, max_len: int, *, dtype=jnp.bfloat16
) -> Dict[str, jax.Array]:
    """The MLA selling point: cache ONLY (c_kv, k_rope) — rank 512 + 64
    per token instead of 128 heads × 256 dims."""
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, 1, max_len, cfg.qk_rope_dim), dtype),
    }


def mla_decode_step(
    p: Params,
    cfg: MLAConfig,
    x: jax.Array,        # (B, 1, d_model)
    cache: Dict[str, jax.Array],
    lengths: jax.Array,  # (B,)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Weight-absorbed decode (the MLA inference trick).

    Instead of expanding the compressed cache into per-head K/V —
    a (B, H, T, d) materialization that dominates decode memory — the
    up-projections are absorbed into the attention math:

      scores_nope = (q_nope · W_uk) @ c_kv^T      (q in latent space)
      out         = (softmax @ c_kv) · W_uv       (context in latent space)

    so the only T-sized tensors are the latent cache itself and the
    (B, H, T) score matrix.  §Perf iteration for deepseek decode.
    """
    b = x.shape[0]
    cd = cfg.compute_dtype
    h, dn, dv, dkv = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    positions = lengths[:, None]
    q_nope, q_rope, c_new, kr_new = _compress(p, cfg, x, positions)
    s_max = cache["c_kv"].shape[1]
    onehot = (jnp.arange(s_max)[None, :] == lengths[:, None]).astype(
        cache["c_kv"].dtype
    )
    oh2, oh4 = onehot[..., None], onehot[:, None, :, None]
    # REPLACE semantics — see attention.decode_step
    c_kv = cache["c_kv"] * (1 - oh2) + oh2 * c_new.astype(cache["c_kv"].dtype)
    k_rope = cache["k_rope"] * (1 - oh4) + oh4 * kr_new.astype(
        cache["k_rope"].dtype
    )
    new_lengths = lengths + 1

    wkv = p["wkv_b"]["w"].astype(cd).reshape(dkv, h, dn + dv)
    w_uk, w_uv = wkv[..., :dn], wkv[..., dn:]
    # absorb: q into latent space (B, H, dkv)
    q_eff = jnp.einsum("bhqd,khd->bhk", q_nope.astype(cd), w_uk)
    scale = cfg.qk_dim**-0.5
    scores = (
        jnp.einsum("bhk,btk->bht", q_eff, c_kv, preferred_element_type=jnp.float32)
        + jnp.einsum(
            "bhqd,bxtd->bht", q_rope.astype(cd), k_rope,
            preferred_element_type=jnp.float32,
        )
    ) * scale
    visible = jnp.arange(s_max)[None, :] < new_lengths[:, None]  # (B,T)
    scores = jnp.where(visible[:, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum(
        "bht,btk->bhk", w.astype(cd), c_kv, preferred_element_type=jnp.float32
    )  # (B, H, dkv) — context still in latent space
    out = jnp.einsum("bhk,khd->bhd", ctx.astype(cd), w_uv)
    merged = out.reshape(b, 1, h * dv)
    attn = linear(p["wo"], merged.astype(cd), compute_dtype=cd)
    return attn, {"c_kv": c_kv, "k_rope": k_rope}
