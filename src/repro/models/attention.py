"""GQA/MQA/MHA attention with RoPE, optional qk-norm and sliding window.

Three entry points matching the three workload shapes:
* ``attend_train``   — full-sequence causal (training / prefill), pure-jnp
  reference math by default, Pallas flash kernel when enabled;
* ``prefill``        — causal pass that also returns the KV cache;
* ``decode_step``    — one token against a KV cache (serving), pure-jnp
  masked softmax by default, Pallas decode kernel when enabled.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    Params,
    apply_rope,
    init_linear,
    init_rmsnorm,
    linear,
    rmsnorm,
)


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10000.0
    qk_norm: bool = False
    window: Optional[int] = None  # sliding-window size (SWA archs)
    use_flash_kernel: bool = False  # Pallas path (TPU target)
    #: kv-chunked online-softmax ("flash in XLA"): bounds the scores
    #: working set to S×chunk instead of S×S. None = dense S×S scores.
    chunk: Optional[int] = 1024
    compute_dtype: Any = jnp.bfloat16


def init_attention(key, cfg: AttentionConfig, *, dtype=jnp.float32) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p: Params = {
        "wq": init_linear(kq, cfg.d_model, cfg.n_heads * cfg.d_head, dtype=dtype),
        "wk": init_linear(kk, cfg.d_model, cfg.n_kv_heads * cfg.d_head, dtype=dtype),
        "wv": init_linear(kv, cfg.d_model, cfg.n_kv_heads * cfg.d_head, dtype=dtype),
        "wo": init_linear(
            ko, cfg.n_heads * cfg.d_head, cfg.d_model, dtype=dtype,
            scale=(cfg.n_heads * cfg.d_head) ** -0.5,
        ),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(cfg.d_head, dtype=dtype)
        p["k_norm"] = init_rmsnorm(cfg.d_head, dtype=dtype)
    return p


def _project_qkv(
    p: Params, cfg: AttentionConfig, x: jax.Array, positions: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    cd = cfg.compute_dtype
    q = linear(p["wq"], x, compute_dtype=cd).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = linear(p["wk"], x, compute_dtype=cd).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = linear(p["wv"], x, compute_dtype=cd).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = apply_rope(q.swapaxes(1, 2), positions, theta=cfg.rope_theta)  # (B,H,S,D)
    k = apply_rope(k.swapaxes(1, 2), positions, theta=cfg.rope_theta)
    v = v.swapaxes(1, 2)
    return q, k, v


def _sdpa(
    q: jax.Array,  # (B,H,S,D)
    k: jax.Array,  # (B,Hkv,T,D)
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    q_offset: jax.Array | int = 0,
) -> jax.Array:
    """Reference scaled-dot-product attention with GQA head grouping."""
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    group = h // hkv
    qg = q.reshape(b, hkv, group, s, d).astype(jnp.float32)
    scores = jnp.einsum("bkgqd,bktd->bkgqt", qg, k.astype(jnp.float32))
    scores = scores * (d**-0.5)
    rows = q_offset + jnp.arange(s)[:, None]
    cols = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,bktd->bkgqd", w, v.astype(jnp.float32))
    return out.reshape(b, h, s, d)


_NEG = -1e30


def _sdpa_chunked(
    q: jax.Array,  # (B,H,S,D)
    k: jax.Array,  # (B,Hkv,T,D)
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    chunk: int,
    q_offset: jax.Array | int = 0,
) -> jax.Array:
    """kv-chunked online-softmax attention ("flash" expressed in XLA).

    A ``lax.scan`` over key/value chunks with running (max, denominator,
    accumulator) carries — the scores working set is S×chunk, so 32k/500k
    prefill shapes stop owning the memory roofline.  Numerically matches
    ``_sdpa`` to f32 rounding (same online recurrence as the Pallas
    kernel; cross-checked in tests).
    """
    from repro.distribution.sharding import constrain_heads

    q = constrain_heads(q)  # heads over TP (q heads always divide)
    k = constrain_heads(k)  # kv heads shard only when they divide TP
    v = constrain_heads(v)
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    group = h // hkv
    cd = q.dtype
    pad = -t % chunk
    if pad:
        # padded keys sit at positions >= t > any causal row — masked for
        # free by the causal comparison (train paths are always causal)
        assert causal, "chunk padding relies on causal masking"
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        t += pad
    qg = (
        q.reshape(b, hkv, group, s, d).astype(jnp.float32) * (d**-0.5)
    ).astype(cd)
    rows = q_offset + jnp.arange(s)  # (S,)

    def body(carry, kc):
        acc, m, l = carry
        ks = jax.lax.dynamic_slice_in_dim(k, kc * chunk, chunk, 2)
        vs = jax.lax.dynamic_slice_in_dim(v, kc * chunk, chunk, 2)
        scores = jnp.einsum(
            "bkgqd,bktd->bkgqt", qg, ks, preferred_element_type=jnp.float32
        )  # (B,Hkv,G,S,c)
        cols = kc * chunk + jnp.arange(chunk)
        mask = jnp.ones((s, chunk), bool)
        if causal:
            mask &= cols[None, :] <= rows[:, None]
        if window is not None:
            mask &= cols[None, :] > rows[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, _NEG)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqt,bktd->bkgqd", p.astype(cd), vs,
            preferred_element_type=jnp.float32,
        )
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, hkv, group, s, d), jnp.float32)
    m0 = jnp.full((b, hkv, group, s), _NEG, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, s), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(t // chunk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, s, d)


def _attend_full(q, k, v, cfg: AttentionConfig):
    """Dispatch dense vs chunked by config and shape."""
    t = k.shape[2]
    if cfg.chunk is not None and t > cfg.chunk:
        return _sdpa_chunked(
            q, k, v, causal=True, window=cfg.window, chunk=cfg.chunk
        )
    return _sdpa(q, k, v, causal=True, window=cfg.window)


def attend_train(
    p: Params, cfg: AttentionConfig, x: jax.Array, positions: jax.Array
) -> jax.Array:
    """Causal self-attention over the full sequence."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    if cfg.use_flash_kernel:
        from repro.kernels.flash_attention import flash_attention

        out = flash_attention(
            q, k, v, causal=True, window=cfg.window, interpret=True
        )
    else:
        out = _attend_full(q, k, v, cfg)
    b, h, s, d = out.shape
    merged = out.swapaxes(1, 2).reshape(b, s, h * d).astype(cfg.compute_dtype)
    return linear(p["wo"], merged, compute_dtype=cfg.compute_dtype)


# ------------------------------------------------------------------ serving
def init_cache(
    cfg: AttentionConfig, batch: int, max_len: int, *, dtype=jnp.bfloat16
) -> Dict[str, jax.Array]:
    shape = (batch, cfg.n_kv_heads, max_len, cfg.d_head)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def prefill(
    p: Params,
    cfg: AttentionConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    q, k, v = _project_qkv(p, cfg, x, positions)
    s = x.shape[1]
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)
        ),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)
        ),
    }
    if cfg.use_flash_kernel:
        from repro.kernels.flash_attention import flash_attention

        out = flash_attention(q, k, v, causal=True, window=cfg.window, interpret=True)
    else:
        out = _attend_full(q, k, v, cfg)
    b, h, _, d = out.shape
    merged = out.swapaxes(1, 2).reshape(b, s, h * d).astype(cfg.compute_dtype)
    return linear(p["wo"], merged, compute_dtype=cfg.compute_dtype), cache


def decode_step(
    p: Params,
    cfg: AttentionConfig,
    x: jax.Array,           # (B, 1, d_model)
    cache: Dict[str, jax.Array],
    lengths: jax.Array,     # (B,) — tokens already in the cache
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b = x.shape[0]
    cd = cfg.compute_dtype
    positions = lengths[:, None]  # this token's position (B, 1)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    # append the new kv at each sequence's own length (ragged batch)
    s_max = cache["k"].shape[2]
    onehot = (
        jnp.arange(s_max)[None, :] == lengths[:, None]
    ).astype(cache["k"].dtype)  # (B, S)
    oh = onehot[:, None, :, None]
    # REPLACE semantics (not add): re-writing a slot position must be
    # idempotent so serving can reuse slots safely
    k_cache = cache["k"] * (1 - oh) + oh * k_new.astype(cache["k"].dtype)
    v_cache = cache["v"] * (1 - oh) + oh * v_new.astype(cache["v"].dtype)
    new_lengths = lengths + 1
    if cfg.use_flash_kernel:
        from repro.kernels.decode_attention import decode_attention

        out = decode_attention(
            q[:, :, 0], k_cache, v_cache, new_lengths, interpret=True
        )  # (B, H, D)
        out = out.reshape(b, 1, cfg.n_heads * cfg.d_head)
    else:
        t = jnp.arange(s_max)[None, :]
        visible = t < new_lengths[:, None]
        if cfg.window is not None:
            visible &= t > (new_lengths[:, None] - 1 - cfg.window)
        scores = jnp.einsum(
            "bkgqd,bktd->bkgqt",
            q.reshape(b, cfg.n_kv_heads, -1, 1, cfg.d_head).astype(jnp.float32),
            k_cache.astype(jnp.float32),
        ) * (cfg.d_head**-0.5)
        scores = jnp.where(visible[:, None, None, None], scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqt,bktd->bkgqd", w, v_cache.astype(jnp.float32))
        out = out.reshape(b, cfg.n_heads, 1, cfg.d_head).swapaxes(1, 2)
        out = out.reshape(b, 1, cfg.n_heads * cfg.d_head)
    attn = linear(p["wo"], out.astype(cd), compute_dtype=cd)
    return attn, {"k": k_cache, "v": v_cache}
