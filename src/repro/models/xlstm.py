"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM — the parallelizable variant — is implemented in *chunked* form:
within a chunk the quadratic gate-matrix formulation runs on the MXU;
across chunks a ``lax.scan`` carries the (d_k × d_v) matrix state.  This
is O(S·chunk) not O(S²), which is what makes the ``long_500k`` shape
admissible for this architecture (DESIGN.md 4).

sLSTM keeps exponential-gate scalar memories with a per-step recurrence
(``lax.scan`` over time); decode for both is a single O(1) state update.

Both blocks follow the paper's pre-norm residual structure with
up/down projections (xLSTM has no separate FFN — d_ff=0 in the assigned
config): mLSTM projects up 2x, sLSTM uses a 4/3 GLU after mixing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    Params,
    init_linear,
    init_rmsnorm,
    linear,
    rmsnorm,
)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int
    proj_factor_m: float = 2.0
    proj_factor_s: float = 4.0 / 3.0
    chunk: int = 64
    compute_dtype: Any = jnp.bfloat16

    @property
    def d_inner_m(self) -> int:
        return int(self.d_model * self.proj_factor_m)

    @property
    def d_head_m(self) -> int:
        return self.d_inner_m // self.n_heads


# ===================================================================== mLSTM
def init_mlstm(key, cfg: XLSTMConfig, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    d, di = cfg.d_model, cfg.d_inner_m
    return {
        "up": init_linear(ks[0], d, 2 * di, dtype=dtype),  # x and gate halves
        "wq": init_linear(ks[1], di, di, dtype=dtype),
        "wk": init_linear(ks[2], di, di, dtype=dtype),
        "wv": init_linear(ks[3], di, di, dtype=dtype),
        "wi": init_linear(ks[4], di, cfg.n_heads, dtype=dtype),
        "wf": init_linear(ks[5], di, cfg.n_heads, dtype=dtype),
        "down": init_linear(ks[6], di, d, dtype=dtype, scale=di**-0.5),
        "norm": init_rmsnorm(di, dtype=dtype),
    }


def _mlstm_chunk_scan(q, k, v, log_f, log_i):
    """Chunked linear-attention-with-gates.

    q,k,v: (B, H, S, D); log_f/log_i: (B, H, S).  Returns (B, H, S, D).
    Stabilized with per-chunk running max (as in the xLSTM paper's m_t).
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    c = min(64, s)
    assert s % c == 0, (s, c)
    nc = s // c
    qc = q.reshape(b, h, nc, c, dk)
    kc = k.reshape(b, h, nc, c, dk)
    vc = v.reshape(b, h, nc, c, dv)
    fc = log_f.reshape(b, h, nc, c)
    ic = log_i.reshape(b, h, nc, c)

    # cumulative forget within chunk: L[t] = sum_{u<=t} log_f[u]
    csum_f = jnp.cumsum(fc, axis=-1)  # (B,H,nc,c)
    total_f = csum_f[..., -1]  # (B,H,nc)

    # move chunk axis first for scan
    def prep(x):
        return jnp.moveaxis(x, 2, 0)

    qs, ks_, vs, cf, ci, tf = map(prep, (qc, kc, vc, csum_f, ic, total_f))

    def body(carry, inp):
        state, norm, m_prev = carry  # (B,H,dk,dv), (B,H,dk), (B,H)
        qb, kb, vb, cfb, cib, tfb = inp
        # decay for each in-chunk key position to the end of the chunk
        # log weight for key u -> state: total_f - csum_f[u] + log_i[u]
        key_decay = tfb[..., None] - cfb + cib  # (B,H,c)
        # intra-chunk pairwise: log D[t,u] = csum_f[t] - csum_f[u] + log_i[u], u<=t
        pair = cfb[..., :, None] - cfb[..., None, :] + cib[..., None, :]
        tri = jnp.tril(jnp.ones((pair.shape[-1], pair.shape[-1]), bool))
        pair = jnp.where(tri, pair, -jnp.inf)
        # query decay from previous state: csum_f[t] (+ m_prev carried)
        q_decay = cfb + m_prev[..., None]  # (B,H,c)
        m_new = jnp.maximum(
            jnp.max(pair, axis=-1), q_decay
        )  # (B,H,c) running stabilizer per row
        intra_w = jnp.exp(pair - m_new[..., None])  # (B,H,c,c)
        inter_w = jnp.exp(q_decay - m_new)  # (B,H,c)

        scores = jnp.einsum("bhtd,bhud->bhtu", qb, kb) * (qb.shape[-1] ** -0.5)
        intra = jnp.einsum("bhtu,bhud->bhtd", scores * intra_w, vb)
        inter = jnp.einsum("bhtd,bhdv->bhtv", qb, state) * inter_w[..., None] * (
            qb.shape[-1] ** -0.5
        )
        # normalizer (denominator) — xLSTM uses max(|n·q|, 1)
        norm_intra = jnp.einsum("bhtu,bhu->bht", scores * intra_w, jnp.ones_like(cib))
        norm_inter = jnp.einsum("bhtd,bhd->bht", qb, norm) * inter_w * (
            qb.shape[-1] ** -0.5
        )
        denom = jnp.maximum(jnp.abs(norm_intra + norm_inter), jnp.exp(-m_new))
        out = (intra + inter) / denom[..., None]

        # carry to next chunk: new stabilizer is max over chunk end decay
        m_chunk = m_prev + tfb  # decayed previous max
        m_carry = jnp.maximum(m_chunk, jnp.max(key_decay, axis=-1))
        state_new = state * jnp.exp(m_chunk - m_carry)[..., None, None] + jnp.einsum(
            "bhud,bhuv->bhdv", kb * jnp.exp(key_decay - m_carry[..., None])[..., None], vb
        )
        norm_new = norm * jnp.exp(m_chunk - m_carry)[..., None] + jnp.einsum(
            "bhud,bhu->bhd", kb, jnp.exp(key_decay - m_carry[..., None])
        )
        return (state_new, norm_new, m_carry), out

    state0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    norm0 = jnp.zeros((b, h, dk), jnp.float32)
    m0 = jnp.zeros((b, h), jnp.float32)
    (_, _, _), outs = jax.lax.scan(body, (state0, norm0, m0), (qs, ks_, vs, cf, ci, tf))
    return jnp.moveaxis(outs, 0, 2).reshape(b, h, s, dv)


def mlstm_block(
    p: Params, cfg: XLSTMConfig, x: jax.Array
) -> jax.Array:
    """x: (B, S, d_model) → (B, S, d_model), full-sequence (train)."""
    b, s, _ = x.shape
    cd = cfg.compute_dtype
    h, dh = cfg.n_heads, cfg.d_head_m
    up = linear(p["up"], x, compute_dtype=cd)
    inner, gate = jnp.split(up, 2, axis=-1)  # (B,S,di) each
    q = linear(p["wq"], inner, compute_dtype=cd).reshape(b, s, h, dh).swapaxes(1, 2)
    k = linear(p["wk"], inner, compute_dtype=cd).reshape(b, s, h, dh).swapaxes(1, 2)
    v = linear(p["wv"], inner, compute_dtype=cd).reshape(b, s, h, dh).swapaxes(1, 2)
    log_i = linear(p["wi"], inner, compute_dtype=cd).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        linear(p["wf"], inner, compute_dtype=cd).astype(jnp.float32)
    )
    log_i = jnp.moveaxis(log_i, -1, 1)  # (B,H,S)
    log_f = jnp.moveaxis(log_f, -1, 1)
    out = _mlstm_chunk_scan(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        log_f, log_i,
    )  # (B,H,S,dh)
    merged = out.swapaxes(1, 2).reshape(b, s, h * dh).astype(cd)
    merged = rmsnorm(p["norm"], merged) * jax.nn.silu(gate)
    return linear(p["down"], merged, compute_dtype=cd)


def init_mlstm_state(cfg: XLSTMConfig, batch: int) -> Dict[str, jax.Array]:
    h, dh = cfg.n_heads, cfg.d_head_m
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
    }


def mlstm_decode_step(
    p: Params, cfg: XLSTMConfig, x: jax.Array, state: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, 1, d) one token; O(1) recurrent update."""
    b = x.shape[0]
    cd = cfg.compute_dtype
    h, dh = cfg.n_heads, cfg.d_head_m
    up = linear(p["up"], x, compute_dtype=cd)
    inner, gate = jnp.split(up, 2, axis=-1)
    q = linear(p["wq"], inner, compute_dtype=cd).reshape(b, h, dh).astype(jnp.float32)
    k = linear(p["wk"], inner, compute_dtype=cd).reshape(b, h, dh).astype(jnp.float32)
    v = linear(p["wv"], inner, compute_dtype=cd).reshape(b, h, dh).astype(jnp.float32)
    log_i = linear(p["wi"], inner, compute_dtype=cd).astype(jnp.float32).reshape(b, h)
    log_f = jax.nn.log_sigmoid(
        linear(p["wf"], inner, compute_dtype=cd).astype(jnp.float32)
    ).reshape(b, h)
    m_new = jnp.maximum(state["m"] + log_f, log_i)
    f_w = jnp.exp(state["m"] + log_f - m_new)
    i_w = jnp.exp(log_i - m_new)
    C = state["C"] * f_w[..., None, None] + jnp.einsum("bhd,bhv->bhdv", k * i_w[..., None], v)
    nvec = state["n"] * f_w[..., None] + k * i_w[..., None]
    num = jnp.einsum("bhd,bhdv->bhv", q, C) * (dh**-0.5)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", q, nvec)) * (dh**-0.5), jnp.exp(-m_new)
    )
    out = (num / den[..., None]).reshape(b, 1, h * dh).astype(cd)
    out = rmsnorm(p["norm"], out) * jax.nn.silu(gate)
    return linear(p["down"], out, compute_dtype=cd), {"C": C, "n": nvec, "m": m_new}


# ===================================================================== sLSTM
def init_slstm(key, cfg: XLSTMConfig, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    dg = int(d * cfg.proj_factor_s)
    return {
        # i, f, z, o gates from input (recurrent weights folded into a
        # block-diagonal-by-head matrix, simplified to per-head dense)
        "wx": init_linear(ks[0], d, 4 * d, dtype=dtype),
        "wr": init_linear(ks[1], d, 4 * d, dtype=dtype, scale=d**-0.5),
        "norm": init_rmsnorm(d, dtype=dtype),
        "up_gate": init_linear(ks[2], d, dg, dtype=dtype),
        "up": init_linear(ks[3], d, dg, dtype=dtype),
        "down": init_linear(ks[4], dg, d, dtype=dtype, scale=dg**-0.5),
    }


def init_slstm_state(cfg: XLSTMConfig, batch: int) -> Dict[str, jax.Array]:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(p, cfg, state, xt):
    """One sLSTM step. xt: (B, d) f32."""
    cd = cfg.compute_dtype
    gates_x = linear(p["wx"], xt.astype(cd), compute_dtype=cd).astype(jnp.float32)
    gates_r = linear(p["wr"], state["h"].astype(cd), compute_dtype=cd).astype(jnp.float32)
    gi, gf, gz, go = jnp.split(gates_x + gates_r, 4, axis=-1)
    log_i = gi  # exponential input gate (log-space)
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(state["m"] + log_f, log_i)
    i_w = jnp.exp(log_i - m_new)
    f_w = jnp.exp(state["m"] + log_f - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c = f_w * state["c"] + i_w * z
    n = f_w * state["n"] + i_w
    h = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_block(p: Params, cfg: XLSTMConfig, x: jax.Array) -> jax.Array:
    """Sequential scan over time (B, S, d) → (B, S, d)."""
    b, s, d = x.shape
    cd = cfg.compute_dtype
    x32 = x.astype(jnp.float32)

    def step(state, xt):
        new = _slstm_cell(p, cfg, state, xt)
        return new, new["h"]

    state0 = init_slstm_state(cfg, b)
    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(x32, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(cd)  # (B,S,d)
    h = rmsnorm(p["norm"], h)
    u = linear(p["up"], h, compute_dtype=cd)
    g = linear(p["up_gate"], h, compute_dtype=cd)
    return linear(p["down"], u * jax.nn.gelu(g), compute_dtype=cd)


def slstm_decode_step(
    p: Params, cfg: XLSTMConfig, x: jax.Array, state: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    cd = cfg.compute_dtype
    new = _slstm_cell(p, cfg, state, x[:, 0].astype(jnp.float32))
    h = new["h"][:, None].astype(cd)
    h = rmsnorm(p["norm"], h)
    u = linear(p["up"], h, compute_dtype=cd)
    g = linear(p["up_gate"], h, compute_dtype=cd)
    return linear(p["down"], u * jax.nn.gelu(g), compute_dtype=cd), new
