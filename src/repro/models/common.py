"""Shared model components: norms, projections, RoPE, MLPs, losses.

Conventions:
* params are plain nested dicts of jnp arrays;
* every ``init_*`` is pure in a PRNG key and config (usable under
  ``jax.eval_shape`` — required by the allocation-free dry-run);
* computation dtype vs parameter dtype are separated (bf16 compute,
  f32 params by default for training).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ----------------------------------------------------------------- inits
def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_linear(
    key, d_in: int, d_out: int, *, dtype=jnp.float32, scale: Optional[float] = None
) -> Params:
    scale = scale if scale is not None else d_in**-0.5
    return {"w": _normal(key, (d_in, d_out), scale, dtype)}


def linear(p: Params, x: jax.Array, *, compute_dtype=jnp.bfloat16) -> jax.Array:
    return x.astype(compute_dtype) @ p["w"].astype(compute_dtype)


def init_embedding(key, vocab: int, d: int, *, dtype=jnp.float32) -> Params:
    # d**-0.5 keeps the TIED readout (h @ table.T) at unit-scale logits;
    # RMSNorm in the first block re-normalizes the small input embeddings.
    return {"table": _normal(key, (vocab, d), d**-0.5, dtype)}


def embed(p: Params, ids: jax.Array, *, compute_dtype=jnp.bfloat16) -> jax.Array:
    return p["table"].astype(compute_dtype)[ids]


def init_rmsnorm(d: int, *, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(dtype)


# ------------------------------------------------------------------- RoPE
def rope_frequencies(d_head: int, *, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(
    x: jax.Array,  # (B, H, S, D) — rotates (even, odd) halves
    positions: jax.Array,  # (S,) shared, or (B, S) per-sequence (decode)
    *,
    theta: float = 10000.0,
) -> jax.Array:
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta=theta)  # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    if angles.ndim == 3:  # (B, S, D/2) → broadcast over the head axis
        angles = angles[:, None]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1 = x[..., : d // 2].astype(jnp.float32)
    x2 = x[..., d // 2 :].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- MLPs
def init_swiglu(key, d: int, d_ff: int, *, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d, d_ff, dtype=dtype),
        "up": init_linear(k2, d, d_ff, dtype=dtype),
        "down": init_linear(k3, d_ff, d, dtype=dtype, scale=d_ff**-0.5),
    }


def swiglu(p: Params, x: jax.Array, *, compute_dtype=jnp.bfloat16) -> jax.Array:
    g = linear(p["gate"], x, compute_dtype=compute_dtype)
    u = linear(p["up"], x, compute_dtype=compute_dtype)
    return linear(p["down"], jax.nn.silu(g) * u, compute_dtype=compute_dtype)


def init_geglu(key, d: int, d_ff: int, *, dtype=jnp.float32) -> Params:
    return init_swiglu(key, d, d_ff, dtype=dtype)


def geglu(p: Params, x: jax.Array, *, compute_dtype=jnp.bfloat16) -> jax.Array:
    g = linear(p["gate"], x, compute_dtype=compute_dtype)
    u = linear(p["up"], x, compute_dtype=compute_dtype)
    return linear(p["down"], jax.nn.gelu(g) * u, compute_dtype=compute_dtype)


# ------------------------------------------------------------------ losses
def cross_entropy(
    logits: jax.Array,  # (..., V) — any leading dims
    labels: jax.Array,  # (...)
    *,
    mask: Optional[jax.Array] = None,  # (...) 1.0 = count this token
) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def logits_head(
    embedding: Params, x: jax.Array, *, compute_dtype=jnp.bfloat16
) -> jax.Array:
    """Tied-embedding readout (transpose of the input table)."""
    table = embedding["table"].astype(compute_dtype)
    return x.astype(compute_dtype) @ table.T
