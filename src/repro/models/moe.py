"""Mixture-of-Experts: shared + routed top-k, expert-parallel dispatch.

Production-grade dispatch that stays O(tokens·d) in memory and keeps the
expert dimension shardable (EP over the "model" mesh axis):

1. routing is computed per *group* (= one sequence), with a per-group
   expert capacity ``C = S·k/E·factor`` — GShard-style locality dropping;
2. slot assignment uses a sort-based position-in-expert (no one-hot
   cumsum blowup);
3. the dispatch **scatters int32 token indices only** into the
   ``(groups, E, C)`` routing table, then materializes expert inputs with
   one batched gather — the (tokens·k, d) vector scatter/gather that
   dominates naive implementations never exists;
4. expert FFN is one einsum over (groups, E, C, d) × (E, d, f) with E
   sharded over "model" (the EP all-to-all appears at the constraint
   boundary under pjit);
5. combine gathers back per-k (k sequential (g, S, d) gathers), weighted
   by router probs; dropped assignments contribute zero.

Aux losses: switch-style load balance + router z-loss.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.sharding import constrain_batch, constrain_moe_buffer
from repro.models.common import Params, init_linear, init_swiglu, linear, swiglu


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                  # per-expert hidden width
    num_experts: int
    top_k: int
    num_shared: int = 0        # shared experts (always-on), same d_ff each
    capacity_factor: float = 1.25
    balance_loss_weight: float = 0.01
    z_loss_weight: float = 1e-3
    #: normalize the top-k router probs to sum to 1 (deepseek/qwen style)
    norm_topk: bool = True
    compute_dtype: Any = jnp.bfloat16


def init_moe(key, cfg: MoEConfig, *, dtype=jnp.float32) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    kg, ku, kd = jax.random.split(ke, 3)
    scale_in, scale_out = d**-0.5, f**-0.5
    p: Params = {
        "router": init_linear(kr, d, e, dtype=dtype, scale=scale_in),
        "experts": {
            "gate": (jax.random.normal(kg, (e, d, f)) * scale_in).astype(dtype),
            "up": (jax.random.normal(ku, (e, d, f)) * scale_in).astype(dtype),
            "down": (jax.random.normal(kd, (e, f, d)) * scale_out).astype(dtype),
        },
    }
    if cfg.num_shared:
        p["shared"] = init_swiglu(ks, d, f * cfg.num_shared, dtype=dtype)
    return p


def _positions_in_expert(e_flat: jax.Array, num_experts: int) -> jax.Array:
    """Slot index of each assignment within its expert (one group).

    Sort-based: after sorting assignments by expert id, an assignment's
    slot is its rank minus its expert segment's first rank. O(N log N),
    no (N, E) one-hot.
    """
    n = e_flat.shape[0]
    order = jnp.argsort(e_flat)
    sorted_ids = e_flat[order]
    seg_start = jnp.searchsorted(sorted_ids, jnp.arange(num_experts))
    pos_sorted = jnp.arange(n) - seg_start[sorted_ids]
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))


def moe_apply(
    p: Params, cfg: MoEConfig, x: jax.Array
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) → (B, S, d), plus aux-loss dict. Groups = sequences.

    Decode special case (S == 1): per-sequence groups would give every
    group capacity max(k/E·f, 4) ≈ 4 slots × E — 100×+ padding for 1-token
    groups.  Fold the whole batch into ONE dispatch group instead
    (capacity scales with B·k/E) — §Perf iteration for MoE decode.
    """
    if x.shape[1] == 1 and x.shape[0] > 1:
        out, aux = moe_apply(p, cfg, x.reshape(1, x.shape[0], x.shape[2]))
        return out.reshape(x.shape), aux
    b, s, d = x.shape
    k = cfg.top_k
    e = cfg.num_experts
    cd = cfg.compute_dtype
    capacity = max(int(s * k / e * cfg.capacity_factor), 4)

    # ---- routing (f32 numerics)
    logits = linear(p["router"], x, compute_dtype=cd).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (B,S,E)
    top_p, top_e = jax.lax.top_k(probs, k)                      # (B,S,K)
    if cfg.norm_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (scatter-count density, no blowup)
    density = (
        jnp.zeros((b, e), jnp.float32)
        .at[jnp.arange(b)[:, None, None], top_e]
        .add(1.0)
        .mean(axis=0)
        / s
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    balance_loss = e * jnp.sum(density * mean_prob) * cfg.balance_loss_weight
    z = jax.nn.logsumexp(logits, axis=-1)
    z_loss = jnp.mean(z * z) * cfg.z_loss_weight

    # ---- per-group slotting (vmapped sort-based positions)
    e_flat = top_e.reshape(b, s * k)                            # (B, S*K)
    slot = jax.vmap(lambda ef: _positions_in_expert(ef, e))(e_flat)
    keep = slot < capacity                                      # (B, S*K)
    buf_pos = jnp.where(keep, e_flat * capacity + slot, e * capacity)

    # ---- dispatch: scatter TOKEN INDICES + router weights (no vectors).
    # All gathers/scatters are vmapped over the batch dim — vmap emits
    # true gather/scatter batch dims, which is what lets GSPMD keep them
    # batch-sharded (explicit iota indexing would force an all-gather).
    tok_idx = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None, :]  # (1,S*K)
    tok_idx = jnp.broadcast_to(tok_idx, (b, s * k))
    table = jax.vmap(
        lambda pos, tok: jnp.full((e * capacity + 1,), s, jnp.int32)
        .at[pos]
        .set(tok, mode="drop")
    )(buf_pos, tok_idx)
    w_table = jax.vmap(
        lambda pos, w: jnp.zeros((e * capacity + 1,), cd)
        .at[pos]
        .set(w, mode="drop")
    )(buf_pos, top_p.reshape(b, s * k).astype(cd))
    # constrain the small routing tables to the EP layout FIRST so every
    # downstream gather/scatter is born expert-sharded
    routing = constrain_moe_buffer(table[:, :-1].reshape(b, e, capacity))
    w_slot = constrain_moe_buffer(w_table[:, :-1].reshape(b, e, capacity))

    # ---- expert inputs: batched gather with EP-sharded indices
    x_pad = jnp.concatenate([x.astype(cd), jnp.zeros((b, 1, d), cd)], axis=1)
    grouped = jax.vmap(lambda xp, r: xp[r])(x_pad, routing)     # (B,E,C,d)
    grouped = constrain_moe_buffer(grouped)

    # ---- expert FFN (E shardable everywhere)
    we = p["experts"]
    g = jnp.einsum("becd,edf->becf", grouped, we["gate"].astype(cd))
    u = jnp.einsum("becd,edf->becf", grouped, we["up"].astype(cd))
    h = jax.nn.silu(g) * u
    out_e = jnp.einsum("becf,efd->becd", h, we["down"].astype(cd))
    out_e = constrain_moe_buffer(out_e)

    # ---- combine: weighted SCATTER-ADD back to token positions.
    # Each expert shard scatters its slots into a partial (B,S,d) and the
    # compiler reduces partials over the EP axis (add is commutative) —
    # no all-gather of the (B, E·C, d) buffer ever materializes.
    weighted = out_e * w_slot[..., None]                        # (B,E,C,d)
    combined = jax.vmap(
        lambda r, w: jnp.zeros((s, d), cd)
        .at[r.reshape(-1)]
        .add(w.reshape(-1, d), mode="drop")                     # sentinel drops
    )(routing, weighted)
    combined = constrain_batch(combined)

    if cfg.num_shared:
        combined = combined + swiglu(p["shared"], x, compute_dtype=cd)

    aux = {"balance_loss": balance_loss, "z_loss": z_loss}
    return combined, aux
