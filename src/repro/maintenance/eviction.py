"""Differential-cache eviction (``repro cache prune``).

The cache only pays off long-term if its footprint is bounded (FaaS and
Furious, arXiv 2411.08203): every audited run adds entries, and each
entry roots its output manifests against the GC.  The eviction policy is
the classic two-stage filter:

1. **TTL** — entries not used for ``ttl_s`` seconds are dropped outright;
2. **LRU within a byte budget** — survivors are ranked by
   ``last_used_at`` and evicted oldest-first until the summed
   ``output_bytes`` fits ``max_bytes``.

Eviction only removes the registry *entry* (a ref); the entry's blobs
become unreachable the moment no branch/tag/pin still needs them and are
reclaimed by the next ``repro gc`` — eviction releases roots, the
sweeper frees bytes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional

from repro.core.snapshot import NodeCacheEntry, NodeCacheRegistry
from repro.utils.logging import get_logger

log = get_logger("maintenance.eviction")


@dataclass(frozen=True)
class EvictionPolicy:
    """Byte budget + optional TTL; None disables that stage."""

    max_bytes: Optional[int] = None
    ttl_s: Optional[float] = None


@dataclass(frozen=True)
class EvictionReport:
    entries_before: int
    entries_evicted: int
    bytes_before: int
    #: output_bytes released to the sweeper (reclaimed at the next gc)
    bytes_released: int
    bytes_after: int
    dry_run: bool

    def describe(self) -> str:
        verb = "would evict" if self.dry_run else "evicted"
        return (
            f"cache prune: {verb} {self.entries_evicted}/{self.entries_before} "
            f"entries, released {self.bytes_released} bytes "
            f"({self.bytes_before} -> {self.bytes_after})"
        )


def prune_cache(
    registry: NodeCacheRegistry,
    policy: EvictionPolicy,
    *,
    now: Optional[float] = None,
    dry_run: bool = False,
) -> EvictionReport:
    """Apply ``policy`` to the registry; idempotent under retries."""
    now = now if now is not None else time.time()
    entries = list(registry.entries().values())
    bytes_before = sum(e.output_bytes for e in entries)

    expired: List[NodeCacheEntry] = []
    survivors: List[NodeCacheEntry] = []
    for e in entries:
        if policy.ttl_s is not None and now - e.last_used_at > policy.ttl_s:
            expired.append(e)
        else:
            survivors.append(e)

    # LRU: oldest last_used_at evicts first until the budget fits
    survivors.sort(key=lambda e: (e.last_used_at, e.fingerprint))
    if policy.max_bytes is not None:
        total = sum(e.output_bytes for e in survivors)
        while survivors and total > policy.max_bytes:
            victim = survivors.pop(0)
            total -= victim.output_bytes
            expired.append(victim)

    if not dry_run:
        for e in expired:
            registry.invalidate(e.fingerprint)
        registry.store.bump_stat("cache_entries_evicted", len(expired))

    bytes_released = sum(e.output_bytes for e in expired)
    report = EvictionReport(
        entries_before=len(entries),
        entries_evicted=len(expired),
        bytes_before=bytes_before,
        bytes_released=bytes_released,
        bytes_after=bytes_before - bytes_released,
        dry_run=dry_run,
    )
    log.info("%s", report.describe())
    return report
